//! Memory-robustness demo (Fig. 3 in miniature): train BitNet b1.58 and
//! DQT-8bit under FP32/BF16/FP8 environments (+ Adafactor) and plot each
//! run's dev loss against its modeled memory — BitNet degrades as the
//! environment shrinks, DQT holds.
//!
//! Run: `cargo run --release --example memory_robustness -- [steps] [model]`
//! Requires the fig3 artifact suite for the chosen model.

use anyhow::Result;
use dqt::config::{BackendKind, Env, Mode, Optimizer, TrainConfig, VariantSpec};
use dqt::data::Pipeline;
use dqt::memory;
use dqt::runtime::VariantRuntime;
use dqt::train::Trainer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let model = args.get(2).cloned().unwrap_or_else(|| "t130".to_string());

    let artifacts = dqt::default_artifacts_root();

    let mut specs: Vec<VariantSpec> = Vec::new();
    for (mode, bits) in [(Mode::Bitnet158, 1.58), (Mode::Dqt, 8.0)] {
        for env in [Env::Fp32, Env::Bf16, Env::Fp8] {
            specs.push(VariantSpec::new(&model, mode, bits).with_env(env));
        }
        for env in [Env::Bf16, Env::Fp8] {
            specs.push(
                VariantSpec::new(&model, mode, bits)
                    .with_env(env)
                    .with_optimizer(Optimizer::Adafactor),
            );
        }
    }

    println!("| variant                          | mem model (MB, paper-size) | dev loss |");
    for spec in specs {
        let name = spec.variant_name();
        let vrt = match VariantRuntime::open(BackendKind::Auto, None, &artifacts, &spec) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skipping {name}: {e}");
                continue;
            }
        };
        let m = vrt.manifest();
        let pipeline = Pipeline::build(
            "wiki",
            42,
            m.variant.model.vocab_size,
            m.variant.model.max_seq_len,
        )?;
        let cfg = TrainConfig {
            steps,
            warmup_steps: (steps / 10).max(5),
            peak_lr: 1e-3,
            dataset: "wiki".into(),
            log_every: 0,
            ..TrainConfig::default()
        };
        let (mut state, metrics) = Trainer::new(&vrt, &pipeline, cfg).run()?;
        // memory axis uses the paper-size twin (p1b) of this variant,
        // matching Fig. 3's GH200 percentages
        let paper_spec = VariantSpec {
            model: "p1b".into(),
            ..spec.clone()
        };
        let mem = memory::estimate(&paper_spec, true)
            .map(|b| b.total_mb())
            .unwrap_or(f64::NAN);
        println!(
            "| {:<32} | {:>26.0} | {:>8.4} |",
            name,
            mem,
            metrics.final_dev_loss.unwrap_or(f32::NAN)
        );
        // measured host accounting: pack the grids and compare the state's
        // resident bytes (RSS-backed, not just the analytic model)
        let dense_bytes = state.host_param_bytes();
        if state.pack_grids(m).is_ok() {
            println!(
                "    host params: {:.2} MB dense → {:.2} MB packed-grid (RSS {:.1} MB)",
                dense_bytes as f64 / 1e6,
                state.host_param_bytes() as f64 / 1e6,
                memory::process_rss_bytes().unwrap_or(0) as f64 / 1e6
            );
        }
    }
    println!(
        "\nExpected shape (paper Fig. 3): BitNet dev loss worsens sharply in\n\
         BF16/FP8 while DQT-8bit moves <≈0.1 across the whole memory range."
    );
    Ok(())
}
