//! Generate & serve quickstart: train a tiny ternary DQT model for a few
//! minutes of CPU, then decode from it three ways — greedy, seeded
//! sampling, and a continuous-batching burst — all through the KV-cached
//! serving engine (decode-free: every projection matmul runs fused off
//! the 2-bit packed grids).
//!
//! Run: `cargo run --release --example generate -- [steps]`

use std::sync::Arc;

use anyhow::Result;
use dqt::config::{Mode, TrainConfig, VariantSpec};
use dqt::data::Pipeline;
use dqt::runtime::{Decoder, VariantRuntime};
use dqt::serve::{Engine, GenParams, Scheduler};
use dqt::train::Trainer;

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    // --- train a tiny ternary variant on the native backend ---
    let spec = VariantSpec::new("test", Mode::Dqt, 1.58);
    let vrt = VariantRuntime::native(&spec)?;
    let m = vrt.manifest().clone();
    let pipeline = Pipeline::build(
        "tiny",
        42,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )?;
    let cfg = TrainConfig {
        steps,
        warmup_steps: (steps / 10).max(2),
        peak_lr: 2e-3,
        dataset: "tiny".into(),
        log_every: 20,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&vrt, &pipeline, cfg);
    tr.progress = Some(Box::new(|s, l| println!("  step {s}: loss {l:.4}")));
    println!("training test-dqt-b1p58 for {steps} steps…");
    let (mut state, _) = tr.run()?;

    // --- serve from 2-bit residency: grids pack, decoder adopts them ---
    state.pack_grids(&m)?;
    let engine = Engine::new(&vrt, &state, pipeline.tokenizer.clone(), false)?;
    let dec = engine.decoder();
    println!(
        "\nserving form: {} of {} projections packed, {} weight bytes, \
         {} KV bytes/position",
        dec.packed_projections(),
        dec.n_projections(),
        dec.weight_bytes(),
        dec.kv_bytes_per_position()
    );
    assert_eq!(dec.packed_projections(), dec.n_projections());

    // --- greedy + sampled generation ---
    let g = engine.generate("the cat", &GenParams { max_new_tokens: 16, ..Default::default() })?;
    println!("\ngreedy      ({}): {:?}", g.finish.as_str(), g.text);
    for seed in [1u32, 2, 3] {
        let p = GenParams {
            max_new_tokens: 16,
            temperature: 1.0,
            top_p: 0.95,
            seed,
            ..Default::default()
        };
        let g = engine.generate("the cat", &p)?;
        println!("seed {seed} ({}): {:?}", g.finish.as_str(), g.text);
    }

    // --- continuous batching: a burst of mixed requests ---
    let engine = Arc::new(engine);
    let sched = Scheduler::new(engine.clone(), 4);
    for (i, prompt) in ["the cat", "a dog sat", "the mat", "ran to the", "", "sat on"]
        .iter()
        .enumerate()
    {
        sched.submit(
            prompt,
            GenParams {
                max_new_tokens: 12,
                temperature: 0.8,
                seed: i as u32,
                ..Default::default()
            },
        );
    }
    let t0 = std::time::Instant::now();
    sched.run_until_idle()?;
    let secs = t0.elapsed().as_secs_f64();
    let st = sched.stats();
    println!(
        "\nbatched burst: {} requests, {} tokens in {:.2}s ({:.0} tok/s aggregate, peak batch {})",
        st.completed,
        st.tokens_processed,
        secs,
        st.tokens_processed as f64 / secs.max(1e-9),
        st.peak_batch
    );
    for (id, g) in sched.take_finished() {
        println!("  req {id} ({}): {:?}", g.finish.as_str(), g.text);
    }
    println!("\nnext: `repro serve --checkpoint <model.dqt> …` puts this behind HTTP.");
    Ok(())
}
