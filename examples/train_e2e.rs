//! End-to-end driver (the EXPERIMENTS.md §E2E run): train the largest
//! testbed model (`t1b`, the scaled analogue of the paper's 1B) with DQT
//! 8-bit on the wiki-synthetic corpus for a few hundred steps, alongside a
//! ternary run; log loss curves, dev loss, perplexity, zero-shot accuracy
//! and the packed-checkpoint sizes — proving all three layers compose.
//!
//! Run: `cargo run --release --example train_e2e -- [steps] [model]`
//! (defaults: 300 steps, t1b; artifacts must exist for the chosen model)

use std::time::Instant;

use anyhow::Result;
use dqt::config::{BackendKind, Mode, TrainConfig, VariantSpec};
use dqt::data::corpus::CorpusSpec;
use dqt::data::Pipeline;
use dqt::eval;
use dqt::runtime::VariantRuntime;
use dqt::train::{checkpoint, Trainer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = args.get(2).cloned().unwrap_or_else(|| "t1b".to_string());
    let variant = format!("{model}-dqt-b8");

    let artifacts = dqt::default_artifacts_root();
    let results = dqt::default_results_root().join("e2e");

    let t_load = Instant::now();
    let spec = VariantSpec::new(&model, Mode::Dqt, 8.0);
    let vrt = VariantRuntime::open(BackendKind::Auto, None, &artifacts, &spec)?;
    let m = vrt.manifest().clone();
    println!(
        "loaded {variant} on the {} backend: {} params, setup {:.1}s",
        vrt.backend_name(),
        m.variant.model.param_count,
        t_load.elapsed().as_secs_f32()
    );

    let t_data = Instant::now();
    let pipeline = Pipeline::build(
        "wiki",
        42,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )?;
    println!(
        "data: {} train chunks, {} dev chunks, tokenizer merges {} ({:.1}s)",
        pipeline.dataset.n_train,
        pipeline.dataset.n_dev,
        pipeline.tokenizer.merges.len(),
        t_data.elapsed().as_secs_f32()
    );

    let cfg = TrainConfig {
        steps,
        warmup_steps: (steps / 10).max(10),
        peak_lr: 1e-3,
        dataset: "wiki".into(),
        eval_every: (steps / 4).max(1),
        log_every: 10,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&vrt, &pipeline, cfg);
    tr.progress = Some(Box::new(|step, loss| {
        println!("  step {step:>4}: loss {loss:.4}");
    }));
    let t_train = Instant::now();
    let (state, metrics) = tr.run()?;
    let train_secs = t_train.elapsed().as_secs_f64();
    metrics.save(&results.join(&variant))?;

    let toks_per_step = (m.variant.model.batch_size * m.variant.model.max_seq_len) as f64;
    println!("\n=== training summary ===");
    println!(
        "loss: {:.4} → {:.4} over {} steps",
        metrics.records.first().map(|r| r.loss).unwrap_or(f32::NAN),
        metrics.tail_loss(10).unwrap_or(f32::NAN),
        metrics.records.len()
    );
    for (s, dl) in &metrics.dev_losses {
        println!("dev loss @ step {s}: {dl:.4}");
    }
    println!(
        "final dev loss: {:.4}",
        metrics.final_dev_loss.unwrap_or(f32::NAN)
    );
    println!(
        "throughput: {:.1} tokens/s ({:.0} ms/step)",
        toks_per_step * metrics.records.len() as f64 / train_secs,
        metrics.mean_step_ms().unwrap_or(f32::NAN)
    );

    // --- evaluation (Table 1 shape) ---
    let cspec = CorpusSpec::by_name("wiki", 42).unwrap();
    let r8 = eval::evaluate(&vrt, &state, &pipeline, &cspec, 100, false, 7)?;
    println!("\n=== eval (8-bit inference) ===");
    println!("perplexity: {:.3}", r8.perplexity);
    for (t, a) in &r8.task_acc {
        println!("  {t}: {:.1}%", a * 100.0);
    }
    if vrt.has_ternary_inference() {
        let r3 = eval::evaluate(&vrt, &state, &pipeline, &cspec, 100, true, 7)?;
        println!("=== eval (deploy-time ternary inference, §A.2) ===");
        println!("perplexity: {:.3}", r3.perplexity);
        for (t, a) in &r3.task_acc {
            println!("  {t}: {:.1}%", a * 100.0);
        }
    }

    // --- deployment checkpoints (format-true packing) ---
    let p_int8 = results.join(format!("{variant}-int8.dqt"));
    let bytes = checkpoint::save(&p_int8, &m, &state, checkpoint::Codec::F32, false)?;
    let fp32_bytes = m.total_param_values() * 4;
    println!("\n=== packed checkpoint ===");
    println!(
        "INT8-grid checkpoint: {:.2} MB (fp32 equivalent {:.2} MB, {:.1}x smaller)",
        bytes as f64 / 1e6,
        fp32_bytes as f64 / 1e6,
        fp32_bytes as f64 / bytes as f64
    );
    let reload = checkpoint::load(&p_int8, &m)?;
    assert_eq!(reload.params.len(), state.params.len());
    println!("reload OK — lossless on the grid");
    let packed = checkpoint::load_packed(&p_int8, &m)?;
    println!(
        "packed-grid reload: grid params resident at {} bytes (dense {})",
        packed.grid_param_bytes(&m),
        reload.grid_param_bytes(&m)
    );
    println!("\nE2E complete.");
    Ok(())
}
