//! Bit-width sweep (Fig. 4 in miniature): train DQT at n ∈ {1.58, 3, 4, 8}
//! on the same data/schedule and show the monotone quality ordering.
//!
//! Run: `cargo run --release --example bitwidth_sweep -- [steps] [model]`
//! Requires `make artifacts-experiments` (or the fig4 t130 artifacts).

use anyhow::Result;
use dqt::config::{BackendKind, Mode, TrainConfig, VariantSpec};
use dqt::data::Pipeline;
use dqt::runtime::VariantRuntime;
use dqt::train::Trainer;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let model = args.get(2).cloned().unwrap_or_else(|| "t130".to_string());

    let artifacts = dqt::default_artifacts_root();
    let mut rows = Vec::new();
    for bits in [1.58, 3.0, 4.0, 8.0] {
        let spec = VariantSpec::new(&model, Mode::Dqt, bits);
        let variant = spec.variant_name();
        let vrt = match VariantRuntime::open(BackendKind::Auto, None, &artifacts, &spec) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skipping {variant}: {e}");
                continue;
            }
        };
        let m = vrt.manifest();
        let pipeline = Pipeline::build(
            "wiki",
            42,
            m.variant.model.vocab_size,
            m.variant.model.max_seq_len,
        )?;
        let cfg = TrainConfig {
            steps,
            warmup_steps: (steps / 10).max(5),
            peak_lr: 1e-3,
            dataset: "wiki".into(),
            log_every: 0,
            ..TrainConfig::default()
        };
        println!("training {variant} for {steps} steps…");
        let (_, metrics) = Trainer::new(&vrt, &pipeline, cfg).run()?;
        rows.push((
            bits,
            metrics.tail_loss(10).unwrap_or(f32::NAN),
            metrics.final_dev_loss.unwrap_or(f32::NAN),
            metrics.peak_upd_frac().unwrap_or(f32::NAN),
        ));
    }

    println!("\nFig. 4 (mini): DQT bit-width sweep on {model}");
    println!("| bits | final train loss | dev loss | peak upd frac |");
    for (bits, train, dev, upd) in &rows {
        println!("| {bits:>4} | {train:>16.4} | {dev:>8.4} | {:>12.3}% |", upd * 100.0);
    }
    if rows.len() >= 2 {
        let monotone = rows.windows(2).all(|w| w[1].1 <= w[0].1 + 0.05);
        println!(
            "\nhigher bits ⇒ lower loss: {}",
            if monotone { "HOLDS" } else { "violated (noise at this scale)" }
        );
    }
    Ok(())
}
