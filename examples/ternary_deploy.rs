//! Ternary deployment (paper §A.2 + the §1 memory pitch): train DQT-8bit
//! briefly, project to ternary at deploy time, pack the ternary weights at
//! 2 bits each, reload the packed file and evaluate with ternary inference.
//!
//! Run: `cargo run --release --example ternary_deploy -- [steps]`

use anyhow::Result;
use dqt::config::{BackendKind, Mode, TrainConfig, VariantSpec};
use dqt::data::corpus::CorpusSpec;
use dqt::data::Pipeline;
use dqt::eval;
use dqt::quant::{sr, ternary};
use dqt::runtime::{Decoder, VariantRuntime};
use dqt::train::{checkpoint, Trainer};

fn main() -> Result<()> {
    let steps: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let artifacts = dqt::default_artifacts_root();
    let out = dqt::default_results_root().join("ternary_deploy");
    let spec = VariantSpec::new("t130", Mode::Dqt, 8.0);
    let vrt = VariantRuntime::open(BackendKind::Auto, None, &artifacts, &spec)?;
    let m = vrt.manifest().clone();
    println!("backend: {}", vrt.backend_name());

    let pipeline = Pipeline::build(
        "wiki",
        42,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )?;
    let cfg = TrainConfig {
        steps,
        warmup_steps: (steps / 10).max(5),
        peak_lr: 1e-3,
        dataset: "wiki".into(),
        log_every: 20,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&vrt, &pipeline, cfg);
    tr.progress = Some(Box::new(|s, l| println!("  step {s}: {l:.4}")));
    println!("training t130-dqt-b8 for {steps} steps…");
    let (state, _) = tr.run()?;

    // --- deploy-time ternary projection + 2-bit packing on the host ---
    let mut packed_bytes = 0usize;
    let mut fp32_bytes = 0usize;
    let mut gemv_checked = 0usize;
    for (i, meta) in m.params.iter().enumerate() {
        if !meta.is_grid() {
            continue;
        }
        let w = state.params[i].values()?;
        fp32_bytes += w.len() * 4;
        // AbsMean re-projection of the 8-bit grid weight to ternary (§A.2)
        let s3 = dqt::quant::absmean_scale(&w, 1.58);
        let w3 = dqt::quant::absmean_quantize(&w, 1.58, s3);
        let trits: Vec<f32> = w3.iter().map(|&v| (v * s3).round()).collect();
        let packed = ternary::pack(&trits).map_err(|e| anyhow::anyhow!(e))?;
        packed_bytes += packed.len() * 4;
        // verify a lossless round-trip of the ternary grid
        let back = ternary::unpack(&packed, trits.len());
        assert_eq!(back, trits, "{}", meta.name);
        // and that the fused GEMV (dot products straight off the 2-bit
        // codes, scale once per row) matches unpack-then-dot — the
        // decode-free matmul serving runs on
        if gemv_checked < 4 && meta.shape.len() == 2 {
            let (n_out, k) = (meta.shape[0], meta.shape[1]);
            let x: Vec<f32> = (0..k).map(|j| ((j % 7) as f32 - 3.0) * 0.21).collect();
            let y = ternary::gemv(&packed, &x, k, n_out, s3);
            for (r, yr) in y.iter().enumerate() {
                let mut reference = 0f32;
                for j in 0..k {
                    reference += w3[r * k + j] * x[j];
                }
                assert!(
                    (yr - reference).abs() < 1e-3,
                    "{} row {r}: fused {yr} vs reference {reference}",
                    meta.name
                );
            }
            gemv_checked += 1;
        }
    }
    println!(
        "\nternary packing: {:.2} MB → {:.3} MB ({:.1}x), fused GEMV verified on {gemv_checked} matrices",
        fp32_bytes as f64 / 1e6,
        packed_bytes as f64 / 1e6,
        fp32_bytes as f64 / packed_bytes as f64
    );

    // --- host SR matches the kernel's stream (sanity of shared PRNG) ---
    let demo = [0.3f32, -0.7, 0.49];
    let r = sr::sr_slice(&demo, 7, 1.58, 1.0);
    println!("host SR sanity: SR({demo:?}) = {r:?}");

    // --- eval both ways through the compiled graphs ---
    let cspec = CorpusSpec::by_name("wiki", 42).unwrap();
    let r8 = eval::evaluate(&vrt, &state, &pipeline, &cspec, 60, false, 7)?;
    let r3 = eval::evaluate(&vrt, &state, &pipeline, &cspec, 60, true, 7)?;
    let task_names: Vec<String> = r8.task_acc.iter().map(|(t, _)| t.clone()).collect();
    println!("\n| inference | perplexity | {} |", task_names.join(" | "));
    for r in [&r8, &r3] {
        println!(
            "| {:<9} | {:>10.3} | {} |",
            if r.ternary_inference { "ternary" } else { "int8" },
            r.perplexity,
            r.task_acc
                .iter()
                .map(|(_, a)| format!("{:.1}%", a * 100.0))
                .collect::<Vec<_>>()
                .join(" | ")
        );
    }

    // --- full checkpoint with packing for the record ---
    std::fs::create_dir_all(&out)?;
    let ckpt_path = out.join("model-int8.dqt");
    let bytes = checkpoint::save(&ckpt_path, &m, &state, checkpoint::Codec::F32, false)?;
    println!("\nwrote {} ({:.2} MB)", ckpt_path.display(), bytes as f64 / 1e6);

    // --- packed-grid host state: the wire bytes stay the resident bytes ---
    let packed_state = checkpoint::load_packed(&ckpt_path, &m)?;
    let dense_grid: usize = m
        .params
        .iter()
        .filter(|p| p.is_grid())
        .map(|p| p.numel() * 4)
        .sum();
    println!(
        "packed-grid state: grid params resident at {} bytes (dense f32 would be {}; {:.1}x), \
         process RSS {:.1} MB",
        packed_state.grid_param_bytes(&m),
        dense_grid,
        dense_grid as f64 / packed_state.grid_param_bytes(&m).max(1) as f64,
        dqt::memory::process_rss_bytes().unwrap_or(0) as f64 / 1e6
    );
    // packed state evaluates identically through the PJRT boundary decode
    let ppl_packed = eval::perplexity(&vrt, &packed_state, &pipeline, false)?;
    println!("perplexity from packed-grid state: {ppl_packed:.3} (int8 path {:.3})", r8.perplexity);

    // --- decode-free generation: the deployed ternary model serves ---
    let engine = dqt::serve::Engine::new(&vrt, &state, pipeline.tokenizer.clone(), true)?;
    let dec = engine.decoder();
    assert_eq!(
        dec.packed_projections(),
        dec.n_projections(),
        "deploy-time serving must run every projection off the 2-bit codes"
    );
    let g = engine.generate(
        "the",
        &dqt::serve::GenParams {
            max_new_tokens: 24,
            temperature: 0.8,
            seed: 7,
            ..Default::default()
        },
    )?;
    println!(
        "\ndecode-free ternary generation ({} tokens, {}, {} weight bytes resident): {:?}",
        g.token_ids.len(),
        g.finish.as_str(),
        dec.weight_bytes(),
        g.text
    );

    println!("ternary inference stays close to int8 — deployment flexibility (§A.2).");
    Ok(())
}
