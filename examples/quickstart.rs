//! Quickstart: the paper's mechanics end to end, in under a minute,
//! with zero external dependencies — no artifacts, no PJRT, no Python.
//!
//! 1. Prints the Fig. 1/Fig. 8-style worked numeric example: AbsMean
//!    quantization of a small matrix, one stochastically rounded update.
//! 2. Builds the native CPU backend for the ternary `test` variant,
//!    trains a few steps on the tiny synthetic corpus and shows the loss
//!    dropping with the weights pinned to the ternary grid.
//!
//! Run: `cargo run --release --example quickstart`
//! (add `-- pjrt` to drive compiled artifacts instead; needs
//! `make artifacts` + linked PJRT)

use anyhow::Result;
use dqt::config::{BackendKind, Mode, TrainConfig, VariantSpec};
use dqt::data::Pipeline;
use dqt::quant::{absmean_quantize, absmean_scale, sr};
use dqt::runtime::VariantRuntime;
use dqt::train::Trainer;

fn worked_example() {
    println!("=== Fig. 8-style worked example (ternary, Eq. 1-5) ===\n");
    let w = [0.12f32, -0.31, 0.05, -0.18, 0.27, 0.02, -0.44, 0.09, 0.16];
    println!("random init W          = {w:?}");
    let s = absmean_scale(&w, 1.58);
    println!("AbsMean scale s=Qp/mean|W| (Eq. 3)   = {s:.3}");
    let wq = absmean_quantize(&w, 1.58, s);
    let grid: Vec<f32> = wq.iter().map(|v| (v * s).round()).collect();
    println!("quantized grid k=clip(round(W*s)) (Eq. 4) = {grid:?}");

    // a dense optimizer update W' arrives…
    let w_dense: Vec<f32> = wq.iter().map(|v| v - 0.3 / s).collect();
    println!("\ndense update W' = W̃ - 0.3/s   (transient, never stored)");
    // …and is stochastically rounded straight back onto the grid (Eq. 5)
    for seed in [1u32, 2, 3] {
        let w_new = sr::sr_slice(&w_dense, seed, 1.58, s);
        let k: Vec<f32> = w_new.iter().map(|v| (v * s).round()).collect();
        println!("SR(W', seed={seed}) grid = {k:?}");
    }
    println!(
        "\nEach -0.3 pull flips a trit with p=0.3 — unbiased in expectation,\n\
         so sub-grid updates accumulate over steps (the paper's §5.1 claim).\n"
    );
}

fn main() -> Result<()> {
    worked_example();

    println!("=== ternary DQT training (test config, 30 steps) ===\n");
    let backend = if std::env::args().any(|a| a == "pjrt") {
        BackendKind::Pjrt
    } else {
        BackendKind::Native
    };
    let spec = VariantSpec::new("test", Mode::Dqt, 1.58);
    let vrt = VariantRuntime::open(backend, None, dqt::default_artifacts_root(), &spec)?;
    println!("backend: {}", vrt.backend_name());
    let m = vrt.manifest();
    println!(
        "model {}: {} params, {} grid matrices",
        m.variant.model.name,
        m.variant.model.param_count,
        m.params.iter().filter(|p| p.is_grid()).count()
    );

    let pipeline = Pipeline::build(
        "tiny",
        1,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )?;
    let cfg = TrainConfig {
        steps: 30,
        warmup_steps: 5,
        peak_lr: 2e-3,
        dataset: "tiny".into(),
        log_every: 5,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&vrt, &pipeline, cfg);
    tr.progress = Some(Box::new(|step, loss| {
        println!("  step {step:>3}: loss {loss:.4}");
    }));
    let (state, metrics) = tr.run()?;

    // weights really are ternary: inspect the first grid matrix
    let grid_idx = m.params.iter().position(|p| p.is_grid()).unwrap();
    let w = state.params[grid_idx].values()?;
    let s = state.params[grid_idx + 1].scalar()?;
    let mut counts = [0usize; 3];
    for &v in w.iter() {
        let k = (v * s).round() as i32;
        counts[(k + 1) as usize] += 1;
    }
    println!(
        "\nfirst grid matrix {}: -1/0/+1 counts = {:?} (scale {s:.2})",
        m.params[grid_idx].name, counts
    );
    println!(
        "loss {:.4} → {:.4}; dev loss {:.4}",
        metrics.records.first().map(|r| r.loss).unwrap_or(f32::NAN),
        metrics.tail_loss(5).unwrap_or(f32::NAN),
        metrics.final_dev_loss.unwrap_or(f32::NAN),
    );
    println!("\nOK — weights stayed on the ternary grid for the whole run.");
    Ok(())
}
