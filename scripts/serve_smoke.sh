#!/usr/bin/env bash
# Serve-smoke: train a tiny ternary DQT variant on the native backend,
# serve it over HTTP, and assert the /v1/generate contract — 200s,
# nonzero generated tokens, EOS termination, per-seed determinism.
# CI runs this as the required serve-smoke job.
#
# Usage: scripts/serve_smoke.sh [port]
set -euo pipefail
cd "$(dirname "$0")/.."

PORT="${1:-${DQT_SMOKE_PORT:-18473}}"
OUT="$(mktemp -d)"
SERVER_PID=""
cleanup() {
    [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null || true
    rm -rf "$OUT"
}
trap cleanup EXIT

(cd rust && cargo build --release)
BIN=rust/target/release/repro

echo "== training a tiny ternary variant (native backend) =="
"$BIN" train --model test --mode dqt --bits 1.58 --backend native \
       --dataset tiny --steps 40 --seed 42 --out "$OUT"

echo "== starting the server on 127.0.0.1:$PORT =="
"$BIN" serve --model test --mode dqt --bits 1.58 --backend native \
       --dataset tiny --checkpoint "$OUT/model.dqt" \
       --addr "127.0.0.1:$PORT" --max-batch 4 &
SERVER_PID=$!

python3 scripts/serve_smoke_assert.py "http://127.0.0.1:$PORT"
echo "serve-smoke OK"
