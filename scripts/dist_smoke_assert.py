#!/usr/bin/env python3
"""Assert a 2-worker distributed run is bitwise equal to the 1-worker run.

Usage: dist_smoke_assert.py <dir_w1> <dir_w2>

Each directory is a `repro train --out` result: metrics.json + curve.csv +
model.dqt. Checks, in order:

  1. the per-step loss curve (loss, lr, upd_frac, gnorm columns of
     curve.csv — step_ms is wall time and legitimately differs) is
     IDENTICAL text, i.e. bit-identical f32 values;
  2. final_dev_loss (the eval NLL over the dev split) is identical in
     metrics.json;
  3. the saved checkpoints (model.dqt: every weight, scale and optimizer
     tensor) are byte-identical files.

Any diff prints the first offending step/field and exits non-zero.
"""

import hashlib
import json
import pathlib
import sys


def die(msg: str) -> None:
    print(f"DIST SMOKE FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def curve_rows(d: pathlib.Path):
    lines = (d / "curve.csv").read_text().strip().splitlines()
    header = lines[0].split(",")
    keep = [i for i, name in enumerate(header) if name != "step_ms"]
    return [tuple(line.split(",")[i] for i in keep) for line in lines[1:]]


def main() -> None:
    if len(sys.argv) != 3:
        die("usage: dist_smoke_assert.py <dir_w1> <dir_w2>")
    w1, w2 = pathlib.Path(sys.argv[1]), pathlib.Path(sys.argv[2])

    # 1. loss curve, field by field
    c1, c2 = curve_rows(w1), curve_rows(w2)
    if len(c1) != len(c2):
        die(f"step counts differ: {len(c1)} vs {len(c2)}")
    if not c1:
        die("empty loss curves")
    for row1, row2 in zip(c1, c2):
        if row1 != row2:
            die(f"loss curve diverged at step {row1[0]}: {row1} vs {row2}")
    print(f"curve OK: {len(c1)} steps bitwise equal")

    # 2. eval NLL (final dev loss)
    m1 = json.loads((w1 / "metrics.json").read_text())
    m2 = json.loads((w2 / "metrics.json").read_text())
    d1, d2 = m1.get("final_dev_loss"), m2.get("final_dev_loss")
    if d1 is None or d2 is None:
        die(f"missing final_dev_loss: {d1} vs {d2}")
    if d1 != d2:
        die(f"final dev loss (eval NLL) differs: {d1} vs {d2}")
    print(f"eval NLL OK: {d1}")

    # 3. checkpoint bytes
    h1 = hashlib.sha256((w1 / "model.dqt").read_bytes()).hexdigest()
    h2 = hashlib.sha256((w2 / "model.dqt").read_bytes()).hexdigest()
    if h1 != h2:
        die(f"checkpoints differ: {h1} vs {h2}")
    print(f"checkpoint OK: sha256 {h1[:16]}… identical")


if __name__ == "__main__":
    main()
