#!/usr/bin/env python3
"""Assert the distributed gradient-exchange contract between two runs.

Usage:
  dist_smoke_assert.py <dir_w1> <dir_w2>
      Bitwise mode (the --grad-format f32 contract): the 2-worker run
      must be bit-identical to the 1-worker run.
  dist_smoke_assert.py <dir_ref> <dir_q> --tolerance NATS \\
      --wire-baseline <dir_f32_w2> --wire-shrink RATIO
      Convergence mode (the --grad-format int8 contract): the quantized
      run must track the reference within the tolerance while its
      reported all-reduce wire bytes shrink by at least RATIO.

Each directory is a `repro train --out` result: metrics.json + curve.csv +
model.dqt (+ dist.json for --workers runs).

Bitwise mode checks, in order:

  1. the per-step loss curve (loss, lr, upd_frac, gnorm columns of
     curve.csv — step_ms is wall time and legitimately differs) is
     IDENTICAL text, i.e. bit-identical f32 values;
  2. final_dev_loss (the eval NLL over the dev split) is identical in
     metrics.json;
  3. the saved checkpoints (model.dqt: every weight, scale and optimizer
     tensor) are byte-identical files.

Convergence mode instead checks:

  1. per-step |loss_q - loss_ref| <= tolerance at every step, AND the
     curves are NOT identical text (a quantizer that secretly ships f32
     would pass any tolerance vacuously);
  2. |final_dev_loss_q - final_dev_loss_ref| <= tolerance;
  3. dist.json wire accounting: the quantized run's allreduce_bytes are
     at least --wire-shrink times under the f32 baseline's, both runs
     report world 2, and the grad_format tags are int8 vs f32.

Any failure prints the first offending step/field and exits non-zero.
"""

import argparse
import hashlib
import json
import pathlib
import sys


def die(msg: str) -> None:
    print(f"DIST SMOKE FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def curve_rows(d: pathlib.Path):
    lines = (d / "curve.csv").read_text().strip().splitlines()
    header = lines[0].split(",")
    keep = [i for i, name in enumerate(header) if name != "step_ms"]
    rows = [tuple(line.split(",")[i] for i in keep) for line in lines[1:]]
    names = [header[i] for i in keep]
    return names, rows


def load_curves(a: pathlib.Path, b: pathlib.Path):
    names_a, ca = curve_rows(a)
    names_b, cb = curve_rows(b)
    if names_a != names_b:
        die(f"curve columns differ: {names_a} vs {names_b}")
    if len(ca) != len(cb):
        die(f"step counts differ: {len(ca)} vs {len(cb)}")
    if not ca:
        die("empty loss curves")
    return names_a, ca, cb


def dev_losses(a: pathlib.Path, b: pathlib.Path):
    ma = json.loads((a / "metrics.json").read_text())
    mb = json.loads((b / "metrics.json").read_text())
    da, db = ma.get("final_dev_loss"), mb.get("final_dev_loss")
    if da is None or db is None:
        die(f"missing final_dev_loss: {da} vs {db}")
    return da, db


def assert_bitwise(w1: pathlib.Path, w2: pathlib.Path) -> None:
    # 1. loss curve, field by field
    _, c1, c2 = load_curves(w1, w2)
    for row1, row2 in zip(c1, c2):
        if row1 != row2:
            die(f"loss curve diverged at step {row1[0]}: {row1} vs {row2}")
    print(f"curve OK: {len(c1)} steps bitwise equal")

    # 2. eval NLL (final dev loss)
    d1, d2 = dev_losses(w1, w2)
    if d1 != d2:
        die(f"final dev loss (eval NLL) differs: {d1} vs {d2}")
    print(f"eval NLL OK: {d1}")

    # 3. checkpoint bytes
    h1 = hashlib.sha256((w1 / "model.dqt").read_bytes()).hexdigest()
    h2 = hashlib.sha256((w2 / "model.dqt").read_bytes()).hexdigest()
    if h1 != h2:
        die(f"checkpoints differ: {h1} vs {h2}")
    print(f"checkpoint OK: sha256 {h1[:16]}… identical")


def assert_convergence(
    ref: pathlib.Path,
    quant: pathlib.Path,
    tol: float,
    wire_baseline: pathlib.Path,
    wire_shrink: float,
) -> None:
    # 1. loss curve within tolerance, but not secretly identical
    names, cr, cq = load_curves(ref, quant)
    loss_col = names.index("loss")
    worst = 0.0
    for row_r, row_q in zip(cr, cq):
        gap = abs(float(row_q[loss_col]) - float(row_r[loss_col]))
        worst = max(worst, gap)
        if gap > tol:
            die(
                f"quantized loss drifted at step {row_q[0]}: "
                f"{row_q[loss_col]} vs f32 {row_r[loss_col]} (> {tol} nats)"
            )
    if cr == cq:
        die("quantized curve is bitwise equal to f32 — quantization isn't happening")
    print(f"curve OK: {len(cq)} steps within {tol} nats of f32 (worst gap {worst:.6f})")

    # 2. eval NLL within tolerance
    dr, dq = dev_losses(ref, quant)
    if abs(dq - dr) > tol:
        die(f"final dev loss drifted: {dq} vs f32 {dr} (> {tol} nats)")
    print(f"eval NLL OK: {dq} vs f32 {dr}")

    # 3. reported all-reduce wire bytes shrink
    dist_q = json.loads((quant / "dist.json").read_text())
    dist_f = json.loads((wire_baseline / "dist.json").read_text())
    for d, want in ((dist_q, "int8"), (dist_f, "f32")):
        if d.get("grad_format") != want:
            die(f"dist.json grad_format is {d.get('grad_format')!r}, expected {want!r}")
        if d.get("world") != 2:
            die(f"dist.json world is {d.get('world')}, expected 2")
    bytes_q, bytes_f = dist_q["allreduce_bytes"], dist_f["allreduce_bytes"]
    if not bytes_q or not bytes_f:
        die(f"zero all-reduce bytes reported: int8 {bytes_q}, f32 {bytes_f}")
    ratio = bytes_f / bytes_q
    if ratio < wire_shrink:
        die(
            f"int8 all-reduce moved {bytes_q} bytes vs f32 {bytes_f} — "
            f"only {ratio:.2f}x smaller, need >= {wire_shrink}x"
        )
    print(f"wire OK: int8 {bytes_q} B vs f32 {bytes_f} B ({ratio:.2f}x smaller)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("ref", type=pathlib.Path)
    ap.add_argument("run", type=pathlib.Path)
    ap.add_argument("--tolerance", type=float, default=None, help="nats; enables convergence mode")
    ap.add_argument("--wire-baseline", type=pathlib.Path, default=None)
    ap.add_argument("--wire-shrink", type=float, default=3.9)
    args = ap.parse_args()

    if args.tolerance is None:
        assert_bitwise(args.ref, args.run)
    else:
        if args.wire_baseline is None:
            die("--tolerance requires --wire-baseline")
        assert_convergence(
            args.ref, args.run, args.tolerance, args.wire_baseline, args.wire_shrink
        )


if __name__ == "__main__":
    main()
