#!/usr/bin/env bash
# Run the quant_codecs bench and append a labeled record to
# BENCH_quant_codecs.json (results also land under rust/results/bench/).
#
# Usage: scripts/bench_codecs.sh [label]
set -euo pipefail
cd "$(dirname "$0")/.."

label="${1:-$(git rev-parse --short HEAD 2>/dev/null || echo unlabeled)}"

(cd rust && cargo bench --bench quant_codecs)

python3 - "$label" <<'PY'
import json, sys, pathlib

root = pathlib.Path(".")
label = sys.argv[1]
records = json.loads((root / "rust/results/bench/quant_codecs.json").read_text())
baseline_path = root / "BENCH_quant_codecs.json"
baseline = json.loads(baseline_path.read_text())
run = {
    "label": label,
    "results": {
        r["name"]: {
            "mean_ns": r["mean_ns"],
            "gb_per_s": (r["bytes"] / r["mean_ns"]) if r.get("bytes") else None,
        }
        for r in records
    },
}
baseline["runs"] = [r for r in baseline.get("runs", []) if r.get("label") != label]
baseline["runs"].append(run)
baseline_path.write_text(json.dumps(baseline, indent=2) + "\n")
print(f"recorded run '{label}' with {len(run['results'])} benchmarks")
PY
