#!/usr/bin/env python3
"""Validate a train run's quant_health.json against the quant-health
contract (docs/OBSERVABILITY.md §Quant health). CI's e2e-smoke-train job
runs this on a real 20-step native run.

Checks:
  1. The run dir's model.dqt header (the JSON first line of the `.dqt`
     format, docs/CHECKPOINT_FORMAT.md) names the grid-quantized params —
     the entries carrying an absmax scale. quant_health.json must report
     exactly those layers, in manifest order, with matching weight counts.
  2. Schema: version 1, every documented per-layer field present and
     finite; fractions in [0, 1]; the 5-bin occupancy histogram sums to
     the layer's weight count; per-layer steps equals the run's steps.
  3. Liveness: the run moved weights — total level flips > 0 and the
     stored lifetime flip_rate is consistent with
     flips_total / (weights * steps).
  4. Anomaly verdicts: a Python replica of the three documented detectors
     (dead layer, saturation, oscillation, thresholds from
     docs/OBSERVABILITY.md) must agree with the file's `anomalies` array
     on which (kind, layer) pairs are flagged — so the emitted verdicts
     can never drift from the documented thresholds. The replica itself
     is self-tested on a synthetic dead layer before it judges anything.

Usage: check_quant_health.py <run_dir>
"""

import json
import math
import pathlib
import sys

# thresholds of rust/src/obs/quant.rs, pinned to docs/OBSERVABILITY.md
DEAD_FLIP_RATE = 1e-4
DEAD_GNORM_FLOOR = 1e-12
SATURATION_WARN = 0.9
OSCILLATION_WARN = 0.6

LAYER_FIELDS = [
    "name",
    "weights",
    "steps",
    "flips_total",
    "flip_rate",
    "last_flips",
    "net_upd_grid_steps",
    "abs_upd_grid_steps",
    "occupancy",
    "scale",
    "scale_drift",
    "saturation",
    "zero_frac",
    "oscillation",
    "grad_norm",
]

FRACTION_FIELDS = ["flip_rate", "saturation", "zero_frac", "oscillation"]

failures = []


def check(ok, msg):
    if not ok:
        failures.append(msg)


def grid_params_from_checkpoint(path):
    """(name, numel) for every grid-quantized param, from the .dqt JSON
    header: exactly the entries written with a non-null absmax scale."""
    with open(path, "rb") as f:
        header = json.loads(f.readline().decode("utf-8"))
    out = []
    for p in header["params"]:
        if p.get("scale") is not None:
            numel = 1
            for d in p["shape"]:
                numel *= d
            out.append((p["name"], numel))
    return out


def replica_verdicts(layers):
    """The documented anomaly detectors, reimplemented: {(kind, layer)}."""
    flagged = set()
    for l in layers:
        if l["steps"] == 0:
            continue
        rate = l["flips_total"] / (l["weights"] * l["steps"]) if l["weights"] else 0.0
        if rate < DEAD_FLIP_RATE and l["grad_norm"] > DEAD_GNORM_FLOOR:
            flagged.add(("dead-layer", l["name"]))
        if l["saturation"] > SATURATION_WARN:
            flagged.add(("saturation", l["name"]))
        if l["oscillation"] > OSCILLATION_WARN:
            flagged.add(("oscillation", l["name"]))
    return flagged


def parse_verdicts(anomalies):
    """The file's anomaly lines → {(kind, layer)}. Lines are
    `warn[kind] layer: ...` (rust/src/obs/quant.rs::anomalies)."""
    out = set()
    for line in anomalies:
        if not (line.startswith("warn[") and "] " in line and ":" in line):
            failures.append(f"unparseable anomaly line {line!r}")
            continue
        kind = line[len("warn[") : line.index("]")]
        layer = line[line.index("] ") + 2 :].split(":", 1)[0]
        out.add((kind, layer))
    return out


def self_test_replica():
    """A synthetic dead layer must trip the replica (and a healthy one
    must not) — guards against the detectors rotting into no-ops."""
    dead = {
        "name": "synthetic.dead",
        "weights": 1000,
        "steps": 100,
        "flips_total": 1,  # rate 1e-5 < 1e-4
        "grad_norm": 0.5,
        "saturation": 0.0,
        "oscillation": 0.0,
    }
    healthy = {
        "name": "synthetic.healthy",
        "weights": 1000,
        "steps": 100,
        "flips_total": 5000,
        "grad_norm": 0.5,
        "saturation": 0.2,
        "oscillation": 0.1,
    }
    got = replica_verdicts([dead, healthy])
    assert got == {("dead-layer", "synthetic.dead")}, got
    # saturation + oscillation fire independently of flips
    hot = dict(healthy, name="synthetic.hot", saturation=0.95, oscillation=0.7)
    got = replica_verdicts([hot])
    assert got == {("saturation", "synthetic.hot"), ("oscillation", "synthetic.hot")}, got


def main():
    if len(sys.argv) != 2:
        sys.exit(__doc__)
    self_test_replica()
    run_dir = pathlib.Path(sys.argv[1])
    health_path = run_dir / "quant_health.json"
    ckpt_path = run_dir / "model.dqt"
    check(health_path.is_file(), f"{health_path} missing")
    check(ckpt_path.is_file(), f"{ckpt_path} missing")
    if failures:
        report()

    h = json.loads(health_path.read_text())
    check(h.get("version") == 1, f"version {h.get('version')!r} != 1")
    steps = h.get("steps", 0)
    check(steps > 0, "run recorded 0 steps")
    layers = h.get("layers", [])
    check(isinstance(h.get("anomalies"), list), "anomalies array missing")

    # 1. layer set == the checkpoint manifest's grid params, in order
    expected = grid_params_from_checkpoint(ckpt_path)
    check(expected, "checkpoint has no grid-quantized params — wrong run dir?")
    got = [(l.get("name"), l.get("weights")) for l in layers]
    check(
        got == expected,
        f"layers disagree with the manifest grid params:\n  json: {got}\n  ckpt: {expected}",
    )

    # 2. schema per layer
    for l in layers:
        name = l.get("name", "<unnamed>")
        for f in LAYER_FIELDS:
            check(f in l, f"{name}: field {f!r} missing")
        occ = l.get("occupancy", [])
        check(
            isinstance(occ, list) and len(occ) == 5,
            f"{name}: occupancy must be a 5-bin histogram, got {occ!r}",
        )
        if isinstance(occ, list) and all(isinstance(c, int) for c in occ):
            check(
                sum(occ) == l.get("weights"),
                f"{name}: occupancy sums to {sum(occ)}, weights = {l.get('weights')}",
            )
        for f in FRACTION_FIELDS:
            v = l.get(f)
            if isinstance(v, (int, float)):
                check(0.0 <= v <= 1.0, f"{name}: {f} = {v} outside [0, 1]")
        for f in LAYER_FIELDS:
            v = l.get(f)
            if isinstance(v, float):
                check(math.isfinite(v), f"{name}: {f} = {v} is not finite")
        check(
            l.get("steps") == steps,
            f"{name}: layer steps {l.get('steps')} != run steps {steps}",
        )

    # 3. SR liveness + stored-rate consistency
    total_flips = sum(l.get("flips_total", 0) for l in layers)
    check(total_flips > 0, "total flips == 0 — SR recording is broken or the run is dead")
    for l in layers:
        if l.get("weights") and l.get("steps"):
            want = l["flips_total"] / (l["weights"] * l["steps"])
            got_rate = l.get("flip_rate", -1.0)
            check(
                abs(got_rate - want) <= max(1e-9, 1e-5 * want),
                f"{l['name']}: stored flip_rate {got_rate} != {want}",
            )

    # 4. emitted verdicts == documented thresholds applied to the data
    check(
        parse_verdicts(h.get("anomalies", [])) == replica_verdicts(layers),
        "anomalies array disagrees with the documented thresholds: "
        f"file={sorted(parse_verdicts(h.get('anomalies', [])))} "
        f"replica={sorted(replica_verdicts(layers))}",
    )

    report()


def report():
    if failures:
        print(f"check_quant_health: {len(failures)} failure(s)")
        for f in failures:
            print(f"  - {f}")
        sys.exit(1)
    print("check_quant_health: OK")
    sys.exit(0)


if __name__ == "__main__":
    main()
