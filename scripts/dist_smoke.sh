#!/usr/bin/env bash
# Dist-smoke: train the tiny ternary DQT variant for 20 steps twice —
# once with --workers 1 (the single-process reference through the dist
# code path) and once with --workers 2 (rank 0 + one spawned local worker
# process over localhost TCP, packed grid resync active) — then assert
# the two runs are BITWISE equal: loss curve, final dev loss (eval NLL)
# and the saved checkpoint bytes. CI runs this as the required dist-smoke
# job; the same property is pinned in-process by rust/tests/dist.rs.
#
# The 2-worker leg also exercises the observability plane: rank 0 runs
# with --watch-addr and a background `repro watch --join` tails the live
# stream; afterwards its log must show the run header, per-step loss
# frames, and the run-end line (docs/OBSERVABILITY.md). Observation is
# read-only, so the bitwise assertions above hold with it enabled.
#
# Usage: scripts/dist_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="$(mktemp -d)"
cleanup() { rm -rf "$OUT"; }
trap cleanup EXIT

(cd rust && cargo build --release)
BIN=rust/target/release/repro

COMMON=(--model test --mode dqt --bits 1.58 --backend native
        --dataset tiny --steps 20 --seed 42 --sync-every 5)

echo "== 1-worker reference run (dist path, identity reducer) =="
"$BIN" train "${COMMON[@]}" --workers 1 --out "$OUT/w1"

echo "== 2-worker run (rank 0 + spawned local worker, packed sync) =="
# watcher first: `repro watch` retries the connect until the publisher
# binds, so it sees the RunStart header and every step frame
WATCH_ADDR=127.0.0.1:17961
"$BIN" watch --join "$WATCH_ADDR" --timeout 60 > "$OUT/watch.log" &
WATCH_PID=$!
"$BIN" train "${COMMON[@]}" --workers 2 --out "$OUT/w2" \
    --watch-addr "$WATCH_ADDR"
wait "$WATCH_PID"

echo "== watch tail of the 2-worker run =="
cat "$OUT/watch.log"
grep -q "^run start: .* (world 2, 20 steps)$" "$OUT/watch.log"
STEP_LINES=$(grep -c "^step [0-9]*: loss " "$OUT/watch.log")
[ "$STEP_LINES" -eq 20 ] || {
    echo "expected 20 per-step frames in the watch tail, saw $STEP_LINES" >&2
    exit 1
}
grep -q "^run end: dev loss " "$OUT/watch.log"

python3 scripts/dist_smoke_assert.py "$OUT/w1" "$OUT/w2"
echo "dist-smoke OK"
