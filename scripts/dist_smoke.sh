#!/usr/bin/env bash
# Dist-smoke: train the tiny ternary DQT variant for 20 steps twice —
# once with --workers 1 (the single-process reference through the dist
# code path) and once with --workers 2 (rank 0 + one spawned local worker
# process over localhost TCP, packed grid resync active) — then assert
# the two runs are BITWISE equal: loss curve, final dev loss (eval NLL)
# and the saved checkpoint bytes. CI runs this as the required dist-smoke
# job; the same property is pinned in-process by rust/tests/dist.rs.
#
# Usage: scripts/dist_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="$(mktemp -d)"
cleanup() { rm -rf "$OUT"; }
trap cleanup EXIT

(cd rust && cargo build --release)
BIN=rust/target/release/repro

COMMON=(--model test --mode dqt --bits 1.58 --backend native
        --dataset tiny --steps 20 --seed 42 --sync-every 5)

echo "== 1-worker reference run (dist path, identity reducer) =="
"$BIN" train "${COMMON[@]}" --workers 1 --out "$OUT/w1"

echo "== 2-worker run (rank 0 + spawned local worker, packed sync) =="
"$BIN" train "${COMMON[@]}" --workers 2 --out "$OUT/w2"

python3 scripts/dist_smoke_assert.py "$OUT/w1" "$OUT/w2"
echo "dist-smoke OK"
