#!/usr/bin/env bash
# Dist-smoke: train the tiny ternary DQT variant for 20 steps through the
# distributed code path and assert the gradient-exchange contract for one
# `--grad-format` leg (CI runs both as a matrix):
#
#   f32 leg (default): --workers 1 reference vs --workers 2 (rank 0 + one
#   spawned local worker over localhost TCP, packed grid resync active)
#   must be BITWISE equal — loss curve, final dev loss (eval NLL) and the
#   saved checkpoint bytes. Same property as rust/tests/dist.rs, across
#   OS processes via the CLI.
#
#   int8 leg: the quantized gradient exchange trades that bitwise
#   contract for a convergence contract — the 2-worker int8 run's loss
#   curve must track the 1-worker reference within a tolerance while its
#   reported all-reduce wire bytes (out/dist.json) shrink >=3.9x vs a
#   2-worker f32 run (the whole-frame int8 ratio approaches 4.0 from
#   below as per-entry metadata amortizes).
#
# The f32 leg also exercises the observability plane: rank 0 runs with
# --watch-addr and a background `repro watch --join` tails the live
# stream; afterwards its log must show the run header, per-step loss
# frames, and the run-end line (docs/OBSERVABILITY.md). Observation is
# read-only, so the bitwise assertions hold with it enabled.
#
# Usage: scripts/dist_smoke.sh [f32|int8]
set -euo pipefail
cd "$(dirname "$0")/.."

GRAD_FORMAT="${1:-f32}"
case "$GRAD_FORMAT" in
    f32|int8) ;;
    *) echo "usage: scripts/dist_smoke.sh [f32|int8]" >&2; exit 2 ;;
esac

OUT="$(mktemp -d)"
cleanup() { rm -rf "$OUT"; }
trap cleanup EXIT

(cd rust && cargo build --release)
BIN=rust/target/release/repro

COMMON=(--model test --mode dqt --bits 1.58 --backend native
        --dataset tiny --steps 20 --seed 42 --sync-every 5)

echo "== 1-worker reference run (dist path, identity reducer) =="
"$BIN" train "${COMMON[@]}" --workers 1 --out "$OUT/w1"

if [ "$GRAD_FORMAT" = f32 ]; then
    echo "== 2-worker run (rank 0 + spawned local worker, packed sync) =="
    # watcher first: `repro watch` retries the connect until the publisher
    # binds, so it sees the RunStart header and every step frame
    WATCH_ADDR=127.0.0.1:17961
    "$BIN" watch --join "$WATCH_ADDR" --timeout 60 > "$OUT/watch.log" &
    WATCH_PID=$!
    "$BIN" train "${COMMON[@]}" --workers 2 --out "$OUT/w2" \
        --watch-addr "$WATCH_ADDR"
    wait "$WATCH_PID"

    echo "== watch tail of the 2-worker run =="
    cat "$OUT/watch.log"
    grep -q "^run start: .* (world 2, 20 steps)$" "$OUT/watch.log"
    STEP_LINES=$(grep -c "^step [0-9]*: loss " "$OUT/watch.log")
    [ "$STEP_LINES" -eq 20 ] || {
        echo "expected 20 per-step frames in the watch tail, saw $STEP_LINES" >&2
        exit 1
    }
    grep -q "^run end: dev loss " "$OUT/watch.log"

    python3 scripts/dist_smoke_assert.py "$OUT/w1" "$OUT/w2"
else
    echo "== 2-worker f32 run (wire-bytes baseline) =="
    "$BIN" train "${COMMON[@]}" --workers 2 --grad-format f32 --out "$OUT/w2f32"

    echo "== 2-worker int8 run (quantized gradient exchange) =="
    "$BIN" train "${COMMON[@]}" --workers 2 --grad-format int8 --out "$OUT/w2int8"

    python3 scripts/dist_smoke_assert.py "$OUT/w1" "$OUT/w2int8" \
        --tolerance 0.35 --wire-baseline "$OUT/w2f32" --wire-shrink 3.9
fi
echo "dist-smoke OK ($GRAD_FORMAT leg)"
