#!/usr/bin/env python3
"""Validate a --trace-out Chrome trace-event JSON file against the span
contract (docs/OBSERVABILITY.md §Tracing). CI's trace-smoke job runs this
on a real train trace and a real serve trace.

Checks:
  1. The file is well-formed Chrome trace JSON: a top-level object with a
     "traceEvents" array; every complete ("X") event carries name/cat/
     ts/dur/pid/tid; every metadata ("M") event is a thread_name row.
  2. Every span name is in the documented vocabulary — an undocumented
     name in a real trace means code and contract drifted.
  3. The mode's required spans are present (--expect train|serve), with
     at least --min-steps train.step (or serve.request) spans.
  4. Train coverage: the per-phase children (data_load, forward,
     backward, optimizer, allreduce, grid_sync) must account for >= 90%
     of total train.step wall time — if they don't, a phase went
     uninstrumented and the profile table is lying by omission.

Usage: check_trace.py <trace.json> --expect train|serve [--min-steps N]
"""

import argparse
import json
import pathlib
import sys

# the span vocabulary of rust/src/obs/trace.rs::names::ALL, pinned to
# docs/OBSERVABILITY.md by rust/tests/trace_contract.rs
KNOWN_SPANS = {
    "train.step",
    "train.data_load",
    "train.forward",
    "train.backward",
    "train.optimizer",
    "train.sr_project",
    "dist.allreduce",
    "dist.grid_sync",
    "fwd.rmsnorm",
    "fwd.attention",
    "fwd.swiglu",
    "fwd.head",
    "serve.request",
    "serve.queue_wait",
    "serve.prefill",
    "serve.decode",
    "serve.sample",
    "serve.detokenize",
    "kernel.task",
}

REQUIRED = {
    "train": {
        "train.step",
        "train.data_load",
        "train.forward",
        "train.backward",
        "train.optimizer",
        "train.sr_project",
        "fwd.rmsnorm",
        "fwd.attention",
        "fwd.swiglu",
        "fwd.head",
    },
    "serve": {
        "serve.request",
        "serve.queue_wait",
        "serve.prefill",
        "serve.decode",
        "serve.sample",
        "serve.detokenize",
    },
}

# disjoint phases nested directly under train.step; their summed duration
# is the accounted-for share of step wall time
STEP_PHASES = {
    "train.data_load",
    "train.forward",
    "train.backward",
    "train.optimizer",
    "dist.allreduce",
    "dist.grid_sync",
}

COVERAGE_FLOOR = 0.90


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", type=pathlib.Path)
    ap.add_argument("--expect", choices=["train", "serve"], required=True)
    ap.add_argument("--min-steps", type=int, default=1)
    args = ap.parse_args()

    try:
        doc = json.loads(args.trace.read_text())
    except (OSError, ValueError) as e:
        fail(f"{args.trace} is not readable well-formed JSON: {e}")

    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        fail('top level must be an object with a "traceEvents" array')
    events = doc["traceEvents"]
    if not events:
        fail("traceEvents is empty — was tracing actually enabled?")

    spans = []
    for e in events:
        ph = e.get("ph")
        if ph == "M":
            if e.get("name") != "thread_name" or "name" not in e.get("args", {}):
                fail(f"malformed metadata event: {e}")
            continue
        if ph != "X":
            fail(f"unexpected event phase {ph!r} (only X spans and M metadata): {e}")
        for field in ("name", "cat", "ts", "dur", "pid", "tid"):
            if field not in e:
                fail(f"X event lacks {field!r}: {e}")
        if not isinstance(e["ts"], (int, float)) or not isinstance(e["dur"], (int, float)):
            fail(f"ts/dur must be numeric: {e}")
        if e["ts"] < 0 or e["dur"] < 0:
            fail(f"negative ts/dur: {e}")
        if e["name"] not in KNOWN_SPANS:
            fail(
                f"undocumented span name {e['name']!r} — update "
                "rust/src/obs/trace.rs::names, docs/OBSERVABILITY.md and "
                "this script together"
            )
        spans.append(e)

    present = {e["name"] for e in spans}
    missing = REQUIRED[args.expect] - present
    if missing:
        fail(f"required {args.expect} spans absent from the trace: {sorted(missing)}")

    root = "train.step" if args.expect == "train" else "serve.request"
    n_roots = sum(1 for e in spans if e["name"] == root)
    if n_roots < args.min_steps:
        fail(f"only {n_roots} {root} spans recorded, expected >= {args.min_steps}")

    dropped = doc.get("otherData", {}).get("dropped_events", 0)
    if dropped:
        print(f"check_trace: note: ring dropped {dropped} oldest events")

    if args.expect == "train":
        step_us = sum(e["dur"] for e in spans if e["name"] == root)
        phase_us = sum(e["dur"] for e in spans if e["name"] in STEP_PHASES)
        if step_us <= 0:
            fail("train.step spans have zero total duration")
        coverage = phase_us / step_us
        if coverage < COVERAGE_FLOOR:
            fail(
                f"per-phase spans cover only {coverage:.1%} of train.step wall "
                f"time (floor {COVERAGE_FLOOR:.0%}) — a phase went uninstrumented"
            )
        print(
            f"check_trace: OK — {len(spans)} spans, {n_roots} {root}, "
            f"phase coverage {coverage:.1%}"
        )
    else:
        print(f"check_trace: OK — {len(spans)} spans, {n_roots} {root}")


if __name__ == "__main__":
    main()
