#!/usr/bin/env python3
"""Assertions for the serve-smoke CI job, against a live `repro serve`.

Checks (see docs/SERVING.md for the API contract):
  1. /healthz answers 200 ok, with the ternary model fully packed
     (packed_projections == n_projections — the decode-free assertion).
  2. POST /v1/generate answers 200 with nonzero generated tokens and a
     valid finish_reason.
  3. Greedy generation is deterministic across requests.
  4. Sampled generation is deterministic per seed and, across a sweep of
     seeds, terminates at EOS at least once (the EOS-termination leg).
  5. Bad requests get 400, unknown routes 404.
  6. /healthz and /v1/stats attribute the numeric tier ("precision");
     when the CI matrix pins DQT_PRECISION the server must report it.

Usage: serve_smoke_assert.py <base-url>
"""

import json
import os
import sys
import time
import urllib.error
import urllib.request

BASE = sys.argv[1] if len(sys.argv) > 1 else "http://127.0.0.1:18473"


def get(path):
    with urllib.request.urlopen(BASE + path, timeout=30) as r:
        return r.status, json.loads(r.read().decode())


def post(path, body):
    req = urllib.request.Request(
        BASE + path,
        data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"},
        method="POST",
    )
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read().decode())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode())


def wait_healthy():
    for _ in range(100):
        try:
            status, body = get("/healthz")
            if status == 200 and body.get("status") == "ok":
                return body
        except OSError:
            pass
        time.sleep(0.2)
    raise SystemExit("server never became healthy")


def main():
    health = wait_healthy()
    assert health["packed_projections"] == health["n_projections"] > 0, (
        f"ternary serving must be decode-free: {health}"
    )
    # the numeric tier is attributed on /healthz; when the smoke matrix
    # pins DQT_PRECISION the server must be running the requested tier
    assert health.get("precision") in ("exact", "fast"), health
    want_precision = os.environ.get("DQT_PRECISION")
    if want_precision:
        assert health["precision"] == want_precision, (health, want_precision)
    print(f"healthz ok: {health}")

    # greedy: 200, nonzero tokens, deterministic
    body = {"prompt": "the cat sat", "max_new_tokens": 10}
    status, a = post("/v1/generate", body)
    assert status == 200, (status, a)
    assert a["gen_tokens"] > 0 and len(a["token_ids"]) == a["gen_tokens"], a
    assert a["finish_reason"] in ("eos", "length", "cache_full"), a
    status, b = post("/v1/generate", body)
    assert status == 200 and b["token_ids"] == a["token_ids"], (a, b)
    print(f"greedy ok: {a['gen_tokens']} tokens, finish={a['finish_reason']}")

    # seeded sampling: deterministic per seed, EOS within the sweep
    eos_seed = None
    for seed in range(48):
        req = {
            "prompt": "the cat",
            "max_new_tokens": 12,
            "temperature": 2.0,
            "seed": seed,
        }
        status, g = post("/v1/generate", req)
        assert status == 200 and g["gen_tokens"] > 0, (status, g)
        if g["finish_reason"] == "eos":
            eos_seed = seed
            status, g2 = post("/v1/generate", req)
            assert status == 200 and g2["token_ids"] == g["token_ids"], (g, g2)
            break
    assert eos_seed is not None, "no EOS termination across 48 sampled seeds"
    print(f"eos termination ok (seed {eos_seed})")

    # stats + error paths (threads + decode throughput attribute every
    # recorded number to a configuration — the kernel-layer contract)
    status, stats = get("/v1/stats")
    assert status == 200 and stats["completed"] >= 3, stats
    assert stats.get("threads", 0) >= 1, stats
    assert stats.get("precision") == health["precision"], (stats, health)
    assert stats.get("decode_tokens_per_sec", 0) > 0, stats
    status, err = post("/v1/generate", {"nope": 1})
    assert status == 400 and "error" in err, (status, err)
    try:
        status, _ = get("/nope")
    except urllib.error.HTTPError as e:
        status = e.code
    assert status == 404, status
    print(f"stats + error paths ok: {stats}")


if __name__ == "__main__":
    main()
