#!/usr/bin/env python3
"""Assertions for the serve-smoke CI job, against a live `repro serve`.

Checks (see docs/SERVING.md for the API contract):
  1. /healthz answers 200 ok, with the ternary model fully packed
     (packed_projections == n_projections — the decode-free assertion).
  2. POST /v1/generate answers 200 with nonzero generated tokens and a
     valid finish_reason.
  3. Greedy generation is deterministic across requests.
  4. Sampled generation is deterministic per seed and, across a sweep of
     seeds, terminates at EOS at least once (the EOS-termination leg).
  5. Bad requests get 400, unknown routes 404.
  6. /healthz and /v1/stats attribute the numeric tier ("precision");
     when the CI matrix pins DQT_PRECISION the server must report it.
  7. /metrics serves valid Prometheus text (docs/OBSERVABILITY.md):
     decode throughput nonzero, TTFT observations recorded, request
     counters consistent with the traffic this script generated.

Usage: serve_smoke_assert.py <base-url>
"""

import json
import os
import sys
import time
import urllib.error
import urllib.request

BASE = sys.argv[1] if len(sys.argv) > 1 else "http://127.0.0.1:18473"


def get(path):
    with urllib.request.urlopen(BASE + path, timeout=30) as r:
        return r.status, json.loads(r.read().decode())


def post(path, body):
    req = urllib.request.Request(
        BASE + path,
        data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"},
        method="POST",
    )
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read().decode())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read().decode())


def get_text(path):
    with urllib.request.urlopen(BASE + path, timeout=30) as r:
        return r.status, r.headers.get("Content-Type", ""), r.read().decode()


def metric_value(body, name):
    """Value of an exposition series; `name` may include its label set."""
    for line in body.splitlines():
        if line.startswith(name + " "):
            return float(line.rsplit(" ", 1)[1])
    return None


def check_metrics():
    status, content_type, body = get_text("/metrics")
    assert status == 200, status
    assert "text/plain; version=0.0.4" in content_type, content_type
    # every non-comment line is `series value` with a finite float value
    for line in body.splitlines():
        if not line or line.startswith("#"):
            continue
        series, value = line.rsplit(" ", 1)
        assert series.startswith("dqt_serve_"), f"foreign series: {line}"
        float(value)
    # the traffic above must have moved the decode counters
    assert metric_value(body, "dqt_serve_tokens_generated_total") > 0, body
    assert metric_value(body, "dqt_serve_decode_tokens_per_sec") > 0, body
    assert metric_value(body, "dqt_serve_ttft_seconds_count") > 0, body
    assert metric_value(body, "dqt_serve_request_seconds_count") > 0, body
    completed = metric_value(body, "dqt_serve_completed_total")
    assert completed == metric_value(body, "dqt_serve_requests_total") >= 3, body
    assert metric_value(body, 'dqt_serve_http_responses_total{code="200"}') >= 3, body
    assert metric_value(body, 'dqt_serve_http_responses_total{code="400"}') >= 1, body
    print(
        f"metrics ok: {completed:.0f} completed, "
        f"{metric_value(body, 'dqt_serve_decode_tokens_per_sec'):.1f} decode tok/s"
    )


def wait_healthy():
    for _ in range(100):
        try:
            status, body = get("/healthz")
            if status == 200 and body.get("status") == "ok":
                return body
        except OSError:
            pass
        time.sleep(0.2)
    raise SystemExit("server never became healthy")


def main():
    health = wait_healthy()
    assert health["packed_projections"] == health["n_projections"] > 0, (
        f"ternary serving must be decode-free: {health}"
    )
    # the numeric tier is attributed on /healthz; when the smoke matrix
    # pins DQT_PRECISION the server must be running the requested tier
    assert health.get("precision") in ("exact", "fast"), health
    want_precision = os.environ.get("DQT_PRECISION")
    if want_precision:
        assert health["precision"] == want_precision, (health, want_precision)
    print(f"healthz ok: {health}")

    # greedy: 200, nonzero tokens, deterministic
    body = {"prompt": "the cat sat", "max_new_tokens": 10}
    status, a = post("/v1/generate", body)
    assert status == 200, (status, a)
    assert a["gen_tokens"] > 0 and len(a["token_ids"]) == a["gen_tokens"], a
    assert a["finish_reason"] in ("eos", "length", "cache_full"), a
    status, b = post("/v1/generate", body)
    assert status == 200 and b["token_ids"] == a["token_ids"], (a, b)
    print(f"greedy ok: {a['gen_tokens']} tokens, finish={a['finish_reason']}")

    # seeded sampling: deterministic per seed, EOS within the sweep
    eos_seed = None
    for seed in range(48):
        req = {
            "prompt": "the cat",
            "max_new_tokens": 12,
            "temperature": 2.0,
            "seed": seed,
        }
        status, g = post("/v1/generate", req)
        assert status == 200 and g["gen_tokens"] > 0, (status, g)
        if g["finish_reason"] == "eos":
            eos_seed = seed
            status, g2 = post("/v1/generate", req)
            assert status == 200 and g2["token_ids"] == g["token_ids"], (g, g2)
            break
    assert eos_seed is not None, "no EOS termination across 48 sampled seeds"
    print(f"eos termination ok (seed {eos_seed})")

    # stats + error paths (threads + decode throughput attribute every
    # recorded number to a configuration — the kernel-layer contract)
    status, stats = get("/v1/stats")
    assert status == 200 and stats["completed"] >= 3, stats
    assert stats.get("threads", 0) >= 1, stats
    assert stats.get("precision") == health["precision"], (stats, health)
    assert stats.get("decode_tokens_per_sec", 0) > 0, stats
    status, err = post("/v1/generate", {"nope": 1})
    assert status == 400 and "error" in err, (status, err)
    try:
        status, _ = get("/nope")
    except urllib.error.HTTPError as e:
        status = e.code
    assert status == 404, status
    print(f"stats + error paths ok: {stats}")

    # /metrics last, so the scrape sees everything this script sent
    check_metrics()


if __name__ == "__main__":
    main()
