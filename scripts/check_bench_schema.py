#!/usr/bin/env python3
"""Fail when measured bench records drift from the checked-in BENCH_*.json
schemas: every tracked benchmark name must have been measured, no unknown
names may appear, and each record must carry the fields the perf-diff
tooling reads. CI runs this after the bench job so a renamed/dropped
benchmark (or a harness output change) fails the PR instead of silently
breaking the perf history.

Baselines checked:
  BENCH_quant_codecs.json  <- rust/results/bench/quant_codecs.json
  BENCH_serving.json       <- rust/results/bench/serving.json
  BENCH_kernels.json       <- rust/results/bench/kernels.json
  BENCH_data_pipeline.json <- rust/results/bench/data_pipeline.json
  BENCH_dist.json          <- rust/results/bench/dist.json
"""

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
# "threads" records the active kernel-pool width (config::effective_threads)
# so every perf number is attributable to a configuration
RECORD_FIELDS = {"group", "name", "iters", "mean_ns", "p50_ns", "p95_ns", "threads"}
BASELINE_KEYS = {"bench", "command", "metric", "tracked", "runs"}

BASELINES = [
    ("BENCH_quant_codecs.json", "rust/results/bench/quant_codecs.json"),
    ("BENCH_serving.json", "rust/results/bench/serving.json"),
    ("BENCH_kernels.json", "rust/results/bench/kernels.json"),
    ("BENCH_data_pipeline.json", "rust/results/bench/data_pipeline.json"),
    ("BENCH_dist.json", "rust/results/bench/dist.json"),
]


def check_one(baseline_name, measured_name):
    baseline_path = ROOT / baseline_name
    baseline = json.loads(baseline_path.read_text())
    measured_path = ROOT / measured_name
    if not measured_path.exists():
        return [
            f"no measured records at {measured_path} — "
            f"run `cargo bench --bench {baseline.get('bench')}` first"
        ]
    records = json.loads(measured_path.read_text())

    problems = []
    absent_keys = BASELINE_KEYS - baseline.keys()
    if absent_keys:
        problems.append(f"{baseline_name}: baseline lacks keys {sorted(absent_keys)}")

    tracked = set(baseline.get("tracked", []))
    measured = {r.get("name") for r in records}
    missing = tracked - measured
    extra = measured - tracked
    if missing:
        problems.append(
            f"{baseline_name}: tracked benchmarks not measured: {sorted(missing)}"
        )
    if extra:
        problems.append(
            f"{baseline_name}: measured benchmarks missing from 'tracked': "
            f"{sorted(extra)} (add them to {baseline_name} or rename back)"
        )
    for r in records:
        lacking = RECORD_FIELDS - r.keys()
        if lacking:
            problems.append(
                f"{baseline_name}: record {r.get('name')!r} lacks fields {sorted(lacking)}"
            )
    for run in baseline.get("runs", []):
        if "label" not in run or "results" not in run:
            problems.append(f"{baseline_name}: malformed baseline run entry: {run}")
    if not problems:
        print(
            f"{baseline_name}: schema OK — {len(measured)} measured == "
            f"{len(tracked)} tracked"
        )
    return problems


def main() -> int:
    problems = []
    for baseline_name, measured_name in BASELINES:
        problems.extend(check_one(baseline_name, measured_name))
    if problems:
        print("BENCH SCHEMA DRIFT:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
