#!/usr/bin/env python3
"""Fail when measured bench records drift from the checked-in
BENCH_quant_codecs.json schema: every tracked benchmark name must have
been measured, no unknown names may appear, and each record must carry
the fields the perf-diff tooling reads. CI runs this after the bench job
so a renamed/dropped benchmark (or a harness output change) fails the PR
instead of silently breaking the perf history.
"""

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
RECORD_FIELDS = {"group", "name", "iters", "mean_ns", "p50_ns", "p95_ns"}
BASELINE_KEYS = {"bench", "command", "metric", "tracked", "runs"}


def main() -> int:
    baseline_path = ROOT / "BENCH_quant_codecs.json"
    baseline = json.loads(baseline_path.read_text())
    measured_path = ROOT / "rust/results/bench/quant_codecs.json"
    if not measured_path.exists():
        print(
            f"no measured records at {measured_path} — "
            "run `cargo bench --bench quant_codecs` first",
            file=sys.stderr,
        )
        return 1
    records = json.loads(measured_path.read_text())

    problems = []
    absent_keys = BASELINE_KEYS - baseline.keys()
    if absent_keys:
        problems.append(f"baseline lacks keys {sorted(absent_keys)}")

    tracked = set(baseline.get("tracked", []))
    measured = {r.get("name") for r in records}
    missing = tracked - measured
    extra = measured - tracked
    if missing:
        problems.append(f"tracked benchmarks not measured: {sorted(missing)}")
    if extra:
        problems.append(
            f"measured benchmarks missing from 'tracked': {sorted(extra)} "
            "(add them to BENCH_quant_codecs.json or rename back)"
        )
    for r in records:
        lacking = RECORD_FIELDS - r.keys()
        if lacking:
            problems.append(f"record {r.get('name')!r} lacks fields {sorted(lacking)}")
    for run in baseline.get("runs", []):
        if "label" not in run or "results" not in run:
            problems.append(f"malformed baseline run entry: {run}")

    if problems:
        print("BENCH SCHEMA DRIFT:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"bench schema OK: {len(measured)} measured == {len(tracked)} tracked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
