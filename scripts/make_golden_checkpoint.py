#!/usr/bin/env python3
"""Regenerate rust/tests/golden/golden-ternary.dqt.

Reference implementation of the `.dqt` wire format as written by the seed
`train::checkpoint` code (see docs/CHECKPOINT_FORMAT.md), kept independent
of the Rust codec registry so the golden-file test pins the format against
an implementation that cannot drift with the crate.

The serialized state matches `golden_state()` in rust/tests/integration.rs;
every value is chosen to be bit-exact in f32 so the file is deterministic.
"""

import struct
from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "rust" / "tests" / "golden" / "golden-ternary.dqt"


def jnum(x):
    """Format a number the way the in-tree Rust JSON writer does: integral
    f64 values print as integers."""
    if float(x) == int(x) and abs(x) < 9.0e15:
        return str(int(x))
    return repr(float(x))


def jstr(s):
    return '"' + s.replace("\\", "\\\\").replace('"', '\\"') + '"'


def jentry(name, shape, codec, offset, nbytes, scale):
    scale_s = "null" if scale is None else jnum(scale)
    return (
        "{"
        + f'"name":{jstr(name)},"shape":[{",".join(str(d) for d in shape)}],'
        + f'"codec":{jstr(codec)},"offset":{offset},"bytes":{nbytes},"scale":{scale_s}'
        + "}"
    )


def f32s(vals):
    return struct.pack(f"<{len(vals)}f", *vals)


def ternary_pack(ks):
    """Seed ternary codec: 2-bit codes (00=0, 01=+1, 10=-1), 16 trits per
    little-endian u32."""
    words = [0] * ((len(ks) + 15) // 16)
    for i, k in enumerate(ks):
        code = {0: 0b00, 1: 0b01, -1: 0b10}[k]
        words[i // 16] |= code << ((i % 16) * 2)
    return b"".join(struct.pack("<I", w) for w in words)


def main():
    # --- the golden state (mirrors golden_state() in integration.rs) ---
    emb = [0.5, -0.25, 1.0, -1.0, 2.0, 0.125]
    w0_scale = 4.0
    w0_k = [1, -1, 0, 1, 0, -1, 1, 0]          # grid indices
    w0 = [k / w0_scale for k in w0_k]          # resident f32 values (k/s)
    norm = [1.0, 1.0, 1.0, 1.0]
    opt_step = [3.0]
    opt_m = [0.0625, -0.0625, 0.5, -0.5, 0.0, 1.0]

    payload = b""
    entries = []

    def push(name, shape, codec, blob, scale=None):
        nonlocal payload
        entries.append(jentry(name, shape, codec, len(payload), len(blob), scale))
        payload += blob

    params_start = len(entries)
    push("emb", [2, 3], "f32", f32s(emb))
    push("w0", [2, 4], "ternary_2bit", ternary_pack(w0_k), scale=w0_scale)
    push("w0.s", [], "f32", f32s([w0_scale]))
    push("norm", [4], "f32", f32s(norm))
    params = entries[params_start:]

    opt_start = len(entries)
    push("step", [], "f32", f32s(opt_step))
    push("m", [6], "f32", f32s(opt_m))
    opt = entries[opt_start:]

    header = (
        "{"
        + f'"magic":"DQT1","variant":"golden","step":{jnum(opt_step[0])},'
        + f'"params":[{",".join(params)}],'
        + f'"opt":[{",".join(opt)}],'
        + f'"payload_bytes":{len(payload)}'
        + "}"
    )

    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_bytes(header.encode() + b"\n" + payload)
    print(f"wrote {OUT} ({len(header) + 1 + len(payload)} bytes)")
    print(header)


if __name__ == "__main__":
    main()
