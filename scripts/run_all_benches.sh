#!/usr/bin/env bash
# One-shot baseline filler (docs/PERFORMANCE.md §Filling in baselines):
# build + test sanity gate, then every tracked bench suite at full
# iteration counts, with the measured records merged into the matching
# BENCH_*.json `runs` arrays — labeled with the git SHA and hostname so
# numbers stay attributable. Run on an otherwise idle machine with
# DQT_BENCH_FAST unset.
#
# Usage: scripts/run_all_benches.sh [label]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ -n "${DQT_BENCH_FAST+x}" ]; then
    echo "run_all_benches: unset DQT_BENCH_FAST first — its mere presence" >&2
    echo "(even =0) shrinks iteration counts to the CI validation budget." >&2
    exit 1
fi

label="${1:-$(git rev-parse --short HEAD 2>/dev/null || echo unlabeled)}"
host="$(hostname 2>/dev/null || echo unknown-host)"

echo "== sanity gate: build + tests must be green before recording =="
(cd rust && cargo build --release && cargo test -q)

echo "== quant_codecs (bench_codecs.sh appends its own record) =="
scripts/bench_codecs.sh "$label"

for suite in gemm serving dist data_pipeline; do
    echo "== $suite =="
    (cd rust && cargo bench --bench "$suite")
done

echo "== merging records into BENCH_*.json =="
python3 - "$label" "$host" <<'PY'
import json
import pathlib
import sys

label, host = sys.argv[1], sys.argv[2]
root = pathlib.Path(".")

# bench group output file -> repo-root baseline (bench_codecs.sh already
# handled quant_codecs above)
SUITES = {
    "kernels": "BENCH_kernels.json",
    "serving": "BENCH_serving.json",
    "dist": "BENCH_dist.json",
    "data_pipeline": "BENCH_data_pipeline.json",
}

for group, baseline_name in SUITES.items():
    measured_path = root / "rust/results/bench" / f"{group}.json"
    records = json.loads(measured_path.read_text())
    baseline_path = root / baseline_name
    baseline = json.loads(baseline_path.read_text())
    run = {
        "label": label,
        "host": host,
        "results": {
            r["name"]: {
                "mean_ns": r["mean_ns"],
                "p50_ns": r["p50_ns"],
                "p95_ns": r["p95_ns"],
                "threads": r["threads"],
            }
            for r in records
        },
    }
    baseline["runs"] = [
        r for r in baseline.get("runs", []) if r.get("label") != label
    ]
    baseline["runs"].append(run)
    baseline_path.write_text(json.dumps(baseline, indent=2) + "\n")
    print(f"{baseline_name}: recorded run '{label}' ({len(run['results'])} benchmarks)")
PY

echo "== validating against the tracked schemas =="
python3 scripts/check_bench_schema.py

echo "run_all_benches OK — review the BENCH_*.json diffs and commit them"
