#!/usr/bin/env python3
"""Fail on dead relative links in the markdown docs.

Scans README.md, docs/*.md and the other root-level markdown files for
`[text](target)` links, resolves each relative target against the file
that contains it, and errors if the target does not exist. External
links (http/https/mailto) and pure in-page anchors are skipped — this
guards the cross-reference graph between the in-repo documents (the
docs index, subsystem guides, and code paths they point at), which is
exactly what silently rots when files move.

CI runs this in the docs job; run locally with:
    python3 scripts/check_docs_links.py
"""

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

SCANNED = sorted(
    [ROOT / "README.md", *(ROOT / "docs").glob("*.md")]
)

# [text](target) — target up to the first unescaped ')'; tolerate titles
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(path):
    problems = []
    text = path.read_text()
    in_code_fence = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code_fence = not in_code_fence
            continue
        if in_code_fence:
            continue
        for m in LINK.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if ROOT not in resolved.parents and resolved != ROOT:
                problems.append(
                    f"{path.relative_to(ROOT)}:{lineno}: link escapes the repo: {target}"
                )
            elif not resolved.exists():
                problems.append(
                    f"{path.relative_to(ROOT)}:{lineno}: dead link: {target}"
                )
    return problems


def main() -> int:
    problems = []
    for path in SCANNED:
        problems.extend(check_file(path))
    if problems:
        print("DEAD DOC LINKS:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"docs links OK across {len(SCANNED)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
