#!/usr/bin/env bash
# Trace-smoke: record Chrome trace-event JSON from a real 20-step train
# run and from one generation served over HTTP, then validate both files
# against the span contract (scripts/check_trace.py, which enforces the
# docs/OBSERVABILITY.md §Tracing vocabulary and the >=90% per-phase
# coverage of train.step wall time). CI runs this as the required
# trace-smoke job.
#
# Usage: scripts/trace_smoke.sh [port]
set -euo pipefail
cd "$(dirname "$0")/.."

PORT="${1:-${DQT_SMOKE_PORT:-18474}}"
OUT="$(mktemp -d)"
SERVER_PID=""
cleanup() {
    [ -n "$SERVER_PID" ] && kill "$SERVER_PID" 2>/dev/null || true
    rm -rf "$OUT"
}
trap cleanup EXIT

(cd rust && cargo build --release)
BIN=rust/target/release/repro

echo "== 20-step train with --trace-out =="
"$BIN" train --model test --mode dqt --bits 1.58 --backend native \
       --dataset tiny --steps 20 --seed 42 --out "$OUT" \
       --trace-out "$OUT/train_trace.json"
python3 scripts/check_trace.py "$OUT/train_trace.json" --expect train --min-steps 20

echo "== serve + one generation with --trace-out =="
"$BIN" serve --model test --mode dqt --bits 1.58 --backend native \
       --dataset tiny --checkpoint "$OUT/model.dqt" \
       --addr "127.0.0.1:$PORT" --max-batch 4 \
       --trace-out "$OUT/serve_trace.json" &
SERVER_PID=$!

python3 - "http://127.0.0.1:$PORT" <<'PY'
import json, sys, time, urllib.error, urllib.request

base = sys.argv[1]

# wait for the server to come up
deadline = time.time() + 120
while True:
    try:
        with urllib.request.urlopen(base + "/healthz", timeout=5) as r:
            assert r.status == 200
            break
    except (urllib.error.URLError, ConnectionError, OSError):
        if time.time() > deadline:
            sys.exit("trace_smoke: server never became healthy")
        time.sleep(0.5)

# one generation through the scheduler path
req = urllib.request.Request(
    base + "/v1/generate",
    data=json.dumps({"prompt": "the", "max_new_tokens": 8}).encode(),
    headers={"Content-Type": "application/json"},
    method="POST",
)
with urllib.request.urlopen(req, timeout=120) as r:
    body = json.loads(r.read().decode())
    assert r.status == 200, body

# the finished request must surface a span summary in /v1/stats
with urllib.request.urlopen(base + "/v1/stats", timeout=30) as r:
    stats = json.loads(r.read().decode())
recent = stats.get("recent_requests")
assert isinstance(recent, list) and recent, f"no recent_requests: {stats}"
last = recent[-1]
for field in ("id", "ttft_ms", "decode_steps", "total_ms", "finish"):
    assert field in last, f"summary lacks {field!r}: {last}"
assert last["decode_steps"] >= 1, last
print(f"trace_smoke: /v1/stats summary OK: {last}")
PY

# the server flushes the trace at its next idle moment — poll for a file
# that passes validation
ok=""
for _ in $(seq 1 60); do
    if [ -s "$OUT/serve_trace.json" ] \
        && python3 scripts/check_trace.py "$OUT/serve_trace.json" --expect serve 2>/dev/null; then
        ok=1
        break
    fi
    sleep 0.5
done
if [ -z "$ok" ]; then
    echo "trace_smoke: serve trace never validated; last attempt said:" >&2
    python3 scripts/check_trace.py "$OUT/serve_trace.json" --expect serve || true
    exit 1
fi

echo "trace-smoke OK"
