"""L2 model tests: shapes, parameter contract, mode semantics, gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, quant
from compile.configs import ALL_CONFIGS, variant_from_flags
from compile.kernels import ref


def vc_of(mode, bits=1.58, **kw):
    return variant_from_flags("test", mode, bits=bits, **kw)


def toks(key, vc, extra=1):
    cfg = vc.model
    return jax.random.randint(
        jax.random.PRNGKey(key), (cfg.batch_size, cfg.max_seq_len + extra), 1,
        cfg.vocab_size,
    )


# ---------------------------------------------------------------------------
# parameter contract
# ---------------------------------------------------------------------------

def test_param_count_matches_formula():
    for name, cfg in ALL_CONFIGS.items():
        shapes = model.param_shapes(cfg)
        total = sum(int(np.prod(s)) for s in shapes.values())
        assert total == cfg.param_count(), name


def test_paper_config_param_counts_near_nominal():
    """Table 2 sanity: the paper-size configs land near 130M/320M/1B."""
    assert 1.0e8 < ALL_CONFIGS["p130m"].param_count() < 1.6e8
    assert 2.6e8 < ALL_CONFIGS["p320m"].param_count() < 4.0e8
    assert 0.8e9 < ALL_CONFIGS["p1b"].param_count() < 1.4e9


def test_flat_param_names_scale_companions():
    vc = vc_of("dqt", 8)
    names = model.flat_param_names(vc)
    qset = set(model.quantized_param_names(vc.model))
    for q in qset:
        assert q in names and q + ".s" in names
        assert names.index(q + ".s") == names.index(q) + 1
    # bitnet/fp32 carry no scale entries
    for mode in ("fp32", "bitnet158"):
        assert not any(
            n.endswith(".s") for n in model.flat_param_names(vc_of(mode))
        )


def test_init_params_grid_property():
    """DQT init: every quantized weight is on its grid; scales positive."""
    for bits in (1.58, 3.0, 8.0):
        vc = vc_of("dqt", bits)
        params = model.init_params(vc, jax.random.PRNGKey(0))
        for q in model.quantized_param_names(vc.model):
            s = float(params[q + ".s"])
            assert s > 0
            k = np.asarray(params[q]) * s
            assert np.all(np.abs(k - np.round(k)) < 1e-3), (q, bits)
            qn, qp = ref.qrange(bits)
            assert k.min() >= qn - 1e-3 and k.max() <= qp + 1e-3


def test_init_params_deterministic_in_seed():
    vc = vc_of("dqt", 1.58)
    a = model.init_params(vc, jax.random.PRNGKey(7))
    b = model.init_params(vc, jax.random.PRNGKey(7))
    c = model.init_params(vc, jax.random.PRNGKey(8))
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert any((np.asarray(a[k]) != np.asarray(c[k])).any() for k in a)


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,bits", [("fp32", 1.58), ("bitnet158", 1.58),
                                       ("dqt", 1.58), ("dqt", 8.0),
                                       ("dqt_ternary_inf", 8.0)])
def test_forward_shapes_and_finite(mode, bits):
    vc = vc_of(mode, bits)
    params = model.init_params(vc, jax.random.PRNGKey(0))
    t = toks(1, vc, extra=0)
    logits = model.forward(params, t, vc, use_pallas=False)
    cfg = vc.model
    assert logits.shape == (cfg.batch_size, cfg.max_seq_len, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_pallas_matches_ref_path():
    """The Pallas kernel path and the pure-jnp path agree."""
    for mode, bits in [("dqt", 1.58), ("dqt", 8.0), ("bitnet158", 1.58)]:
        vc = vc_of(mode, bits)
        params = model.init_params(vc, jax.random.PRNGKey(2))
        t = toks(3, vc, extra=0)
        lp = model.forward(params, t, vc, use_pallas=True)
        lr_ = model.forward(params, t, vc, use_pallas=False)
        np.testing.assert_allclose(lp, lr_, rtol=1e-4, atol=1e-4)


def test_causality():
    """Changing future tokens must not change past logits."""
    vc = vc_of("dqt", 8.0)
    params = model.init_params(vc, jax.random.PRNGKey(4))
    t = np.asarray(toks(5, vc, extra=0))
    t2 = t.copy()
    t2[:, -1] = (t2[:, -1] % (vc.model.vocab_size - 1)) + 1
    l1 = model.forward(params, jnp.asarray(t), vc, use_pallas=False)
    l2 = model.forward(params, jnp.asarray(t2), vc, use_pallas=False)
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], atol=1e-5)


def test_ternary_override_uses_ternary_weights():
    """Under ternary_override the effective weights take ≤3 distinct values
    per matrix — checked indirectly: override of a 1.58-bit model is a
    no-op (already ternary grid), while it changes an 8-bit model."""
    vc8 = vc_of("dqt", 8.0)
    params = model.init_params(vc8, jax.random.PRNGKey(6))
    t = toks(7, vc8, extra=0)
    base = model.forward(params, t, vc8, use_pallas=False)
    tern = model.forward(params, t, vc8, use_pallas=False, ternary_override=True)
    assert float(jnp.max(jnp.abs(base - tern))) > 1e-4

    for q in model.quantized_param_names(vc8.model):
        w3, s3 = quant.ternary_project(params[q])
        assert len(np.unique(np.asarray(w3))) <= 3


def test_loss_fn_masks_padding():
    vc = vc_of("fp32")
    params = model.init_params(vc, jax.random.PRNGKey(8))
    t = np.asarray(toks(9, vc))
    t_padded = t.copy()
    t_padded[:, -4:] = model.PAD_ID
    l_full = model.loss_fn(params, jnp.asarray(t), vc, use_pallas=False)
    l_pad = model.loss_fn(params, jnp.asarray(t_padded), vc, use_pallas=False)
    assert np.isfinite(float(l_full)) and np.isfinite(float(l_pad))
    assert abs(float(l_full) - float(l_pad)) > 0  # different masks => different means


def test_nll_sums_consistent_with_loss():
    vc = vc_of("fp32")
    params = model.init_params(vc, jax.random.PRNGKey(10))
    t = toks(11, vc)
    loss = model.loss_fn(params, t, vc, use_pallas=False)
    sum_nll, count = model.nll_sums(params, t, vc, use_pallas=False)
    np.testing.assert_allclose(float(sum_nll) / float(count), float(loss), rtol=1e-5)


# ---------------------------------------------------------------------------
# gradients / STE semantics
# ---------------------------------------------------------------------------

def test_dqt_grad_flows_to_grid_weights():
    vc = vc_of("dqt", 1.58)
    params = model.init_params(vc, jax.random.PRNGKey(12))
    t = toks(13, vc)
    g = jax.grad(lambda p: model.loss_fn(p, t, vc, use_pallas=False))(params)
    q0 = model.quantized_param_names(vc.model)[0]
    assert float(jnp.max(jnp.abs(g[q0]))) > 0
    # scales are frozen: grad is identically zero
    assert float(jnp.max(jnp.abs(g[q0 + ".s"]))) == 0.0


def test_bitnet_ste_grad_matches_dense_path_direction():
    """STE: grad w.r.t. master ≈ grad of the loss w.r.t. the quantized
    weight (identity backward through quantization)."""
    vc = vc_of("bitnet158")
    params = model.init_params(vc, jax.random.PRNGKey(14))
    t = toks(15, vc)
    g = jax.grad(lambda p: model.loss_fn(p, t, vc, use_pallas=False))(params)
    q0 = model.quantized_param_names(vc.model)[0]
    assert bool(jnp.all(jnp.isfinite(g[q0])))
    assert float(jnp.max(jnp.abs(g[q0]))) > 0


def test_rope_rotation_preserves_norm():
    cos, sin = model.rope_tables(8, 16, 10000.0)
    x = jax.random.normal(jax.random.PRNGKey(16), (2, 4, 8, 16))
    y = model.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
    )
