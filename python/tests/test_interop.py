"""Cross-language interop: the Python (kernel) and Rust (host) PRNG twins
must produce identical streams so checkpoint-time SR reproduces in-graph SR.

Golden values are pinned here AND in rust/src/quant/sr.rs +
rust/tests/properties.rs; regenerating: `python -m tests.test_interop`.
"""

import jax.numpy as jnp
import numpy as np

from compile.kernels import prng

# (counter, seed) -> hash_u32 — mirrored in rust/src/quant/sr.rs tests
GOLDEN_HASH = [
    (0, 0, 0),
    (1, 2, 3024231355),
    (12345, 67890, 2856791855),
    (4294967295, 1, 3893119930),
]


def test_hash_golden_values():
    for c, s, want in GOLDEN_HASH:
        got = int(prng.hash_u32(jnp.uint32(c), jnp.uint32(s)))
        assert got == want, (c, s, got, want)


def test_uniform_golden_values():
    # uniform01 = top 24 bits / 2^24, exactly
    for c, s, h in GOLDEN_HASH:
        want = (h >> 8) / (1 << 24)
        got = float(prng.uniform01(jnp.uint32(c), jnp.uint32(s)))
        np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_hash_vectorized_matches_scalar():
    ctr = prng.counter_grid((4, 8), 100)
    out = np.asarray(prng.hash_u32(ctr, jnp.uint32(7)))
    for i in range(4):
        for j in range(8):
            scalar = int(prng.hash_u32(jnp.uint32(100 + i * 8 + j), jnp.uint32(7)))
            assert out[i, j] == scalar


if __name__ == "__main__":
    for c, s, _ in GOLDEN_HASH:
        print(c, s, int(prng.hash_u32(jnp.uint32(c), jnp.uint32(s))))
