"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

The CORE correctness signal of the repo: if these pass, the HLO artifacts
compute the paper's equations. hypothesis sweeps shapes/dtypes; statistical
tests validate the SR stream's unbiasedness.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    absmean_quantize,
    adamw_sr_update,
    qlinear,
    rmsnorm,
    stochastic_round,
    stochastic_round_hash_ref,
)
from compile.kernels import prng
from compile.kernels import ref

BITS = [1.58, 3.0, 4.0, 8.0]

dims = st.integers(min_value=1, max_value=96)


def rand(key, shape, scale=0.05):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# absmean quantization (Eq. 2-4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
def test_absmean_matches_ref(bits):
    w = rand(0, (64, 48))
    s = ref.absmean_scale(w, bits)
    np.testing.assert_allclose(
        absmean_quantize(w, bits, s), ref.absmean_quantize_ref(w, bits, s), rtol=1e-6
    )


@pytest.mark.parametrize("bits", BITS)
def test_absmean_on_grid(bits):
    w = rand(1, (32, 32), scale=0.2)
    s = ref.absmean_scale(w, bits)
    wq = absmean_quantize(w, bits, s)
    k = np.asarray(wq) * float(s)
    qn, qp = ref.qrange(bits)
    assert np.all(np.abs(k - np.round(k)) < 1e-4), "values must be integers/s"
    assert k.min() >= qn - 1e-4 and k.max() <= qp + 1e-4


@settings(max_examples=25, deadline=None)
@given(rows=dims, cols=dims, bits=st.sampled_from(BITS))
def test_absmean_matches_ref_hypothesis(rows, cols, bits):
    w = rand(rows * 1000 + cols, (rows, cols), scale=0.1)
    s = ref.absmean_scale(w, bits)
    np.testing.assert_allclose(
        absmean_quantize(w, bits, s),
        ref.absmean_quantize_ref(w, bits, s),
        rtol=1e-6,
        atol=1e-7,
    )


def test_absmean_1d_and_3d_shapes():
    for shape in [(48,), (4, 8, 16)]:
        w = rand(7, shape, scale=0.1)
        s = ref.absmean_scale(w, 8.0)
        np.testing.assert_allclose(
            absmean_quantize(w, 8.0, s), ref.absmean_quantize_ref(w, 8.0, s), rtol=1e-6
        )


# ---------------------------------------------------------------------------
# stochastic rounding (Eq. 1 / 5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
def test_sr_kernel_matches_hash_ref_exactly(bits):
    x = rand(2, (64, 48))
    s = ref.absmean_scale(x, bits)
    for seed in (0, 1, 999):
        out_k = stochastic_round(x, jnp.uint32(seed), bits, s)
        out_r = stochastic_round_hash_ref(x, jnp.uint32(seed), bits, s)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("bits", BITS)
def test_sr_support_is_floor_or_ceil(bits):
    x = rand(3, (40, 30), scale=0.3)
    s = ref.absmean_scale(x, bits)
    out = np.asarray(stochastic_round(x, jnp.uint32(5), bits, s)) * float(s)
    y = np.asarray(x) * float(s)
    qn, qp = ref.qrange(bits)
    lo = np.clip(np.floor(y), qn, qp)
    hi = np.clip(np.ceil(y), qn, qp)
    assert np.all((np.abs(out - lo) < 1e-3) | (np.abs(out - hi) < 1e-3))


def test_sr_unbiased_statistically():
    """E[SR(x)] == x for in-range x: the property that makes DQT train."""
    s = jnp.float32(1.0)
    x = jnp.full((100, 100), 0.37)
    samples = [
        float(jnp.mean(stochastic_round(x, jnp.uint32(i), 8.0, s)))
        for i in range(20)
    ]
    mean = np.mean(samples)
    # 200k Bernoulli(0.37) draws → se ≈ 0.48/sqrt(200000) ≈ 0.0011
    assert abs(mean - 0.37) < 0.005, mean


def test_sr_deterministic_per_seed_and_distinct_across_seeds():
    x = rand(4, (32, 32), scale=0.4)
    s = jnp.float32(10.0)
    a = np.asarray(stochastic_round(x, jnp.uint32(7), 8.0, s))
    b = np.asarray(stochastic_round(x, jnp.uint32(7), 8.0, s))
    c = np.asarray(stochastic_round(x, jnp.uint32(8), 8.0, s))
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()


def test_sr_exact_grid_points_stay_fixed():
    """Values already on the grid must not move (frac == 0)."""
    s = jnp.float32(4.0)
    x = jnp.array([[-0.5, 0.0, 0.25, 1.0]])  # *4 => integers -2,0,1,4
    out = np.asarray(stochastic_round(x, jnp.uint32(3), 8.0, s))
    np.testing.assert_allclose(out, np.asarray(x), atol=1e-7)


def test_sr_clips_out_of_range():
    s = jnp.float32(1.0)
    x = jnp.array([[5.0, -5.0]])
    out = np.asarray(stochastic_round(x, jnp.uint32(0), 1.58, s))
    np.testing.assert_allclose(out, [[1.0, -1.0]])


@settings(max_examples=25, deadline=None)
@given(rows=dims, cols=dims, seed=st.integers(0, 2**32 - 1))
def test_sr_matches_hash_ref_hypothesis(rows, cols, seed):
    x = rand(rows + cols, (rows, cols), scale=0.2)
    s = jnp.float32(7.3)
    out_k = stochastic_round(x, jnp.uint32(seed), 4.0, s)
    out_r = stochastic_round_hash_ref(x, jnp.uint32(seed), 4.0, s)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# ---------------------------------------------------------------------------
# counter-hash PRNG quality
# ---------------------------------------------------------------------------

def test_prng_uniform_range_and_moments():
    u = np.asarray(prng.uniform01(prng.counter_grid((1000, 100), 0), jnp.uint32(3)))
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.var() - 1 / 12) < 0.01


def test_prng_seed_sensitivity():
    c = prng.counter_grid((64, 64), 0)
    a = np.asarray(prng.hash_u32(c, jnp.uint32(1)))
    b = np.asarray(prng.hash_u32(c, jnp.uint32(2)))
    assert (a != b).mean() > 0.99


# ---------------------------------------------------------------------------
# activation quantization
# ---------------------------------------------------------------------------

def test_act_quantize_per_row_absmax():
    x = jnp.array([[1.0, -2.0, 0.5], [100.0, 1.0, 0.0]])
    xq = np.asarray(ref.act_quantize_ref(x, 8))
    # max element of each row must be preserved exactly
    np.testing.assert_allclose(xq[0, 1], -2.0, rtol=1e-6)
    np.testing.assert_allclose(xq[1, 0], 100.0, rtol=1e-6)
    # all values on a 127-point grid scaled per row
    scale0 = 127.0 / 2.0
    k = xq[0] * scale0
    np.testing.assert_allclose(k, np.round(k), atol=1e-3)


def test_act_quantize_error_bound():
    x = rand(11, (32, 64), scale=1.0)
    xq = ref.act_quantize_ref(x, 8)
    amax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    assert np.all(np.abs(np.asarray(xq - x)) <= amax / 127.0 * 0.5 + 1e-6)


# ---------------------------------------------------------------------------
# qlinear (fused act-quant + matmul)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
)
def test_qlinear_matches_ref_hypothesis(m, k, n):
    x = rand(m * 7 + k, (m, k), scale=0.5)
    w = rand(n * 13 + k, (n, k), scale=0.05)
    s = ref.absmean_scale(w, 1.58)
    wq = ref.absmean_quantize_ref(w, 1.58, s)
    np.testing.assert_allclose(
        qlinear(x, wq, 8), ref.qlinear_ref(x, wq, 8), rtol=1e-5, atol=1e-5
    )


def test_qlinear_batched_leading_dims():
    x = rand(21, (2, 8, 32), scale=0.5)
    w = rand(22, (16, 32), scale=0.05)
    np.testing.assert_allclose(
        qlinear(x, w, 8), ref.qlinear_ref(x, w, 8), rtol=1e-5, atol=1e-5
    )


def test_qlinear_grad_matches_ste_formula():
    """Custom VJP: dx = dy @ wq (STE), dwq = dy.T @ xq."""
    x = rand(31, (8, 16), scale=0.5)
    w = rand(32, (12, 16), scale=0.05)
    dy = rand(33, (8, 12), scale=1.0)

    _, vjp = jax.vjp(lambda x_, w_: qlinear(x_, w_, 8), x, w)
    dx, dw = vjp(dy)
    np.testing.assert_allclose(dx, dy @ w, rtol=1e-5, atol=1e-6)
    xq = ref.act_quantize_ref(x, 8)
    np.testing.assert_allclose(dw, dy.T @ xq, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 64), h=st.integers(1, 96))
def test_rmsnorm_matches_ref_hypothesis(rows, h):
    x = rand(rows * 3 + h, (rows, h), scale=1.0)
    g = 1.0 + rand(rows + h, (h,), scale=0.2)
    np.testing.assert_allclose(
        rmsnorm(x, g), ref.rmsnorm_ref(x, g), rtol=1e-5, atol=1e-6
    )


def test_rmsnorm_grad_matches_autodiff_of_ref():
    x = rand(41, (6, 24), scale=1.0)
    g = 1.0 + rand(42, (24,), scale=0.2)

    def f_kernel(x_, g_):
        return jnp.sum(jnp.sin(rmsnorm(x_, g_)))

    def f_ref(x_, g_):
        return jnp.sum(jnp.sin(ref.rmsnorm_ref(x_, g_)))

    gx_k, gg_k = jax.grad(f_kernel, argnums=(0, 1))(x, g)
    gx_r, gg_r = jax.grad(f_ref, argnums=(0, 1))(x, g)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gg_k, gg_r, rtol=1e-4, atol=1e-5)


def test_rmsnorm_3d():
    x = rand(43, (2, 5, 16))
    g = jnp.ones((16,))
    np.testing.assert_allclose(
        rmsnorm(x, g), ref.rmsnorm_ref(x, g), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# fused AdamW + SR
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
def test_adamw_sr_moments_match_ref(bits):
    """m/v outputs are deterministic — must match the reference exactly."""
    w0 = rand(50, (32, 24), scale=0.05)
    s = ref.absmean_scale(w0, bits)
    w = ref.absmean_quantize_ref(w0, bits, s)
    g = rand(51, (32, 24), scale=0.01)
    m = rand(52, (32, 24), scale=0.001)
    v = jnp.abs(rand(53, (32, 24), scale=0.001))
    wk, mk, vk = adamw_sr_update(
        w, g, m, v, seed=jnp.uint32(9), lr=jnp.float32(1e-3),
        step=jnp.float32(3), bits=bits, s=s,
    )
    _, mr, vr = ref.adamw_sr_update_ref(
        w, g, m, v, jax.random.PRNGKey(0), lr=jnp.float32(1e-3),
        step=jnp.float32(3), bits=bits, s=s,
    )
    np.testing.assert_allclose(mk, mr, rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(vk, vr, rtol=1e-5, atol=1e-8)
    # weights stay on grid
    k = np.asarray(wk) * float(s)
    assert np.all(np.abs(k - np.round(k)) < 1e-3)


def test_adamw_sr_matches_composed_kernels():
    """Fused kernel == dense AdamW + standalone SR kernel, same seed."""
    w0 = rand(60, (16, 48), scale=0.05)
    s = ref.absmean_scale(w0, 1.58)
    w = ref.absmean_quantize_ref(w0, 1.58, s)
    g = rand(61, (16, 48), scale=0.01)
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    lr, step, seed = jnp.float32(1e-3), jnp.float32(1), jnp.uint32(77)

    wk, mk, vk = adamw_sr_update(
        w, g, m, v, seed=seed, lr=lr, step=step, bits=1.58, s=s
    )
    # compose manually
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    mhat = m2 / (1 - b1**step)
    vhat = v2 / (1 - b2**step)
    w_dense = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * w)
    w_sr = stochastic_round(w_dense, seed, 1.58, s)
    np.testing.assert_allclose(wk, w_sr, atol=1e-7)
    np.testing.assert_allclose(mk, m2, rtol=1e-6)
    np.testing.assert_allclose(vk, v2, rtol=1e-6)


def test_adamw_sr_accumulates_small_updates():
    """The paper's core claim (§5.1): repeated sub-grid updates eventually
    move a weight under SR, but never under round-to-nearest."""
    s = jnp.float32(1.0)
    w = jnp.zeros((64, 64))
    g = jnp.full_like(w, 1.0)  # constant pull
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    moved_sr = 0.0
    for i in range(30):
        w2, m, v = adamw_sr_update(
            w, g, m, v, seed=jnp.uint32(i), lr=jnp.float32(0.02),
            step=jnp.float32(i + 1), bits=1.58, s=s, weight_decay=0.0,
        )
        w = w2
    moved_sr = float(jnp.mean(jnp.abs(w) > 0))
    assert moved_sr > 0.25, f"SR should have moved many weights, got {moved_sr}"

    # same trajectory with round-to-nearest: lr*update ≈ 0.02 < 0.5 ⇒ frozen
    w_rn = jnp.zeros((64, 64))
    m = jnp.zeros_like(w_rn)
    v = jnp.zeros_like(w_rn)
    for i in range(30):
        m = 0.9 * m + 0.1 * g
        v = 0.95 * v + 0.05 * g * g
        mhat = m / (1 - 0.9 ** (i + 1))
        vhat = v / (1 - 0.95 ** (i + 1))
        w_dense = w_rn - 0.02 * mhat / (jnp.sqrt(vhat) + 1e-8)
        w_rn = ref.round_nearest_ref(w_dense, 1.58, s)
    assert float(jnp.mean(jnp.abs(w_rn) > 0)) == 0.0
