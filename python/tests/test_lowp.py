"""Numeric-format tests: bf16/fp8 casts (value-level codecs, paper §4.3).

Golden vectors here are mirrored in rust/src/quant/{bf16,fp8}.rs tests so
the two implementations stay bit-compatible.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import lowp


def test_bf16_roundtrip_values():
    x = jnp.array([0.0, 1.0, -1.0, 0.5, 3.140625, 65504.0])
    y = np.asarray(lowp.cast_bf16(x))
    # bf16-representable values survive exactly
    np.testing.assert_array_equal(y[:5], np.asarray(x[:5]))


def test_bf16_rounds_mantissa():
    # 1 + 2^-9 is not representable in bf16 (7 mantissa bits) → rounds to 1
    x = jnp.array([1.0 + 2.0**-9])
    np.testing.assert_array_equal(np.asarray(lowp.cast_bf16(x)), [1.0])


# --- FP8 E4M3 golden vectors (OCP spec) ------------------------------------

E4M3_EXACT = [0.0, 1.0, -1.0, 0.5, 448.0, -448.0, 2.0**-6, 2.0**-9, 1.75, 240.0]
E4M3_ROUNDED = [
    (1.0 + 2.0**-4, 1.0),        # below half ULP at binade [1,2): ULP=1/8
    (449.0, 448.0),              # saturate
    (1e9, 448.0),
    (-1e9, -448.0),
    (0.0626, 0.0625),            # near 2^-4
]


@pytest.mark.parametrize("v", E4M3_EXACT)
def test_e4m3_exact_values(v):
    np.testing.assert_array_equal(
        np.asarray(lowp.cast_fp8_e4m3(jnp.array([v]))), [v]
    )


@pytest.mark.parametrize("x,want", E4M3_ROUNDED)
def test_e4m3_rounding(x, want):
    np.testing.assert_allclose(
        np.asarray(lowp.cast_fp8_e4m3(jnp.array([x]))), [want], rtol=1e-6
    )


def test_e4m3_subnormals():
    # subnormal grid step is 2^-9
    step = 2.0**-9
    xs = jnp.array([step, 2.5 * step, 0.4 * step])
    y = np.asarray(lowp.cast_fp8_e4m3(xs))
    np.testing.assert_allclose(y[0], step, rtol=1e-6)
    assert y[1] in (2 * step, 3 * step)  # round-half-even boundary
    assert y[2] in (0.0, step)


@settings(max_examples=50, deadline=None)
@given(st.floats(min_value=-400, max_value=400, allow_nan=False))
def test_e4m3_idempotent_and_close(v):
    x = jnp.array([v], jnp.float32)
    y = lowp.cast_fp8_e4m3(x)
    y2 = lowp.cast_fp8_e4m3(y)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))
    # relative error ≤ 2^-4 for normals (+ absolute floor for subnormals)
    err = abs(float(y[0]) - v)
    assert err <= max(abs(v) * 2.0**-3.5, 2.0**-10)


def test_e5m2_range():
    x = jnp.array([57344.0, 60000.0, 2.0**-14, 2.0**-16])
    y = np.asarray(lowp.cast_fp8_e5m2(x))
    np.testing.assert_allclose(y[0], 57344.0)
    np.testing.assert_allclose(y[1], 57344.0)  # saturate
    np.testing.assert_allclose(y[2], 2.0**-14, rtol=1e-6)
    np.testing.assert_allclose(y[3], 2.0**-16, rtol=1e-6)


def test_env_cast_dispatch():
    x = jnp.array([1.0 + 2.0**-12])
    np.testing.assert_array_equal(np.asarray(lowp.env_cast(x, "fp32")), np.asarray(x))
    assert float(lowp.env_cast(x, "bf16")[0]) == 1.0
    assert float(lowp.env_cast(x, "fp8")[0]) == 1.0
    with pytest.raises(ValueError):
        lowp.env_cast(x, "int4")


def test_env_state_cast_fp8_uses_wider_format():
    # v values can exceed E4M3's 448 — E5M2 must carry them
    x = jnp.array([1000.0])
    assert float(lowp.env_state_cast(x, "fp8")[0]) == 1024.0  # e5m2 rounding
    assert float(lowp.env_cast(x, "fp8")[0]) == 448.0  # e4m3 saturates
