"""Optimizer tests: update semantics per mode, env casts, interventions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import lowp, model, optim
from compile.configs import variant_from_flags
from compile.kernels import ref


def setup(mode, bits=1.58, **kw):
    vc = variant_from_flags("test", mode, bits=bits, **kw)
    params = model.init_params(vc, jax.random.PRNGKey(0))
    opt = optim.init_opt_state(vc)
    shapes = model.param_shapes(vc.model)
    grads = {
        n: 0.01 * jax.random.normal(jax.random.PRNGKey(i + 1), shapes[n])
        for i, n in enumerate(model.param_names(vc.model))
    }
    return vc, params, opt, grads


LR = jnp.float32(1e-3)
SEED = jnp.uint32(99)


def test_opt_state_names_adamw_vs_adafactor():
    vc_a, *_ = setup("dqt")
    names_a = optim.opt_state_names(vc_a)
    assert names_a[0] == "step"
    assert any(n.endswith(".m") for n in names_a)

    vc_f, *_ = setup("dqt", optimizer="adafactor")
    names_f = optim.opt_state_names(vc_f)
    assert any(n.endswith(".vr") for n in names_f)
    assert not any(n.endswith(".m") for n in names_f)
    # adafactor state is much smaller than adamw state
    sh_a = optim.opt_state_shapes(vc_a)
    sh_f = optim.opt_state_shapes(vc_f)
    size = lambda sh: sum(int(np.prod(s)) for s in sh.values())
    assert size(sh_f) < size(sh_a) / 10


def test_dqt_update_stays_on_grid():
    for bits in (1.58, 3.0, 8.0):
        vc, params, opt, grads = setup("dqt", bits)
        new_p, new_o, aux = optim.apply_updates(params, grads, opt, vc, LR, SEED)
        for q in model.quantized_param_names(vc.model):
            s = float(new_p[q + ".s"])
            k = np.asarray(new_p[q]) * s
            assert np.all(np.abs(k - np.round(k)) < 1e-3), (q, bits)
        assert float(new_o["step"]) == 1.0
        assert 0.0 <= float(aux["upd_frac"]) <= 1.0


def test_dqt_absmax_zeros_are_absorbing():
    """Fig. 5 mechanism: max-based RTN re-quantization. A zero trit needs a
    single-step update ≥ half the max |W'| to flip — impossible at normal
    LRs — so under absmax the zero set can only grow (no accumulation
    path), while SR flips zeros with probability ∝ the update."""
    vc, params, opt, grads = setup("dqt_absmax", 1.58)
    p, o = params, opt
    zero_masks = {}
    for q in model.quantized_param_names(vc.model):
        zero_masks[q] = np.asarray(p[q]) == 0
    for i in range(3):
        p, o, _ = optim.apply_updates(p, grads, o, vc, LR, jnp.uint32(i))
        for q, was_zero in zero_masks.items():
            now = np.asarray(p[q])
            assert np.all(now[was_zero] == 0), f"{q}: a zero flipped under RTN"
            zero_masks[q] = now == 0

    # contrast: SR *does* revive zeros over a few steps
    vc_sr, p_sr, o_sr, _ = setup("dqt", 1.58)
    q0 = model.quantized_param_names(vc_sr.model)[0]
    was_zero = np.asarray(p_sr[q0]) == 0
    revived = 0
    for i in range(3):
        p_sr, o_sr, _ = optim.apply_updates(p_sr, grads, o_sr, vc_sr, LR, jnp.uint32(i))
        revived += int((np.asarray(p_sr[q0])[was_zero] != 0).sum())
    assert revived > 0, "SR never flipped a zero — not accumulating"


def test_dqt_sr_moves_some_weights_even_with_small_updates():
    vc, params, opt, grads = setup("dqt", 1.58)
    moved = 0
    p, o = params, opt
    for i in range(5):
        p, o, aux = optim.apply_updates(p, grads, o, vc, LR, jnp.uint32(i))
        moved += float(aux["upd_frac"])
    assert moved > 0.0


def test_fused_path_equals_generic_path_distributionally():
    """Fused pallas AdamW+SR and generic jnp AdamW+SR use the same seed
    stream ⇒ identical outputs."""
    vc, params, opt, grads = setup("dqt", 1.58)
    new_p1, new_o1, _ = optim.apply_updates(params, grads, opt, vc, LR, SEED)

    # force the generic path by toggling the intervention flag off/on trick:
    # use dqt_absmax config but run SR manually — instead compare through
    # a second call (determinism check of the fused path)
    new_p2, new_o2, _ = optim.apply_updates(params, grads, opt, vc, LR, SEED)
    for k in new_p1:
        np.testing.assert_array_equal(np.asarray(new_p1[k]), np.asarray(new_p2[k]))
    for k in new_o1:
        np.testing.assert_array_equal(np.asarray(new_o1[k]), np.asarray(new_o2[k]))


def test_bitnet_master_stays_dense_fp32():
    vc, params, opt, grads = setup("bitnet158")
    new_p, _, aux = optim.apply_updates(params, grads, opt, vc, LR, SEED)
    q0 = model.quantized_param_names(vc.model)[0]
    w = np.asarray(new_p[q0])
    # master is dense: many distinct values, not on a 3-point grid
    assert len(np.unique(w)) > 10


def test_env_bf16_casts_opt_state():
    vc, params, opt, grads = setup("dqt", 8.0, env="bf16")
    _, new_o, _ = optim.apply_updates(params, grads, opt, vc, LR, SEED)
    for k, v in new_o.items():
        if k == "step":
            continue
        x = np.asarray(v)
        np.testing.assert_array_equal(
            x, np.asarray(lowp.cast_bf16(jnp.asarray(x)))
        )


def test_env_fp8_bitnet_master_absorbs_small_updates():
    """The Fig. 3 degradation mechanism, in isolation."""
    vc, params, opt, grads = setup("bitnet158", env="fp8")
    # Adam updates are O(lr) regardless of grad scale, so shrink lr: a
    # 1e-6-scale dense update on 0.02-scale weights is far below half an
    # E4M3 ULP (~1e-3 at that binade) → the master does not move at all
    new_p, _, _ = optim.apply_updates(params, grads, opt, vc, jnp.float32(1e-6), SEED)
    q0 = model.quantized_param_names(vc.model)[0]
    before = lowp.cast_fp8_e4m3(params[q0])
    np.testing.assert_array_equal(np.asarray(new_p[q0]), np.asarray(before))


def test_dqt_fp8_env_still_accumulates():
    """DQT's SR keeps accumulating under fp8 env (the paper's robustness)."""
    vc, params, opt, grads = setup("dqt", 8.0, env="fp8")
    p, o = params, opt
    moved = 0.0
    for i in range(5):
        p, o, aux = optim.apply_updates(p, o_grads_like(grads), o, vc, LR, jnp.uint32(i))
        moved += float(aux["upd_frac"])
    assert moved > 0.0


def o_grads_like(grads):
    return grads


def test_interventions_change_outcome():
    vc_n, params, opt, grads = setup("dqt", 1.58)
    vc_r, *_ = setup("dqt", 1.58, intervention="force_remain")
    vc_u, *_ = setup("dqt", 1.58, intervention="force_update")
    pn, _, auxn = optim.apply_updates(params, grads, opt, vc_n, LR, SEED)
    pr, _, auxr = optim.apply_updates(params, grads, opt, vc_r, LR, SEED)
    pu, _, auxu = optim.apply_updates(params, grads, opt, vc_u, LR, SEED)
    # force_update must flip at least as many weights as plain SR;
    # force_remain at most as many
    assert float(auxu["upd_frac"]) >= float(auxn["upd_frac"])
    assert float(auxr["upd_frac"]) <= float(auxn["upd_frac"])
    # all grid-valued
    for pp, vcx in ((pr, vc_r), (pu, vc_u)):
        for q in model.quantized_param_names(vcx.model):
            k = np.asarray(pp[q]) * float(pp[q + ".s"])
            assert np.all(np.abs(k - np.round(k)) < 1e-3)


def test_recompute_scale_updates_scale():
    vc, params, opt, grads = setup("dqt", 1.58, recompute_scale=True)
    grads = {k: g * 10 for k, g in grads.items()}  # move absmean noticeably
    new_p, _, _ = optim.apply_updates(params, grads, opt, vc, LR, SEED)
    q0 = model.quantized_param_names(vc.model)[0]
    assert float(new_p[q0 + ".s"]) != float(params[q0 + ".s"])


def test_adafactor_dense_update_reasonable():
    vc, params, opt, grads = setup("fp32", optimizer="adafactor")
    new_p, new_o, _ = optim.apply_updates(params, grads, opt, vc, LR, SEED)
    # all params moved, no NaN
    for k in model.param_names(vc.model):
        assert bool(jnp.all(jnp.isfinite(new_p[k]))), k
    assert float(new_o["step"]) == 1.0


def test_grad_clipping():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, gnorm = optim.clip_global_norm(g, 1.0)
    assert float(gnorm) > 100.0
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-4
    )
    # small grads untouched
    g2 = {"a": jnp.ones((4,)) * 0.01}
    clipped2, _ = optim.clip_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), np.asarray(g2["a"]))
