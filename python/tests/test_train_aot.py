"""Train-step and AOT manifest tests: the L2↔L3 contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, optim
from compile.aot import lower_variant, to_hlo_text
from compile.configs import variant_from_flags
from compile.train import make_fns


def build(mode, bits=1.58, **kw):
    vc = variant_from_flags("test", mode, bits=bits, **kw)
    return vc, make_fns(vc, use_pallas=False)  # jnp path: faster for tests


def run_steps(vc, fns, n=3, lr=1e-3, seed0=0):
    n_p, n_o = len(fns["param_names"]), len(fns["opt_names"])
    state = jax.jit(fns["init"])(jnp.uint32(42))
    cfg = vc.model
    tok = jax.random.randint(
        jax.random.PRNGKey(1), (cfg.batch_size, cfg.max_seq_len + 1), 1,
        cfg.vocab_size,
    )
    step = jax.jit(fns["train_step"])
    losses = []
    for i in range(n):
        out = step(*state, tok, jnp.uint32(seed0 + i), jnp.float32(lr))
        state = out[: n_p + n_o]
        losses.append(float(out[n_p + n_o]))
    return state, losses, out


@pytest.mark.parametrize(
    "mode,bits",
    [("fp32", 1.58), ("bitnet158", 1.58), ("dqt", 1.58), ("dqt", 8.0),
     ("dqt_absmax", 1.58), ("dqt_ternary_inf", 8.0)],
)
def test_train_step_decreases_loss(mode, bits):
    vc, fns = build(mode, bits)
    _, losses, _ = run_steps(vc, fns, n=4)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


def test_train_step_deterministic():
    vc, fns = build("dqt", 1.58)
    s1, l1, _ = run_steps(vc, fns, n=3)
    s2, l2, _ = run_steps(vc, fns, n=3)
    assert l1 == l2
    for a, b in zip(s1, s2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_step_sr_seed_changes_trajectory():
    vc, fns = build("dqt", 1.58)
    _, l1, _ = run_steps(vc, fns, n=3, seed0=0)
    _, l2, _ = run_steps(vc, fns, n=3, seed0=1000)
    assert l1 != l2  # different SR draws → different quantized trajectories


def test_grid_invariant_preserved_across_steps():
    vc, fns = build("dqt", 1.58)
    state, _, _ = run_steps(vc, fns, n=3)
    pnames = fns["param_names"]
    params = dict(zip(pnames, state[: len(pnames)]))
    for q in model.quantized_param_names(vc.model):
        s = float(params[q + ".s"])
        k = np.asarray(params[q]) * s
        assert np.all(np.abs(k - np.round(k)) < 1e-3)


def test_eval_and_logits_steps():
    vc, fns = build("dqt", 8.0)
    state, _, _ = run_steps(vc, fns, n=1)
    n_p = len(fns["param_names"])
    cfg = vc.model
    tok = jax.random.randint(
        jax.random.PRNGKey(2), (cfg.batch_size, cfg.max_seq_len + 1), 1,
        cfg.vocab_size,
    )
    s, c = jax.jit(fns["eval_step"])(*state[:n_p], tok)
    assert float(c) == cfg.batch_size * cfg.max_seq_len
    assert np.isfinite(float(s)) and float(s) > 0

    tok2 = tok[:, :-1]
    (logits,) = jax.jit(fns["logits_step"])(*state[:n_p], tok2)
    assert logits.shape == (cfg.batch_size, cfg.max_seq_len, cfg.vocab_size)

    # ternary-inference eval differs from plain eval on an 8-bit model
    s3, _ = jax.jit(fns["eval_step_ternary"])(*state[:n_p], tok)
    assert float(s3) != float(s)


def test_upd_frac_ordering_ternary_vs_8bit():
    """Fig. 6's core qualitative claim at unit scale: 8-bit DQT flips far
    more weights per step than ternary DQT (finer grid ⇒ closer levels)."""
    fr = {}
    for bits in (1.58, 8.0):
        vc, fns = build("dqt", bits)
        _, _, out = run_steps(vc, fns, n=3, lr=5e-4)
        n_state = len(fns["param_names"]) + len(fns["opt_names"])
        fr[bits] = float(out[n_state + 1])
    assert fr[8.0] > fr[1.58]


# ---------------------------------------------------------------------------
# AOT manifest contract
# ---------------------------------------------------------------------------

def test_lower_variant_writes_manifest(tmp_path):
    vc = variant_from_flags("test", "dqt", bits=8.0)
    manifest = lower_variant(vc, str(tmp_path), use_pallas=False, verbose=False)
    vdir = tmp_path / vc.variant_name
    for e in ("init", "train_step", "eval_step", "logits_step",
              "eval_step_ternary", "logits_step_ternary"):
        assert (vdir / f"{e}.hlo.txt").stat().st_size > 1000
    with open(vdir / "manifest.json") as f:
        m = json.load(f)
    assert m == manifest
    # flat order round-trips
    assert [p["name"] for p in m["params"]] == model.flat_param_names(vc)
    assert [o["name"] for o in m["opt_state"]] == optim.opt_state_names(vc)
    assert m["train_step_outputs"]["metrics"] == ["loss", "upd_frac", "gnorm"]
    # HLO text is parseable-looking (has ENTRY and the right param count)
    text = (vdir / "train_step.hlo.txt").read_text()
    assert "ENTRY" in text
    # nested fusion computations also declare parameters — count only the
    # ENTRY computation's (ENTRY is the last block in jax's HLO dump)
    entry = text[text.rindex("ENTRY") :]
    n_inputs = len(m["params"]) + len(m["opt_state"]) + 3
    assert entry.count("parameter(") == n_inputs


def test_hlo_text_format_compatibility():
    """HLO text must be the 0.5.1-compatible flavor: module + ENTRY and no
    serialized-proto artifacts."""
    vc = variant_from_flags("test", "fp32")
    fns = make_fns(vc, use_pallas=False)
    lowered = jax.jit(fns["init"]).lower(jnp.zeros((), jnp.uint32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule") or "HloModule" in text.split("\n")[0]
    assert "ENTRY" in text
