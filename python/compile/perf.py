"""§Perf harness for L1/L2: block-shape sweep + HLO cost analysis.

Times the jitted t130 DQT train step under different Pallas block shapes
and compares against the pure-jnp (no-pallas) lowering, and reports XLA
cost-analysis FLOPs/bytes for the lowered module. CPU wallclock under
interpret=True is *not* a TPU proxy — the block sweep is about structure
(HLO op count, slicing overhead) and the VMEM/MXU estimates are analytic.

Usage (from python/):
  DQT_QLINEAR_BLOCK_M=2048 python -m compile.perf --model t130 --reps 5
"""

from __future__ import annotations

import argparse
import importlib
import os
import time

import jax
import jax.numpy as jnp


def bench_step(model: str, mode: str, bits: float, use_pallas: bool, reps: int):
    # (re)import after env vars are set so block constants pick them up
    import compile.kernels.qlinear as qlinear
    import compile.kernels.quantize as quantize
    importlib.reload(qlinear)
    importlib.reload(quantize)
    from compile.configs import variant_from_flags
    from compile.train import make_fns

    vc = variant_from_flags(model, mode, bits=bits)
    fns = make_fns(vc, use_pallas=use_pallas)
    n_state = len(fns["param_names"]) + len(fns["opt_names"])
    state = jax.jit(fns["init"])(jnp.uint32(0))
    cfg = vc.model
    tok = jax.random.randint(
        jax.random.PRNGKey(0), (cfg.batch_size, cfg.max_seq_len + 1), 1,
        cfg.vocab_size,
    )
    step = jax.jit(fns["train_step"], keep_unused=True)
    # warmup/compile
    out = step(*state, tok, jnp.uint32(0), jnp.float32(1e-3))
    jax.block_until_ready(out)
    times = []
    for i in range(reps):
        t0 = time.perf_counter()
        out = step(*out[:n_state], tok, jnp.uint32(i), jnp.float32(1e-3))
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def cost_analysis(model: str, mode: str, bits: float, use_pallas: bool):
    from compile.configs import variant_from_flags
    from compile.train import make_fns

    vc = variant_from_flags(model, mode, bits=bits)
    fns = make_fns(vc, use_pallas=use_pallas)
    pm = [jnp.zeros(s.shape, s.dtype) for s in jax.eval_shape(
        lambda: fns["init"](jnp.uint32(0)))]
    cfg = vc.model
    tok = jnp.zeros((cfg.batch_size, cfg.max_seq_len + 1), jnp.int32)
    compiled = (
        jax.jit(fns["train_step"], keep_unused=True)
        .lower(*pm, tok, jnp.uint32(0), jnp.float32(1e-3))
        .compile()
    )
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {k: ca.get(k) for k in ("flops", "bytes accessed", "transcendentals")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="t130")
    ap.add_argument("--mode", default="dqt")
    ap.add_argument("--bits", type=float, default=1.58)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--cost", action="store_true")
    ap.add_argument("--no-pallas", action="store_true")
    args = ap.parse_args()

    bm = os.environ.get("DQT_QLINEAR_BLOCK_M", "128")
    br = os.environ.get("DQT_ELEMWISE_BLOCK_ROWS", "256")
    t = bench_step(args.model, args.mode, args.bits, not args.no_pallas, args.reps)
    tag = "jnp-ref" if args.no_pallas else f"pallas bm={bm} rows={br}"
    print(f"{args.model}-{args.mode}-b{args.bits:g} [{tag}]: {t*1e3:.1f} ms/step")
    if args.cost:
        print(cost_analysis(args.model, args.mode, args.bits, not args.no_pallas))


if __name__ == "__main__":
    main()
