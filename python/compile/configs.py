"""Model-size presets and variant descriptors for the DQT reproduction.

The paper (Table 2) trains 130M / 320M / 1B LLaMA-structured models. Those
exact configs are kept here (used by the Rust memory model and available for
AOT if you have the compute); the default presets are scaled-down versions
("t130" / "t320" / "t1b") that preserve the *relative* scaling so the
paper's size-trend experiments (Fig. 2) run on a CPU PJRT testbed.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """LLaMA-structured transformer configuration (paper Table 2 schema)."""

    name: str
    vocab_size: int
    hidden_size: int
    intermediate_size: int
    num_hidden_layers: int
    num_attention_heads: int
    max_seq_len: int
    batch_size: int
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.hidden_size % self.num_attention_heads == 0
        return self.hidden_size // self.num_attention_heads

    def param_count(self) -> int:
        """Exact parameter count for this config (used by the memory model)."""
        v, h, i, l = (
            self.vocab_size,
            self.hidden_size,
            self.intermediate_size,
            self.num_hidden_layers,
        )
        emb = v * h
        per_layer = (
            4 * h * h  # q, k, v, o projections
            + 3 * h * i  # gate, up, down
            + 2 * h  # two RMSNorm scales
        )
        final_norm = h
        head = 0 if self.tie_embeddings else v * h
        return emb + l * per_layer + final_norm + head

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["head_dim"] = self.head_dim
        d["param_count"] = self.param_count()
        return d


# ---------------------------------------------------------------------------
# Paper-exact configs (Table 2). vocab 32k ~ the released BitNet tokenizer.
# ---------------------------------------------------------------------------
PAPER_CONFIGS = {
    "p130m": ModelConfig(
        name="p130m",
        vocab_size=32000,
        hidden_size=768,
        intermediate_size=2048,
        num_hidden_layers=12,
        num_attention_heads=12,
        max_seq_len=512,
        batch_size=64,
    ),
    "p320m": ModelConfig(
        name="p320m",
        vocab_size=32000,
        hidden_size=1024,
        intermediate_size=2048,
        num_hidden_layers=24,
        num_attention_heads=16,
        max_seq_len=512,
        batch_size=32,
    ),
    "p1b": ModelConfig(
        name="p1b",
        vocab_size=32000,
        hidden_size=2048,
        intermediate_size=3072,
        num_hidden_layers=24,
        num_attention_heads=32,
        max_seq_len=512,
        batch_size=16,
    ),
}

# ---------------------------------------------------------------------------
# Scaled testbed configs: same depth/width *ratios* as the paper triple, sized
# for CPU PJRT. vocab matches the Rust BPE tokenizer (data/tokenizer.rs).
# ---------------------------------------------------------------------------
TESTBED_CONFIGS = {
    # ~1.0M params
    "t130": ModelConfig(
        name="t130",
        vocab_size=512,
        hidden_size=96,
        intermediate_size=256,
        num_hidden_layers=6,
        num_attention_heads=6,
        max_seq_len=128,
        batch_size=16,
    ),
    # ~2.8M params
    "t320": ModelConfig(
        name="t320",
        vocab_size=512,
        hidden_size=128,
        intermediate_size=256,
        num_hidden_layers=12,
        num_attention_heads=8,
        max_seq_len=128,
        batch_size=8,
    ),
    # ~9.8M params
    "t1b": ModelConfig(
        name="t1b",
        vocab_size=512,
        hidden_size=256,
        intermediate_size=384,
        num_hidden_layers=12,
        num_attention_heads=8,
        max_seq_len=128,
        batch_size=4,
    ),
    # micro config used by pytest only
    "test": ModelConfig(
        name="test",
        vocab_size=64,
        hidden_size=32,
        intermediate_size=64,
        num_hidden_layers=2,
        num_attention_heads=2,
        max_seq_len=16,
        batch_size=2,
    ),
}

ALL_CONFIGS = {**PAPER_CONFIGS, **TESTBED_CONFIGS}


# ---------------------------------------------------------------------------
# Quantization / training-variant descriptor
# ---------------------------------------------------------------------------

#: weight-handling modes (paper §3, §4, §5)
MODES = (
    "fp32",  # unquantized LLaMA baseline
    "bitnet158",  # BitNet b1.58: FP32 master + STE, absmean ternary forward
    "dqt",  # ours: grid-only weights + stochastic rounding (bits below)
    "dqt_absmax",  # Fig. 5 ablation: requantize W' with round-to-nearest
    "dqt_ternary_inf",  # §A.2: 8-bit DQT trained for ternary inference (STE)
)

#: precision environments (paper §4.3). These simulate reduced-memory
#: training by casting optimizer state and transient dense updates.
ENVS = ("fp32", "bf16", "fp8")

OPTIMIZERS = ("adamw", "adafactor")

#: Fig. 7 interventions on the bottom-20% smallest weight updates
INTERVENTIONS = ("none", "force_remain", "force_update")


@dataclasses.dataclass(frozen=True)
class VariantConfig:
    """Full descriptor of one trainable variant == one artifact directory."""

    model: ModelConfig
    mode: str = "dqt"
    bits: float = 1.58  # 1.58 => ternary {-1,0,1}; else integer n in [2,8]
    env: str = "fp32"
    optimizer: str = "adamw"
    intervention: str = "none"
    intervention_frac: float = 0.2
    act_bits: int = 8  # activation quantization (BitNet setting)
    recompute_scale: bool = False  # abl1: recompute grid scale each step
    sr_seed_salt: int = 0
    weight_decay: float = 0.01
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    grad_clip: float = 1.0

    def __post_init__(self):
        assert self.mode in MODES, self.mode
        assert self.env in ENVS, self.env
        assert self.optimizer in OPTIMIZERS, self.optimizer
        assert self.intervention in INTERVENTIONS, self.intervention
        if self.mode in ("dqt", "dqt_absmax"):
            assert self.bits == 1.58 or (
                float(self.bits).is_integer() and 2 <= self.bits <= 8
            ), f"bits={self.bits}"

    @property
    def quantized(self) -> bool:
        return self.mode != "fp32"

    @property
    def bits_tag(self) -> str:
        if self.mode in ("fp32",):
            return "fp32"
        if self.mode == "bitnet158":
            return "1.58"
        return f"{self.bits:g}"

    @property
    def variant_name(self) -> str:
        """Stable directory name under artifacts/."""
        parts = [self.model.name, self.mode]
        if self.mode.startswith("dqt"):
            parts.append(f"b{self.bits:g}".replace(".", "p"))
        if self.env != "fp32":
            parts.append(self.env)
        if self.optimizer != "adamw":
            parts.append(self.optimizer)
        if self.intervention != "none":
            parts.append(self.intervention)
        if self.recompute_scale:
            parts.append("rescale")
        return "-".join(parts)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["model"] = self.model.to_json()
        d["variant_name"] = self.variant_name
        return d


def variant_from_flags(
    model: str,
    mode: str,
    bits: float = 1.58,
    env: str = "fp32",
    optimizer: str = "adamw",
    intervention: str = "none",
    recompute_scale: bool = False,
) -> VariantConfig:
    return VariantConfig(
        model=ALL_CONFIGS[model],
        mode=mode,
        bits=bits,
        env=env,
        optimizer=optimizer,
        intervention=intervention,
        recompute_scale=recompute_scale,
    )


if __name__ == "__main__":
    for name, cfg in ALL_CONFIGS.items():
        print(f"{name}: {cfg.param_count():,} params")
