"""Weight-handling modes: fp32 / BitNet-STE / DQT forward+update semantics.

This module is the L2 glue between the model (which just asks for "a linear
layer under mode M") and the L1 kernels. The three families:

  fp32        y = x @ W.T, plain AdamW — the unquantized baseline.
  bitnet158   BitNet b1.58: FP32 master W; forward re-quantizes W to ternary
              via AbsMean (Eq. 2-4) *every step* with an STE; activations
              int8-absmax with STE. Optimizer updates the master.
  dqt*        ours: W lives ON the INTn grid (values k/s, s fixed at init).
              Forward consumes it directly (no per-step re-quantization);
              the optimizer's dense update is stochastically rounded back
              onto the grid (Eq. 5) — no master copy exists.

`dqt_ternary_inf` (§A.2) stores the 8-bit grid but *forwards* through a
ternary AbsMean re-projection with an STE back to the 8-bit grid, enabling
ternary-weight deployment of an 8-bit-trained DQT model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import qlinear, rmsnorm
from .kernels import ref as kref


def ste(value: jnp.ndarray, quantized: jnp.ndarray) -> jnp.ndarray:
    """Straight-through estimator: forward `quantized`, grad to `value`."""
    return value + jax.lax.stop_gradient(quantized - value)


def act_quant_ste(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """BitNet's 8-bit per-token activation quantization with STE."""
    return ste(x, kref.act_quantize_ref(x, bits))


def linear_fp32(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain dense linear; x: [..., K], w: [N, K]."""
    return x @ w.T


def linear_bitnet(
    x: jnp.ndarray, w: jnp.ndarray, act_bits: int, use_pallas: bool
) -> jnp.ndarray:
    """BitNet b1.58 forward: re-quantize master to ternary each step (STE)."""
    s = kref.absmean_scale(jax.lax.stop_gradient(w), 1.58)
    wq = ste(w, kref.absmean_quantize_ref(w, 1.58, s))
    if use_pallas:
        return qlinear(x, wq, act_bits)
    return kref.qlinear_ref(x, wq, act_bits)


def linear_dqt(
    x: jnp.ndarray, wq: jnp.ndarray, act_bits: int, use_pallas: bool
) -> jnp.ndarray:
    """DQT forward: the weight is already on the grid — just use it."""
    if use_pallas:
        return qlinear(x, wq, act_bits)
    return kref.qlinear_ref(x, wq, act_bits)


def linear_dqt_ternary_inf(
    x: jnp.ndarray, w8: jnp.ndarray, act_bits: int, use_pallas: bool
) -> jnp.ndarray:
    """§A.2: forward through a ternary re-projection of the 8-bit grid
    weight, STE back to the 8-bit weight for the backward pass."""
    s3 = kref.absmean_scale(jax.lax.stop_gradient(w8), 1.58)
    w3 = ste(w8, kref.absmean_quantize_ref(w8, 1.58, s3))
    if use_pallas:
        return qlinear(x, w3, act_bits)
    return kref.qlinear_ref(x, w3, act_bits)


def quant_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    mode: str,
    act_bits: int = 8,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Dispatch a linear layer under weight-handling mode ``mode``."""
    if mode == "fp32":
        return linear_fp32(x, w)
    if mode == "bitnet158":
        return linear_bitnet(x, w, act_bits, use_pallas)
    if mode in ("dqt", "dqt_absmax"):
        return linear_dqt(x, w, act_bits, use_pallas)
    if mode == "dqt_ternary_inf":
        return linear_dqt_ternary_inf(x, w, act_bits, use_pallas)
    raise ValueError(f"unknown mode {mode!r}")


def norm(x: jnp.ndarray, g: jnp.ndarray, eps: float, use_pallas: bool):
    if use_pallas:
        return rmsnorm(x, g, eps)
    return kref.rmsnorm_ref(x, g, eps)


def init_grid_weight(w_dense: jnp.ndarray, bits: float):
    """Project a freshly initialized dense weight onto its INTn grid.

    Returns (w_on_grid, scale). The scale is *fixed* for the rest of
    training (paper §3.2 skips Eq. 2-4 after initialization).
    """
    s = kref.absmean_scale(w_dense, bits)
    return kref.absmean_quantize_ref(w_dense, bits, s), s


def ternary_project(w: jnp.ndarray):
    """Deployment-time ternary projection of an n-bit grid weight (§A.2)."""
    s3 = kref.absmean_scale(w, 1.58)
    return kref.absmean_quantize_ref(w, 1.58, s3), s3
