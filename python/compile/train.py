"""Step-function builders: the four AOT entry points per variant.

Each returns a *flat-signature* jittable function (lists of arrays in the
manifest's order) so the Rust runtime can drive it positionally:

  init(seed u32)                        → params…  ++ opt_state…
  train_step(params…, opt…, tokens, sr_seed, lr)
                                        → params'… ++ opt'… ++ [loss, upd_frac, gnorm]
  eval_step(params…, tokens)            → [sum_nll, count]
  logits_step(params…, tokens)          → [logits]

Params/opt buffers are donatable: Rust keeps them device-resident and feeds
each step's outputs into the next step's inputs (see rust/src/train/).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model, optim
from .configs import VariantConfig


def flatten(d: dict[str, jnp.ndarray], names: list[str]) -> list[jnp.ndarray]:
    return [d[n] for n in names]


def unflatten(vals, names: list[str]) -> dict[str, jnp.ndarray]:
    return dict(zip(names, vals))


def make_fns(vc: VariantConfig, use_pallas: bool = True):
    """Build the four entry points for variant ``vc``.

    Returns dict with keys init/train_step/eval_step/logits_step plus the
    flat name orders (param_names/opt_names) and example args for lowering.
    """
    pnames = model.flat_param_names(vc)
    onames = optim.opt_state_names(vc)
    n_p, n_o = len(pnames), len(onames)
    cfg = vc.model
    tshape = (cfg.batch_size, cfg.max_seq_len + 1)
    ternary_inf = vc.mode == "dqt_ternary_inf"

    def init(seed: jnp.ndarray):
        key = jax.random.PRNGKey(seed.astype(jnp.uint32))
        params = model.init_params(vc, key)
        opt = optim.init_opt_state(vc)
        return flatten(params, pnames) + flatten(opt, onames)

    def train_step(*args):
        params = unflatten(args[:n_p], pnames)
        opt = unflatten(args[n_p : n_p + n_o], onames)
        tokens, sr_seed, lr = args[n_p + n_o :]

        def loss_of(p):
            return model.loss_fn(p, tokens, vc, use_pallas)

        loss, grads = jax.value_and_grad(loss_of)(params)
        # `.s` scales are frozen grid metadata, not trainable
        grads = {k: g for k, g in grads.items() if not k.endswith(".s")}
        new_params, new_opt, aux = optim.apply_updates(
            params, grads, opt, vc, lr, sr_seed
        )
        return (
            flatten(new_params, pnames)
            + flatten(new_opt, onames)
            + [loss, aux["upd_frac"], aux["gnorm"]]
        )

    def eval_step(*args):
        params = unflatten(args[:n_p], pnames)
        tokens = args[n_p]
        s, c = model.nll_sums(
            params, tokens, vc, use_pallas, ternary_override=ternary_inf
        )
        return [s, c]

    def logits_step(*args):
        params = unflatten(args[:n_p], pnames)
        tokens = args[n_p]
        return [
            model.forward(
                params, tokens, vc, use_pallas, ternary_override=ternary_inf
            )
        ]

    # eval variant that forces ternary projection regardless of mode
    # (Table 1 "ternary Inf." rows for a plain dqt-8bit model)
    def eval_step_ternary(*args):
        params = unflatten(args[:n_p], pnames)
        tokens = args[n_p]
        s, c = model.nll_sums(params, tokens, vc, use_pallas, ternary_override=True)
        return [s, c]

    def logits_step_ternary(*args):
        params = unflatten(args[:n_p], pnames)
        tokens = args[n_p]
        return [model.forward(params, tokens, vc, use_pallas, ternary_override=True)]

    example = {
        "seed": jnp.zeros((), jnp.uint32),
        "tokens": jnp.zeros(tshape, jnp.int32),
        "eval_tokens": jnp.zeros(tshape, jnp.int32),
        "logits_tokens": jnp.zeros((cfg.batch_size, cfg.max_seq_len), jnp.int32),
        "sr_seed": jnp.zeros((), jnp.uint32),
        "lr": jnp.zeros((), jnp.float32),
    }
    return {
        "init": init,
        "train_step": train_step,
        "eval_step": eval_step,
        "logits_step": logits_step,
        "eval_step_ternary": eval_step_ternary,
        "logits_step_ternary": logits_step_ternary,
        "param_names": pnames,
        "opt_names": onames,
        "example": example,
    }
