"""Low-precision *environment* simulation (paper §4.3, Fig. 3, Table 3).

The paper simulates training under FP32 / BF16 / FP8 (MS-AMP O2) memory
environments. The mechanism that matters for the DQT-vs-BitNet contrast:

  * BitNet keeps a high-precision **master copy**; in a BF16/FP8 environment
    that master is *stored* in the low-precision format, so every optimizer
    step's small update is round-to-nearest-absorbed — the update signal
    below half a ULP vanishes. This is why BitNet degrades in Fig. 3.
  * DQT has no master; its optimizer states are stored low-precision, but
    the weight update goes through *stochastic rounding*, which is unbiased
    at any storage precision — updates accumulate in expectation.

These casts are value-level fake-quantizers in f32, bit-exact with the
corresponding formats (validated against the Rust `quant/{bf16,fp8}.rs`
codecs through golden vectors in the test suites).
"""

from __future__ import annotations

import jax.numpy as jnp

# FP8 E4M3 (OCP FP8, "MS-AMP weights/grads" format): 4 exp bits (bias 7),
# 3 mantissa bits, max normal 448, min normal 2^-6, subnormal step 2^-9.
_E4M3_MAX = 448.0
_E4M3_MIN_NORMAL = 2.0**-6
_E4M3_SUB_STEP = 2.0**-9

# FP8 E5M2: 5 exp bits (bias 15), 2 mantissa bits, max 57344.
_E5M2_MAX = 57344.0
_E5M2_MIN_NORMAL = 2.0**-14
_E5M2_SUB_STEP = 2.0**-16


def cast_bf16(x: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest-even BF16 storage, returned as f32 values."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def _cast_fp8(x, mant_bits: int, max_val: float, min_normal: float, sub_step: float):
    ax = jnp.abs(x)
    # exponent of the enclosing binade, clamped into the normal range
    e = jnp.floor(jnp.log2(jnp.maximum(ax, min_normal)))
    ulp_normal = jnp.exp2(e - mant_bits)
    ulp = jnp.where(ax < min_normal, sub_step, ulp_normal)
    q = jnp.round(ax / ulp) * ulp
    q = jnp.minimum(q, max_val)  # saturate (MS-AMP saturating cast)
    return jnp.where(x < 0, -q, q).astype(jnp.float32)


def cast_fp8_e4m3(x: jnp.ndarray) -> jnp.ndarray:
    """Saturating round-to-nearest FP8 E4M3 storage, as f32 values."""
    return _cast_fp8(x, 3, _E4M3_MAX, _E4M3_MIN_NORMAL, _E4M3_SUB_STEP)


def cast_fp8_e5m2(x: jnp.ndarray) -> jnp.ndarray:
    """Saturating round-to-nearest FP8 E5M2 storage, as f32 values."""
    return _cast_fp8(x, 2, _E5M2_MAX, _E5M2_MIN_NORMAL, _E5M2_SUB_STEP)


def env_cast(x: jnp.ndarray, env: str) -> jnp.ndarray:
    """Apply the storage-precision cast of environment ``env``."""
    if env == "fp32":
        return x
    if env == "bf16":
        return cast_bf16(x)
    if env == "fp8":
        return cast_fp8_e4m3(x)
    raise ValueError(f"unknown env {env!r}")


def env_state_cast(x: jnp.ndarray, env: str) -> jnp.ndarray:
    """Cast for *optimizer second-moment* state.

    E4M3's 448 max overflows Adam's v for large grads; MS-AMP keeps v in a
    wider format, so in the fp8 env we store v as E5M2 (range over precision)
    and m as E4M3 (precision over range) — the standard MS-AMP O2 split.
    """
    if env == "fp8":
        return cast_fp8_e5m2(x)
    return env_cast(x, env)
