"""AOT pipeline: lower every variant's step functions to HLO text + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage (from python/):
  python -m compile.aot --suite core --out-dir ../artifacts
  python -m compile.aot --model t130 --mode dqt --bits 8 --out-dir ../artifacts

Each variant directory gets:
  init.hlo.txt  train_step.hlo.txt  eval_step.hlo.txt  logits_step.hlo.txt
  [eval_step_ternary.hlo.txt logits_step_ternary.hlo.txt]  manifest.json
An index.json at the artifacts root lists all built variants.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import ALL_CONFIGS, VariantConfig, variant_from_flags
from . import model, optim
from .train import make_fns

jax.config.update("jax_platforms", "cpu")


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args) -> list[dict]:
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args]


def lower_variant(
    vc: VariantConfig, out_dir: str, use_pallas: bool = True, verbose: bool = True
) -> dict:
    """Lower all entry points for one variant; write HLO text + manifest."""
    fns = make_fns(vc, use_pallas=use_pallas)
    pnames, onames = fns["param_names"], fns["opt_names"]
    ex = fns["example"]
    cfg = vc.model

    pshapes = model.param_shapes(cfg)
    oshapes = optim.opt_state_shapes(vc)
    qset = set(model.quantized_param_names(cfg)) if vc.quantized else set()

    vdir = os.path.join(out_dir, vc.variant_name)
    os.makedirs(vdir, exist_ok=True)

    params_meta = []
    for n in pnames:
        if n.endswith(".s"):
            params_meta.append(
                {"name": n, "shape": [], "dtype": "float32", "role": "scale"}
            )
        else:
            params_meta.append(
                {
                    "name": n,
                    "shape": list(pshapes[n]),
                    "dtype": "float32",
                    "role": "grid"
                    if (n in qset and model.has_grid_weights(vc))
                    else "dense",
                }
            )
    opt_meta = [
        {"name": n, "shape": list(oshapes[n]), "dtype": "float32"} for n in onames
    ]

    # example flat args for lowering
    p_ex = [jnp.zeros(tuple(m["shape"]), jnp.float32) for m in params_meta]
    o_ex = [jnp.zeros(tuple(m["shape"]), jnp.float32) for m in opt_meta]
    n_state = len(p_ex) + len(o_ex)

    entries = {}

    def lower(name, fn, args, donate=()):
        t0 = time.time()
        # keep_unused=True: the Rust runtime feeds every manifest buffer
        # positionally — jit's default pruning of unused args (e.g. the `.s`
        # scales in eval_step, or sr_seed in the fp32 train_step) would
        # silently change the calling convention.
        lowered = jax.jit(fn, donate_argnums=donate, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(vdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries[name] = {"inputs": _sig(args)}
        if verbose:
            print(
                f"  [{vc.variant_name}] {name}: {len(text)/1e6:.1f} MB HLO "
                f"({time.time()-t0:.1f}s)",
                flush=True,
            )

    lower("init", fns["init"], [ex["seed"]])
    # donate params+opt buffers: XLA aliases them input→output so the step
    # updates in place on the device
    donate = tuple(range(n_state))
    lower(
        "train_step",
        fns["train_step"],
        p_ex + o_ex + [ex["tokens"], ex["sr_seed"], ex["lr"]],
        donate=donate,
    )
    lower("eval_step", fns["eval_step"], p_ex + [ex["eval_tokens"]])
    lower("logits_step", fns["logits_step"], p_ex + [ex["logits_tokens"]])
    if vc.mode == "dqt" and vc.bits != 1.58:
        # Table 1 "ternary Inf." rows: deploy-time ternary projection
        lower(
            "eval_step_ternary", fns["eval_step_ternary"], p_ex + [ex["eval_tokens"]]
        )
        lower(
            "logits_step_ternary",
            fns["logits_step_ternary"],
            p_ex + [ex["logits_tokens"]],
        )

    manifest = {
        "variant": vc.to_json(),
        "params": params_meta,
        "opt_state": opt_meta,
        "tokens_shape": [cfg.batch_size, cfg.max_seq_len + 1],
        "logits_tokens_shape": [cfg.batch_size, cfg.max_seq_len],
        "pad_id": model.PAD_ID,
        "train_step_outputs": {
            "n_params": len(pnames),
            "n_opt": len(onames),
            "metrics": ["loss", "upd_frac", "gnorm"],
        },
        "entries": sorted(entries.keys()),
        "use_pallas": use_pallas,
    }
    with open(os.path.join(vdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


# ---------------------------------------------------------------------------
# Suites
# ---------------------------------------------------------------------------

def suite_variants(name: str) -> list[VariantConfig]:
    """Named artifact suites (Makefile targets map onto these)."""
    V = variant_from_flags
    if name == "core":
        # minimum set: quickstart example + integration tests + fig5/fig6
        return [
            V("t130", "fp32"),
            V("t130", "bitnet158"),
            V("t130", "dqt", bits=1.58),
            V("t130", "dqt", bits=8),
            V("t130", "dqt_absmax", bits=1.58),
            V("test", "dqt", bits=1.58),
            V("test", "dqt", bits=8),
            V("test", "fp32"),
            V("test", "bitnet158"),
        ]
    if name == "fig2":
        out = []
        for size in ("t130", "t320", "t1b"):
            for mode, bits in (
                ("fp32", 1.58),
                ("bitnet158", 1.58),
                ("dqt", 1.58),
                ("dqt", 8),
            ):
                out.append(V(size, mode, bits=bits))
        return out
    if name == "fig3":
        out = []
        for size in ("t130", "t1b"):
            for mode, bits in (("bitnet158", 1.58), ("dqt", 8)):
                for env in ("fp32", "bf16", "fp8"):
                    out.append(V(size, mode, bits=bits, env=env))
                for env in ("bf16", "fp8"):
                    out.append(
                        V(size, mode, bits=bits, env=env, optimizer="adafactor")
                    )
        return out
    if name == "fig4":
        return [
            V(size, "dqt", bits=b)
            for size in ("t130", "t1b")
            for b in (1.58, 3, 4, 8)
        ]
    if name == "fig7":
        return [
            V("t130", "dqt", bits=1.58, intervention=iv)
            for iv in ("force_remain", "force_update")
        ]
    if name == "fig9":
        return [V("t130", "dqt_ternary_inf", bits=8)]
    if name == "abl":
        return [
            V("t130", "dqt", bits=1.58, recompute_scale=True),
            V("t130", "dqt", bits=1.58, optimizer="adafactor"),
        ]
    raise ValueError(f"unknown suite {name!r}")


def dedup(variants: list[VariantConfig]) -> list[VariantConfig]:
    seen, out = set(), []
    for v in variants:
        if v.variant_name not in seen:
            seen.add(v.variant_name)
            out.append(v)
    return out


def update_index(out_dir: str):
    idx = {}
    for d in sorted(os.listdir(out_dir)):
        mpath = os.path.join(out_dir, d, "manifest.json")
        if os.path.isfile(mpath):
            with open(mpath) as f:
                m = json.load(f)
            idx[d] = {
                "model": m["variant"]["model"]["name"],
                "mode": m["variant"]["mode"],
                "bits": m["variant"]["bits"],
                "env": m["variant"]["env"],
                "optimizer": m["variant"]["optimizer"],
                "entries": m["entries"],
            }
    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump(idx, f, indent=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--suite", action="append", default=[])
    ap.add_argument("--model", choices=sorted(ALL_CONFIGS))
    ap.add_argument("--mode", default="dqt")
    ap.add_argument("--bits", type=float, default=1.58)
    ap.add_argument("--env", default="fp32")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--intervention", default="none")
    ap.add_argument("--recompute-scale", action="store_true")
    ap.add_argument("--no-pallas", action="store_true")
    ap.add_argument("--force", action="store_true", help="rebuild even if present")
    args = ap.parse_args()

    variants: list[VariantConfig] = []
    for s in args.suite:
        variants.extend(suite_variants(s))
    if args.model:
        variants.append(
            variant_from_flags(
                args.model,
                args.mode,
                bits=args.bits,
                env=args.env,
                optimizer=args.optimizer,
                intervention=args.intervention,
                recompute_scale=args.recompute_scale,
            )
        )
    if not variants:
        variants = suite_variants("core")
    variants = dedup(variants)

    os.makedirs(args.out_dir, exist_ok=True)
    for vc in variants:
        vdir = os.path.join(args.out_dir, vc.variant_name)
        if not args.force and os.path.isfile(os.path.join(vdir, "manifest.json")):
            print(f"  [{vc.variant_name}] cached, skipping", flush=True)
            continue
        lower_variant(vc, args.out_dir, use_pallas=not args.no_pallas)
    update_index(args.out_dir)
    print(f"wrote {len(variants)} variants to {args.out_dir}")


if __name__ == "__main__":
    main()
