"""Optimizers with stochastic-rounding weight updates (paper §3.2, §4.3).

Two optimizers × five weight-handling modes:

  AdamW      (paper main experiments) — m, v per parameter.
  Adafactor  (paper §4.3 memory-efficient option) — factored second moment
             for matrices (row/col vectors), no momentum: the optimizer
             state for an [n,m] matrix is n+m floats instead of 2nm.

For DQT-family variants, the update of every grid weight goes through
stochastic rounding (Eq. 5) — on the hot path via the fused Pallas
`adamw_sr_update` kernel, and via the standalone SR kernel for the ablation
paths (absmax re-quantization, Fig. 7 interventions, Adafactor, scale
recomputation). Non-grid parameters (embeddings, norms, head) always get
plain dense updates, matching BitNet's treatment of non-linear layers.

Low-precision environments (§4.3) are applied as storage casts:
optimizer state is stored in the env format for every mode, and BitNet's
FP32 master weights are additionally stored in the env format — which is
exactly the mechanism that degrades BitNet in Fig. 3 (sub-ULP master
updates get round-to-nearest-absorbed) while DQT's SR stays unbiased.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import lowp, model, quant
from .configs import VariantConfig
from .kernels import adamw_sr_update, stochastic_round
from .kernels import prng
from .kernels import ref as kref

ADAFACTOR_B2 = 0.99
ADAFACTOR_EPS = 1e-30


# ---------------------------------------------------------------------------
# Optimizer state layout
# ---------------------------------------------------------------------------

def trainable_names(vc: VariantConfig) -> list[str]:
    """Parameters that receive optimizer state (excludes `.s` scales)."""
    return model.param_names(vc.model)


def opt_state_names(vc: VariantConfig) -> list[str]:
    """Flat opt-state entry order (the manifest/Rust contract)."""
    names = ["step"]
    shapes = model.param_shapes(vc.model)
    for p in trainable_names(vc):
        if vc.optimizer == "adamw":
            names.extend([f"{p}.m", f"{p}.v"])
        else:  # adafactor
            if len(shapes[p]) == 2:
                names.extend([f"{p}.vr", f"{p}.vc"])
            else:
                names.append(f"{p}.v")
    return names


def init_opt_state(vc: VariantConfig) -> dict[str, jnp.ndarray]:
    shapes = model.param_shapes(vc.model)
    st: dict[str, jnp.ndarray] = {"step": jnp.zeros((), jnp.float32)}
    for p in trainable_names(vc):
        shape = shapes[p]
        if vc.optimizer == "adamw":
            st[f"{p}.m"] = jnp.zeros(shape, jnp.float32)
            st[f"{p}.v"] = jnp.zeros(shape, jnp.float32)
        else:
            if len(shape) == 2:
                st[f"{p}.vr"] = jnp.zeros((shape[0],), jnp.float32)
                st[f"{p}.vc"] = jnp.zeros((shape[1],), jnp.float32)
            else:
                st[f"{p}.v"] = jnp.zeros(shape, jnp.float32)
    return st


def opt_state_shapes(vc: VariantConfig) -> dict[str, tuple[int, ...]]:
    shapes = model.param_shapes(vc.model)
    out: dict[str, tuple[int, ...]] = {"step": ()}
    for p in trainable_names(vc):
        shape = shapes[p]
        if vc.optimizer == "adamw":
            out[f"{p}.m"] = shape
            out[f"{p}.v"] = shape
        else:
            if len(shape) == 2:
                out[f"{p}.vr"] = (shape[0],)
                out[f"{p}.vc"] = (shape[1],)
            else:
                out[f"{p}.v"] = shape
    return out


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------

def clip_global_norm(grads: dict[str, jnp.ndarray], max_norm: float):
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in grads.values()) + 1e-12
    )
    scale = jnp.minimum(1.0, max_norm / gnorm)
    return {k: g * scale for k, g in grads.items()}, gnorm


# ---------------------------------------------------------------------------
# Dense update rules (produce the transient W')
# ---------------------------------------------------------------------------

def _adamw_dense(w, g, m, v, lr, step, vc: VariantConfig):
    b1, b2, eps, wd = vc.adam_b1, vc.adam_b2, vc.adam_eps, vc.weight_decay
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * jnp.square(g)
    mhat = m_new / (1.0 - b1 ** step)
    vhat = v_new / (1.0 - b2 ** step)
    w_dense = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * w)
    return w_dense, {"m": m_new, "v": v_new}


def _adafactor_dense(w, g, state, lr, vc: VariantConfig):
    wd = vc.weight_decay
    g2 = jnp.square(g) + ADAFACTOR_EPS
    if g.ndim == 2:
        vr = ADAFACTOR_B2 * state["vr"] + (1 - ADAFACTOR_B2) * jnp.mean(g2, axis=1)
        vc_ = ADAFACTOR_B2 * state["vc"] + (1 - ADAFACTOR_B2) * jnp.mean(g2, axis=0)
        denom = jnp.sqrt(
            jnp.outer(vr, vc_) / jnp.clip(jnp.mean(vr), ADAFACTOR_EPS, None)
        )
        u = g / jnp.clip(denom, 1e-12, None)
        new_state = {"vr": vr, "vc": vc_}
    else:
        v = ADAFACTOR_B2 * state["v"] + (1 - ADAFACTOR_B2) * g2
        u = g / jnp.clip(jnp.sqrt(v), 1e-12, None)
        new_state = {"v": v}
    # update clipping (d = 1.0)
    rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
    u = u / jnp.maximum(1.0, rms_u)
    w_dense = w - lr * (u + wd * w)
    return w_dense, new_state


# ---------------------------------------------------------------------------
# Grid projection of W' (SR / absmax / interventions)
# ---------------------------------------------------------------------------

def _project_to_grid(w_old, w_dense, seed, bits, s, vc: VariantConfig):
    """Project the transient dense update onto the grid per the variant."""
    if vc.mode == "dqt_absmax":
        # Fig. 5 ablation — the paper's "absmax quantization on the updated
        # weight matrices": a *max*-based scale recomputed each step with
        # round-to-nearest. Since max|W| ≫ typical |w|, most entries round
        # to 0 and small updates can never accumulate — the mechanism
        # behind the flat non-converging curve in Fig. 5.
        qn, qp = kref.qrange(bits)
        s_max = qp / (jnp.max(jnp.abs(w_dense)) + kref.EPS)
        return kref.round_nearest_ref(w_dense, bits, s_max), s_max

    if vc.intervention == "none":
        return stochastic_round(w_dense, seed, bits, s), s

    # Fig. 7: rank |update| in grid units, intervene on the bottom 20 %
    qn, qp = kref.qrange(bits)
    delta = (w_dense - w_old) * s
    thresh = jnp.percentile(jnp.abs(delta), vc.intervention_frac * 100.0)
    small = jnp.abs(delta) <= thresh
    sr = stochastic_round(w_dense, seed, bits, s)
    if vc.intervention == "force_remain":
        return jnp.where(small, w_old, sr), s
    # force_update: move small ones to the *adjacent* grid point in the
    # update's direction, even though the update wouldn't reach it
    step_dir = jnp.where(delta >= 0, 1.0, -1.0)
    forced = jnp.clip(jnp.round(w_old * s) + step_dir, qn, qp) / s
    return jnp.where(small, forced, sr), s


# ---------------------------------------------------------------------------
# Full update: params × grads × state → new params/state (+ metrics)
# ---------------------------------------------------------------------------

def apply_updates(
    params: dict[str, jnp.ndarray],
    grads: dict[str, jnp.ndarray],
    opt_state: dict[str, jnp.ndarray],
    vc: VariantConfig,
    lr: jnp.ndarray,
    seed: jnp.ndarray,
):
    """One optimizer step. Returns (new_params, new_opt_state, aux).

    aux = {"upd_frac": fraction of grid weights whose quantized value
    changed (Fig. 6), "gnorm": pre-clip global grad norm}.
    """
    step = opt_state["step"] + 1.0
    grads, gnorm = clip_global_norm(grads, vc.grad_clip)
    qset = set(model.quantized_param_names(vc.model)) if vc.quantized else set()
    grid = model.has_grid_weights(vc)
    bits = model.grid_bits(vc)

    new_params: dict[str, jnp.ndarray] = {}
    new_state: dict[str, jnp.ndarray] = {"step": step}
    changed = []
    total = []

    for idx, p in enumerate(trainable_names(vc)):
        w = params[p]
        g = grads[p]
        tseed = prng.hash_u32(
            jnp.asarray(idx, jnp.uint32), seed.astype(jnp.uint32)
        ) + jnp.uint32(vc.sr_seed_salt)

        is_grid = grid and p in qset
        use_fused = (
            is_grid
            and vc.optimizer == "adamw"
            and vc.mode == "dqt"
            and vc.intervention == "none"
            and not vc.recompute_scale
        )

        if use_fused:
            # hot path: fused AdamW + SR Pallas kernel; W' never leaves VMEM
            s = params[p + ".s"]
            w_new, m_new, v_new = adamw_sr_update(
                w, g, opt_state[f"{p}.m"], opt_state[f"{p}.v"],
                seed=tseed, lr=lr, step=step, bits=bits, s=s,
                b1=vc.adam_b1, b2=vc.adam_b2, eps=vc.adam_eps,
                weight_decay=vc.weight_decay,
            )
            new_params[p] = w_new
            new_params[p + ".s"] = s
            new_state[f"{p}.m"] = lowp.env_cast(m_new, vc.env)
            new_state[f"{p}.v"] = lowp.env_state_cast(v_new, vc.env)
            changed.append(jnp.sum(w_new != w))
            total.append(w.size)
            continue

        # generic path ---------------------------------------------------
        if vc.optimizer == "adamw":
            w_dense, st = _adamw_dense(
                w, g, opt_state[f"{p}.m"], opt_state[f"{p}.v"], lr, step, vc
            )
            new_state[f"{p}.m"] = lowp.env_cast(st["m"], vc.env)
            new_state[f"{p}.v"] = lowp.env_state_cast(st["v"], vc.env)
        else:
            if w.ndim == 2:
                st_in = {"vr": opt_state[f"{p}.vr"], "vc": opt_state[f"{p}.vc"]}
            else:
                st_in = {"v": opt_state[f"{p}.v"]}
            w_dense, st = _adafactor_dense(w, g, st_in, lr, vc)
            for k, val in st.items():
                new_state[f"{p}.{k}"] = lowp.env_state_cast(val, vc.env)

        if is_grid:
            s = params[p + ".s"]
            if vc.recompute_scale:
                # abl1: re-derive the grid from the transient dense update
                s = kref.absmean_scale(w_dense, bits)
            w_new, s_new = _project_to_grid(w, w_dense, tseed, bits, s, vc)
            new_params[p] = w_new
            new_params[p + ".s"] = jnp.asarray(s_new, jnp.float32)
            changed.append(jnp.sum(w_new != w))
            total.append(w.size)
        elif vc.mode == "bitnet158" and p in qset:
            # BitNet master update, stored in the env's precision — the
            # Fig. 3 degradation mechanism (RTN-absorbed small updates).
            w_new = lowp.env_cast(w_dense, vc.env)
            new_params[p] = w_new
            # Fig. 6: BitNet update freq = change in the *quantized* weights
            s_old = kref.absmean_scale(w, 1.58)
            s_new = kref.absmean_scale(w_new, 1.58)
            q_old = kref.absmean_quantize_ref(w, 1.58, s_old)
            q_new = kref.absmean_quantize_ref(w_new, 1.58, s_new)
            changed.append(jnp.sum(jnp.sign(q_new) != jnp.sign(q_old)))
            total.append(w.size)
        else:
            # dense (non-grid) parameter; fp32 baseline counts all params
            w_new = lowp.env_cast(w_dense, vc.env) if vc.mode != "fp32" else w_dense
            new_params[p] = w_new
            if vc.mode == "fp32":
                changed.append(jnp.sum(w_new != w))
                total.append(w.size)

    upd_frac = (
        sum(changed) / float(sum(total)) if total else jnp.zeros((), jnp.float32)
    )
    aux = {"upd_frac": upd_frac.astype(jnp.float32), "gnorm": gnorm}
    return new_params, new_state, aux
