"""L2: LLaMA-structured transformer (paper §4.1 / Table 2) in JAX.

Architecture: token embedding → N × [RMSNorm → causal MHA with RoPE →
residual → RMSNorm → SwiGLU MLP → residual] → final RMSNorm → tied LM head.

All seven per-layer projection matrices (wq, wk, wv, wo, w_gate, w_up,
w_down) go through `quant.quant_linear` and are the *quantized set* in
BitNet/DQT modes (BitNet quantizes exactly the nn.Linear replacements;
embeddings, norms and the tied head stay high-precision). Parameters are a
flat name→array dict with a deterministic name order so the AOT manifest
and the Rust side agree on buffer positions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import quant
from .configs import ModelConfig, VariantConfig

PAD_ID = 0

#: per-layer projection names, in parameter order
LAYER_LINEARS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def param_names(cfg: ModelConfig) -> list[str]:
    """Deterministic parameter order (the manifest/Rust contract)."""
    names = ["emb"]
    for i in range(cfg.num_hidden_layers):
        names.append(f"layers.{i}.attn_norm")
        names.extend(f"layers.{i}.{n}" for n in LAYER_LINEARS[:4])
        names.append(f"layers.{i}.mlp_norm")
        names.extend(f"layers.{i}.{n}" for n in LAYER_LINEARS[4:])
    names.append("final_norm")
    if not cfg.tie_embeddings:
        names.append("lm_head")
    return names


def quantized_param_names(cfg: ModelConfig) -> list[str]:
    """The subset living on the INTn grid in quantized modes."""
    return [
        f"layers.{i}.{n}"
        for i in range(cfg.num_hidden_layers)
        for n in LAYER_LINEARS
    ]


def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    h, i_, v = cfg.hidden_size, cfg.intermediate_size, cfg.vocab_size
    shapes: dict[str, tuple[int, ...]] = {"emb": (v, h)}
    for l in range(cfg.num_hidden_layers):
        shapes[f"layers.{l}.attn_norm"] = (h,)
        shapes[f"layers.{l}.wq"] = (h, h)
        shapes[f"layers.{l}.wk"] = (h, h)
        shapes[f"layers.{l}.wv"] = (h, h)
        shapes[f"layers.{l}.wo"] = (h, h)
        shapes[f"layers.{l}.mlp_norm"] = (h,)
        shapes[f"layers.{l}.w_gate"] = (i_, h)
        shapes[f"layers.{l}.w_up"] = (i_, h)
        shapes[f"layers.{l}.w_down"] = (h, i_)
    shapes["final_norm"] = (h,)
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (v, h)
    return shapes


def grid_bits(vc: VariantConfig) -> float:
    """Bit width of the stored weight grid for a DQT-family variant."""
    if vc.mode == "dqt_ternary_inf":
        return 8.0  # §A.2: train an 8-bit grid, deploy ternary
    if vc.mode == "bitnet158":
        return 1.58
    return vc.bits


def has_grid_weights(vc: VariantConfig) -> bool:
    """DQT family stores grid weights (+ fixed scales); BitNet stores masters."""
    return vc.quantized and vc.mode != "bitnet158"


def init_params(vc: VariantConfig, key: jax.Array) -> dict[str, jnp.ndarray]:
    """LLaMA-style init; DQT modes project linears onto their grid.

    For each grid matrix `p` a companion scalar `p.s` (the fixed scale,
    Eq. 3) is stored in the dict.
    """
    cfg = vc.model
    shapes = param_shapes(cfg)
    qset = set(quantized_param_names(cfg)) if has_grid_weights(vc) else set()
    params: dict[str, jnp.ndarray] = {}
    std = 0.02
    for name in param_names(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
            continue
        w = jax.random.normal(sub, shape, jnp.float32) * std
        if name in qset:
            wq, s = quant.init_grid_weight(w, grid_bits(vc))
            params[name] = wq
            params[name + ".s"] = jnp.asarray(s, jnp.float32)
        else:
            params[name] = w
    return params


def flat_param_names(vc: VariantConfig) -> list[str]:
    """Names including the `.s` scale companions, in flattening order."""
    cfg = vc.model
    qset = set(quantized_param_names(cfg)) if has_grid_weights(vc) else set()
    out = []
    for name in param_names(cfg):
        out.append(name)
        if name in qset:
            out.append(name + ".s")
    return out


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_tables(seq_len: int, head_dim: int, theta: float):
    """cos/sin tables [S, D/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: [B, H, S, D] with D even; rotate adjacent pairs."""
    x0 = x[..., 0::2]
    x1 = x[..., 1::2]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    y0 = x0 * c - x1 * s
    y1 = x0 * s + x1 * c
    return jnp.stack([y0, y1], axis=-1).reshape(x.shape)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def forward(
    params: dict[str, jnp.ndarray],
    tokens: jnp.ndarray,  # [B, S] int32
    vc: VariantConfig,
    use_pallas: bool = True,
    ternary_override: bool = False,
) -> jnp.ndarray:
    """Return logits [B, S, V].

    ``ternary_override`` re-projects every grid weight to ternary in the
    forward pass — deployment-style ternary inference of an n-bit DQT model
    (§A.2, Table 1 "ternary Inf." rows).
    """
    cfg = vc.model
    b, s = tokens.shape
    h, nh, hd = cfg.hidden_size, cfg.num_attention_heads, cfg.head_dim
    x = params["emb"][tokens]  # [B, S, H]
    cos, sin = rope_tables(s, hd, cfg.rope_theta)
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    def lin(pre, name, inp):
        w = params[pre + name]
        if vc.mode == "fp32":
            return quant.linear_fp32(inp, w)
        if ternary_override:
            w3, _ = quant.ternary_project(w)
            return quant.linear_dqt(inp, w3, vc.act_bits, use_pallas)
        return quant.quant_linear(
            inp, w, mode=vc.mode, act_bits=vc.act_bits, use_pallas=use_pallas
        )

    for l in range(cfg.num_hidden_layers):
        pre = f"layers.{l}."
        # --- attention block ---
        xn = quant.norm(x, params[pre + "attn_norm"], cfg.rms_eps, use_pallas)
        q = lin(pre, "wq", xn).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        k = lin(pre, "wk", xn).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        v = lin(pre, "wv", xn).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, h)
        x = x + lin(pre, "wo", ctx)

        # --- MLP block (SwiGLU) ---
        xn = quant.norm(x, params[pre + "mlp_norm"], cfg.rms_eps, use_pallas)
        gate = lin(pre, "w_gate", xn)
        up = lin(pre, "w_up", xn)
        x = x + lin(pre, "w_down", jax.nn.silu(gate) * up)

    x = quant.norm(x, params["final_norm"], cfg.rms_eps, use_pallas)
    head = params["emb"] if cfg.tie_embeddings else params["lm_head"]
    return x @ head.T  # high-precision head (BitNet keeps it unquantized)


def loss_fn(
    params,
    tokens,  # [B, S+1] int32, PAD_ID-padded
    vc: VariantConfig,
    use_pallas: bool = True,
    ternary_override: bool = False,
):
    """Mean next-token cross-entropy over non-pad positions."""
    from .kernels.ref import softmax_xent_ref

    inputs = tokens[:, :-1]
    labels = tokens[:, 1:]
    logits = forward(params, inputs, vc, use_pallas, ternary_override)
    mask = labels != PAD_ID
    return softmax_xent_ref(logits, labels, mask)


def nll_sums(params, tokens, vc, use_pallas=True, ternary_override=False):
    """(sum NLL, token count) over non-pad positions — the eval_step payload."""
    inputs = tokens[:, :-1]
    labels = tokens[:, 1:]
    logits = forward(params, inputs, vc, use_pallas, ternary_override)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    mask = (labels != PAD_ID).astype(jnp.float32)
    return jnp.sum(nll * mask), jnp.sum(mask)
