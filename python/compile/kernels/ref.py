"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness).

These are the ground truth the pytest/hypothesis suite checks the kernels
against, and they double as the non-Pallas fallback path in the L2 model
(`use_pallas=False`). Everything here follows the paper's equations:

  Eq. (1)  stochastic rounding  SR(x) = floor(x) w.p. ceil(x)-x else ceil(x)
  Eq. (2)  AbsMean(W) = mean(|W|)
  Eq. (3)  s = Qp / AbsMean(W)
  Eq. (4)  W~ = clip(round(W*s), Qn, Qp) / s
  Eq. (5)  W~' = SR(W') on the same grid
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-8


# ---------------------------------------------------------------------------
# Quantization ranges
# ---------------------------------------------------------------------------

def qrange(bits: float) -> tuple[float, float]:
    """Integer grid range [Qn, Qp] for an n-bit format.

    bits == 1.58 is the paper's ternary format {-1, 0, 1}. For integer n,
    Qn = -2^(n-1), Qp = 2^(n-1) - 1 (paper §3.2).
    """
    if bits == 1.58:
        return -1.0, 1.0
    n = int(bits)
    return float(-(2 ** (n - 1))), float(2 ** (n - 1) - 1)


# ---------------------------------------------------------------------------
# AbsMean weight quantization (paper Eq. 2-4; BitNet b1.58 weight quant)
# ---------------------------------------------------------------------------

def absmean_scale(w: jnp.ndarray, bits: float) -> jnp.ndarray:
    """Per-matrix scale s = Qp / AbsMean(W) (Eq. 3). Scalar array."""
    _, qp = qrange(bits)
    return qp / (jnp.mean(jnp.abs(w)) + EPS)


def absmean_quantize_ref(w: jnp.ndarray, bits: float, s=None) -> jnp.ndarray:
    """Eq. (4): fake-quantized weights on the INTn grid (values k/s)."""
    if s is None:
        s = absmean_scale(w, bits)
    qn, qp = qrange(bits)
    return jnp.clip(jnp.round(w * s), qn, qp) / s


# ---------------------------------------------------------------------------
# Stochastic rounding (paper Eq. 1) on an integer grid scaled by s
# ---------------------------------------------------------------------------

def stochastic_round_ref(
    x: jnp.ndarray, key: jax.Array, bits: float, s: jnp.ndarray
) -> jnp.ndarray:
    """Eq. (5): project a dense update onto the INTn/s grid with SR.

    y = x*s; floor w.p. (ceil(y) - y), ceil otherwise; clip to [Qn, Qp]; /s.
    Unbiased: E[result * s] equals y wherever y is inside the clip range.
    """
    qn, qp = qrange(bits)
    y = x * s
    lo = jnp.floor(y)
    frac = y - lo  # in [0, 1); P(ceil) = frac
    u = jax.random.uniform(key, x.shape, dtype=x.dtype)
    rounded = lo + (u < frac).astype(x.dtype)
    return jnp.clip(rounded, qn, qp) / s


def round_nearest_ref(x: jnp.ndarray, bits: float, s: jnp.ndarray) -> jnp.ndarray:
    """Fig. 5 ablation: deterministic round-to-nearest onto the same grid."""
    qn, qp = qrange(bits)
    return jnp.clip(jnp.round(x * s), qn, qp) / s


# ---------------------------------------------------------------------------
# 8-bit absmax activation quantization (BitNet setting; per-token / per-row)
# ---------------------------------------------------------------------------

def act_quantize_ref(x: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """Per-row (last-dim) absmax fake-quantization to INTn for activations."""
    qp = float(2 ** (bits - 1) - 1)
    scale = qp / jnp.clip(jnp.max(jnp.abs(x), axis=-1, keepdims=True), EPS, None)
    return jnp.clip(jnp.round(x * scale), -qp - 1, qp) / scale


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_ref(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


# ---------------------------------------------------------------------------
# Quantized linear forward: y = actquant(x) @ wq.T
# (wq is already on the grid; the kernel fuses the act quant + matmul)
# ---------------------------------------------------------------------------

def qlinear_ref(x: jnp.ndarray, wq: jnp.ndarray, act_bits: int = 8) -> jnp.ndarray:
    """x: [..., in], wq: [out, in] already fake-quantized. Returns [..., out]."""
    xq = act_quantize_ref(x, act_bits)
    return xq @ wq.T


# ---------------------------------------------------------------------------
# Fused AdamW-with-SR weight update (the DQT hot path, paper Eq. 5)
# ---------------------------------------------------------------------------

def adamw_sr_update_ref(
    w: jnp.ndarray,
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    key: jax.Array,
    *,
    lr: jnp.ndarray,
    step: jnp.ndarray,
    bits: float,
    s: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
):
    """One AdamW step producing the transient dense W', then SR back to grid.

    Returns (w_new_on_grid, m_new, v_new). `step` is 1-based.
    """
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * jnp.square(g)
    mhat = m_new / (1.0 - b1 ** step)
    vhat = v_new / (1.0 - b2 ** step)
    w_dense = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w)
    w_new = stochastic_round_ref(w_dense, key, bits, s)
    return w_new, m_new, v_new


# ---------------------------------------------------------------------------
# Cross-entropy (next-token LM loss)
# ---------------------------------------------------------------------------

def softmax_xent_ref(logits: jnp.ndarray, labels: jnp.ndarray, mask=None):
    """Mean cross-entropy over (optionally masked) positions.

    logits: [..., V] f32, labels: [...] i32, mask: [...] bool/float or None.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.clip(jnp.sum(mask), 1.0, None)
