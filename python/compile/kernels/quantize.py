"""Pallas kernels for the paper's quantization primitives.

Two elementwise-tiled kernels:

  * ``absmean_quantize``   — Eq. (4): project W onto the INTn grid given a
    precomputed scale s (the AbsMean reduction, Eq. 2-3, is a one-shot
    full-matrix reduction done at the jnp level — it is not a hot path).
  * ``stochastic_round``   — Eq. (1)/(5): SR a transient dense update back
    onto the grid, generating uniform bits in-kernel from (seed, counter).

Both run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); on a real TPU the same BlockSpecs tile HBM→VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import prng
from .ref import qrange

import os

# Block rows per grid step for 2-D tiles. 256 rows × ≤4096 cols of f32 is
# ≤4 MiB — comfortably inside a TPU core's ~16 MiB VMEM with double-buffering.
# Overridable for the §Perf block-shape sweep.
_BLOCK_ROWS = int(os.environ.get("DQT_ELEMWISE_BLOCK_ROWS", 256))


def _pick_block(n: int, maximum: int = _BLOCK_ROWS) -> int:
    """Largest divisor of n that is ≤ maximum (keeps the grid exact)."""
    b = min(n, maximum)
    while n % b != 0:
        b -= 1
    return b


def _as2d(x: jnp.ndarray) -> tuple[jnp.ndarray, tuple[int, ...]]:
    """Collapse any-rank input to [rows, cols] for row-tiled kernels."""
    shape = x.shape
    if x.ndim == 1:
        return x.reshape(1, -1), shape
    if x.ndim == 2:
        return x, shape
    return x.reshape(-1, shape[-1]), shape


# ---------------------------------------------------------------------------
# absmean quantize (Eq. 4)
# ---------------------------------------------------------------------------

def _absmean_kernel(w_ref, s_ref, o_ref, *, qn: float, qp: float):
    s = s_ref[0]
    w = w_ref[...]
    o_ref[...] = jnp.clip(jnp.round(w * s), qn, qp) / s


def absmean_quantize(w: jnp.ndarray, bits: float, s: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantize ``w`` onto the INTn/s grid (Eq. 4), tiled by rows."""
    qn, qp = qrange(bits)
    w2, shape = _as2d(w)
    rows, cols = w2.shape
    br = _pick_block(rows)
    s_arr = jnp.reshape(s.astype(jnp.float32), (1,))
    out = pl.pallas_call(
        functools.partial(_absmean_kernel, qn=qn, qp=qp),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), w2.dtype),
        interpret=True,
    )(w2, s_arr)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# stochastic rounding (Eq. 1 / Eq. 5)
# ---------------------------------------------------------------------------

def _sr_kernel(x_ref, s_ref, seed_ref, o_ref, *, qn, qp, cols, block_rows):
    s = s_ref[0]
    seed = seed_ref[0]
    x = x_ref[...]
    y = x * s
    lo = jnp.floor(y)
    frac = y - lo
    # element counter = global row-major index of this block's elements
    base = pl.program_id(0).astype(jnp.uint32) * jnp.uint32(block_rows * cols)
    ctr = prng.counter_grid(x.shape, 0) + base
    u = prng.uniform01(ctr, seed)
    rounded = lo + (u < frac).astype(x.dtype)
    o_ref[...] = jnp.clip(rounded, qn, qp) / s


def stochastic_round(
    x: jnp.ndarray, seed: jnp.ndarray, bits: float, s: jnp.ndarray
) -> jnp.ndarray:
    """SR ``x`` onto the INTn/s grid; uniform bits from (seed, element index).

    ``seed`` is a uint32 scalar; pass a distinct value per (tensor, step) —
    the trainer derives it as hash(step, param_index, salt).
    """
    qn, qp = qrange(bits)
    x2, shape = _as2d(x)
    rows, cols = x2.shape
    br = _pick_block(rows)
    s_arr = jnp.reshape(s.astype(jnp.float32), (1,))
    seed_arr = jnp.reshape(seed.astype(jnp.uint32), (1,))
    out = pl.pallas_call(
        functools.partial(
            _sr_kernel, qn=qn, qp=qp, cols=cols, block_rows=br
        ),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x2.dtype),
        interpret=True,
    )(x2, s_arr, seed_arr)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# hash-PRNG reference twin (exact-match oracle for the kernels above)
# ---------------------------------------------------------------------------

def stochastic_round_hash_ref(
    x: jnp.ndarray, seed: jnp.ndarray, bits: float, s: jnp.ndarray
) -> jnp.ndarray:
    """Pure-jnp twin of ``stochastic_round`` using the same hash stream."""
    qn, qp = qrange(bits)
    x2, shape = _as2d(x)
    y = x2 * s
    lo = jnp.floor(y)
    frac = y - lo
    ctr = prng.counter_grid(x2.shape, 0)
    u = prng.uniform01(ctr, jnp.asarray(seed, jnp.uint32))
    rounded = lo + (u < frac).astype(x2.dtype)
    return (jnp.clip(rounded, qn, qp) / s).reshape(shape)
