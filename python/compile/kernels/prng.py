"""Counter-based in-kernel PRNG for stochastic rounding.

Stochastic rounding needs one uniform sample per weight element per step.
Materializing a full `jax.random.uniform` tensor per parameter in HBM would
double the update's memory traffic, so the kernels generate bits *in place*
from `(seed, element counter)` with a PCG-style integer hash — the GPU
analogue is a curand state per thread, the TPU analogue is
`pltpu.prng_random_bits`. The hash is pure uint32 jnp arithmetic, so it
lowers identically inside a Pallas kernel body (interpret=True) and in the
pure-jnp reference, which lets the pytest suite assert *exact* agreement
between kernel and oracle.

Quality: a 3-round xorshift-multiply mix (Steele & Vigna's splitmix-style
finalizer truncated to 32 bits). SR only needs unbiasedness of the uniform
in [0,1): the suite checks mean/variance and floor/ceil support explicitly.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# numpy uint32 scalars (not jnp arrays): Pallas kernels may not close over
# jnp array constants created outside the trace, and bare Python ints above
# 2^31-1 overflow JAX's weak int32 typing. numpy scalars inline as literals.
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def hash_u32(counter: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Mix a uint32 counter tensor with a uint32 seed into uniform bits."""
    x = counter.astype(jnp.uint32) * _GOLDEN + seed.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * _M1
    x = (x ^ (x >> 13)) * _M2
    x = x ^ (x >> 16)
    return x


def uniform01(counter: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Uniform f32 in [0, 1) from (counter, seed).

    Uses the top 24 bits so the f32 mantissa is exact (no rounding bias).
    """
    bits = hash_u32(counter, seed)
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def counter_grid(shape: tuple[int, ...], offset) -> jnp.ndarray:
    """Row-major element counters for `shape`, starting at `offset` (u32)."""
    n = 1
    for d in shape:
        n *= d
    idx = jnp.arange(n, dtype=jnp.uint32).reshape(shape)
    return idx + jnp.uint32(offset)
