"""Fused quantized-linear Pallas kernel (the forward hot path).

Computes ``y = actquant8(x) @ wq.T`` in one pass: the per-token absmax
reduction, the INT8 fake-quantization of activations, and the matmul against
the already-on-grid weight tile all happen on the same VMEM-resident block —
one HBM read of x per (M-block, N-block) pair instead of three kernel
launches. On a GPU the paper's PyTorch code does this as three CUDA ops;
the TPU mapping tiles M×N over the grid with the full K (reduction) axis in
VMEM, feeding the MXU with [bm, K] × [K, bn] f32 tiles.

The backward pass uses the straight-through estimator for the activation
quantizer (BitNet's choice, which DQT keeps): dL/dx = dy @ wq,
dL/dwq = dy.T @ xq. These are plain matmuls that XLA fuses well, so the
VJP is expressed at the jnp level (and checked against finite differences
in the test suite).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS, act_quantize_ref

import os

# Block shapes are the L1 tuning knobs (§Perf): overridable at lowering time
# so the perf harness can sweep them without editing code. Defaults chosen
# for the MXU story — see DESIGN.md §Hardware-Adaptation.
_BLOCK_M = int(os.environ.get("DQT_QLINEAR_BLOCK_M", 128))
_BLOCK_N = int(os.environ.get("DQT_QLINEAR_BLOCK_N", 128))


def _pick_block(n: int, maximum: int) -> int:
    b = min(n, maximum)
    while n % b != 0:
        b -= 1
    return b


def _qlinear_kernel(x_ref, w_ref, o_ref, *, qp: float):
    x = x_ref[...]  # [bm, K]
    w = w_ref[...]  # [bn, K]
    scale = qp / jnp.clip(jnp.max(jnp.abs(x), axis=-1, keepdims=True), EPS, None)
    xq = jnp.clip(jnp.round(x * scale), -qp - 1.0, qp) / scale
    o_ref[...] = jnp.dot(xq, w.T, precision=jax.lax.Precision.HIGHEST)


def _qlinear_fwd_pallas(x2: jnp.ndarray, wq: jnp.ndarray, act_bits: int):
    m, k = x2.shape
    n, k2 = wq.shape
    assert k == k2, (x2.shape, wq.shape)
    qp = float(2 ** (act_bits - 1) - 1)
    bm = _pick_block(m, _BLOCK_M)
    bn = _pick_block(n, _BLOCK_N)
    return pl.pallas_call(
        functools.partial(_qlinear_kernel, qp=qp),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x2.dtype),
        interpret=True,
    )(x2, wq)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def qlinear(x: jnp.ndarray, wq: jnp.ndarray, act_bits: int = 8) -> jnp.ndarray:
    """Fused act-quant + matmul. x: [..., K], wq: [N, K] → [..., N]."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _qlinear_fwd_pallas(x2, wq, act_bits)
    return y.reshape(*lead, wq.shape[0])


def _qlinear_fwd(x, wq, act_bits):
    return qlinear(x, wq, act_bits), (x, wq)


def _qlinear_bwd(act_bits, res, dy):
    x, wq = res
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    dy2 = dy.reshape(-1, wq.shape[0])
    # STE through the activation quantizer: treat xq ≈ x for dL/dx.
    dx = (dy2 @ wq).reshape(x.shape)
    # Weight grad sees the *quantized* activations (what the matmul consumed).
    xq = act_quantize_ref(x2, act_bits)
    dwq = dy2.T @ xq
    return dx, dwq


qlinear.defvjp(_qlinear_fwd, _qlinear_bwd)
