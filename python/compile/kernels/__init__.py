"""L1: Pallas kernels for the DQT hot spots + pure-jnp oracles.

Public surface:
  absmean_quantize(w, bits, s)          Eq. (4) grid projection
  stochastic_round(x, seed, bits, s)    Eq. (1)/(5) SR onto the grid
  qlinear(x, wq, act_bits)              fused act-quant + matmul fwd
  rmsnorm(x, g, eps)                    row RMSNorm
  adamw_sr_update(...)                  fused AdamW + SR (DQT update path)

All kernels run under interpret=True (CPU PJRT). ref.py holds the oracles.
"""

from .adamw_sr import adamw_sr_update
from .qlinear import qlinear
from .quantize import absmean_quantize, stochastic_round, stochastic_round_hash_ref
from .rmsnorm import rmsnorm

__all__ = [
    "absmean_quantize",
    "stochastic_round",
    "stochastic_round_hash_ref",
    "qlinear",
    "rmsnorm",
    "adamw_sr_update",
]
