"""Fused AdamW + stochastic-rounding Pallas kernel — the DQT update hot path.

This is the paper's core step (Fig. 1 lower, Eq. 5): the optimizer's dense
update W' exists only *inside* the kernel's VMEM tile and is stochastically
rounded back onto the INTn grid before it ever reaches HBM. That is the
memory story of DQT made literal: no high-precision master copy is written
anywhere. One HBM read of (w, g, m, v), one HBM write of (w', m', v').

The kernel is elementwise over row tiles; uniform bits come from the shared
counter-hash PRNG (prng.py) so the pure-jnp twin in tests matches exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import prng
from .ref import qrange

_BLOCK_ROWS = 256


def _pick_block(n: int, maximum: int = _BLOCK_ROWS) -> int:
    b = min(n, maximum)
    while n % b != 0:
        b -= 1
    return b


def _adamw_sr_kernel(
    w_ref, g_ref, m_ref, v_ref, s_ref, seed_ref, lr_ref, bc_ref,
    wo_ref, mo_ref, vo_ref,
    *, qn, qp, b1, b2, eps, weight_decay, cols, block_rows,
):
    w = w_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    s = s_ref[0]
    seed = seed_ref[0]
    lr = lr_ref[0]
    bc1 = bc_ref[0]  # 1 - b1^t
    bc2 = bc_ref[1]  # 1 - b2^t

    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    mhat = m_new / bc1
    vhat = v_new / bc2
    w_dense = w - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * w)

    # SR back onto the grid (Eq. 5) — W' dies here, in VMEM.
    y = w_dense * s
    lo = jnp.floor(y)
    frac = y - lo
    base = pl.program_id(0).astype(jnp.uint32) * jnp.uint32(block_rows * cols)
    ctr = prng.counter_grid(w.shape, 0) + base
    u = prng.uniform01(ctr, seed)
    rounded = lo + (u < frac).astype(w.dtype)

    wo_ref[...] = jnp.clip(rounded, qn, qp) / s
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def adamw_sr_update(
    w: jnp.ndarray,
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    *,
    seed: jnp.ndarray,
    lr: jnp.ndarray,
    step: jnp.ndarray,
    bits: float,
    s: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
):
    """Fused AdamW step + SR projection. Returns (w_new, m_new, v_new).

    ``step`` is the 1-based step count (f32 scalar), used for bias
    correction; ``seed`` a uint32 scalar unique per (tensor, step).
    """
    qn, qp = qrange(bits)
    shape = w.shape
    w2 = w.reshape(-1, shape[-1]) if w.ndim != 2 else w
    rows, cols = w2.shape
    br = _pick_block(rows)
    stepf = jnp.asarray(step, jnp.float32)
    bc = jnp.stack([1.0 - b1 ** stepf, 1.0 - b2 ** stepf]).astype(jnp.float32)
    args = (
        w2,
        g.reshape(rows, cols),
        m.reshape(rows, cols),
        v.reshape(rows, cols),
        jnp.reshape(s.astype(jnp.float32), (1,)),
        jnp.reshape(seed.astype(jnp.uint32), (1,)),
        jnp.reshape(lr.astype(jnp.float32), (1,)),
        bc,
    )
    tile = lambda i: (i, 0)
    scalar = lambda i: (0,)
    out = pl.pallas_call(
        functools.partial(
            _adamw_sr_kernel,
            qn=qn, qp=qp, b1=b1, b2=b2, eps=eps,
            weight_decay=weight_decay, cols=cols, block_rows=br,
        ),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), tile),
            pl.BlockSpec((br, cols), tile),
            pl.BlockSpec((br, cols), tile),
            pl.BlockSpec((br, cols), tile),
            pl.BlockSpec((1,), scalar),
            pl.BlockSpec((1,), scalar),
            pl.BlockSpec((1,), scalar),
            pl.BlockSpec((2,), scalar),
        ],
        out_specs=[
            pl.BlockSpec((br, cols), tile),
            pl.BlockSpec((br, cols), tile),
            pl.BlockSpec((br, cols), tile),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), w2.dtype),
            jax.ShapeDtypeStruct((rows, cols), w2.dtype),
            jax.ShapeDtypeStruct((rows, cols), w2.dtype),
        ],
        interpret=True,
    )(*args)
    w_new, m_new, v_new = out
    return w_new.reshape(shape), m_new.reshape(shape), v_new.reshape(shape)
