"""RMSNorm Pallas kernel with an analytic custom VJP.

Row-tiled: each grid step normalizes a [block_rows, H] tile in VMEM. The
backward is the closed-form RMSNorm gradient expressed in jnp (fuses into
the surrounding HLO), validated against jax.grad of the reference in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 256


def _pick_block(n: int, maximum: int = _BLOCK_ROWS) -> int:
    b = min(n, maximum)
    while n % b != 0:
        b -= 1
    return b


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...]
    g = g_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(var + eps) * g


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """x: [..., H], g: [H] → [..., H]."""
    lead = x.shape[:-1]
    h = x.shape[-1]
    x2 = x.reshape(-1, h)
    rows = x2.shape[0]
    br = _pick_block(rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, h), x2.dtype),
        interpret=True,
    )(x2, g)
    return out.reshape(*lead, h)


def _rmsnorm_fwd(x, g, eps):
    return rmsnorm(x, g, eps), (x, g)


def _rmsnorm_bwd(eps, res, dy):
    x, g = res
    h = x.shape[-1]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)  # 1/rms
    xhat = x * r
    dg = jnp.sum((dy * xhat).reshape(-1, h), axis=0)
    dyg = dy * g
    # d/dx [x * r(x)] : dx = r * (dyg - xhat * mean(dyg * xhat, -1))
    dx = r * (dyg - xhat * jnp.mean(dyg * xhat, axis=-1, keepdims=True))
    return dx, dg


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)
