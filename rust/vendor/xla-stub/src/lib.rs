//! Stub of the PJRT/XLA binding surface the `dqt` crate uses.
//!
//! The real backend links libxla and executes the AOT artifacts under
//! `rust/artifacts/`. This stub keeps the whole host layer (codecs,
//! checkpointing, data pipeline, memory model, benches, tests) building
//! and running in environments without a PJRT toolchain:
//!
//! * [`Literal`] is fully functional — it stores shaped bytes on the host,
//!   so literal marshalling code and its benches behave normally.
//! * [`PjRtClient::cpu`] succeeds (platform `"stub"`), but
//!   [`HloModuleProto::from_text_file`] and [`PjRtClient::compile`] return
//!   errors explaining that no PJRT runtime is linked. Artifact-driven
//!   paths therefore fail fast with a clear message instead of at link
//!   time, and artifact-gated tests skip exactly as they do when
//!   `make artifacts` has not run.
//!
//! The API mirrors the subset of the real bindings used by
//! `dqt::runtime::client`; swap the `xla` path dependency to the real
//! bindings to run on hardware.

use std::fmt;

/// Error type: a message, `Display`-compatible with the real bindings'
/// error formatting in `dqt`'s `map_err` call sites.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn no_runtime<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: no PJRT runtime linked (xla stub build) — \
         point the `xla` dependency at the real bindings to execute artifacts"
    )))
}

/// Element types used by the `dqt` runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

impl ElementType {
    fn byte_width(self) -> usize {
        4
    }
}

/// Array-or-tuple shape, as reported by [`Literal::shape`].
#[derive(Clone, Debug)]
pub enum Shape {
    Array { ty: ElementType, dims: Vec<usize> },
    Tuple(Vec<Shape>),
}

/// Host values types a [`Literal`] can read back.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn from_le(bytes: [u8; 4]) -> Self {
        u32::from_le_bytes(bytes)
    }
}

/// A shaped host buffer. Fully functional in the stub.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product::<usize>().max(1);
        if data.len() != numel * ty.byte_width() {
            return Err(Error(format!(
                "literal shape {dims:?} ({ty:?}) wants {} bytes, got {}",
                numel * ty.byte_width(),
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.to_vec(),
            data: data.to_vec(),
        })
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array {
            ty: self.ty,
            dims: self.dims.clone(),
        })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error("stub literal is not a tuple".into()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }
}

/// Parsed HLO module. The stub cannot parse HLO text.
pub struct HloModuleProto {
    _never: Never,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        no_runtime(&format!("parsing HLO text {path}"))
    }
}

/// Computation handle built from an [`HloModuleProto`].
pub struct XlaComputation {
    _never: Never,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto._never {}
    }
}

/// PJRT client. Construction succeeds so host-only flows (which never
/// compile a computation) keep working; `compile` reports the stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub (no PJRT linked)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        no_runtime("compiling computation")
    }
}

/// Compiled executable — unconstructible in the stub.
pub struct PjRtLoadedExecutable {
    _never: Never,
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self._never {}
    }
}

/// Device buffer — unconstructible in the stub.
pub struct PjRtBuffer {
    _never: Never,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self._never {}
    }
}

enum Never {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let vals = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.5);
        assert!(matches!(lit.shape().unwrap(), Shape::Array { .. }));
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_checks_byte_length() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 8]).is_err()
        );
    }

    #[test]
    fn scalar_shape_is_one_element() {
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::U32,
            &[],
            &7u32.to_le_bytes(),
        )
        .unwrap();
        assert_eq!(lit.get_first_element::<u32>().unwrap(), 7);
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
    }
}
