//! Report renderer: paper-style tables and ASCII loss curves from
//! `results/` — the `repro report` subcommand.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::config::{Env, Mode, ModelConfig, Optimizer, VariantSpec};
use crate::memory;
use crate::train::RunMetrics;

/// Render Table 2 (model configurations) — paper-exact + testbed presets.
pub fn table2() -> String {
    let mut out = String::new();
    out.push_str("Table 2: model configurations\n");
    out.push_str(
        "| name  | params | hidden | inter | layers | heads | batch | seq |\n\
         |-------|--------|--------|-------|--------|-------|-------|-----|\n",
    );
    for name in ModelConfig::paper_names()
        .iter()
        .chain(ModelConfig::testbed_names().iter())
    {
        let c = ModelConfig::by_name(name).unwrap();
        out.push_str(&format!(
            "| {:<5} | {:>6} | {:>6} | {:>5} | {:>6} | {:>5} | {:>5} | {:>3} |\n",
            c.name,
            human(c.param_count() as f64),
            c.hidden_size,
            c.intermediate_size,
            c.num_hidden_layers,
            c.num_attention_heads,
            c.batch_size,
            c.max_seq_len
        ));
    }
    out
}

/// Render Table 3 (training memory, MB) from the analytic model for the
/// paper-exact configs — the reproduction of §A.3.
pub fn table3() -> String {
    let mut out = String::new();
    out.push_str("Table 3: modeled training memory (MB/GPU, paper-exact configs, BitNet-style)\n");
    out.push_str(
        "| size  | FP32   | BF16   | BF16+AF | FP8    | FP8+AF |\n\
         |-------|--------|--------|---------|--------|--------|\n",
    );
    for (label, name) in [("130M", "p130m"), ("1B", "p1b")] {
        let m = |env, opt| {
            let spec = VariantSpec::new(name, Mode::Bitnet158, 1.58)
                .with_env(env)
                .with_optimizer(opt);
            memory::estimate(&spec, true).unwrap().total_mb()
        };
        out.push_str(&format!(
            "| {:<5} | {:>6.0} | {:>6.0} | {:>7.0} | {:>6.0} | {:>6.0} |\n",
            label,
            m(Env::Fp32, Optimizer::Adamw),
            m(Env::Bf16, Optimizer::Adamw),
            m(Env::Bf16, Optimizer::Adafactor),
            m(Env::Fp8, Optimizer::Adamw),
            m(Env::Fp8, Optimizer::Adafactor),
        ));
    }
    out.push_str(
        "paper (GH200, measured): 130M: 69327/54675/53827/39276/38315; \
         1B: 76533/58345/53723/40945/37669\n",
    );
    out
}

/// Render a train run's `quant_health.json` as the per-layer table plus
/// anomaly verdicts — the offline twin of the `repro watch` QuantHealth
/// view (`repro report --exp quant-health [--run DIR]`).
pub fn quant_health(run_dir: &Path) -> Result<String> {
    let h = crate::obs::quant::QuantHealth::load(run_dir).map_err(|e| {
        anyhow!(
            "no quant health for {}: {e} (quant_health.json is written by \
             train runs with grid-quantized layers)",
            run_dir.display()
        )
    })?;
    Ok(format!("run: {}\n{}", run_dir.display(), h.render_table()))
}

/// Default run dir for `report --exp quant-health`: the most recently
/// modified directory under `results/train` that holds a
/// `quant_health.json`.
pub fn latest_quant_health_run(results: &Path) -> Result<std::path::PathBuf> {
    let train = results.join("train");
    let mut best: Option<(std::time::SystemTime, std::path::PathBuf)> = None;
    if let Ok(entries) = std::fs::read_dir(&train) {
        for e in entries.flatten() {
            let file = e.path().join("quant_health.json");
            let Ok(meta) = std::fs::metadata(&file) else { continue };
            let t = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            if best.as_ref().map_or(true, |(bt, _)| t > *bt) {
                best = Some((t, e.path()));
            }
        }
    }
    best.map(|(_, p)| p).ok_or_else(|| {
        anyhow!(
            "no run with quant_health.json under {} — pass --run DIR or train first",
            train.display()
        )
    })
}

/// DQT-vs-BitNet state memory comparison (the §1 motivation table). The
/// deployed-checkpoint column reads packed sizes from the codec registry
/// (`quant::codec::Format`) instead of re-deriving bit widths.
pub fn memory_comparison(model: &str) -> Result<String> {
    let cfg = ModelConfig::by_name(model).ok_or_else(|| anyhow!("bad model"))?;
    let p_total = cfg.param_count() as usize;
    let p_quant = cfg.quantized_param_count() as usize;
    let mut out = String::new();
    out.push_str(&format!(
        "Model-state memory (no activations/framework), {model}:\n"
    ));
    out.push_str(
        "| variant        | weights  | grads    | optim    | total    | deploy   |\n",
    );
    for (label, spec) in [
        ("fp32", VariantSpec::new(model, Mode::Fp32, 1.58)),
        ("bitnet b1.58", VariantSpec::new(model, Mode::Bitnet158, 1.58)),
        ("dqt ternary", VariantSpec::new(model, Mode::Dqt, 1.58)),
        ("dqt 8bit", VariantSpec::new(model, Mode::Dqt, 8.0)),
    ] {
        let b = memory::estimate(&spec, false).ok_or_else(|| anyhow!("bad model"))?;
        // packed checkpoint on disk / packed-grid state on the host:
        // quantized set in its true format, the rest in f32
        let deploy = if spec.mode.quantized() {
            let fmt = crate::quant::Format::from_bits(spec.bits);
            (fmt.packed_bytes(p_quant) + (p_total - p_quant) * 4) as f64
        } else {
            (p_total * 4) as f64
        };
        out.push_str(&format!(
            "| {:<14} | {:>8} | {:>8} | {:>8} | {:>8} | {:>8} |\n",
            label,
            human(b.weights),
            human(b.grads),
            human(b.optimizer),
            human(b.state_bytes()),
            human(deploy),
        ));
    }
    Ok(out)
}

/// Serving-footprint table (`repro report --exp serving`): packed grid
/// bytes next to the KV-cache term at the batch widths the serving bench
/// measures — what a deployed replica actually holds resident.
pub fn serving_memory(model: &str) -> Result<String> {
    let cfg = ModelConfig::by_name(model).ok_or_else(|| anyhow!("bad model"))?;
    let mut out = String::new();
    out.push_str(&format!(
        "Serving memory (weights + KV cache, no grads/optimizer), {model} \
         (seq {}):\n",
        cfg.max_seq_len
    ));
    out.push_str(
        "| variant          | grid     | dense    | kv b=1   | kv b=16  | total b=16 |\n",
    );
    for (label, spec, ternary) in [
        ("fp32", VariantSpec::new(model, Mode::Fp32, 1.58), false),
        ("bitnet b1.58", VariantSpec::new(model, Mode::Bitnet158, 1.58), false),
        ("dqt ternary", VariantSpec::new(model, Mode::Dqt, 1.58), false),
        ("dqt 8bit", VariantSpec::new(model, Mode::Dqt, 8.0), false),
        ("dqt 8bit →tern", VariantSpec::new(model, Mode::Dqt, 8.0), true),
    ] {
        let b1 = memory::serving_estimate(&spec, 1, ternary)
            .ok_or_else(|| anyhow!("bad model"))?;
        let b16 = memory::serving_estimate(&spec, 16, ternary)
            .ok_or_else(|| anyhow!("bad model"))?;
        out.push_str(&format!(
            "| {:<16} | {:>8} | {:>8} | {:>8} | {:>8} | {:>10} |\n",
            label,
            human(b1.grid_weights),
            human(b1.dense_weights),
            human(b1.kv_cache),
            human(b16.kv_cache),
            human(b16.total()),
        ));
    }
    Ok(out)
}

/// Distributed-training traffic table (`repro report --exp dist`): what
/// each rank keeps resident and what one training step / one weight
/// resync puts on the wire, f32 vs packed-grid exchange — the place the
/// paper's no-master-weights argument shows up as network bytes.
pub fn dist_memory(model: &str, workers: usize) -> Result<String> {
    let cfg = ModelConfig::by_name(model).ok_or_else(|| anyhow!("bad model"))?;
    let mut out = String::new();
    out.push_str(&format!(
        "Distributed data-parallel traffic, {model} at {workers} workers \
         (global batch {}):\n",
        cfg.batch_size
    ));
    out.push_str(
        "| variant        | state/rank | acts/rank | grads/step | sync f32 | sync packed | ratio |\n",
    );
    for (label, spec) in [
        ("fp32", VariantSpec::new(model, Mode::Fp32, 1.58)),
        ("bitnet b1.58", VariantSpec::new(model, Mode::Bitnet158, 1.58)),
        ("dqt ternary", VariantSpec::new(model, Mode::Dqt, 1.58)),
        ("dqt 8bit", VariantSpec::new(model, Mode::Dqt, 8.0)),
    ] {
        let d = memory::dist_estimate(&spec, workers).ok_or_else(|| anyhow!("bad model"))?;
        out.push_str(&format!(
            "| {:<14} | {:>10} | {:>9} | {:>10} | {:>8} | {:>11} | {:>5.1} |\n",
            label,
            human(d.per_rank_state),
            human(d.per_rank_activations),
            human(d.grad_bytes_per_step),
            human(d.sync_bytes_f32),
            human(d.sync_bytes_packed),
            d.sync_ratio(),
        ));
    }
    out.push_str(
        "grads/step: one rank's f32 gradient partial, each way per worker \
         link; sync: an every-K-steps weight resync as f32 vs the packed \
         grid codes + scales (dist::wire GridSync framing).\n",
    );
    // the quantized gradient-exchange tiers apply uniformly — wire cost
    // depends only on parameter count, not the variant's weight mode
    let q = memory::dist_estimate(&VariantSpec::new(model, Mode::Dqt, 1.58), workers)
        .ok_or_else(|| anyhow!("bad model"))?;
    out.push_str(&format!(
        "quantized exchange (--grad-format): int8 puts {} on the wire per \
         step ({:.1}x smaller), ternary {} ({:.1}x); error-feedback \
         residuals hold {} of f32 state per rank.\n",
        human(q.grad_bytes_per_step_int8),
        q.grad_ratio_int8(),
        human(q.grad_bytes_per_step_ternary),
        q.grad_ratio_ternary(),
        human(q.ef_residual_bytes),
    ));
    Ok(out)
}

/// Re-render the per-phase profile table from a `--trace-out` Chrome
/// trace file (`repro report --exp profile --trace trace.json`) — the
/// exact aggregation the traced run printed at exit, replayable offline
/// from the exported JSON.
pub fn profile_from_trace(path: &Path) -> Result<String> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        anyhow!(
            "no trace at {}: {e} — produce one with --trace-out (docs/OBSERVABILITY.md §Tracing)",
            path.display()
        )
    })?;
    let v = crate::util::json::parse(&text)?;
    let evs = v
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow!("not a Chrome trace: no traceEvents array"))?;
    // ph:"X" complete events carry (name, ts, dur); metadata rows don't
    let spans: Vec<(String, u64, u64)> = evs
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .filter_map(|e| {
            Some((
                e.get("name")?.as_str()?.to_string(),
                e.get("ts")?.as_u64()?,
                e.get("dur")?.as_u64()?,
            ))
        })
        .collect();
    if spans.is_empty() {
        return Err(anyhow!("no span events in {}", path.display()));
    }
    let stats = crate::obs::trace::aggregate(spans);
    Ok(crate::obs::trace::render_table(&stats))
}

fn human(bytes: f64) -> String {
    if bytes >= 1e9 {
        format!("{:.2}G", bytes / 1e9)
    } else if bytes >= 1e6 {
        format!("{:.1}M", bytes / 1e6)
    } else if bytes >= 1e3 {
        format!("{:.1}K", bytes / 1e3)
    } else {
        format!("{bytes:.0}")
    }
}

/// Load every job's metrics under `results/<exp>/`.
pub fn load_runs(results_root: &Path, exp: &str) -> Result<Vec<RunMetrics>> {
    let dir = results_root.join(exp);
    let mut runs = Vec::new();
    for entry in std::fs::read_dir(&dir)
        .map_err(|e| {
            anyhow!(
                "no results for {exp:?} at {}: {e} — run `repro sweep --exp {exp}`",
                dir.display()
            )
        })?
    {
        let p = entry?.path();
        if p.join("metrics.json").is_file() {
            runs.push(RunMetrics::load(&p)?);
        }
    }
    if runs.is_empty() {
        return Err(anyhow!("no completed runs under {}", dir.display()));
    }
    runs.sort_by(|a, b| a.variant.cmp(&b.variant).then(a.dataset.cmp(&b.dataset)));
    Ok(runs)
}

/// ASCII multi-curve plot of smoothed training losses (Fig. 2/4/5/7-style).
pub fn ascii_curves(runs: &[RunMetrics], width: usize, height: usize) -> String {
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for r in runs {
        let pts: Vec<(f64, f64)> = r
            .records
            .iter()
            .map(|rec| (rec.step as f64, smooth(r, rec.step) as f64))
            .collect();
        if !pts.is_empty() {
            series.push((format!("{} ({})", r.variant, r.dataset), pts));
        }
    }
    if series.is_empty() {
        return "(no data)".into();
    }
    let xmax = series
        .iter()
        .flat_map(|(_, p)| p.iter().map(|q| q.0))
        .fold(1.0f64, f64::max);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for (_, p) in &series {
        for &(_, y) in p {
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !(ymax > ymin) {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![b' '; width]; height];
    let marks = b"o+x*#@%&";
    for (si, (_, pts)) in series.iter().enumerate() {
        for &(x, y) in pts {
            let col = ((x / xmax) * (width - 1) as f64).round() as usize;
            let row = (((ymax - y) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:7.3} |")
        } else if i == height - 1 {
            format!("{ymin:7.3} |")
        } else {
            "        |".to_string()
        };
        out.push_str(&label);
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("        +{}\n", "-".repeat(width)));
    out.push_str(&format!("         0 .. {xmax:.0} steps\n"));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", marks[si % marks.len()] as char, name));
    }
    out
}

fn smooth(r: &RunMetrics, step: u64) -> f32 {
    // trailing window mean, window 10
    let recs = &r.records;
    let idx = recs.iter().position(|x| x.step == step).unwrap_or(0);
    let lo = idx.saturating_sub(9);
    let w = &recs[lo..=idx];
    w.iter().map(|x| x.loss).sum::<f32>() / w.len() as f32
}

/// Final-loss summary table for an experiment's runs.
pub fn summary_table(runs: &[RunMetrics]) -> String {
    let mut out = String::new();
    out.push_str(
        "| run                                      | final train | dev loss | peak upd%% | ms/step |\n",
    );
    for r in runs {
        out.push_str(&format!(
            "| {:<40} | {:>11.4} | {:>8} | {:>9} | {:>7.1} |\n",
            format!("{} ({})", r.variant, r.dataset),
            r.tail_loss(10).unwrap_or(f32::NAN),
            r.final_dev_loss
                .map(|v| format!("{v:.4}"))
                .unwrap_or_else(|| "-".into()),
            r.peak_upd_frac()
                .map(|v| format!("{:.3}%", v * 100.0))
                .unwrap_or_else(|| "-".into()),
            r.mean_step_ms().unwrap_or(f32::NAN),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::StepRecord;

    /// `quant-health` renders saved runs, picks the newest by default,
    /// and errors helpfully when nothing has been trained yet.
    #[test]
    fn quant_health_report_renders_and_finds_latest_run() {
        use crate::obs::quant::{LayerStep, QuantHealth, QuantStepRecord};
        let results = std::env::temp_dir().join("dqt_report_quant_health_test");
        std::fs::remove_dir_all(&results).ok();
        assert!(latest_quant_health_run(&results).is_err());

        let run = results.join("train").join("test-dqt-b1p58");
        let mut h = QuantHealth::new(&[("layers.0.wq".to_string(), 4)]);
        let mut rec = QuantStepRecord::new(1);
        rec.slots[0] = LayerStep {
            n: 4,
            flips: 2,
            flips_up: 2,
            flips_down: 0,
            net_upd: 2.0,
            abs_upd: 2.0,
            occupancy: [1, 0, 2, 0, 1],
            scale: 2.0,
            gsq: 4.0,
        };
        h.record_step(&rec);
        h.save(&run).unwrap();

        assert_eq!(latest_quant_health_run(&results).unwrap(), run);
        let out = quant_health(&run).unwrap();
        assert!(out.contains("layers.0.wq"), "{out}");
        assert!(out.contains("1 steps recorded"), "{out}");
        let err = quant_health(&results.join("train").join("missing")).unwrap_err();
        assert!(err.to_string().contains("no quant health"), "{err}");
        std::fs::remove_dir_all(&results).ok();
    }

    #[test]
    fn table2_contains_all_presets() {
        let t = table2();
        for n in ["p130m", "p320m", "p1b", "t130", "t320", "t1b"] {
            assert!(t.contains(n), "{n} missing:\n{t}");
        }
    }

    #[test]
    fn table3_monotone_rows() {
        let t = table3();
        assert!(t.contains("130M") && t.contains("1B"));
    }

    #[test]
    fn memory_comparison_renders() {
        let t = memory_comparison("p1b").unwrap();
        assert!(t.contains("dqt ternary"));
    }

    #[test]
    fn serving_memory_renders_all_modes() {
        let t = serving_memory("p1b").unwrap();
        for needle in ["fp32", "bitnet b1.58", "dqt ternary", "dqt 8bit", "kv b=16"] {
            assert!(t.contains(needle), "{needle} missing:\n{t}");
        }
        assert!(serving_memory("nope").is_err());
    }

    #[test]
    fn dist_memory_renders_and_shows_packed_savings() {
        let t = dist_memory("p1b", 4).unwrap();
        for needle in [
            "fp32",
            "bitnet b1.58",
            "dqt ternary",
            "dqt 8bit",
            "sync packed",
            "--grad-format",
            "error-feedback",
        ] {
            assert!(t.contains(needle), "{needle} missing:\n{t}");
        }
        assert!(dist_memory("nope", 4).is_err());
    }

    #[test]
    fn ascii_curves_renders() {
        let mut r = RunMetrics::new("v1", "wiki");
        for i in 0..50 {
            r.push(StepRecord {
                step: i,
                loss: 5.0 - (i as f32) * 0.05,
                lr: 1e-3,
                upd_frac: 0.0,
                gnorm: 1.0,
                step_ms: 1.0,
            });
        }
        let plot = ascii_curves(&[r], 40, 10);
        assert!(plot.contains('o'));
        assert!(plot.contains("steps"));
    }

    #[test]
    fn profile_from_trace_round_trips() {
        let p = std::env::temp_dir().join("dqt_report_trace_test.json");
        std::fs::write(
            &p,
            r#"{"traceEvents":[
                {"ph":"M","pid":1,"tid":0,"name":"thread_name","args":{"name":"main"}},
                {"ph":"X","pid":1,"tid":0,"name":"train.step","cat":"train","ts":0,"dur":100},
                {"ph":"X","pid":1,"tid":0,"name":"train.forward","cat":"train","ts":0,"dur":60}
            ],"otherData":{"dropped_events":0}}"#,
        )
        .unwrap();
        let t = profile_from_trace(&p).unwrap();
        // sorted by total descending; the metadata row is not a phase
        assert!(t.find("train.step").unwrap() < t.find("train.forward").unwrap());
        assert!(!t.contains("thread_name"));
        std::fs::remove_file(&p).ok();
        assert!(profile_from_trace(Path::new("/nonexistent/trace.json")).is_err());
    }

    #[test]
    fn human_units() {
        assert_eq!(human(1.5e9), "1.50G");
        assert_eq!(human(2.5e6), "2.5M");
        assert_eq!(human(999.0), "999");
    }
}
