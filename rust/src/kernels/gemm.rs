//! Cache-blocked, row-partitioned dense GEMM kernels.
//!
//! Three shapes cover the whole model — the forward linear and the two
//! gradients of `y = x·Wᵀ`:
//!
//! * [`matmul_nt`]     — `y[M,N] = x[M,K] @ w[N,K]ᵀ`
//! * [`add_matmul_nn`] — `dx[M,K] += dy[M,N] @ w[N,K]`
//! * [`add_matmul_tn`] — `dw[N,K] += dy[M,N]ᵀ @ x[M,K]`
//!
//! ## Determinism contract
//!
//! The pool only ever partitions **output rows**: a given output element
//! is always produced by exactly one task, with exactly the same
//! floating-point addition chain as the scalar reference loop. Blocking
//! over K ([`KC`]) parks the running accumulator in the output between
//! panels, which keeps the chain k-ascending, one product at a time —
//! bitwise identical to an unblocked dot. Consequently every kernel here
//! is bitwise-reproducible across thread counts *and* against the
//! `#[cfg(test)]` scalar oracles retained in `runtime::native::math`
//! (pinned by exact-equality property tests, not tolerance tests).
//!
//! ## Fast tier
//!
//! When the pool carries [`Precision::Fast`], each kernel dispatches to a
//! wide multi-accumulator microkernel instead: [`LANES`]-lane (`[f32; 8]`)
//! partial accumulators per output column, [`NB_FAST`] columns per block,
//! an unrolled K loop, and a fixed pairwise horizontal reduction at the
//! end ([`hsum8`]). The independent lanes break the loop-carried addition
//! dependence, so LLVM auto-vectorizes the inner loop to SIMD — that is
//! the whole speedup; there are no intrinsics here. Reassociating the sum
//! changes low-order bits, so fast results match exact to f32 tolerance
//! (property-tested below), not bitwise; every output element still has
//! *one* fixed chain, so fast mode stays deterministic for a fixed thread
//! count. Exact remains the default tier ([`Pool::new`]).
//!
//! ## Blocking scheme
//!
//! * `matmul_nt` walks K in [`KC`]-sized panels so one pass keeps the
//!   `x`-row slice resident while streaming `w`; rows of `y` are chunked
//!   ~4 chunks per worker for balance. Batches smaller than the pool
//!   (decode steps, GEMV) switch to splitting each output row's columns
//!   across the pool instead, so single-sequence decode still scales.
//!   Chunk sizes come from [`Pool::chunk_rows`], whose minimum-work gate
//!   runs tiny products inline (single chunk, no spawns) — partition
//!   choice never changes the bits, only where they are computed.
//! * `add_matmul_nn` reuses an [`NC`]-row panel of `w` across every row
//!   of its band before moving on.
//! * `add_matmul_tn` partitions the `dw` output channels; each pass over
//!   a batch row `x[r]` is reused by the whole band.

use super::pool::Pool;
use crate::config::Precision;

/// K-panel length (f32 elements): a panel of one `x` row is 1 KiB.
const KC: usize = 256;
/// Output-channel panel for the input-gradient kernel.
const NC: usize = 64;
/// Fast tier: f32 lanes per partial accumulator (one 256-bit vector).
const LANES: usize = 8;
/// Fast tier: output columns per dense microkernel block — four
/// independent `[f32; LANES]` accumulators live across the K loop, and
/// the `x` panel loaded for column `c` is reused by all four.
const NB_FAST: usize = 4;

/// `y[M,N] = x[M,K] @ w[N,K]ᵀ` — the forward linear (`w` row-major
/// `[out, in]`, matching the python `x @ w.T`).
pub fn matmul_nt(pool: &Pool, x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    let mut y = vec![0f32; m * n];
    if m == 0 || n == 0 {
        return y;
    }
    let fast = pool.precision() == Precision::Fast;
    if m < pool.threads() {
        // decode-sized batches: split each row's output columns instead
        let cchunk = pool.chunk_rows(n, k);
        for r in 0..m {
            let xr = &x[r * k..(r + 1) * k];
            pool.for_each_chunk_mut(&mut y[r * n..(r + 1) * n], cchunk, |ci, seg| {
                let c0 = ci * cchunk;
                for (j, o) in seg.iter_mut().enumerate() {
                    let wr = &w[(c0 + j) * k..(c0 + j + 1) * k];
                    *o = if fast {
                        dot_fast(xr, wr)
                    } else {
                        let mut acc = 0f32;
                        for (a, b) in xr.iter().zip(wr.iter()) {
                            acc += a * b;
                        }
                        acc
                    };
                }
            });
        }
        return y;
    }
    let rows_per = pool.chunk_rows(m, n * k);
    pool.for_each_chunk_mut(&mut y, rows_per * n, |ci, band| {
        if fast {
            matmul_nt_band_fast(x, w, ci * rows_per, band.len() / n, k, n, band);
        } else {
            matmul_nt_band(x, w, ci * rows_per, band.len() / n, k, n, band);
        }
    });
    y
}

/// Fast-tier pairwise horizontal reduction of one lane accumulator. The
/// tree shape is fixed, so a given output element's value is independent
/// of which band/path computed it.
#[inline]
fn hsum8(a: &[f32; LANES]) -> f32 {
    ((a[0] + a[4]) + (a[2] + a[6])) + ((a[1] + a[5]) + (a[3] + a[7]))
}

/// Fast-tier dot product: one `[f32; LANES]` accumulator, K unrolled by
/// [`LANES`], scalar tail, [`hsum8`] reduction. The per-element chain is
/// identical to a single column of [`matmul_nt_band_fast`], so the
/// column-split decode path and the row-banded path agree bitwise.
#[inline]
fn dot_fast(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let kb = k - k % LANES;
    let mut acc = [0f32; LANES];
    let mut j = 0;
    while j < kb {
        let xs = &a[j..j + LANES];
        let ws = &b[j..j + LANES];
        for l in 0..LANES {
            acc[l] += xs[l] * ws[l];
        }
        j += LANES;
    }
    let mut t = hsum8(&acc);
    while j < k {
        t += a[j] * b[j];
        j += 1;
    }
    t
}

/// Fast-tier row-band of [`matmul_nt`]: [`NB_FAST`] output columns per
/// block, each with its own `[f32; LANES]` accumulator across an unrolled
/// K loop (the `x` panel is loaded once per block and reused by all four
/// columns). No K-panels: the whole `x` row stays L1/L2-resident and `y`
/// is written exactly once.
fn matmul_nt_band_fast(
    x: &[f32],
    w: &[f32],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) {
    let kb = k - k % LANES;
    for r in 0..rows {
        let xr = &x[(row0 + r) * k..(row0 + r) * k + k];
        let yr = &mut y[r * n..(r + 1) * n];
        let mut c = 0;
        while c + NB_FAST <= n {
            let w0 = &w[c * k..c * k + k];
            let w1 = &w[(c + 1) * k..(c + 1) * k + k];
            let w2 = &w[(c + 2) * k..(c + 2) * k + k];
            let w3 = &w[(c + 3) * k..(c + 3) * k + k];
            let mut a0 = [0f32; LANES];
            let mut a1 = [0f32; LANES];
            let mut a2 = [0f32; LANES];
            let mut a3 = [0f32; LANES];
            let mut j = 0;
            while j < kb {
                let xs = &xr[j..j + LANES];
                let s0 = &w0[j..j + LANES];
                let s1 = &w1[j..j + LANES];
                let s2 = &w2[j..j + LANES];
                let s3 = &w3[j..j + LANES];
                for l in 0..LANES {
                    let xv = xs[l];
                    a0[l] += xv * s0[l];
                    a1[l] += xv * s1[l];
                    a2[l] += xv * s2[l];
                    a3[l] += xv * s3[l];
                }
                j += LANES;
            }
            let mut t0 = hsum8(&a0);
            let mut t1 = hsum8(&a1);
            let mut t2 = hsum8(&a2);
            let mut t3 = hsum8(&a3);
            while j < k {
                let xv = xr[j];
                t0 += xv * w0[j];
                t1 += xv * w1[j];
                t2 += xv * w2[j];
                t3 += xv * w3[j];
                j += 1;
            }
            yr[c] = t0;
            yr[c + 1] = t1;
            yr[c + 2] = t2;
            yr[c + 3] = t3;
            c += NB_FAST;
        }
        while c < n {
            yr[c] = dot_fast(xr, &w[c * k..c * k + k]);
            c += 1;
        }
    }
}

/// One row-band of [`matmul_nt`]: rows `row0..row0+rows` of `y`, K walked
/// in [`KC`] panels with the running total parked in `y` between panels
/// (the accumulation chain stays k-ascending — see the module docs).
fn matmul_nt_band(
    x: &[f32],
    w: &[f32],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) {
    let mut kb = 0;
    while kb < k {
        let kc = KC.min(k - kb);
        for r in 0..rows {
            let xr = &x[(row0 + r) * k + kb..(row0 + r) * k + kb + kc];
            let yr = &mut y[r * n..(r + 1) * n];
            for (c, yc) in yr.iter_mut().enumerate() {
                let wr = &w[c * k + kb..c * k + kb + kc];
                let mut acc = *yc;
                for (a, b) in xr.iter().zip(wr.iter()) {
                    acc += a * b;
                }
                *yc = acc;
            }
        }
        kb += kc;
    }
}

/// `dx[M,K] += dy[M,N] @ w[N,K]` — input gradient of the linear.
/// Partitioned over rows of `dx`; within a band, an [`NC`]-row panel of
/// `w` is reused across every band row. Per element the contributions
/// still land in ascending-`c` order, so the result is bitwise identical
/// to the scalar loop at any thread count.
pub fn add_matmul_nn(
    pool: &Pool,
    dy: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(dx.len(), m * k);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let fast = pool.precision() == Precision::Fast;
    let rows_per = pool.chunk_rows(m, n * k);
    pool.for_each_chunk_mut(dx, rows_per * k, |ci, band| {
        let row0 = ci * rows_per;
        let rows = band.len() / k;
        if fast {
            add_nn_rows_fast(dy, w, row0, rows, n, k, band);
            return;
        }
        let mut cb = 0;
        while cb < n {
            let cc = NC.min(n - cb);
            for r in 0..rows {
                let dyr = &dy[(row0 + r) * n + cb..(row0 + r) * n + cb + cc];
                let dxr = &mut band[r * k..(r + 1) * k];
                for (cj, &d) in dyr.iter().enumerate() {
                    if d == 0.0 {
                        continue;
                    }
                    let wr = &w[(cb + cj) * k..(cb + cj + 1) * k];
                    for (o, &wv) in dxr.iter_mut().zip(wr.iter()) {
                        *o += d * wv;
                    }
                }
            }
            cb += cc;
        }
    });
}

/// Fast-tier band of [`add_matmul_nn`]: [`NB_FAST`] `dy` columns are
/// folded into `dx` per pass, so each element of the band is
/// loaded/stored once per four contributions instead of once per one —
/// and the four-term update has no loop-carried dependence, so it
/// vectorizes. Reassociates the ascending-`c` chain (tolerance, not
/// bitwise, vs exact).
fn add_nn_rows_fast(
    dy: &[f32],
    w: &[f32],
    row0: usize,
    rows: usize,
    n: usize,
    k: usize,
    band: &mut [f32],
) {
    let nb = n - n % NB_FAST;
    for r in 0..rows {
        let dyr = &dy[(row0 + r) * n..(row0 + r) * n + n];
        let dxr = &mut band[r * k..(r + 1) * k];
        let mut c = 0;
        while c < nb {
            let (d0, d1, d2, d3) = (dyr[c], dyr[c + 1], dyr[c + 2], dyr[c + 3]);
            if d0 != 0.0 || d1 != 0.0 || d2 != 0.0 || d3 != 0.0 {
                let w0 = &w[c * k..c * k + k];
                let w1 = &w[(c + 1) * k..(c + 1) * k + k];
                let w2 = &w[(c + 2) * k..(c + 2) * k + k];
                let w3 = &w[(c + 3) * k..(c + 3) * k + k];
                for j in 0..k {
                    dxr[j] += d0 * w0[j] + d1 * w1[j] + d2 * w2[j] + d3 * w3[j];
                }
            }
            c += NB_FAST;
        }
        while c < n {
            let d = dyr[c];
            if d != 0.0 {
                let wr = &w[c * k..c * k + k];
                for (o, &wv) in dxr.iter_mut().zip(wr.iter()) {
                    *o += d * wv;
                }
            }
            c += 1;
        }
    }
}

/// `dw[N,K] += dy[M,N]ᵀ @ x[M,K]` — weight gradient of the linear.
/// Partitioned over output channels of `dw`; every pass over a batch row
/// `x[r]` serves the whole band. Contributions land in ascending-`r`
/// order per element — the scalar loop's chain, bitwise.
pub fn add_matmul_tn(
    pool: &Pool,
    dy: &[f32],
    x: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dw.len(), n * k);
    if n == 0 || k == 0 {
        return;
    }
    let fast = pool.precision() == Precision::Fast;
    let cols_per = pool.chunk_rows(n, m * k);
    pool.for_each_chunk_mut(dw, cols_per * k, |ci, band| {
        let c0 = ci * cols_per;
        let cols = band.len() / k;
        if fast {
            add_tn_cols_fast(dy, x, c0, cols, m, n, k, band);
            return;
        }
        for r in 0..m {
            let xr = &x[r * k..(r + 1) * k];
            let dyr = &dy[r * n..(r + 1) * n];
            for cj in 0..cols {
                let d = dyr[c0 + cj];
                if d == 0.0 {
                    continue;
                }
                let dwr = &mut band[cj * k..(cj + 1) * k];
                for (o, &xv) in dwr.iter_mut().zip(xr.iter()) {
                    *o += d * xv;
                }
            }
        }
    });
}

/// Fast-tier band of [`add_matmul_tn`]: [`NB_FAST`] batch rows folded
/// into each `dw` channel per pass (the transpose-side twin of
/// [`add_nn_rows_fast`] — same four-term vectorizable update, same
/// tolerance-not-bitwise contract vs the ascending-`r` exact chain).
#[allow(clippy::too_many_arguments)]
fn add_tn_cols_fast(
    dy: &[f32],
    x: &[f32],
    c0: usize,
    cols: usize,
    m: usize,
    n: usize,
    k: usize,
    band: &mut [f32],
) {
    let mb = m - m % NB_FAST;
    let mut r = 0;
    while r < mb {
        let x0 = &x[r * k..r * k + k];
        let x1 = &x[(r + 1) * k..(r + 1) * k + k];
        let x2 = &x[(r + 2) * k..(r + 2) * k + k];
        let x3 = &x[(r + 3) * k..(r + 3) * k + k];
        for cj in 0..cols {
            let c = c0 + cj;
            let (d0, d1, d2, d3) = (
                dy[r * n + c],
                dy[(r + 1) * n + c],
                dy[(r + 2) * n + c],
                dy[(r + 3) * n + c],
            );
            if d0 != 0.0 || d1 != 0.0 || d2 != 0.0 || d3 != 0.0 {
                let dwr = &mut band[cj * k..(cj + 1) * k];
                for j in 0..k {
                    dwr[j] += d0 * x0[j] + d1 * x1[j] + d2 * x2[j] + d3 * x3[j];
                }
            }
        }
        r += NB_FAST;
    }
    while r < m {
        let xr = &x[r * k..(r + 1) * k];
        let dyr = &dy[r * n..(r + 1) * n];
        for cj in 0..cols {
            let d = dyr[c0 + cj];
            if d != 0.0 {
                let dwr = &mut band[cj * k..(cj + 1) * k];
                for (o, &xv) in dwr.iter_mut().zip(xr.iter()) {
                    *o += d * xv;
                }
            }
        }
        r += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect()
    }

    /// The blocked kernels are bitwise thread-count-invariant on shapes
    /// that are NOT multiples of any block size (odd M, N, K, including
    /// M smaller than the pool — the column-split decode path).
    #[test]
    fn kernels_are_bitwise_identical_across_thread_counts() {
        let mut rng = Rng::new(0x6E44);
        for case in 0..40 {
            let m = 1 + rng.below(13);
            let k = 1 + rng.below(2 * KC + 11);
            let n = 1 + rng.below(37);
            let x = rand_vec(&mut rng, m * k);
            let w = rand_vec(&mut rng, n * k);
            let dy = rand_vec(&mut rng, m * n);
            let pools = [Pool::new(1), Pool::new(2), Pool::new(5)];
            let ys: Vec<Vec<f32>> = pools
                .iter()
                .map(|p| matmul_nt(p, &x, &w, m, k, n))
                .collect();
            assert_eq!(ys[0], ys[1], "case {case} (m={m} k={k} n={n})");
            assert_eq!(ys[0], ys[2], "case {case} (m={m} k={k} n={n})");
            let dxs: Vec<Vec<f32>> = pools
                .iter()
                .map(|p| {
                    let mut dx = rand_vec(&mut Rng::new(7), m * k);
                    add_matmul_nn(p, &dy, &w, m, n, k, &mut dx);
                    dx
                })
                .collect();
            assert_eq!(dxs[0], dxs[1], "case {case} dx");
            assert_eq!(dxs[0], dxs[2], "case {case} dx");
            let dws: Vec<Vec<f32>> = pools
                .iter()
                .map(|p| {
                    let mut dw = rand_vec(&mut Rng::new(9), n * k);
                    add_matmul_tn(p, &dy, &x, m, n, k, &mut dw);
                    dw
                })
                .collect();
            assert_eq!(dws[0], dws[1], "case {case} dw");
            assert_eq!(dws[0], dws[2], "case {case} dw");
        }
    }

    #[test]
    fn degenerate_shapes_do_not_panic() {
        for pool in [Pool::new(3), Pool::with_precision(3, Precision::Fast)] {
            assert!(matmul_nt(&pool, &[], &[], 0, 4, 0).is_empty());
            assert_eq!(matmul_nt(&pool, &[], &[], 1, 0, 2), vec![0.0, 0.0]);
            let mut dx: Vec<f32> = vec![];
            add_matmul_nn(&pool, &[], &[], 0, 0, 0, &mut dx);
            let mut dw: Vec<f32> = vec![];
            add_matmul_tn(&pool, &[], &[], 0, 0, 3, &mut dw);
        }
    }

    /// Fast-tier kernels agree with exact to f32 tolerance on shapes that
    /// are not multiples of any lane/block width, at every thread count —
    /// and over the whole suite the reassociated sums must actually
    /// differ from exact somewhere (else the fast path silently ran the
    /// exact chains and the tolerance gate is vacuous).
    #[test]
    fn fast_kernels_match_exact_within_tolerance() {
        let mut rng = Rng::new(0xFA57);
        let mut any_bit_diff = false;
        for case in 0..40 {
            let m = 1 + rng.below(13);
            let k = 1 + rng.below(2 * KC + 11);
            let n = 1 + rng.below(37);
            let x = rand_vec(&mut rng, m * k);
            let w = rand_vec(&mut rng, n * k);
            let dy = rand_vec(&mut rng, m * n);
            let close = |a: &[f32], b: &[f32], dim: usize, what: &str| {
                let tol = 1e-5 + dim as f32 * 1e-6;
                for (i, (u, v)) in a.iter().zip(b.iter()).enumerate() {
                    assert!(
                        (u - v).abs() <= tol * (1.0 + v.abs()),
                        "case {case} {what}[{i}]: fast {u} vs exact {v} (m={m} k={k} n={n})"
                    );
                }
            };
            let y_exact = matmul_nt(&Pool::new(1), &x, &w, m, k, n);
            let mut dx_exact = rand_vec(&mut Rng::new(7), m * k);
            add_matmul_nn(&Pool::new(1), &dy, &w, m, n, k, &mut dx_exact);
            let mut dw_exact = rand_vec(&mut Rng::new(9), n * k);
            add_matmul_tn(&Pool::new(1), &dy, &x, m, n, k, &mut dw_exact);
            for threads in [1usize, 2, 5] {
                let fp = Pool::with_precision(threads, Precision::Fast);
                let y = matmul_nt(&fp, &x, &w, m, k, n);
                close(&y, &y_exact, k, "y");
                any_bit_diff |= y != y_exact;
                let mut dx = rand_vec(&mut Rng::new(7), m * k);
                add_matmul_nn(&fp, &dy, &w, m, n, k, &mut dx);
                close(&dx, &dx_exact, n, "dx");
                any_bit_diff |= dx != dx_exact;
                let mut dw = rand_vec(&mut Rng::new(9), n * k);
                add_matmul_tn(&fp, &dy, &x, m, n, k, &mut dw);
                close(&dw, &dw_exact, m, "dw");
                any_bit_diff |= dw != dw_exact;
            }
        }
        assert!(
            any_bit_diff,
            "fast tier never reassociated a single sum across 40 cases"
        );
    }

    /// Fast mode is deterministic for a fixed thread count: rerunning the
    /// same kernel on an identical pool reproduces every bit.
    #[test]
    fn fast_kernels_are_deterministic_per_thread_count() {
        let mut rng = Rng::new(0xD373);
        for threads in [1usize, 4] {
            let fp = Pool::with_precision(threads, Precision::Fast);
            let (m, k, n) = (9, 2 * KC + 5, 33);
            let x = rand_vec(&mut rng, m * k);
            let w = rand_vec(&mut rng, n * k);
            let dy = rand_vec(&mut rng, m * n);
            assert_eq!(
                matmul_nt(&fp, &x, &w, m, k, n),
                matmul_nt(&fp, &x, &w, m, k, n),
                "t{threads}"
            );
            let run_nn = || {
                let mut dx = vec![0.1f32; m * k];
                add_matmul_nn(&fp, &dy, &w, m, n, k, &mut dx);
                dx
            };
            assert_eq!(run_nn(), run_nn(), "t{threads} dx");
            let run_tn = || {
                let mut dw = vec![0.2f32; n * k];
                add_matmul_tn(&fp, &dy, &x, m, n, k, &mut dw);
                dw
            };
            assert_eq!(run_tn(), run_tn(), "t{threads} dw");
        }
    }

    #[test]
    fn matmul_small_known_answer() {
        // x = [[1,2],[3,4]], w = [[1,0],[0,1],[1,1]]
        for threads in [1, 4] {
            let pool = Pool::new(threads);
            let y = matmul_nt(
                &pool,
                &[1.0, 2.0, 3.0, 4.0],
                &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
                2,
                2,
                3,
            );
            assert_eq!(y, vec![1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
        }
    }
}
