//! Cache-blocked, row-partitioned dense GEMM kernels.
//!
//! Three shapes cover the whole model — the forward linear and the two
//! gradients of `y = x·Wᵀ`:
//!
//! * [`matmul_nt`]     — `y[M,N] = x[M,K] @ w[N,K]ᵀ`
//! * [`add_matmul_nn`] — `dx[M,K] += dy[M,N] @ w[N,K]`
//! * [`add_matmul_tn`] — `dw[N,K] += dy[M,N]ᵀ @ x[M,K]`
//!
//! ## Determinism contract
//!
//! The pool only ever partitions **output rows**: a given output element
//! is always produced by exactly one task, with exactly the same
//! floating-point addition chain as the scalar reference loop. Blocking
//! over K ([`KC`]) parks the running accumulator in the output between
//! panels, which keeps the chain k-ascending, one product at a time —
//! bitwise identical to an unblocked dot. Consequently every kernel here
//! is bitwise-reproducible across thread counts *and* against the
//! `#[cfg(test)]` scalar oracles retained in `runtime::native::math`
//! (pinned by exact-equality property tests, not tolerance tests).
//!
//! ## Blocking scheme
//!
//! * `matmul_nt` walks K in [`KC`]-sized panels so one pass keeps the
//!   `x`-row slice resident while streaming `w`; rows of `y` are chunked
//!   ~4 chunks per worker for balance. Batches smaller than the pool
//!   (decode steps, GEMV) switch to splitting each output row's columns
//!   across the pool instead, so single-sequence decode still scales.
//!   Chunk sizes come from [`Pool::chunk_rows`], whose minimum-work gate
//!   runs tiny products inline (single chunk, no spawns) — partition
//!   choice never changes the bits, only where they are computed.
//! * `add_matmul_nn` reuses an [`NC`]-row panel of `w` across every row
//!   of its band before moving on.
//! * `add_matmul_tn` partitions the `dw` output channels; each pass over
//!   a batch row `x[r]` is reused by the whole band.

use super::pool::Pool;

/// K-panel length (f32 elements): a panel of one `x` row is 1 KiB.
const KC: usize = 256;
/// Output-channel panel for the input-gradient kernel.
const NC: usize = 64;

/// `y[M,N] = x[M,K] @ w[N,K]ᵀ` — the forward linear (`w` row-major
/// `[out, in]`, matching the python `x @ w.T`).
pub fn matmul_nt(pool: &Pool, x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), n * k);
    let mut y = vec![0f32; m * n];
    if m == 0 || n == 0 {
        return y;
    }
    if m < pool.threads() {
        // decode-sized batches: split each row's output columns instead
        let cchunk = pool.chunk_rows(n, k);
        for r in 0..m {
            let xr = &x[r * k..(r + 1) * k];
            pool.for_each_chunk_mut(&mut y[r * n..(r + 1) * n], cchunk, |ci, seg| {
                let c0 = ci * cchunk;
                for (j, o) in seg.iter_mut().enumerate() {
                    let wr = &w[(c0 + j) * k..(c0 + j + 1) * k];
                    let mut acc = 0f32;
                    for (a, b) in xr.iter().zip(wr.iter()) {
                        acc += a * b;
                    }
                    *o = acc;
                }
            });
        }
        return y;
    }
    let rows_per = pool.chunk_rows(m, n * k);
    pool.for_each_chunk_mut(&mut y, rows_per * n, |ci, band| {
        matmul_nt_band(x, w, ci * rows_per, band.len() / n, k, n, band);
    });
    y
}

/// One row-band of [`matmul_nt`]: rows `row0..row0+rows` of `y`, K walked
/// in [`KC`] panels with the running total parked in `y` between panels
/// (the accumulation chain stays k-ascending — see the module docs).
fn matmul_nt_band(
    x: &[f32],
    w: &[f32],
    row0: usize,
    rows: usize,
    k: usize,
    n: usize,
    y: &mut [f32],
) {
    let mut kb = 0;
    while kb < k {
        let kc = KC.min(k - kb);
        for r in 0..rows {
            let xr = &x[(row0 + r) * k + kb..(row0 + r) * k + kb + kc];
            let yr = &mut y[r * n..(r + 1) * n];
            for (c, yc) in yr.iter_mut().enumerate() {
                let wr = &w[c * k + kb..c * k + kb + kc];
                let mut acc = *yc;
                for (a, b) in xr.iter().zip(wr.iter()) {
                    acc += a * b;
                }
                *yc = acc;
            }
        }
        kb += kc;
    }
}

/// `dx[M,K] += dy[M,N] @ w[N,K]` — input gradient of the linear.
/// Partitioned over rows of `dx`; within a band, an [`NC`]-row panel of
/// `w` is reused across every band row. Per element the contributions
/// still land in ascending-`c` order, so the result is bitwise identical
/// to the scalar loop at any thread count.
pub fn add_matmul_nn(
    pool: &Pool,
    dy: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(dx.len(), m * k);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let rows_per = pool.chunk_rows(m, n * k);
    pool.for_each_chunk_mut(dx, rows_per * k, |ci, band| {
        let row0 = ci * rows_per;
        let rows = band.len() / k;
        let mut cb = 0;
        while cb < n {
            let cc = NC.min(n - cb);
            for r in 0..rows {
                let dyr = &dy[(row0 + r) * n + cb..(row0 + r) * n + cb + cc];
                let dxr = &mut band[r * k..(r + 1) * k];
                for (cj, &d) in dyr.iter().enumerate() {
                    if d == 0.0 {
                        continue;
                    }
                    let wr = &w[(cb + cj) * k..(cb + cj + 1) * k];
                    for (o, &wv) in dxr.iter_mut().zip(wr.iter()) {
                        *o += d * wv;
                    }
                }
            }
            cb += cc;
        }
    });
}

/// `dw[N,K] += dy[M,N]ᵀ @ x[M,K]` — weight gradient of the linear.
/// Partitioned over output channels of `dw`; every pass over a batch row
/// `x[r]` serves the whole band. Contributions land in ascending-`r`
/// order per element — the scalar loop's chain, bitwise.
pub fn add_matmul_tn(
    pool: &Pool,
    dy: &[f32],
    x: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dw: &mut [f32],
) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dw.len(), n * k);
    if n == 0 || k == 0 {
        return;
    }
    let cols_per = pool.chunk_rows(n, m * k);
    pool.for_each_chunk_mut(dw, cols_per * k, |ci, band| {
        let c0 = ci * cols_per;
        let cols = band.len() / k;
        for r in 0..m {
            let xr = &x[r * k..(r + 1) * k];
            let dyr = &dy[r * n..(r + 1) * n];
            for cj in 0..cols {
                let d = dyr[c0 + cj];
                if d == 0.0 {
                    continue;
                }
                let dwr = &mut band[cj * k..(cj + 1) * k];
                for (o, &xv) in dwr.iter_mut().zip(xr.iter()) {
                    *o += d * xv;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect()
    }

    /// The blocked kernels are bitwise thread-count-invariant on shapes
    /// that are NOT multiples of any block size (odd M, N, K, including
    /// M smaller than the pool — the column-split decode path).
    #[test]
    fn kernels_are_bitwise_identical_across_thread_counts() {
        let mut rng = Rng::new(0x6E44);
        for case in 0..40 {
            let m = 1 + rng.below(13);
            let k = 1 + rng.below(2 * KC + 11);
            let n = 1 + rng.below(37);
            let x = rand_vec(&mut rng, m * k);
            let w = rand_vec(&mut rng, n * k);
            let dy = rand_vec(&mut rng, m * n);
            let pools = [Pool::new(1), Pool::new(2), Pool::new(5)];
            let ys: Vec<Vec<f32>> = pools
                .iter()
                .map(|p| matmul_nt(p, &x, &w, m, k, n))
                .collect();
            assert_eq!(ys[0], ys[1], "case {case} (m={m} k={k} n={n})");
            assert_eq!(ys[0], ys[2], "case {case} (m={m} k={k} n={n})");
            let dxs: Vec<Vec<f32>> = pools
                .iter()
                .map(|p| {
                    let mut dx = rand_vec(&mut Rng::new(7), m * k);
                    add_matmul_nn(p, &dy, &w, m, n, k, &mut dx);
                    dx
                })
                .collect();
            assert_eq!(dxs[0], dxs[1], "case {case} dx");
            assert_eq!(dxs[0], dxs[2], "case {case} dx");
            let dws: Vec<Vec<f32>> = pools
                .iter()
                .map(|p| {
                    let mut dw = rand_vec(&mut Rng::new(9), n * k);
                    add_matmul_tn(p, &dy, &x, m, n, k, &mut dw);
                    dw
                })
                .collect();
            assert_eq!(dws[0], dws[1], "case {case} dw");
            assert_eq!(dws[0], dws[2], "case {case} dw");
        }
    }

    #[test]
    fn degenerate_shapes_do_not_panic() {
        let pool = Pool::new(3);
        assert!(matmul_nt(&pool, &[], &[], 0, 4, 0).is_empty());
        assert_eq!(matmul_nt(&pool, &[], &[], 1, 0, 2), vec![0.0, 0.0]);
        let mut dx: Vec<f32> = vec![];
        add_matmul_nn(&pool, &[], &[], 0, 0, 0, &mut dx);
        let mut dw: Vec<f32> = vec![];
        add_matmul_tn(&pool, &[], &[], 0, 0, 3, &mut dw);
    }

    #[test]
    fn matmul_small_known_answer() {
        // x = [[1,2],[3,4]], w = [[1,0],[0,1],[1,1]]
        for threads in [1, 4] {
            let pool = Pool::new(threads);
            let y = matmul_nt(
                &pool,
                &[1.0, 2.0, 3.0, 4.0],
                &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0],
                2,
                2,
                3,
            );
            assert_eq!(y, vec![1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
        }
    }
}
