//! Parallel packed-ternary GEMM/GEMV over 2-bit codes.
//!
//! The arithmetic lives in [`crate::quant::ternary::dot_rows`] — the
//! byte-LUT fused dot products that read the 2-bit weight stream without
//! ever materializing an f32 weight. This module owns the *partitioning*:
//! output channels (rows of the packed weight) are fanned across the
//! [`Pool`], each task writing its own contiguous band of a transposed
//! `[n_out, M]` scratch, which is then transposed back to the row-major
//! `[M, n_out]` the callers expect (for GEMV, `M = 1`, the scratch *is*
//! the result and no transpose happens).
//!
//! Determinism: a task computes whole output channels with the exact
//! accumulation order of the serial kernel, so results are bitwise
//! identical at every thread count — the property the serving decode path
//! relies on (`tests/parallel_determinism.rs`).
//!
//! ## Fast tier
//!
//! A pool carrying [`Precision::Fast`] switches eligible calls
//! (byte-aligned rows, `k % 4 == 0`, and at least [`LUT_MIN_CHANNELS`]
//! output channels to amortize the build) to the activation-block LUT
//! GEMM: [`crate::quant::ternary::block_tables`] precomputes, per
//! activation row and 4-wide activation block, the partial dot sum of
//! every possible weight byte (256 entries, built once per call and
//! fanned over the pool), after which each weight byte costs one table
//! hit + one add per batch row instead of four decode-multiply-adds
//! ([`crate::quant::ternary::dot_rows_lut`]). The fast tier's contract
//! is f32 tolerance vs exact plus bitwise determinism for a fixed thread
//! count (table values are partition-independent; channels keep one
//! fixed chain); the *current* table chain happens to agree with exact
//! bitwise, because trit weights are exact ±1/0 and both kernels group
//! sums by weight byte. Ineligible calls fall back to the exact core
//! even under a fast pool.

use super::pool::Pool;
use crate::config::Precision;
use crate::quant::ternary::{block_tables, dot_rows, dot_rows_lut};

/// Minimum output channels before the fast tier builds activation-block
/// tables: a block-row costs 255 madds per batch row to build and saves
/// ~3 of 4 madds per channel per batch row, so the build amortizes past
/// ~85 channels — gated higher for a clear win. Below this (tiny attn
/// projections) the exact core is used even under a fast pool.
pub const LUT_MIN_CHANNELS: usize = 128;

/// Fused packed-ternary GEMM against a row-major `[n_out, k]` weight whose
/// trits live contiguously in `packed` (row `r` starts at trit `r*k`):
/// `y[M, n_out] = x[M, k] @ Wᵀ / scale`, rows of `W` fanned across `pool`.
pub fn gemm_nt(
    pool: &Pool,
    packed: &[u32],
    x: &[f32],
    m: usize,
    k: usize,
    n_out: usize,
    scale: f32,
) -> Vec<f32> {
    assert!(
        packed.len() * 16 >= n_out * k,
        "packed ternary stream holds {} trits, {n_out}x{k} requested",
        packed.len() * 16
    );
    assert_eq!(x.len(), m * k, "input is {} values, expected {m}x{k}", x.len());
    if m == 0 || n_out == 0 {
        return vec![0f32; m * n_out];
    }
    let inv_s = 1.0 / scale;
    // transposed scratch: output channel r owns the contiguous band
    // yt[r*m..(r+1)*m], so channel-partitioning hands out disjoint slices
    let mut yt = vec![0f32; n_out * m];
    let rows_per = pool.chunk_rows(n_out, m * k);
    let use_lut =
        pool.precision() == Precision::Fast && k % 4 == 0 && n_out >= LUT_MIN_CHANNELS;
    if use_lut {
        // one table build per activation row, amortized over all n_out
        // channels; [block][byte][batch] layout keeps the per-byte adds
        // contiguous across the batch
        let blocks = k / 4;
        let mut tables = vec![0f32; blocks * 256 * m];
        let bchunk = pool.chunk_rows(blocks, 256 * m);
        pool.for_each_chunk_mut(&mut tables, bchunk * 256 * m, |ci, band| {
            block_tables(x, m, k, ci * bchunk, band);
        });
        pool.for_each_chunk_mut(&mut yt, rows_per * m, |ci, band| {
            dot_rows_lut(packed, &tables, m, k, ci * rows_per, band.len() / m, inv_s, band);
        });
    } else {
        pool.for_each_chunk_mut(&mut yt, rows_per * m, |ci, band| {
            dot_rows(packed, x, m, k, ci * rows_per, band.len() / m, inv_s, band);
        });
    }
    if m == 1 {
        return yt; // [n_out, 1] and [1, n_out] are the same buffer
    }
    let mut y = vec![0f32; m * n_out];
    for r in 0..n_out {
        for bi in 0..m {
            y[bi * n_out + r] = yt[r * m + bi];
        }
    }
    y
}

/// Fused packed-ternary GEMV: `y[n_out] = W @ x / scale` (single row of
/// [`gemm_nt`] — the batch-1 decode step, channel-parallel).
pub fn gemv(pool: &Pool, packed: &[u32], x: &[f32], k: usize, n_out: usize, scale: f32) -> Vec<f32> {
    gemm_nt(pool, packed, x, 1, k, n_out, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Rng;
    use crate::quant::ternary::pack;

    /// Channel-parallel GEMM is bitwise thread-count-invariant on shapes
    /// that straddle the byte/word boundaries of the 2-bit stream — and
    /// the pool's minimum-work gate, so both the inline and fanned-out
    /// paths are exercised.
    #[test]
    fn parallel_gemm_is_bitwise_identical_across_thread_counts() {
        let mut rng = Rng::new(0x7E51);
        for case in 0..60 {
            let k = 1 + rng.below(300);
            let n_out = 1 + rng.below(60);
            let m = 1 + rng.below(6);
            let s = 0.5 + 10.0 * rng.next_f64() as f32;
            let trits: Vec<f32> = (0..n_out * k).map(|_| rng.below(3) as f32 - 1.0).collect();
            let p = pack(&trits).unwrap();
            let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let y1 = gemm_nt(&Pool::new(1), &p, &x, m, k, n_out, s);
            let y2 = gemm_nt(&Pool::new(2), &p, &x, m, k, n_out, s);
            let y5 = gemm_nt(&Pool::new(5), &p, &x, m, k, n_out, s);
            assert_eq!(y1, y2, "case {case} (m={m} k={k} n={n_out})");
            assert_eq!(y1, y5, "case {case} (m={m} k={k} n={n_out})");
        }
    }

    /// Above the channel gate, a fast pool routes through the
    /// activation-block LUT: results match exact to f32 tolerance at
    /// every thread count, and rerunning on an identical pool is
    /// bitwise-deterministic. (Tolerance is the *contract*; the current
    /// LUT chain happens to match exact bitwise because trit weights are
    /// exact ±1/0 and both kernels group sums by weight byte — a future
    /// table layout is free to reassociate within the gate.)
    #[test]
    fn fast_lut_gemm_matches_exact_within_tolerance() {
        let mut rng = Rng::new(0xFA58);
        for case in 0..10 {
            let k = 4 * (1 + rng.below(50)); // byte-aligned
            let n_out = super::LUT_MIN_CHANNELS + rng.below(40);
            let m = 1 + rng.below(5);
            let s = 0.5 + 10.0 * rng.next_f64() as f32;
            let trits: Vec<f32> = (0..n_out * k).map(|_| rng.below(3) as f32 - 1.0).collect();
            let p = pack(&trits).unwrap();
            let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let exact = gemm_nt(&Pool::new(1), &p, &x, m, k, n_out, s);
            for threads in [1usize, 2, 5] {
                let fp = Pool::with_precision(threads, Precision::Fast);
                let fast = gemm_nt(&fp, &p, &x, m, k, n_out, s);
                let rerun = gemm_nt(&fp, &p, &x, m, k, n_out, s);
                assert_eq!(fast, rerun, "case {case} t{threads} not deterministic");
                let tol = 1e-5 + 1e-6 * k as f32;
                for (i, (a, b)) in fast.iter().zip(exact.iter()).enumerate() {
                    assert!(
                        (a - b).abs() <= tol * (1.0 + b.abs()),
                        "case {case} t{threads} (m={m} k={k} n={n_out}) [{i}]: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Below the channel gate — or with unaligned rows — a fast pool
    /// falls back to the exact core, bitwise.
    #[test]
    fn fast_pool_falls_back_to_exact_when_ineligible() {
        let mut rng = Rng::new(0xFA59);
        for &(k, n_out) in &[
            (40usize, super::LUT_MIN_CHANNELS - 1), // too few channels
            (41, super::LUT_MIN_CHANNELS + 8),      // k % 4 != 0
        ] {
            let m = 2;
            let trits: Vec<f32> = (0..n_out * k).map(|_| rng.below(3) as f32 - 1.0).collect();
            let p = pack(&trits).unwrap();
            let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let exact = gemm_nt(&Pool::new(1), &p, &x, m, k, n_out, 2.0);
            let fast = gemm_nt(&Pool::with_precision(3, Precision::Fast), &p, &x, m, k, n_out, 2.0);
            assert_eq!(exact, fast, "k={k} n={n_out}");
        }
    }

    #[test]
    fn gemv_equals_row_of_gemm() {
        let trits: Vec<f32> = (0..6 * 17).map(|i| ((i * 5 % 3) as f32) - 1.0).collect();
        let p = pack(&trits).unwrap();
        let x: Vec<f32> = (0..17).map(|i| 0.2 * i as f32 - 1.1).collect();
        let a = gemv(&Pool::new(3), &p, &x, 17, 6, 2.5);
        let b = gemm_nt(&Pool::new(1), &p, &x, 1, 17, 6, 2.5);
        assert_eq!(a, b);
    }
}
