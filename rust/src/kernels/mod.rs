//! Shared parallel kernel layer: one thread pool, every matmul.
//!
//! This module is the single dispatch point for the heavy linear algebra
//! in the native backend and the serving path:
//!
//! * [`pool`]    — [`Pool`], a zero-dependency `std::thread::scope`-based
//!                 scoped thread pool sized by `--threads` / `DQT_THREADS`
//!                 (see [`crate::config::effective_threads`]).
//! * [`gemm`]    — cache-blocked dense kernels (`matmul_nt`,
//!                 `add_matmul_nn`, `add_matmul_tn`) with K-panel
//!                 micro-blocking, row-partitioned across the pool.
//! * [`ternary`] — the packed-ternary parallel GEMM/GEMV, fanning output
//!                 channels of the 2-bit weight stream across the pool and
//!                 delegating the byte-LUT dot products to
//!                 [`crate::quant::ternary`].
//!
//! **Determinism contract.** Parallelism here never changes a result bit:
//! work is partitioned over *output rows/channels only*, so each output
//! element's floating-point accumulation chain is independent of the
//! thread count (and identical to the scalar reference oracles kept under
//! `#[cfg(test)]` in `runtime::native::math`). Training curves, eval
//! losses and generated tokens are bitwise equal at every `DQT_THREADS`
//! value — pinned at 1 vs 4 threads by `tests/parallel_determinism.rs`
//! and the CI smoke matrix. See `docs/PERFORMANCE.md`.
//!
//! **Two-tier precision.** The [`Pool`] also carries a
//! [`crate::config::Precision`] tier (`--precision exact|fast` /
//! `DQT_PRECISION`). `Exact` — the default — is the contract above.
//! `Fast` dispatches eligible kernels to SIMD-friendly variants (wide
//! multi-accumulator dense microkernels in [`gemm`], the
//! activation-block LUT GEMM in [`ternary`]) whose reassociated sums
//! match exact to f32 tolerance instead of bitwise, while remaining
//! deterministic for a fixed thread count. See `docs/PERFORMANCE.md`
//! §"Two-tier precision policy".

pub mod gemm;
pub mod pool;
pub mod ternary;

pub use pool::{default_pool, Pool};
