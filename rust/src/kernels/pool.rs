//! Zero-dependency scoped thread pool.
//!
//! A [`Pool`] is just a worker count: every parallel region opens a
//! `std::thread::scope`, fans the work across that many OS threads (the
//! calling thread participates, so `threads = 1` never spawns), and joins
//! before returning. No queues, no channels, no `unsafe`, no global
//! mutable state — which is exactly what makes the determinism contract
//! easy to audit: the pool only ever hands a task a *disjoint* region of
//! the output, so the arithmetic inside a task is identical at every
//! thread count.
//!
//! Sizing: [`Pool::new`] takes an explicit count (the `--threads` CLI
//! knob); [`Pool::from_env`] resolves `DQT_THREADS` and falls back to the
//! machine's available parallelism (see
//! [`crate::config::effective_threads`]).
//!
//! The pool also carries the kernel numeric tier
//! ([`crate::config::Precision`]): it is the one handle every matmul call
//! site already threads through, so the `--precision` policy rides along
//! the same way `--threads` does. `Pool::new` defaults to `Exact`, which
//! keeps every pre-existing construction site on the bitwise-deterministic
//! kernels.

use std::sync::OnceLock;

use crate::config::Precision;
use crate::obs::trace;

/// A fixed-width fan-out handle for the kernel layer, carrying the
/// numeric tier the kernels should dispatch on. Cheap to clone via
/// `Arc`; `Pool::new(1)` (or [`Pool::serial`]) degrades every primitive
/// to a plain loop on the calling thread.
#[derive(Clone, Debug)]
pub struct Pool {
    threads: usize,
    precision: Precision,
}

impl Pool {
    /// A pool that fans work across `threads` OS threads (clamped to ≥ 1),
    /// on the exact (bitwise-deterministic) kernel tier.
    pub fn new(threads: usize) -> Pool {
        Pool::with_precision(threads, Precision::Exact)
    }

    /// A pool with an explicit numeric tier (the `--precision` CLI path).
    pub fn with_precision(threads: usize, precision: Precision) -> Pool {
        Pool {
            threads: threads.max(1),
            precision,
        }
    }

    /// The single-threaded pool: every primitive runs inline.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Pool sized by `DQT_THREADS` on the `DQT_PRECISION` tier, falling
    /// back to the machine's available parallelism and the exact tier.
    pub fn from_env() -> Pool {
        Pool::with_precision(
            crate::config::effective_threads(None),
            crate::config::effective_precision(None),
        )
    }

    /// Worker count this pool fans across (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Numeric tier the kernels dispatch on.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Chunk extent for partitioning `rows` output rows whose per-row cost
    /// is roughly `work_per_row` multiply-adds: ~4 chunks per worker for
    /// load balance — or one single chunk when the whole job is too small
    /// to amortize a scoped fan-out (a `for_each_chunk_mut` over one chunk
    /// runs inline, spawning nothing). The threshold only changes *where*
    /// work runs, never what it computes, so results stay bitwise
    /// identical either way. All kernel-layer partitioners derive their
    /// chunk sizes here so the policy lives in one place.
    pub fn chunk_rows(&self, rows: usize, work_per_row: usize) -> usize {
        let gate = Pool::min_par_work(self.precision);
        if self.threads <= 1 || rows.saturating_mul(work_per_row) < gate {
            return rows.max(1);
        }
        rows.div_ceil(self.threads * 4).max(1)
    }

    /// Minimum nominal multiply-adds before [`Pool::chunk_rows`] fans
    /// out: below this, the spawn/join cost of one scoped region exceeds
    /// the kernel work (relevant for batch-1 decode steps on small
    /// models). Callers quote work in *nominal* madds (`rows × k`-style),
    /// so the gate is tier-specific: a fast-tier weight costs roughly a
    /// quarter of an exact one (one LUT hit + add instead of four
    /// decode-multiply-adds; vectorized dense lanes), so a fan-out must
    /// cover ~4x more nominal work before it pays for itself.
    pub const fn min_par_work(precision: Precision) -> usize {
        match precision {
            Precision::Exact => 32 * 1024,
            Precision::Fast => 128 * 1024,
        }
    }

    /// Split `data` into `chunk_len`-element chunks and run
    /// `f(chunk_index, chunk)` on every one, fanned across the pool.
    ///
    /// Chunks are handed out as contiguous per-worker bands (no work
    /// stealing), so the only thing the thread count changes is *which
    /// thread* runs a chunk — never what a chunk computes. Callers
    /// partition outputs in whole logical rows (pass `chunk_len` as a
    /// multiple of the row width) to keep every accumulation chain intact.
    pub fn for_each_chunk_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let n_chunks = data.len().div_ceil(chunk_len);
        let workers = self.threads.min(n_chunks);
        if workers <= 1 {
            for (i, c) in data.chunks_mut(chunk_len).enumerate() {
                f(i, c);
            }
            return;
        }
        // contiguous bands of `per` chunks each; the last band runs on the
        // calling thread so `threads` means total workers, not extras
        let per = n_chunks.div_ceil(workers);
        std::thread::scope(|s| {
            let f = &f;
            let mut bands: Vec<(usize, &mut [T])> = Vec::with_capacity(workers);
            let mut rest = data;
            let mut chunk0 = 0usize;
            while !rest.is_empty() {
                let take = (per * chunk_len).min(rest.len());
                let (band, tail) = std::mem::take(&mut rest).split_at_mut(take);
                rest = tail;
                bands.push((chunk0, band));
                chunk0 += per;
            }
            let last = bands.pop();
            let last_worker = bands.len();
            let precision = self.precision.as_str();
            for (w, (c0, band)) in bands.into_iter().enumerate() {
                s.spawn(move || {
                    // one kernel.task span per worker band, on the
                    // worker's stable trace track (no-op unless tracing)
                    let _sp = trace::worker_span(w, precision);
                    for (j, c) in band.chunks_mut(chunk_len).enumerate() {
                        f(c0 + j, c);
                    }
                });
            }
            if let Some((c0, band)) = last {
                let _sp = trace::worker_span(last_worker, precision);
                for (j, c) in band.chunks_mut(chunk_len).enumerate() {
                    f(c0 + j, c);
                }
            }
        });
    }

    /// Run `f(0) .. f(n-1)` across the pool and return the results in
    /// task order. Tasks are assigned round-robin (task `i` runs on
    /// worker `i % workers`); since every task only reads shared inputs
    /// and builds its own output, placement cannot affect values.
    pub fn map_collect<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let workers = self.threads.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<Option<T>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let precision = self.precision.as_str();
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = (1..workers)
                .map(|w| {
                    s.spawn(move || {
                        let _sp = trace::worker_span(w, precision);
                        let mut acc = Vec::new();
                        let mut i = w;
                        while i < n {
                            acc.push((i, f(i)));
                            i += workers;
                        }
                        acc
                    })
                })
                .collect();
            // stride 0 runs on the calling thread
            let mut mine = Vec::new();
            {
                let _sp = trace::worker_span(0, precision);
                let mut i = 0;
                while i < n {
                    mine.push((i, f(i)));
                    i += workers;
                }
            }
            for (i, v) in mine {
                out[i] = Some(v);
            }
            for h in handles {
                for (i, v) in h.join().expect("kernel pool worker panicked") {
                    out[i] = Some(v);
                }
            }
        });
        out.into_iter()
            .map(|v| v.expect("every task index filled"))
            .collect()
    }
}

/// The process-wide default pool (`DQT_THREADS` / available cores),
/// used by entry points that keep a pool-less signature for
/// compatibility (e.g. [`crate::quant::ternary::gemm_nt`]). Code that
/// owns a backend should thread an explicit pool instead.
pub fn default_pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(Pool::from_env)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_indices_cover_everything_once() {
        for threads in [1usize, 2, 3, 8] {
            for len in [0usize, 1, 5, 16, 17, 100] {
                for chunk in [1usize, 3, 7, 100] {
                    let pool = Pool::new(threads);
                    let mut data = vec![0u32; len];
                    pool.for_each_chunk_mut(&mut data, chunk, |ci, c| {
                        for v in c.iter_mut() {
                            *v += 1 + ci as u32;
                        }
                    });
                    for (i, v) in data.iter().enumerate() {
                        assert_eq!(*v, 1 + (i / chunk) as u32, "t{threads} len{len} chunk{chunk} i{i}");
                    }
                }
            }
        }
    }

    #[test]
    fn map_collect_preserves_task_order() {
        for threads in [1usize, 2, 5] {
            let pool = Pool::new(threads);
            let out = pool.map_collect(23, |i| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>());
            assert!(pool.map_collect(0, |i| i).is_empty());
        }
    }

    #[test]
    fn chunk_rows_policy() {
        // serial pools and sub-threshold jobs get one chunk (inline, no
        // spawns); large jobs get ~4 chunks per worker
        assert_eq!(Pool::new(1).chunk_rows(100, 1_000_000), 100);
        assert_eq!(Pool::new(4).chunk_rows(10, 1), 10);
        assert_eq!(Pool::new(4).chunk_rows(100, 1_000_000), 7); // ceil(100/16)
        assert_eq!(Pool::new(4).chunk_rows(0, 1_000_000), 1);
    }

    #[test]
    fn chunk_rows_gate_is_per_precision() {
        // the fast tier's cheaper per-weight cost raises the fan-out gate
        // 4x, so a mid-size job pools under exact but stays inline under
        // fast — and both tiers pool once past the fast gate
        assert_eq!(Pool::min_par_work(Precision::Exact), 32 * 1024);
        assert_eq!(Pool::min_par_work(Precision::Fast), 128 * 1024);
        let exact4 = Pool::new(4);
        let fast4 = Pool::with_precision(4, Precision::Fast);
        assert_eq!(exact4.precision(), Precision::Exact);
        assert_eq!(fast4.precision(), Precision::Fast);
        // 100k nominal madds: past the exact gate, under the fast gate
        assert_eq!(exact4.chunk_rows(100, 1_000), 7); // pooled
        assert_eq!(fast4.chunk_rows(100, 1_000), 100); // inline
        // 200k nominal madds: past both gates
        assert_eq!(exact4.chunk_rows(100, 2_000), 7);
        assert_eq!(fast4.chunk_rows(100, 2_000), 7);
        // serial pools always run inline regardless of tier
        assert_eq!(Pool::with_precision(1, Precision::Fast).chunk_rows(100, 1_000_000), 100);
    }

    #[test]
    fn pool_clamps_to_one_thread() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::serial().threads(), 1);
        assert!(Pool::from_env().threads() >= 1);
        assert_eq!(Pool::with_precision(0, Precision::Fast).threads(), 1);
    }
}
