//! Length-prefixed binary frames for the distributed training plane.
//!
//! One frame = a 1-byte tag, an 8-byte little-endian payload length, then
//! the payload. Tensors travel in three shapes: raw little-endian f32
//! runs (gradient partials — [`Frame::GradSet`]), stochastically rounded
//! gradient grids ([`Frame::PackedGradSet`] — int8/ternary codes + one
//! absmax scale per buffer, the `--grad-format` wire), and
//! [`PackedTensor`] grids ([`Frame::GridSync`]). The packed layouts are
//! exactly the codec registry in [`crate::quant::codec`] — the same
//! `Format` tags and byte layouts the `.dqt` checkpoint format uses, so
//! a ternary weight resync ships 2 bits/weight + one f32 scale per
//! matrix instead of 32 bits/weight (~16× less traffic), and a ternary
//! gradient frame does the same for the every-step exchange.
//!
//! Decoding is hardened the way `train::checkpoint` is: truncated
//! headers, short payload reads, oversized length prefixes, unknown tags,
//! trailing bytes and packed-size mismatches all report as errors instead
//! of panicking or reading garbage (pinned by the tests below).

use std::io::{Read, Write};

use anyhow::{anyhow, Result};

use crate::quant::codec::{Format, PackedTensor};
use crate::quant::gradcodec::PackedGrad;

/// Bumped whenever a frame layout changes; checked at rendezvous.
/// v2: added [`Frame::PackedGradSet`] (quantized gradient exchange).
pub const PROTOCOL_VERSION: u32 = 2;

/// Hard cap on one frame's payload — a corrupt length prefix fails loudly
/// instead of attempting a multi-gigabyte allocation.
pub const MAX_FRAME_BYTES: u64 = 1 << 32;

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_GRAD_SET: u8 = 3;
const TAG_GRID_SYNC: u8 = 4;
const TAG_BYE: u8 = 5;
const TAG_PACKED_GRAD_SET: u8 = 6;

/// One message of the distributed protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Worker → coordinator at rendezvous: who am I, what am I training.
    Hello {
        rank: u32,
        world: u32,
        variant: String,
    },
    /// Coordinator → worker: rendezvous accepted.
    Welcome { rank: u32, world: u32 },
    /// One rank's gradient partial (worker → coordinator) or the reduced
    /// global set (coordinator → workers): per-param buffers in manifest
    /// order (`None` for `.s` scales), plus the masked-NLL sum and
    /// non-pad token count riding the same reduction.
    GradSet {
        step: u64,
        nll: f32,
        count: u64,
        entries: Vec<Option<Vec<f32>>>,
    },
    /// Periodic weight resync (coordinator → workers): `(param index,
    /// packed tensor)` pairs — grid weights in the variant's true bit
    /// width (or f32 when packed sync is off) and their f32 scales.
    GridSync {
        step: u64,
        entries: Vec<(u32, PackedTensor)>,
    },
    /// Orderly teardown.
    Bye { rank: u32 },
    /// A [`GradSet`](Frame::GradSet) quantized for the wire
    /// (`--grad-format int8|ternary`): per-param stochastically rounded
    /// grid codes + one absmax scale each, in manifest order. The format
    /// rides once per frame; nll/count ride uncompressed. Packing is the
    /// codec registry via [`crate::quant::gradcodec`].
    PackedGradSet {
        step: u64,
        nll: f32,
        count: u64,
        format: Format,
        entries: Vec<Option<PackedGrad>>,
    },
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32_slice(buf: &mut Vec<u8>, vals: &[f32]) {
    put_u64(buf, vals.len() as u64);
    for &v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked payload reader: every decode error names what ran out
/// instead of slicing out of bounds.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow!("corrupt frame: {what} length overflows"))?;
        if end > self.buf.len() {
            return Err(anyhow!(
                "corrupt frame: {what} wants {n} bytes, {} remain",
                self.buf.len() - self.pos
            ));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let n = self.u32(what)? as usize;
        let bytes = self.take(n, what)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|_| anyhow!("corrupt frame: {what} is not UTF-8"))?
            .to_string())
    }

    fn f32_slice(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.u64(what)? as usize;
        let bytes = self.take(
            n.checked_mul(4)
                .ok_or_else(|| anyhow!("corrupt frame: {what} length overflows"))?,
            what,
        )?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn finish(self, tag: &str) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(anyhow!(
                "corrupt frame: {tag} has {} trailing bytes",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

impl Frame {
    fn tag(&self) -> u8 {
        match self {
            Frame::Hello { .. } => TAG_HELLO,
            Frame::Welcome { .. } => TAG_WELCOME,
            Frame::GradSet { .. } => TAG_GRAD_SET,
            Frame::GridSync { .. } => TAG_GRID_SYNC,
            Frame::Bye { .. } => TAG_BYE,
            Frame::PackedGradSet { .. } => TAG_PACKED_GRAD_SET,
        }
    }

    fn payload(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        match self {
            Frame::Hello {
                rank,
                world,
                variant,
            } => {
                put_u32(&mut buf, PROTOCOL_VERSION);
                put_u32(&mut buf, *rank);
                put_u32(&mut buf, *world);
                put_str(&mut buf, variant);
            }
            Frame::Welcome { rank, world } => {
                put_u32(&mut buf, PROTOCOL_VERSION);
                put_u32(&mut buf, *rank);
                put_u32(&mut buf, *world);
            }
            Frame::GradSet {
                step,
                nll,
                count,
                entries,
            } => {
                put_u64(&mut buf, *step);
                put_f32(&mut buf, *nll);
                put_u64(&mut buf, *count);
                put_u32(&mut buf, entries.len() as u32);
                for e in entries {
                    match e {
                        Some(vals) => {
                            buf.push(1);
                            put_f32_slice(&mut buf, vals);
                        }
                        None => buf.push(0),
                    }
                }
            }
            Frame::GridSync { step, entries } => {
                put_u64(&mut buf, *step);
                put_u32(&mut buf, entries.len() as u32);
                for (idx, pt) in entries {
                    put_u32(&mut buf, *idx);
                    // on-wire packing == the codec registry: same tag
                    // strings and byte layouts as the checkpoint format
                    put_str(&mut buf, &pt.format.tag());
                    buf.push(pt.shape.len() as u8);
                    for &d in &pt.shape {
                        put_u64(&mut buf, d as u64);
                    }
                    match pt.scale {
                        Some(s) => {
                            buf.push(1);
                            put_f32(&mut buf, s);
                        }
                        None => buf.push(0),
                    }
                    put_u64(&mut buf, pt.bytes.len() as u64);
                    buf.extend_from_slice(&pt.bytes);
                }
            }
            Frame::Bye { rank } => put_u32(&mut buf, *rank),
            Frame::PackedGradSet {
                step,
                nll,
                count,
                format,
                entries,
            } => {
                put_u64(&mut buf, *step);
                put_f32(&mut buf, *nll);
                put_u64(&mut buf, *count);
                // one codec tag per frame — every entry shares the format
                put_str(&mut buf, &format.tag());
                put_u32(&mut buf, entries.len() as u32);
                for e in entries {
                    match e {
                        Some(p) => {
                            buf.push(1);
                            put_f32(&mut buf, p.scale);
                            put_u64(&mut buf, p.numel as u64);
                            put_u64(&mut buf, p.bytes.len() as u64);
                            buf.extend_from_slice(&p.bytes);
                        }
                        None => buf.push(0),
                    }
                }
            }
        }
        buf
    }

    /// Serialize to the full wire form: tag, length prefix, payload.
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut buf = Vec::with_capacity(9 + payload.len());
        buf.push(self.tag());
        buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        buf.extend_from_slice(&payload);
        buf
    }

    /// Write the frame and flush; returns the bytes shipped.
    pub fn write_to(&self, w: &mut impl Write) -> Result<u64> {
        let buf = self.encode();
        w.write_all(&buf)?;
        w.flush()?;
        Ok(buf.len() as u64)
    }

    /// Read one frame; returns it with the total bytes consumed.
    pub fn read_from_counted(r: &mut impl Read) -> Result<(Frame, u64)> {
        let mut header = [0u8; 9];
        r.read_exact(&mut header).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                anyhow!("truncated frame header (connection closed mid-frame)")
            } else {
                anyhow!("reading frame header: {e}")
            }
        })?;
        let tag = header[0];
        let len = u64::from_le_bytes(header[1..9].try_into().unwrap());
        if len > MAX_FRAME_BYTES {
            return Err(anyhow!(
                "corrupt frame: oversized payload length {len} (cap {MAX_FRAME_BYTES})"
            ));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                anyhow!("frame payload truncated: wanted {len} bytes")
            } else {
                anyhow!("reading frame payload: {e}")
            }
        })?;
        Ok((Self::decode(tag, &payload)?, 9 + len))
    }

    /// Read one frame from a stream.
    pub fn read_from(r: &mut impl Read) -> Result<Frame> {
        Ok(Self::read_from_counted(r)?.0)
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<Frame> {
        let mut c = Cursor::new(payload);
        let frame = match tag {
            TAG_HELLO => {
                let version = c.u32("hello version")?;
                if version != PROTOCOL_VERSION {
                    return Err(anyhow!(
                        "protocol version mismatch: peer speaks v{version}, \
                         this build speaks v{PROTOCOL_VERSION}"
                    ));
                }
                Frame::Hello {
                    rank: c.u32("hello rank")?,
                    world: c.u32("hello world")?,
                    variant: c.str("hello variant")?,
                }
            }
            TAG_WELCOME => {
                let version = c.u32("welcome version")?;
                if version != PROTOCOL_VERSION {
                    return Err(anyhow!(
                        "protocol version mismatch: peer speaks v{version}, \
                         this build speaks v{PROTOCOL_VERSION}"
                    ));
                }
                Frame::Welcome {
                    rank: c.u32("welcome rank")?,
                    world: c.u32("welcome world")?,
                }
            }
            TAG_GRAD_SET => {
                let step = c.u64("grad step")?;
                let nll = c.f32("grad nll")?;
                let count = c.u64("grad count")?;
                let n = c.u32("grad entry count")? as usize;
                // cap the up-front reservation by what the payload could
                // possibly hold (≥1 byte per entry) — a corrupt count must
                // not turn into a huge allocation before bounds checks run
                let mut entries = Vec::with_capacity(n.min(payload.len()));
                for i in 0..n {
                    let what = format!("grad entry {i}");
                    match c.u8(&what)? {
                        0 => entries.push(None),
                        1 => entries.push(Some(c.f32_slice(&what)?)),
                        m => {
                            return Err(anyhow!(
                                "corrupt frame: grad entry {i} has presence marker {m}"
                            ))
                        }
                    }
                }
                Frame::GradSet {
                    step,
                    nll,
                    count,
                    entries,
                }
            }
            TAG_GRID_SYNC => {
                let step = c.u64("sync step")?;
                let n = c.u32("sync entry count")? as usize;
                // same huge-count guard as GradSet above
                let mut entries = Vec::with_capacity(n.min(payload.len()));
                for i in 0..n {
                    let what = format!("sync entry {i}");
                    let idx = c.u32(&what)?;
                    let format =
                        Format::from_tag(&c.str(&what)?).map_err(|e| anyhow!("{what}: {e}"))?;
                    let ndim = c.u8(&what)? as usize;
                    let mut shape = Vec::with_capacity(ndim);
                    for _ in 0..ndim {
                        shape.push(c.u64(&what)? as usize);
                    }
                    let scale = match c.u8(&what)? {
                        0 => None,
                        1 => Some(c.f32(&what)?),
                        m => {
                            return Err(anyhow!(
                                "corrupt frame: {what} has scale marker {m}"
                            ))
                        }
                    };
                    let nbytes = c.u64(&what)? as usize;
                    let bytes = c.take(nbytes, &what)?.to_vec();
                    // from_bytes re-checks the codec's size invariant —
                    // the same hardening the checkpoint loader applies
                    let pt = PackedTensor::from_bytes(bytes, shape, format, scale)
                        .map_err(|e| anyhow!("{what}: {e}"))?;
                    entries.push((idx, pt));
                }
                Frame::GridSync { step, entries }
            }
            TAG_BYE => Frame::Bye {
                rank: c.u32("bye rank")?,
            },
            TAG_PACKED_GRAD_SET => {
                let step = c.u64("packed grad step")?;
                let nll = c.f32("packed grad nll")?;
                let count = c.u64("packed grad count")?;
                let format = Format::from_tag(&c.str("packed grad format")?)
                    .map_err(|e| anyhow!("packed grad format: {e}"))?;
                if !format.is_grid_format() {
                    return Err(anyhow!(
                        "corrupt frame: packed grad format {} is not a grid format",
                        format.tag()
                    ));
                }
                let n = c.u32("packed grad entry count")? as usize;
                // same huge-count guard as GradSet above
                let mut entries = Vec::with_capacity(n.min(payload.len()));
                for i in 0..n {
                    let what = format!("packed grad entry {i}");
                    match c.u8(&what)? {
                        0 => entries.push(None),
                        1 => {
                            let scale = c.f32(&what)?;
                            let numel = c.u64(&what)? as usize;
                            let nbytes = c.u64(&what)? as usize;
                            let bytes = c.take(nbytes, &what)?.to_vec();
                            // from_wire re-checks the codec size invariant
                            // (and that the scale dequantizes sanely) —
                            // the GridSync-grade hardening
                            let p = PackedGrad::from_wire(format, scale, numel, bytes)
                                .map_err(|e| anyhow!("corrupt frame: {what}: {e}"))?;
                            entries.push(Some(p));
                        }
                        m => {
                            return Err(anyhow!(
                                "corrupt frame: {what} has presence marker {m}"
                            ))
                        }
                    }
                }
                Frame::PackedGradSet {
                    step,
                    nll,
                    count,
                    format,
                    entries,
                }
            }
            other => return Err(anyhow!("unknown frame tag {other}")),
        };
        c.finish(match tag {
            TAG_HELLO => "hello",
            TAG_WELCOME => "welcome",
            TAG_GRAD_SET => "grad_set",
            TAG_GRID_SYNC => "grid_sync",
            TAG_PACKED_GRAD_SET => "packed_grad_set",
            _ => "bye",
        })?;
        Ok(frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor as IoCursor;

    fn roundtrip(f: &Frame) -> Frame {
        let buf = f.encode();
        let (back, n) = Frame::read_from_counted(&mut IoCursor::new(&buf)).unwrap();
        assert_eq!(n, buf.len() as u64);
        back
    }

    fn ternary_pt(n: usize, s: f32) -> PackedTensor {
        let vals: Vec<f32> = (0..n).map(|i| ((i % 3) as f32 - 1.0) / s).collect();
        PackedTensor::pack(&vals, vec![n], Format::Ternary2bit, Some(s)).unwrap()
    }

    fn f32_pt(n: usize) -> PackedTensor {
        let vals: Vec<f32> = (0..n).map(|i| i as f32 * 0.25 - 3.0).collect();
        PackedTensor::pack(&vals, vec![n], Format::F32, None).unwrap()
    }

    /// A realistic packed gradient entry: SR-encode a smooth buffer
    /// through the gradient codec (the production encoder).
    fn packed_grad(n: usize, format: Format) -> PackedGrad {
        let g: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.11).sin() * 2e-3).collect();
        let mut codec = crate::quant::gradcodec::GradCodec::new(format).unwrap();
        codec.encode_set(0, 0, &[Some(g)]).unwrap()[0]
            .take()
            .unwrap()
    }

    #[test]
    fn all_frames_roundtrip() {
        let frames = [
            Frame::Hello {
                rank: 3,
                world: 4,
                variant: "test-dqt-b1p58".into(),
            },
            Frame::Welcome { rank: 3, world: 4 },
            Frame::GradSet {
                step: 17,
                nll: 42.5,
                count: 96,
                entries: vec![
                    Some(vec![1.0, -2.5, 3.25]),
                    None,
                    Some(vec![]),
                    Some(vec![f32::MIN_POSITIVE, f32::MAX]),
                ],
            },
            Frame::GridSync {
                step: 8,
                entries: vec![
                    (2, ternary_pt(37, 25.0)),
                    (5, f32_pt(16)),
                    (
                        9,
                        PackedTensor::pack(
                            &(0..24).map(|i| ((i % 5) as f32 - 2.0) / 7.0).collect::<Vec<_>>(),
                            vec![4, 6],
                            Format::IntN(4),
                            Some(7.0),
                        )
                        .unwrap(),
                    ),
                ],
            },
            Frame::Bye { rank: 1 },
            Frame::PackedGradSet {
                step: 17,
                nll: 42.5,
                count: 96,
                format: Format::IntN(8),
                entries: vec![
                    Some(packed_grad(37, Format::IntN(8))),
                    None,
                    Some(packed_grad(256, Format::IntN(8))),
                ],
            },
            Frame::PackedGradSet {
                step: 3,
                nll: 1.5,
                count: 8,
                format: Format::Ternary2bit,
                entries: vec![Some(packed_grad(100, Format::Ternary2bit)), None],
            },
        ];
        for f in &frames {
            assert_eq!(&roundtrip(f), f);
        }
    }

    #[test]
    fn grad_values_roundtrip_bitwise() {
        let vals: Vec<f32> = (0..1000).map(|i| (i as f32).sin() * 1e-3).collect();
        let f = Frame::GradSet {
            step: 0,
            nll: 1.25,
            count: 10,
            entries: vec![Some(vals.clone())],
        };
        let Frame::GradSet { entries, .. } = roundtrip(&f) else {
            panic!("wrong frame");
        };
        let back = entries[0].as_ref().unwrap();
        for (a, b) in vals.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn truncated_header_and_payload_error_cleanly() {
        let buf = Frame::Bye { rank: 7 }.encode();
        // cut inside the 9-byte header
        for cut in 0..9 {
            let err = Frame::read_from(&mut IoCursor::new(&buf[..cut])).unwrap_err();
            assert!(
                err.to_string().contains("truncated frame header"),
                "cut {cut}: {err}"
            );
        }
        // cut inside the payload
        let err = Frame::read_from(&mut IoCursor::new(&buf[..buf.len() - 1])).unwrap_err();
        assert!(err.to_string().contains("payload truncated"), "{err}");
    }

    #[test]
    fn unknown_tag_and_oversized_length_rejected() {
        let mut buf = Frame::Bye { rank: 0 }.encode();
        buf[0] = 99;
        let err = Frame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("unknown frame tag"), "{err}");

        let mut buf = Frame::Bye { rank: 0 }.encode();
        buf[1..9].copy_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        let err = Frame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut buf = Frame::Bye { rank: 0 }.encode();
        buf.push(0xAB);
        let len = (buf.len() - 9) as u64;
        buf[1..9].copy_from_slice(&len.to_le_bytes());
        let err = Frame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("trailing bytes"), "{err}");
    }

    #[test]
    fn short_grad_entry_errors_not_panics() {
        // a GradSet whose declared f32 run is longer than the payload
        let f = Frame::GradSet {
            step: 1,
            nll: 0.0,
            count: 1,
            entries: vec![Some(vec![1.0, 2.0, 3.0])],
        };
        let mut buf = f.encode();
        let cut = buf.len() - 6;
        buf.truncate(cut);
        let len = (cut - 9) as u64;
        buf[1..9].copy_from_slice(&len.to_le_bytes());
        let err = Frame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("grad entry"), "{err}");
    }

    #[test]
    fn grid_entry_size_invariant_enforced() {
        // hand-corrupt a GridSync payload: declare 37 ternary values but
        // ship too few packed bytes → the codec size check must fire
        let good = Frame::GridSync {
            step: 0,
            entries: vec![(0, ternary_pt(37, 25.0))],
        };
        let buf = good.encode();
        // a lying PackedTensor cannot be built (from_bytes validates), so
        // corrupt the wire directly: drop the last packed byte and patch
        // both length prefixes so only the codec size check can catch it
        let n_packed = super::Format::Ternary2bit.packed_bytes(37) as u64;
        let bytes_len_off = buf.len() - n_packed as usize - 8;
        let mut bad = buf.clone();
        bad[bytes_len_off..bytes_len_off + 8].copy_from_slice(&(n_packed - 1).to_le_bytes());
        bad.truncate(buf.len() - 1);
        let frame_len = (bad.len() - 9) as u64;
        bad[1..9].copy_from_slice(&frame_len.to_le_bytes());
        let err = Frame::read_from(&mut IoCursor::new(&bad)).unwrap_err();
        assert!(
            err.to_string().contains("sync entry"),
            "expected codec size failure, got: {err}"
        );
    }

    /// The satellite claim, measured on the wire: a ternary grid resync
    /// frame is ~16× smaller than the same tensor shipped as f32.
    #[test]
    fn packed_sync_ships_far_fewer_bytes_than_f32() {
        let n = 4096;
        let packed = Frame::GridSync {
            step: 0,
            entries: vec![(0, ternary_pt(n, 20.0))],
        }
        .encode();
        let dense = Frame::GridSync {
            step: 0,
            entries: vec![(0, f32_pt(n))],
        }
        .encode();
        assert!(
            packed.len() * 10 < dense.len(),
            "packed {} !<< dense {}",
            packed.len(),
            dense.len()
        );
        // and the asymptotic ratio approaches 16 (2 bits vs 32 bits)
        let ratio = dense.len() as f64 / packed.len() as f64;
        assert!(ratio > 14.0, "ratio {ratio}");
    }

    #[test]
    fn packed_grad_size_lie_is_rejected() {
        // shrink the declared byte run by one and truncate to match: only
        // the codec size invariant (PackedGrad::from_wire) can catch it
        let n = 37;
        let good = Frame::PackedGradSet {
            step: 4,
            nll: 0.5,
            count: 2,
            format: Format::IntN(8),
            entries: vec![Some(packed_grad(n, Format::IntN(8)))],
        };
        let buf = good.encode();
        let n_packed = Format::IntN(8).packed_bytes(n);
        let nbytes_off = buf.len() - n_packed - 8;
        let mut bad = buf.clone();
        bad[nbytes_off..nbytes_off + 8].copy_from_slice(&((n_packed - 1) as u64).to_le_bytes());
        bad.truncate(buf.len() - 1);
        let frame_len = (bad.len() - 9) as u64;
        bad[1..9].copy_from_slice(&frame_len.to_le_bytes());
        let err = Frame::read_from(&mut IoCursor::new(&bad)).unwrap_err();
        assert!(
            err.to_string().contains("packed grad entry"),
            "expected codec size failure, got: {err}"
        );
    }

    #[test]
    fn packed_grad_rejects_non_grid_format_and_bad_marker() {
        // a frame claiming its gradient grid is "f32" is nonsense — grid
        // codes need a grid format — and must be refused at decode
        let bad_format = Frame::PackedGradSet {
            step: 0,
            nll: 0.0,
            count: 0,
            format: Format::F32,
            entries: vec![],
        }
        .encode();
        let err = Frame::read_from(&mut IoCursor::new(&bad_format)).unwrap_err();
        assert!(err.to_string().contains("not a grid format"), "{err}");

        // presence marker outside {0, 1}
        let good = Frame::PackedGradSet {
            step: 0,
            nll: 0.0,
            count: 0,
            format: Format::Ternary2bit,
            entries: vec![None],
        };
        let mut buf = good.encode();
        let last = buf.len() - 1;
        buf[last] = 7;
        let err = Frame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("presence marker 7"), "{err}");
    }

    #[test]
    fn truncated_packed_grad_entry_errors_not_panics() {
        let f = Frame::PackedGradSet {
            step: 1,
            nll: 0.0,
            count: 1,
            format: Format::IntN(8),
            entries: vec![Some(packed_grad(64, Format::IntN(8)))],
        };
        let mut buf = f.encode();
        let cut = buf.len() - 10;
        buf.truncate(cut);
        let len = (cut - 9) as u64;
        buf[1..9].copy_from_slice(&len.to_le_bytes());
        let err = Frame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("packed grad entry"), "{err}");
    }

    /// The tentpole's wire-bytes acceptance criterion, measured on whole
    /// frames: the quantized gradient frame vs the f32 `GradSet` carrying
    /// the same buffer. int8 asymptotically approaches exactly 4× (8 vs
    /// 32 bits per value; the only gap is the per-tensor scale/length
    /// metadata, which vanishes as 1/n), so the floor is 3.99×. Ternary
    /// approaches 16× with room to spare over its 10× floor.
    #[test]
    fn packed_grad_frames_shrink_4x_int8_and_10x_ternary() {
        let n = 100_000;
        let g: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.013).sin() * 1e-3).collect();
        let dense = Frame::GradSet {
            step: 0,
            nll: 1.0,
            count: 64,
            entries: vec![Some(g)],
        }
        .encode();
        for (format, floor) in [(Format::IntN(8), 3.99), (Format::Ternary2bit, 10.0)] {
            let packed = Frame::PackedGradSet {
                step: 0,
                nll: 1.0,
                count: 64,
                format,
                entries: vec![Some(packed_grad(n, format))],
            }
            .encode();
            let ratio = dense.len() as f64 / packed.len() as f64;
            assert!(
                ratio > floor,
                "{} frame ratio {ratio} !> {floor} (dense {} packed {})",
                format.tag(),
                dense.len(),
                packed.len()
            );
        }
    }
}
