//! TCP collectives over a star topology: rank 0 hosts the rendezvous and
//! owns the reduction; workers hold one duplex link each.
//!
//! The all-reduce is *fixed-rank-order*: rank 0 collects the band partials
//! in rank order and combines them with the same halving tree
//! ([`tree_reduce`]) the backend uses over batch rows inside a band —
//! contiguous equal bands of a power-of-two world are subtrees of that
//! tree, so the cross-rank combine literally finishes the 1-worker run's
//! summation chain. Every node of the tree is
//! [`crate::runtime::add_grad_buffers`]; nothing about the transport can
//! change a bit of the result (see `docs/DISTRIBUTED.md`).

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::quant::codec::{Format, PackedTensor};
use crate::quant::gradcodec::{GradCodec, PackedGrad};
use crate::runtime::{add_grad_buffers, GradReducer, Manifest, Param, State};

use super::wire::Frame;

/// The SR stream lane rank 0's reduced-set broadcast encodes under —
/// distinct from every uplink lane (which use the sender's rank), so no
/// two wire encodings share a random stream.
const DOWNLINK_LANE: u32 = u32::MAX;

/// How long rendezvous waits for the full world to arrive.
pub const RENDEZVOUS_TIMEOUT: Duration = Duration::from_secs(120);
/// Per-frame socket timeout during training — a peer that stalls longer
/// than this is treated as dead instead of hanging the run forever.
pub const STEP_TIMEOUT: Duration = Duration::from_secs(600);

/// One rank's membership in the training collective.
pub struct Collective {
    rank: usize,
    world: usize,
    /// rank 0: one stream per worker, index `rank - 1`; workers: exactly
    /// one stream, to rank 0. Empty for a solo world.
    links: Vec<TcpStream>,
    /// cumulative wire traffic (sent + received) of the training frames
    /// this rank moved — all-reduce and grid-sync; rendezvous/Bye excluded
    wire_bytes: u64,
}

/// One rank's contribution flowing through [`tree_reduce`].
pub(crate) struct GradPart {
    pub entries: Vec<Option<Vec<f32>>>,
    pub nll: f32,
    pub count: u64,
}

/// The fixed halving tree over rank partials: split at
/// `lo + (hi - lo) / 2`, combine left + right (in that order) with
/// [`add_grad_buffers`]. Identical in shape to the per-row tree inside a
/// band, which is what makes an N-rank reduction finish the 1-worker
/// chain bit for bit.
pub(crate) fn tree_reduce(parts: Vec<GradPart>) -> Result<GradPart> {
    fn rec(parts: &mut [Option<GradPart>], lo: usize, hi: usize) -> Result<GradPart> {
        if hi - lo == 1 {
            return Ok(parts[lo].take().expect("each part consumed once"));
        }
        let mid = lo + (hi - lo) / 2;
        let mut l = rec(parts, lo, mid)?;
        let r = rec(parts, mid, hi)?;
        add_grad_buffers(&mut l.entries, &r.entries)?;
        l.nll += r.nll;
        l.count += r.count;
        Ok(l)
    }
    if parts.is_empty() {
        return Err(anyhow!("tree_reduce of zero parts"));
    }
    let n = parts.len();
    let mut slots: Vec<Option<GradPart>> = parts.into_iter().map(Some).collect();
    rec(&mut slots, 0, n)
}

fn configure(stream: &TcpStream, timeout: Duration) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    Ok(())
}

impl Collective {
    /// The world-1 collective: no sockets, every operation is the
    /// identity. The 1-worker reference run goes through exactly the same
    /// code path as an N-worker rank, minus the wire.
    pub fn solo() -> Collective {
        Collective {
            rank: 0,
            world: 1,
            links: Vec::new(),
            wire_bytes: 0,
        }
    }

    /// Rank 0: accept Hellos on `listener` until all of `1..world` have
    /// joined (in any arrival order — the Hello names the rank), validate
    /// each against this run, Welcome them, and order the links by rank.
    pub fn host(
        listener: TcpListener,
        world: usize,
        variant: &str,
        timeout: Duration,
    ) -> Result<Collective> {
        if world <= 1 {
            return Ok(Collective::solo());
        }
        listener
            .set_nonblocking(true)
            .context("rendezvous listener")?;
        let deadline = Instant::now() + timeout;
        let mut slots: Vec<Option<TcpStream>> = (0..world - 1).map(|_| None).collect();
        let mut joined = 0usize;
        // Validate one accepted connection up to its Welcome. A failure
        // here must not kill the rendezvous: a stray connection (port
        // scanner, health check, mistyped worker invocation) is the
        // *peer's* problem — reject it, keep listening for the real
        // workers until the deadline.
        let admit = |mut stream: TcpStream,
                     peer: std::net::SocketAddr,
                     slots: &mut [Option<TcpStream>]|
         -> Result<()> {
            stream.set_nonblocking(false)?;
            configure(&stream, timeout)?;
            let hello = Frame::read_from(&mut stream)
                .with_context(|| format!("rendezvous hello from {peer}"))?;
            let Frame::Hello {
                rank,
                world: w,
                variant: v,
            } = hello
            else {
                return Err(anyhow!("{peer} sent a non-hello frame at rendezvous"));
            };
            if w as usize != world {
                return Err(anyhow!(
                    "{peer} joined with world {w}, this run has world {world}"
                ));
            }
            if v != variant {
                return Err(anyhow!(
                    "{peer} is training {v:?}, this run trains {variant:?}"
                ));
            }
            let r = rank as usize;
            if r == 0 || r >= world {
                return Err(anyhow!("{peer} claims invalid rank {r} of {world}"));
            }
            if slots[r - 1].is_some() {
                return Err(anyhow!("two workers claim rank {r}"));
            }
            Frame::Welcome {
                rank,
                world: world as u32,
            }
            .write_to(&mut stream)?;
            slots[r - 1] = Some(stream);
            Ok(())
        };
        while joined < world - 1 {
            match listener.accept() {
                Ok((stream, peer)) => match admit(stream, peer, &mut slots) {
                    Ok(()) => joined += 1,
                    Err(e) => eprintln!("dist: rendezvous rejected {peer}: {e:#}"),
                },
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!(
                            "rendezvous timed out: {joined} of {} workers joined",
                            world - 1
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(anyhow!("rendezvous accept: {e}")),
            }
        }
        let links: Vec<TcpStream> = slots.into_iter().map(|s| s.unwrap()).collect();
        // rendezvous is over; training frames get the long per-step window
        for link in &links {
            configure(link, STEP_TIMEOUT)?;
        }
        Ok(Collective {
            rank: 0,
            world,
            links,
            wire_bytes: 0,
        })
    }

    /// Worker: connect to rank 0 at `addr` (retrying until it is up or
    /// `timeout` passes), introduce ourselves, await the Welcome.
    pub fn join(
        addr: &str,
        rank: usize,
        world: usize,
        variant: &str,
        timeout: Duration,
    ) -> Result<Collective> {
        if rank == 0 || rank >= world {
            return Err(anyhow!("rank {rank} cannot join a world of {world}"));
        }
        let deadline = Instant::now() + timeout;
        let mut stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow!("joining {addr} timed out: {e}"));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        };
        configure(&stream, timeout)?;
        Frame::Hello {
            rank: rank as u32,
            world: world as u32,
            variant: variant.to_string(),
        }
        .write_to(&mut stream)?;
        match Frame::read_from(&mut stream).context("awaiting rendezvous welcome")? {
            Frame::Welcome { rank: r, world: w } if r as usize == rank && w as usize == world => {}
            other => return Err(anyhow!("unexpected rendezvous reply: {other:?}")),
        }
        // rendezvous is over; training frames get the long per-step window
        configure(&stream, STEP_TIMEOUT)?;
        Ok(Collective {
            rank,
            world,
            links: vec![stream],
            wire_bytes: 0,
        })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Cumulative training-frame wire traffic (sent + received) this rank
    /// has moved — the `dqt_dist_*_bytes_total` metrics read deltas of
    /// this counter.
    pub fn wire_bytes(&self) -> u64 {
        self.wire_bytes
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn is_coordinator(&self) -> bool {
        self.rank == 0
    }

    /// Fixed-rank-order all-reduce of one step's gradient partial (plus
    /// the NLL sum and token count riding along), in place on every rank.
    /// `step` tags the frames so a desynchronized peer fails loudly.
    pub fn all_reduce(
        &mut self,
        step: u64,
        grads: &mut [Option<Vec<f32>>],
        nll: &mut f32,
        count: &mut u64,
    ) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let lens: Vec<Option<usize>> = grads.iter().map(|g| g.as_ref().map(Vec::len)).collect();
        let check = |entries: &[Option<Vec<f32>>], who: &str| -> Result<()> {
            if entries.len() != lens.len() {
                return Err(anyhow!(
                    "{who} sent {} gradient entries, expected {}",
                    entries.len(),
                    lens.len()
                ));
            }
            for (i, (e, l)) in entries.iter().zip(lens.iter()).enumerate() {
                if e.as_ref().map(Vec::len) != *l {
                    return Err(anyhow!("{who} gradient entry {i} has the wrong layout"));
                }
            }
            Ok(())
        };
        if self.rank == 0 {
            // own partial first, then rank order — the tree wants them
            // positionally
            let local: Vec<Option<Vec<f32>>> =
                grads.iter_mut().map(std::mem::take).collect();
            let mut parts = vec![GradPart {
                entries: local,
                nll: *nll,
                count: *count,
            }];
            for r in 1..self.world {
                let (frame, bytes) = Frame::read_from_counted(&mut self.links[r - 1])
                    .with_context(|| format!("rank 0 awaiting rank {r}'s partial"))?;
                self.wire_bytes += bytes;
                let Frame::GradSet {
                    step: s,
                    nll,
                    count,
                    entries,
                } = frame
                else {
                    return Err(anyhow!("rank {r} sent a non-gradient frame mid-step"));
                };
                if s != step {
                    return Err(anyhow!("rank {r} is at step {s}, rank 0 at {step}"));
                }
                check(&entries, &format!("rank {r}"))?;
                parts.push(GradPart { entries, nll, count });
            }
            let reduced = tree_reduce(parts)?;
            let frame = Frame::GradSet {
                step,
                nll: reduced.nll,
                count: reduced.count,
                entries: reduced.entries,
            };
            let buf = frame.encode();
            for link in &mut self.links {
                link.write_all(&buf)?;
                link.flush()?;
            }
            self.wire_bytes += buf.len() as u64 * self.links.len() as u64;
            let Frame::GradSet {
                nll: rn,
                count: rc,
                entries,
                ..
            } = frame
            else {
                unreachable!()
            };
            for (slot, e) in grads.iter_mut().zip(entries) {
                *slot = e;
            }
            *nll = rn;
            *count = rc;
        } else {
            let local: Vec<Option<Vec<f32>>> =
                grads.iter_mut().map(std::mem::take).collect();
            self.wire_bytes += Frame::GradSet {
                step,
                nll: *nll,
                count: *count,
                entries: local,
            }
            .write_to(&mut self.links[0])?;
            let (frame, bytes) = Frame::read_from_counted(&mut self.links[0])
                .with_context(|| format!("rank {} awaiting the reduced set", self.rank))?;
            self.wire_bytes += bytes;
            let Frame::GradSet {
                step: s,
                nll: rn,
                count: rc,
                entries,
            } = frame
            else {
                return Err(anyhow!("rank 0 sent a non-gradient frame mid-step"));
            };
            if s != step {
                return Err(anyhow!(
                    "rank 0 reduced step {s}, rank {} is at {step}",
                    self.rank
                ));
            }
            check(&entries, "rank 0")?;
            for (slot, e) in grads.iter_mut().zip(entries) {
                *slot = e;
            }
            *nll = rn;
            *count = rc;
        }
        Ok(())
    }

    /// The quantized all-reduce (`--grad-format int8|ternary`): same star
    /// topology and halving tree as [`Collective::all_reduce`], but every
    /// wire transfer is a stochastically rounded [`Frame::PackedGradSet`]
    /// instead of dense f32 — workers quantize their uplink partial
    /// through `codec` (which carries their error-feedback residuals),
    /// rank 0 dequantizes, tree-reduces **in f32** (its own partial rides
    /// exact), quantizes the reduced set through its own codec's downlink
    /// lane, and broadcasts. Rank 0 then adopts the *dequantized
    /// broadcast*, not its exact f32 reduction — every rank holds
    /// bit-identical buffers afterwards, so replicas stay in lockstep;
    /// the nll sum and token count ride uncompressed. The bitwise
    /// 1-worker contract does not apply here — the convergence contract
    /// in `rust/tests/dist.rs` does.
    pub fn all_reduce_quantized(
        &mut self,
        step: u64,
        codec: &mut GradCodec,
        grads: &mut [Option<Vec<f32>>],
        nll: &mut f32,
        count: &mut u64,
    ) -> Result<()> {
        if self.world == 1 {
            // the solo run is the f32 reference — nothing to compress
            return Ok(());
        }
        let format = codec.format();
        let lens: Vec<Option<usize>> = grads.iter().map(|g| g.as_ref().map(Vec::len)).collect();
        let check = |f: Format, entries: &[Option<PackedGrad>], who: &str| -> Result<()> {
            if f != format {
                return Err(anyhow!(
                    "{who} quantized gradients as {}, this rank expects {}",
                    f.tag(),
                    format.tag()
                ));
            }
            if entries.len() != lens.len() {
                return Err(anyhow!(
                    "{who} sent {} gradient entries, expected {}",
                    entries.len(),
                    lens.len()
                ));
            }
            for (i, (e, l)) in entries.iter().zip(lens.iter()).enumerate() {
                if e.as_ref().map(|p| p.numel) != *l {
                    return Err(anyhow!("{who} gradient entry {i} has the wrong layout"));
                }
            }
            Ok(())
        };
        if self.rank == 0 {
            // own partial first (exact f32 — it never crosses the wire),
            // then rank order, dequantized
            let local: Vec<Option<Vec<f32>>> =
                grads.iter_mut().map(std::mem::take).collect();
            let mut parts = vec![GradPart {
                entries: local,
                nll: *nll,
                count: *count,
            }];
            for r in 1..self.world {
                let (frame, bytes) = Frame::read_from_counted(&mut self.links[r - 1])
                    .with_context(|| format!("rank 0 awaiting rank {r}'s partial"))?;
                self.wire_bytes += bytes;
                let Frame::PackedGradSet {
                    step: s,
                    nll,
                    count,
                    format: f,
                    entries,
                } = frame
                else {
                    return Err(anyhow!("rank {r} sent a non-gradient frame mid-step"));
                };
                if s != step {
                    return Err(anyhow!("rank {r} is at step {s}, rank 0 at {step}"));
                }
                check(f, &entries, &format!("rank {r}"))?;
                let entries =
                    GradCodec::decode_set(f, &entries).map_err(|e| anyhow!("rank {r}: {e}"))?;
                parts.push(GradPart { entries, nll, count });
            }
            let reduced = tree_reduce(parts)?;
            let packed = codec
                .encode_set(step, DOWNLINK_LANE, &reduced.entries)
                .map_err(|e| anyhow!("quantizing the reduced set: {e}"))?;
            let frame = Frame::PackedGradSet {
                step,
                nll: reduced.nll,
                count: reduced.count,
                format,
                entries: packed,
            };
            let buf = frame.encode();
            for link in &mut self.links {
                link.write_all(&buf)?;
                link.flush()?;
            }
            self.wire_bytes += buf.len() as u64 * self.links.len() as u64;
            // adopt the dequantized broadcast, not the exact reduction —
            // replicas must end the step bit-identical
            let Frame::PackedGradSet { entries, .. } = frame else {
                unreachable!()
            };
            let adopted = GradCodec::decode_set(format, &entries)
                .map_err(|e| anyhow!("decoding own broadcast: {e}"))?;
            for (slot, e) in grads.iter_mut().zip(adopted) {
                *slot = e;
            }
            *nll = reduced.nll;
            *count = reduced.count;
        } else {
            let local: Vec<Option<Vec<f32>>> =
                grads.iter_mut().map(std::mem::take).collect();
            let packed = codec
                .encode_set(step, self.rank as u32, &local)
                .map_err(|e| anyhow!("quantizing rank {}'s partial: {e}", self.rank))?;
            self.wire_bytes += Frame::PackedGradSet {
                step,
                nll: *nll,
                count: *count,
                format,
                entries: packed,
            }
            .write_to(&mut self.links[0])?;
            let (frame, bytes) = Frame::read_from_counted(&mut self.links[0])
                .with_context(|| format!("rank {} awaiting the reduced set", self.rank))?;
            self.wire_bytes += bytes;
            let Frame::PackedGradSet {
                step: s,
                nll: rn,
                count: rc,
                format: f,
                entries,
            } = frame
            else {
                return Err(anyhow!("rank 0 sent a non-gradient frame mid-step"));
            };
            if s != step {
                return Err(anyhow!(
                    "rank 0 reduced step {s}, rank {} is at {step}",
                    self.rank
                ));
            }
            check(f, &entries, "rank 0")?;
            let adopted =
                GradCodec::decode_set(f, &entries).map_err(|e| anyhow!("rank 0: {e}"))?;
            for (slot, e) in grads.iter_mut().zip(adopted) {
                *slot = e;
            }
            *nll = rn;
            *count = rc;
        }
        Ok(())
    }

    /// Build the resync frame for `state`: every grid param in `format`
    /// (its true bit width when packed, f32 otherwise) plus every `.s`
    /// scale as f32. Shared with the bench and the memory model tests.
    pub fn build_grid_sync(
        manifest: &Manifest,
        state: &State,
        packed: bool,
        step: u64,
    ) -> Result<Frame> {
        let grid_fmt = if packed {
            Format::from_bits(manifest.variant.bits)
        } else {
            Format::F32
        };
        let mut entries = Vec::new();
        for (i, meta) in manifest.params.iter().enumerate() {
            let (fmt, scale) = if meta.is_grid() {
                let scale_name = format!("{}.s", meta.name);
                let j = manifest.param_index(&scale_name).ok_or_else(|| {
                    anyhow!("grid param {:?} has no companion scale", meta.name)
                })?;
                let s = state.params[j].scalar()?;
                if grid_fmt.is_grid_format() {
                    (grid_fmt, Some(s))
                } else {
                    (grid_fmt, None)
                }
            } else if meta.is_scale() {
                (Format::F32, None)
            } else {
                continue; // dense params (emb/norms) are not part of the resync
            };
            let vals = state.params[i].to_vec()?;
            let pt = PackedTensor::pack(&vals, meta.shape.clone(), fmt, scale)
                .map_err(|e| anyhow!("packing {:?} for sync: {e}", meta.name))?;
            entries.push((i as u32, pt));
        }
        Ok(Frame::GridSync { step, entries })
    }

    /// Adopt a received resync into `state` (grid + scale params only;
    /// indices and shapes are validated against the manifest first).
    pub fn apply_grid_sync(
        manifest: &Manifest,
        state: &mut State,
        entries: Vec<(u32, PackedTensor)>,
    ) -> Result<()> {
        for (idx, pt) in entries {
            let i = idx as usize;
            let meta = manifest
                .params
                .get(i)
                .ok_or_else(|| anyhow!("sync entry for unknown param index {i}"))?;
            if !meta.is_grid() && !meta.is_scale() {
                return Err(anyhow!(
                    "sync entry {i} ({:?}) is neither grid nor scale",
                    meta.name
                ));
            }
            if pt.numel() != meta.numel() {
                return Err(anyhow!(
                    "sync entry {:?} has {} values, manifest wants {}",
                    meta.name,
                    pt.numel(),
                    meta.numel()
                ));
            }
            let vals = pt
                .unpack()
                .map_err(|e| anyhow!("decoding sync entry {:?}: {e}", meta.name))?;
            state.params[i] = Param::Dense(vals);
        }
        Ok(())
    }

    /// Collective weight resync: rank 0 broadcasts its grid weights (and
    /// scales) and *adopts the same decoded values itself*, workers adopt
    /// them too — so after a sync every rank holds exactly
    /// `unpack(pack(state0))`, even if rank 0 had drifted off-grid (the
    /// scenario the resync exists for). Under the determinism contract
    /// the round trip is a bit-exact no-op — grid values are exactly
    /// `k / s` — so the contract is unperturbed. Returns the wire bytes
    /// this rank shipped (summed over links) or received.
    pub fn sync_grids(
        &mut self,
        step: u64,
        manifest: &Manifest,
        state: &mut State,
        packed: bool,
    ) -> Result<u64> {
        if self.world == 1 {
            return Ok(0);
        }
        if self.rank == 0 {
            let frame = Self::build_grid_sync(manifest, state, packed, step)?;
            let buf = frame.encode();
            for link in &mut self.links {
                link.write_all(&buf)?;
                link.flush()?;
            }
            let Frame::GridSync { entries, .. } = frame else {
                unreachable!("build_grid_sync returns GridSync");
            };
            Self::apply_grid_sync(manifest, state, entries)?;
            let total = buf.len() as u64 * self.links.len() as u64;
            self.wire_bytes += total;
            Ok(total)
        } else {
            let (frame, bytes) = Frame::read_from_counted(&mut self.links[0])
                .with_context(|| format!("rank {} awaiting grid sync", self.rank))?;
            self.wire_bytes += bytes;
            let Frame::GridSync { step: s, entries } = frame else {
                return Err(anyhow!("rank 0 sent a non-sync frame at a sync step"));
            };
            if s != step {
                return Err(anyhow!(
                    "rank 0 synced step {s}, rank {} is at {step}",
                    self.rank
                ));
            }
            Self::apply_grid_sync(manifest, state, entries)?;
            Ok(bytes)
        }
    }

    /// Orderly teardown: workers announce Bye, rank 0 drains them (a
    /// worker that died instead is reported, not hung on).
    pub fn shutdown(mut self) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        if self.rank == 0 {
            for r in 1..self.world {
                match Frame::read_from(&mut self.links[r - 1]) {
                    Ok(Frame::Bye { .. }) => {}
                    Ok(other) => {
                        return Err(anyhow!("rank {r} sent {other:?} instead of Bye"))
                    }
                    Err(e) => return Err(anyhow!("rank {r} vanished at shutdown: {e}")),
                }
            }
        } else {
            Frame::Bye {
                rank: self.rank as u32,
            }
            .write_to(&mut self.links[0])?;
        }
        Ok(())
    }
}

impl GradReducer for Collective {
    fn world(&self) -> usize {
        self.world
    }

    fn reduce(
        &mut self,
        step: u64,
        grads: &mut [Option<Vec<f32>>],
        nll: &mut f32,
        count: &mut u64,
    ) -> Result<()> {
        self.all_reduce(step, grads, nll, count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, VariantSpec};
    use crate::runtime::{Backend, NativeBackend};

    fn short() -> Duration {
        Duration::from_secs(10)
    }

    /// Bind an ephemeral rendezvous port and pair host/join across
    /// threads, running `work` on every rank.
    fn run_world<T: Send + 'static>(
        world: usize,
        work: impl Fn(Collective) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let work = std::sync::Arc::new(work);
        let mut handles = Vec::new();
        for rank in 1..world {
            let addr = addr.clone();
            let work = work.clone();
            handles.push(std::thread::spawn(move || {
                let col = Collective::join(&addr, rank, world, "test-variant", short()).unwrap();
                work(col)
            }));
        }
        let col = Collective::host(listener, world, "test-variant", short()).unwrap();
        let mut out = vec![work(col)];
        for h in handles {
            out.push(h.join().unwrap());
        }
        out
    }

    #[test]
    fn tree_reduce_matches_manual_tree() {
        // four parts with distinguishable values: tree = (p0+p1)+(p2+p3)
        let part = |v: f32| GradPart {
            entries: vec![Some(vec![v, 10.0 * v]), None],
            nll: v,
            count: v as u64,
        };
        let r = tree_reduce(vec![part(1.0), part(2.0), part(3.0), part(4.0)]).unwrap();
        let expect = ((1.0f32 + 2.0) + (3.0 + 4.0), (10.0f32 + 20.0) + (30.0 + 40.0));
        assert_eq!(r.entries[0].as_ref().unwrap()[0].to_bits(), expect.0.to_bits());
        assert_eq!(r.entries[0].as_ref().unwrap()[1].to_bits(), expect.1.to_bits());
        assert_eq!(r.entries[1], None);
        assert_eq!(r.nll, 10.0);
        assert_eq!(r.count, 10);
        // mismatched layouts error instead of corrupting
        let bad = GradPart {
            entries: vec![Some(vec![1.0])],
            nll: 0.0,
            count: 0,
        };
        assert!(tree_reduce(vec![part(1.0), bad]).is_err());
        assert!(tree_reduce(vec![]).is_err());
    }

    #[test]
    fn rendezvous_and_all_reduce_world_4() {
        let outs = run_world(4, |mut col| {
            let r = col.rank() as f32;
            let mut grads = vec![Some(vec![r + 1.0, 2.0 * (r + 1.0)]), None];
            let mut nll = r + 1.0;
            let mut count = col.rank() as u64 + 1;
            col.all_reduce(3, &mut grads, &mut nll, &mut count).unwrap();
            col.shutdown().unwrap();
            (grads, nll, count)
        });
        // tree over ranks: ((1+2)+(3+4)) = 10, same for the doubled lane
        for (grads, nll, count) in &outs {
            assert_eq!(grads[0].as_ref().unwrap(), &vec![10.0f32, 20.0]);
            assert_eq!(grads[1], None);
            assert_eq!(*nll, 10.0);
            assert_eq!(*count, 10);
        }
    }

    #[test]
    fn all_reduce_is_lockstep_over_many_steps() {
        let outs = run_world(2, |mut col| {
            let mut acc = Vec::new();
            for step in 0..5u64 {
                let base = (col.rank() as f32 + 1.0) * (step as f32 + 1.0);
                let mut grads = vec![Some(vec![base])];
                let (mut nll, mut count) = (0.0, 0);
                col.all_reduce(step, &mut grads, &mut nll, &mut count)
                    .unwrap();
                acc.push(grads[0].as_ref().unwrap()[0]);
            }
            let wire = col.wire_bytes();
            col.shutdown().unwrap();
            (acc, wire)
        });
        assert_eq!(outs[0].0, outs[1].0);
        // step s: (s+1) + 2(s+1) = 3(s+1)
        assert_eq!(outs[0].0, vec![3.0, 6.0, 9.0, 12.0, 15.0]);
        // in a 2-rank star both ends move the same frames: bytes agree
        assert!(outs[0].1 > 0, "rank 0 counted no wire traffic");
        assert_eq!(outs[0].1, outs[1].1);
    }

    /// A stray connection (port scanner / health check) that talks
    /// garbage must be rejected without killing the rendezvous for the
    /// real workers.
    #[test]
    fn rendezvous_survives_stray_connections() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stray_addr = addr.clone();
        let stray = std::thread::spawn(move || {
            let mut s = TcpStream::connect(&stray_addr).unwrap();
            let _ = s.write_all(b"GET / HTTP/1.1\r\n\r\n");
        });
        let worker = std::thread::spawn(move || {
            let mut col = Collective::join(&addr, 1, 2, "v", short()).unwrap();
            let mut g = vec![Some(vec![1.0f32])];
            let (mut n, mut c) = (0.0, 0);
            col.all_reduce(0, &mut g, &mut n, &mut c).unwrap();
            col.shutdown().unwrap();
            g[0].as_ref().unwrap()[0]
        });
        let mut col = Collective::host(listener, 2, "v", short()).unwrap();
        let mut g = vec![Some(vec![2.0f32])];
        let (mut n, mut c) = (0.0, 0);
        col.all_reduce(0, &mut g, &mut n, &mut c).unwrap();
        col.shutdown().unwrap();
        stray.join().unwrap();
        assert_eq!(worker.join().unwrap(), 3.0);
        assert_eq!(g[0].as_ref().unwrap()[0], 3.0);
    }

    /// The quantized all-reduce: every rank ends the step holding
    /// bit-identical buffers (rank 0 adopts its own dequantized
    /// broadcast), the values track the exact f32 sum within the grid
    /// resolution, nll/count ride exact, and the wire moves ~4× fewer
    /// bytes than the f32 exchange of the same buffers.
    #[test]
    fn quantized_all_reduce_world_2_tracks_f32_and_shrinks_the_wire() {
        let n = 4096usize;
        let steps = 8u64;
        let outs = run_world(2, move |mut col| {
            let mut codec = GradCodec::new(Format::IntN(8)).unwrap();
            let mut acc: Vec<Vec<f32>> = Vec::new();
            for step in 0..steps {
                // rank- and step-dependent smooth gradients in [-1e-2, 1e-2]
                let r = col.rank() as f32;
                let g: Vec<f32> = (0..n)
                    .map(|i| ((i as f32 * 0.17 + r) + step as f32).sin() * 1e-2)
                    .collect();
                let mut grads = vec![Some(g), None];
                let mut nll = r + 1.0;
                let mut count = col.rank() as u64 + 1;
                col.all_reduce_quantized(step, &mut codec, &mut grads, &mut nll, &mut count)
                    .unwrap();
                assert_eq!((nll, count), (3.0, 3), "nll/count must ride exact");
                assert_eq!(grads[1], None);
                acc.push(grads[0].take().unwrap());
            }
            let wire = col.wire_bytes();
            col.shutdown().unwrap();
            (acc, wire)
        });
        // replica lockstep: both ranks decoded the same broadcast
        for (a, b) in outs[0].0.iter().zip(outs[1].0.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "ranks diverged");
            }
        }
        // accuracy: within a few int8 grid steps of the exact f32 sum
        for (step, got) in outs[0].0.iter().enumerate() {
            let mut absmax = 0.0f32;
            let exact: Vec<f32> = (0..n)
                .map(|i| {
                    let s: f32 = (0..2)
                        .map(|r| ((i as f32 * 0.17 + r as f32) + step as f32).sin() * 1e-2)
                        .sum();
                    absmax = absmax.max(s.abs());
                    s
                })
                .collect();
            // uplink grid step + downlink grid step, with slack for the
            // carried residual (bounded by one step each)
            let tol = 4.0 * absmax / 127.0;
            for (g, e) in got.iter().zip(exact.iter()) {
                assert!((g - e).abs() <= tol, "step {step}: {g} vs {e} (tol {tol})");
            }
        }
        // the wire carried int8 codes: ≥3.9× under the f32 exchange of
        // the same layout (2 transfers/step/rank of ~4n bytes vs ~n)
        let f32_wire_per_rank = steps * 2 * (n as u64 * 4 + 9 + 8 + 4 + 8 + 4 + 2 + 9);
        let ratio = f32_wire_per_rank as f64 / outs[0].1 as f64;
        assert!(ratio > 3.9, "wire ratio {ratio} (bytes {})", outs[0].1);
        assert_eq!(outs[0].1, outs[1].1);
    }

    /// Error feedback across the wire: a constant gradient all-reduced
    /// over many quantized steps averages to the exact sum — the residual
    /// keeps the time-average unbiased even on a coarse ternary grid.
    #[test]
    fn quantized_all_reduce_error_feedback_converges_in_time_average() {
        let steps = 64u64;
        let outs = run_world(2, move |mut col| {
            let mut codec = GradCodec::new(Format::Ternary2bit).unwrap();
            let g = vec![0.003f32, -0.007, 0.011, 0.005];
            let mut mean = vec![0.0f64; g.len()];
            for step in 0..steps {
                let mut grads = vec![Some(g.clone())];
                let (mut nll, mut count) = (0.0, 0);
                col.all_reduce_quantized(step, &mut codec, &mut grads, &mut nll, &mut count)
                    .unwrap();
                for (m, v) in mean.iter_mut().zip(grads[0].as_ref().unwrap()) {
                    *m += *v as f64 / steps as f64;
                }
            }
            col.shutdown().unwrap();
            mean
        });
        let exact = [0.006f64, -0.014, 0.022, 0.010];
        // ternary grid step here is absmax ≈ 0.022+carry; a 64-step EF
        // average must land within ~2 grid steps / 64 of the true sum
        for (m, e) in outs[0].iter().zip(exact.iter()) {
            assert!((m - e).abs() < 2.5e-3, "mean {m} vs exact {e}");
        }
    }

    /// A format mismatch between ranks fails loudly mid-step, not
    /// silently mis-decodes.
    #[test]
    fn quantized_all_reduce_rejects_format_mismatch() {
        let outs = run_world(2, |mut col| {
            let format = if col.rank() == 0 {
                Format::IntN(8)
            } else {
                Format::Ternary2bit
            };
            let mut codec = GradCodec::new(format).unwrap();
            let mut grads = vec![Some(vec![0.5f32, -0.25])];
            let (mut nll, mut count) = (0.0, 0);
            let res = col.all_reduce_quantized(0, &mut codec, &mut grads, &mut nll, &mut count);
            // no Bye handshake here: rank 0 errors mid-step, dropping the
            // collective closes the link and unblocks the worker's read
            // (which then errors too) — a shutdown() would deadlock
            drop(col);
            res.err().map(|e| format!("{e:#}"))
        });
        let msg = outs[0].as_ref().expect("rank 0 must reject the mismatch");
        assert!(msg.contains("quantized gradients as"), "{msg}");
    }

    #[test]
    fn quantized_solo_collective_is_identity() {
        let mut col = Collective::solo();
        let mut codec = GradCodec::new(Format::IntN(8)).unwrap();
        let mut grads = vec![Some(vec![1.5f32])];
        let (mut nll, mut count) = (2.5f32, 3u64);
        col.all_reduce_quantized(0, &mut codec, &mut grads, &mut nll, &mut count)
            .unwrap();
        assert_eq!(grads[0].as_ref().unwrap(), &vec![1.5f32]);
        assert_eq!((nll, count), (2.5, 3));
    }

    #[test]
    fn join_validates_rank_bounds() {
        assert!(Collective::join("127.0.0.1:1", 0, 2, "v", short()).is_err());
        assert!(Collective::join("127.0.0.1:1", 2, 2, "v", short()).is_err());
    }

    #[test]
    fn solo_collective_is_identity() {
        let mut col = Collective::solo();
        assert_eq!(col.world(), 1);
        let mut grads = vec![Some(vec![1.5f32])];
        let (mut nll, mut count) = (2.5f32, 3u64);
        col.all_reduce(0, &mut grads, &mut nll, &mut count).unwrap();
        assert_eq!(grads[0].as_ref().unwrap(), &vec![1.5f32]);
        assert_eq!((nll, count), (2.5, 3));
        let be = NativeBackend::new(&VariantSpec::new("test", Mode::Dqt, 1.58)).unwrap();
        let mut st = be.init_state(1).unwrap();
        assert_eq!(
            Collective::solo()
                .sync_grids(0, be.manifest(), &mut st, true)
                .unwrap(),
            0
        );
        Collective::solo().shutdown().unwrap();
    }

    /// A packed grid sync carries a worker's diverged grid weights back
    /// onto rank 0's exact values (and leaves dense params alone), and the
    /// pack/decode round trip of on-grid values is bit-exact.
    #[test]
    fn grid_sync_restores_worker_to_coordinator_state() {
        let be = NativeBackend::new(&VariantSpec::new("test", Mode::Dqt, 1.58)).unwrap();
        let manifest = be.manifest().clone();
        let coordinator_state = be.init_state(7).unwrap();
        let outs = run_world(2, move |mut col| {
            let be = NativeBackend::new(&VariantSpec::new("test", Mode::Dqt, 1.58)).unwrap();
            let mut st = be.init_state(if col.rank() == 0 { 7 } else { 8 }).unwrap();
            let bytes = col.sync_grids(4, be.manifest(), &mut st, true).unwrap();
            col.shutdown().unwrap();
            (st, bytes)
        });
        let (ref rank0_state, sent) = outs[0];
        let (ref worker_state, received) = outs[1];
        assert!(sent > 0 && received == sent);
        for (i, meta) in manifest.params.iter().enumerate() {
            let a = rank0_state.params[i].to_vec().unwrap();
            let b = worker_state.params[i].to_vec().unwrap();
            let c = coordinator_state.params[i].to_vec().unwrap();
            if meta.is_grid() || meta.is_scale() {
                assert_eq!(a, b, "{} not synced", meta.name);
                assert_eq!(a, c, "{} drifted on rank 0", meta.name);
            } else {
                assert_ne!(a, b, "{} should differ (different init seeds)", meta.name);
            }
        }
    }

    #[test]
    fn apply_grid_sync_validates_entries() {
        let be = NativeBackend::new(&VariantSpec::new("test", Mode::Dqt, 1.58)).unwrap();
        let m = be.manifest().clone();
        let mut st = be.init_state(1).unwrap();
        // out-of-range index
        let pt = PackedTensor::pack(&[1.0], vec![1], Format::F32, None).unwrap();
        assert!(
            Collective::apply_grid_sync(&m, &mut st, vec![(9999, pt.clone())]).is_err()
        );
        // dense (non-grid) target
        let emb_idx = m.params.iter().position(|p| !p.is_grid() && !p.is_scale());
        if let Some(i) = emb_idx {
            assert!(
                Collective::apply_grid_sync(&m, &mut st, vec![(i as u32, pt.clone())]).is_err()
            );
        }
        // shape mismatch against a real grid param
        let grid_idx = m.params.iter().position(|p| p.is_grid()).unwrap();
        assert!(
            Collective::apply_grid_sync(&m, &mut st, vec![(grid_idx as u32, pt)]).is_err()
        );
    }
}
