//! Rank 0 of a distributed run: hosts the rendezvous, optionally spawns
//! the other ranks as local worker processes (`dqt train --workers N`),
//! trains its own band, and owns the run's outputs. Multi-host runs skip
//! the spawning and let `dqt worker --rank R --join ADDR` processes on
//! other machines fill the world.

use std::net::TcpListener;
use std::process::{Child, Command};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::config::{DistConfig, TrainConfig, VariantSpec};
use crate::data::Pipeline;
use crate::kernels::Pool;
use crate::obs::TrainObs;
use crate::runtime::{State, VariantRuntime};
use crate::train::{RunMetrics, Trainer};

use super::collective::{Collective, RENDEZVOUS_TIMEOUT};
use super::{rendezvous_variant, DistExchange};

/// Child worker processes spawned by rank 0. Dropped children are killed
/// (a failed coordinator never leaves orphan trainers burning CPU);
/// [`LocalWorkers::wait`] reaps them and fails on any non-zero exit.
pub struct LocalWorkers {
    children: Vec<(usize, Child)>,
}

impl LocalWorkers {
    /// Spawn ranks `1..world` of this binary as `worker` subcommand
    /// processes joining `addr`. `passthrough` carries the variant/train
    /// flags the ranks must agree on (built by the CLI from its own
    /// invocation).
    pub fn spawn(world: usize, addr: &str, passthrough: &[String]) -> Result<LocalWorkers> {
        let exe = std::env::current_exe().context("locating our own binary")?;
        let mut children = Vec::new();
        for rank in 1..world {
            let child = Command::new(&exe)
                // spawned ranks must not inherit rank 0's observability
                // addresses — every child would race to bind them
                .env_remove("DQT_METRICS_ADDR")
                .env_remove("DQT_WATCH_ADDR")
                .arg("worker")
                .arg("--rank")
                .arg(rank.to_string())
                .arg("--workers")
                .arg(world.to_string())
                .arg("--join")
                .arg(addr)
                .args(passthrough)
                .spawn()
                .with_context(|| format!("spawning local worker rank {rank}"))?;
            children.push((rank, child));
        }
        Ok(LocalWorkers { children })
    }

    /// Reap every worker; errors if any exited non-zero.
    pub fn wait(&mut self) -> Result<()> {
        for (rank, mut child) in self.children.drain(..) {
            let status = child
                .wait()
                .with_context(|| format!("waiting for worker rank {rank}"))?;
            if !status.success() {
                return Err(anyhow!("worker rank {rank} exited with {status}"));
            }
        }
        Ok(())
    }
}

impl Drop for LocalWorkers {
    fn drop(&mut self) {
        for (_, child) in &mut self.children {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// What the distributed run did, next to its training results.
#[derive(Clone, Debug)]
pub struct DistReport {
    pub world: usize,
    /// resyncs performed and their cumulative wire bytes (rank 0's side)
    pub syncs: u64,
    pub sync_bytes: u64,
    /// the gradient wire format tag (`f32|int8|ternary`)
    pub grad_format: String,
    /// cumulative all-reduce wire bytes rank 0 moved (sent + received) —
    /// the number `--grad-format int8|ternary` shrinks ~4×/~16×
    pub allreduce_bytes: u64,
    /// error-feedback residual state held on rank 0 (bytes; 0 for f32)
    pub residual_bytes: u64,
}

impl DistReport {
    /// JSON for `out_dir/dist.json` — what the dist-smoke CI legs assert
    /// wire shrinkage against.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::obj()
            .set("world", Value::Num(self.world as f64))
            .set("grad_format", Value::Str(self.grad_format.clone()))
            .set("allreduce_bytes", Value::Num(self.allreduce_bytes as f64))
            .set("syncs", Value::Num(self.syncs as f64))
            .set("sync_bytes", Value::Num(self.sync_bytes as f64))
            .set("residual_bytes", Value::Num(self.residual_bytes as f64))
    }
}

/// Run rank 0 of a distributed training job end to end: bind the
/// rendezvous address, optionally spawn `world - 1` local worker
/// processes (`spawn_passthrough = Some(flags)`; `None` waits for
/// multi-host workers to join instead), train the rank-0 band in
/// lockstep, and tear the world down. Returns the runtime it trained on
/// (callers need its manifest to persist the checkpoint) with the final
/// state + metrics — bitwise equal to what `--workers 1` produces, by
/// the determinism contract — plus a wire-traffic report.
///
/// When `obs` is given, the trainer reports through it: step/loss gauges
/// and all-reduce / grid-sync accounting land in its registry (served by
/// `--metrics-addr`) and per-step frames stream to any `--watch-addr`
/// publisher attached to it. Observation never touches the reduction, so
/// the bitwise contract is unaffected.
pub fn train_distributed(
    spec: &VariantSpec,
    tcfg: &TrainConfig,
    dcfg: &DistConfig,
    pool: Option<Arc<Pool>>,
    spawn_passthrough: Option<&[String]>,
    obs: Option<Arc<TrainObs>>,
) -> Result<(VariantRuntime, State, RunMetrics, DistReport)> {
    if dcfg.rank != 0 {
        return Err(anyhow!("train_distributed is the rank-0 entry"));
    }
    let cfg = spec
        .model_config()
        .ok_or_else(|| anyhow!("unknown model {:?}", spec.model))?;
    dcfg.validate(cfg.batch_size)?;
    let variant = spec.variant_name();

    let vrt = match pool {
        Some(pool) => VariantRuntime::native_with_pool(spec, pool)?,
        None => VariantRuntime::native(spec)?,
    };
    let pipeline = Pipeline::build(&tcfg.dataset, tcfg.seed, cfg.vocab_size, cfg.max_seq_len)?;

    let listener = TcpListener::bind(&dcfg.addr)
        .with_context(|| format!("binding rendezvous address {}", dcfg.addr))?;
    let bound = listener.local_addr()?;
    let mut workers = match spawn_passthrough {
        Some(flags) if dcfg.world > 1 => {
            eprintln!(
                "dist: rank 0/{} hosting {} — spawning {} local workers",
                dcfg.world,
                bound,
                dcfg.world - 1
            );
            Some(LocalWorkers::spawn(dcfg.world, &bound.to_string(), flags)?)
        }
        _ => {
            if dcfg.world > 1 {
                eprintln!(
                    "dist: rank 0/{} hosting {} — waiting for {} external \
                     workers (`repro worker --rank R --workers {} --join {bound}`)",
                    dcfg.world,
                    bound,
                    dcfg.world - 1,
                    dcfg.world
                );
            }
            None
        }
    };

    let col = Collective::host(
        listener,
        dcfg.world,
        &rendezvous_variant(&variant, dcfg.grad_format),
        RENDEZVOUS_TIMEOUT,
    )?;
    let mut ex = DistExchange::with_obs(col, dcfg, obs.clone());
    let mut trainer = Trainer::new(&vrt, &pipeline, tcfg.clone());
    if let Some(obs) = obs {
        trainer.obs = obs;
    }
    let world = dcfg.world;
    trainer.progress = Some(Box::new(move |step, loss| {
        eprintln!("[rank 0/{world}] step {step}: loss {loss:.4}");
    }));
    let (state, metrics) = trainer.run_sharded(&mut ex)?;
    let report = DistReport {
        world: dcfg.world,
        syncs: ex.syncs(),
        sync_bytes: ex.sync_bytes(),
        grad_format: dcfg.grad_format.as_str().to_string(),
        allreduce_bytes: ex.allreduce_bytes(),
        residual_bytes: ex.residual_bytes(),
    };
    // end the trainer's borrow of `vrt` so it can be handed back
    drop(trainer);
    ex.into_collective().shutdown()?;
    if let Some(w) = workers.as_mut() {
        w.wait()?;
    }
    Ok((vrt, state, metrics, report))
}
