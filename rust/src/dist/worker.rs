//! Rank R ≥ 1 of a distributed run: join a coordinator, train the
//! assigned band in lockstep, write nothing. The `dqt worker --rank R
//! --workers N --join ADDR` subcommand is a thin shell over [`run`] —
//! point it at a coordinator on any host.
//!
//! Every worker holds the full replicated state (data parallelism) and —
//! by the determinism contract — a *bit-identical* copy of it at every
//! step, so a worker's final loss equals rank 0's. Workers still compute
//! their own metrics (the integration tests assert rank parity on them)
//! but rank 0 is the only rank that persists anything.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{DistConfig, TrainConfig, VariantSpec};
use crate::data::Pipeline;
use crate::kernels::Pool;
use crate::obs::TrainObs;
use crate::runtime::{State, VariantRuntime};
use crate::train::{RunMetrics, Trainer};

use super::collective::{Collective, RENDEZVOUS_TIMEOUT};
use super::{rendezvous_variant, DistExchange};

/// Join `dcfg.addr` as rank `dcfg.rank` and train to completion. Returns
/// the final state + metrics (bit-identical to every other rank's).
/// When `obs` is given, this rank's steps and collective traffic are
/// recorded through it (`--metrics-addr` / `--watch-addr` on `worker`).
pub fn run(
    spec: &VariantSpec,
    tcfg: &TrainConfig,
    dcfg: &DistConfig,
    pool: Option<Arc<Pool>>,
    obs: Option<Arc<TrainObs>>,
) -> Result<(State, RunMetrics)> {
    if dcfg.rank == 0 {
        return Err(anyhow!("rank 0 trains via `train --workers N`, not `worker`"));
    }
    let cfg = spec
        .model_config()
        .ok_or_else(|| anyhow!("unknown model {:?}", spec.model))?;
    dcfg.validate(cfg.batch_size)?;
    let variant = spec.variant_name();
    let vrt = match pool {
        Some(pool) => VariantRuntime::native_with_pool(spec, pool)?,
        None => VariantRuntime::native(spec)?,
    };
    // same dataset + seed as every other rank → same corpus, same BPE
    // vocabulary, same shuffle stream (the shard band picks our rows)
    let pipeline = Pipeline::build(&tcfg.dataset, tcfg.seed, cfg.vocab_size, cfg.max_seq_len)?;
    eprintln!(
        "dist: rank {}/{} joining {} ({} kernel threads)",
        dcfg.rank,
        dcfg.world,
        dcfg.addr,
        vrt.threads()
    );
    let col = Collective::join(
        &dcfg.addr,
        dcfg.rank,
        dcfg.world,
        &rendezvous_variant(&variant, dcfg.grad_format),
        RENDEZVOUS_TIMEOUT,
    )?;
    let mut ex = DistExchange::with_obs(col, dcfg, obs.clone());
    let mut trainer = Trainer::new(&vrt, &pipeline, tcfg.clone());
    if let Some(obs) = obs {
        trainer.obs = obs;
    }
    let (rank, world) = (dcfg.rank, dcfg.world);
    trainer.progress = Some(Box::new(move |step, loss| {
        eprintln!("[rank {rank}/{world}] step {step}: loss {loss:.4}");
    }));
    let (state, metrics) = trainer.run_sharded(&mut ex)?;
    ex.into_collective().shutdown()?;
    eprintln!(
        "dist: rank {}/{} done — final loss {:.4}, dev loss {:.4}",
        dcfg.rank,
        dcfg.world,
        metrics.tail_loss(10).unwrap_or(f32::NAN),
        metrics.final_dev_loss.unwrap_or(f32::NAN)
    );
    Ok((state, metrics))
}
