//! Distributed data-parallel training — zero-dependency TCP, bitwise
//! deterministic.
//!
//! The paper's §1 memory argument compounds across workers: DQT has no
//! FP32 master weights to replicate, and because the master state *is* a
//! 2-bit grid, the periodic weight resync ships packed ternary codes +
//! scales at ~16× less traffic than an f32 exchange. This module is the
//! training-plane twin of the `serve` subsystem's data plane:
//!
//! * [`wire`] — length-prefixed binary frames (f32 gradient sets,
//!   SR-quantized [`crate::quant::gradcodec::PackedGrad`] gradient sets,
//!   [`crate::quant::codec::PackedTensor`] grid syncs via the codec
//!   registry), with checkpoint-grade corrupt-frame hardening.
//! * [`collective`] — rendezvous over `TcpListener`, fixed-rank-order
//!   tree all-reduce, packed grid broadcast. [`Collective`] implements
//!   [`crate::runtime::GradReducer`], so the native backend's sharded
//!   train step reduces straight through it.
//! * [`coordinator`] — rank 0: hosts rendezvous, spawns local worker
//!   processes (`dqt train --workers N`), trains, owns the outputs.
//! * [`worker`] — rank R ≥ 1: joins a coordinator
//!   (`dqt worker --rank R --join ADDR`, multi-host capable), trains in
//!   lockstep, writes nothing.
//!
//! ## Determinism contract (extends `docs/PERFORMANCE.md`)
//!
//! Gradients are summed by a *fixed halving tree over global batch rows*
//! whose leaves are per-row unnormalized gradients. Contiguous equal
//! row bands of a power-of-two world are subtrees of that tree, so the
//! rank-order cross-rank combine finishes the exact chain the 1-worker
//! run computes: an N-worker run is **bitwise equal** to the 1-worker
//! run — loss curve, final state, eval NLL — at every step, at every
//! kernel thread count. Pinned by `rust/tests/dist.rs` and the required
//! CI `dist-smoke` job. See `docs/DISTRIBUTED.md`.

pub mod collective;
pub mod coordinator;
pub mod wire;
pub mod worker;

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::{DistConfig, GradFormat};
use crate::obs::trace;
use crate::obs::TrainObs;
use crate::quant::codec::Format;
use crate::quant::gradcodec::GradCodec;
use crate::runtime::{GradReducer, Manifest, State};
use crate::train::StepExchange;

pub use collective::Collective;
pub use coordinator::{train_distributed, DistReport, LocalWorkers};
pub use wire::Frame;

/// The codec-registry format behind a `--grad-format` tier. The mapping
/// lives here (not in `config`, which stays dependency-free): int8 is the
/// full 8-bit grid, ternary the paper's 2-bit packing.
pub fn grad_wire_format(gf: GradFormat) -> Option<Format> {
    match gf {
        GradFormat::F32 => None,
        GradFormat::Int8 => Some(Format::IntN(8)),
        GradFormat::Ternary => Some(Format::Ternary2bit),
    }
}

/// The rendezvous variant string for a run: quantized gradient exchange
/// changes training semantics, so it is part of run identity — a worker
/// joining with a different `--grad-format` must be rejected at Hello,
/// not silently mis-decode mid-step. `f32` keeps the bare variant name
/// so the default path (and every pre-existing invocation) is unchanged.
pub fn rendezvous_variant(variant: &str, gf: GradFormat) -> String {
    match gf {
        GradFormat::F32 => variant.to_string(),
        other => format!("{variant}+grad-{}", other.as_str()),
    }
}

/// The [`StepExchange`] a distributed rank trains through: the TCP
/// collective as the gradient reducer plus the every-K-steps packed-grid
/// resync. A `Collective::solo()` exchange is the 1-worker reference —
/// same code path, no sockets.
///
/// The exchange is itself the [`GradReducer`] handed to the backend: it
/// wraps [`Collective::all_reduce`] with wall-time and wire-byte
/// accounting into an optional [`TrainObs`] (the `dqt_dist_*` metrics),
/// without touching the reduction itself — the bitwise-determinism
/// contract is observation-free.
pub struct DistExchange {
    col: Collective,
    sync_every: u64,
    packed_sync: bool,
    sync_bytes: u64,
    syncs: u64,
    /// how gradient partials travel; `F32` keeps the bitwise contract
    grad_format: GradFormat,
    /// the SR + error-feedback wire codec — `Some` iff `grad_format` is
    /// quantized. One per rank: workers encode their uplink through it,
    /// rank 0 its reduced-set downlink.
    grad_codec: Option<GradCodec>,
    /// cumulative all-reduce wire bytes this rank moved (sent + received)
    allreduce_bytes: u64,
    obs: Option<Arc<TrainObs>>,
}

impl DistExchange {
    pub fn new(col: Collective, dcfg: &DistConfig) -> Self {
        Self::with_obs(col, dcfg, None)
    }

    /// An exchange that reports all-reduce latency/bytes and grid-sync
    /// bytes into `obs` (when given).
    pub fn with_obs(col: Collective, dcfg: &DistConfig, obs: Option<Arc<TrainObs>>) -> Self {
        let grad_codec = grad_wire_format(dcfg.grad_format).map(|f| {
            GradCodec::new(f).expect("grad wire formats are grid formats by construction")
        });
        DistExchange {
            col,
            sync_every: dcfg.sync_every,
            packed_sync: dcfg.packed_sync,
            sync_bytes: 0,
            syncs: 0,
            grad_format: dcfg.grad_format,
            grad_codec,
            allreduce_bytes: 0,
            obs,
        }
    }

    /// Cumulative wire bytes the resyncs shipped or received on this rank.
    pub fn sync_bytes(&self) -> u64 {
        self.sync_bytes
    }

    /// Number of resyncs performed.
    pub fn syncs(&self) -> u64 {
        self.syncs
    }

    /// Cumulative wire bytes the per-step all-reduces moved on this rank
    /// (sent + received) — the number the quantized formats shrink.
    pub fn allreduce_bytes(&self) -> u64 {
        self.allreduce_bytes
    }

    /// The gradient wire format this exchange runs.
    pub fn grad_format(&self) -> GradFormat {
        self.grad_format
    }

    /// Bytes of error-feedback residual state held on this rank (one f32
    /// copy of the gradient set when quantized, 0 for f32).
    pub fn residual_bytes(&self) -> u64 {
        self.grad_codec.as_ref().map_or(0, GradCodec::residual_bytes)
    }

    /// Hand the collective back (for the shutdown handshake).
    pub fn into_collective(self) -> Collective {
        self.col
    }
}

impl GradReducer for DistExchange {
    fn world(&self) -> usize {
        self.col.world()
    }

    fn reduce(
        &mut self,
        step: u64,
        grads: &mut [Option<Vec<f32>>],
        nll: &mut f32,
        count: &mut u64,
    ) -> Result<()> {
        let before = self.col.wire_bytes();
        let t0 = Instant::now();
        {
            let _sp = trace::span("dist", trace::names::DIST_ALLREDUCE);
            match &mut self.grad_codec {
                None => self.col.all_reduce(step, grads, nll, count)?,
                Some(codec) => self
                    .col
                    .all_reduce_quantized(step, codec, grads, nll, count)?,
            }
        }
        let bytes = self.col.wire_bytes() - before;
        self.allreduce_bytes += bytes;
        if let Some(obs) = &self.obs {
            obs.on_allreduce(self.grad_format.as_str(), bytes, t0.elapsed());
        }
        Ok(())
    }
}

impl StepExchange for DistExchange {
    fn rank(&self) -> usize {
        self.col.rank()
    }

    fn world(&self) -> usize {
        self.col.world()
    }

    fn reducer(&mut self) -> &mut dyn GradReducer {
        self
    }

    fn sync_state(
        &mut self,
        manifest: &Manifest,
        state: &mut State,
        step: u64,
    ) -> Result<u64> {
        if self.sync_every == 0 || step == 0 || step % self.sync_every != 0 {
            return Ok(0);
        }
        let bytes = {
            let _sp = trace::span("dist", trace::names::DIST_GRID_SYNC);
            self.col
                .sync_grids(step, manifest, state, self.packed_sync)?
        };
        self.sync_bytes += bytes;
        self.syncs += 1;
        if let Some(obs) = &self.obs {
            obs.on_grid_sync(bytes);
        }
        Ok(bytes)
    }
}
