//! Batch loader: epoch iteration with background prefetch + backpressure.
//!
//! The training loop consumes `[batch, seq+1]` i32 batches. A producer
//! thread assembles batches ahead of the consumer through a bounded
//! channel (capacity = `PREFETCH`), so host-side batch assembly overlaps
//! device execution and a slow consumer naturally backpressures the
//! producer — the L3 streaming-orchestration pattern.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;

use super::corpus::Rng;
use super::dataset::Dataset;

const PREFETCH: usize = 4;

/// One training batch, row-major `[batch_size][seq_len + 1]`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub batch_size: usize,
    pub width: usize,
    /// global step index this batch is destined for
    pub step: u64,
}

/// Iterate `n_steps` batches over the training split (cycling epochs, each
/// epoch reshuffled deterministically from `seed + epoch`).
pub struct Loader {
    rx: Receiver<Batch>,
    _handle: std::thread::JoinHandle<()>,
}

impl Loader {
    pub fn new(ds: Arc<Dataset>, batch_size: usize, n_steps: u64, seed: u64) -> Self {
        Self::new_sharded(ds, batch_size, n_steps, seed, (0, batch_size))
    }

    /// Shard-aware loader for distributed data parallelism: every rank
    /// walks the *same* epoch/shuffle stream over the full
    /// `global_batch`-row batches, but only materializes its contiguous
    /// row band `[band.0, band.1)` of each one. The bands of N ranks
    /// therefore tile the 1-worker batch exactly — same rows, same order,
    /// no duplication — which is half of the distributed determinism
    /// contract (the other half is the fixed gradient-reduction tree).
    pub fn new_sharded(
        ds: Arc<Dataset>,
        global_batch: usize,
        n_steps: u64,
        seed: u64,
        band: (usize, usize),
    ) -> Self {
        assert!(
            band.0 < band.1 && band.1 <= global_batch,
            "bad shard band {band:?} of a {global_batch}-row batch"
        );
        let (tx, rx) = sync_channel::<Batch>(PREFETCH);
        let handle = std::thread::spawn(move || {
            let w = ds.width();
            let n = ds.n_train;
            let band_rows = band.1 - band.0;
            let mut order: Vec<usize> = (0..n).collect();
            let mut pos = 0usize;
            let mut epoch = 0u64;
            let reshuffle = |order: &mut Vec<usize>, epoch: u64| {
                let mut rng = Rng::new(seed.wrapping_add(epoch));
                for i in (1..order.len()).rev() {
                    order.swap(i, rng.below(i + 1));
                }
            };
            reshuffle(&mut order, epoch);
            for step in 0..n_steps {
                let mut tokens = Vec::with_capacity(band_rows * w);
                for row in 0..global_batch {
                    if pos >= n {
                        pos = 0;
                        epoch += 1;
                        reshuffle(&mut order, epoch);
                    }
                    if (band.0..band.1).contains(&row) {
                        tokens.extend_from_slice(ds.train_chunk(order[pos]));
                    }
                    pos += 1;
                }
                let batch = Batch {
                    tokens,
                    batch_size: band_rows,
                    width: w,
                    step,
                };
                if tx.send(batch).is_err() {
                    return; // consumer dropped — stop producing
                }
            }
        });
        Loader {
            rx,
            _handle: handle,
        }
    }

    /// Blocking receive of the next batch; `None` when exhausted.
    pub fn next(&self) -> Option<Batch> {
        self.rx.recv().ok()
    }
}

/// Assemble the dev split into fixed batches (padding the final partial
/// batch with PAD rows so every batch matches the compiled shape).
pub fn dev_batches(ds: &Dataset, batch_size: usize) -> Vec<Batch> {
    let w = ds.width();
    let mut out = Vec::new();
    let mut row = 0usize;
    let mut step = 0u64;
    while row < ds.n_dev {
        let mut tokens = vec![super::tokenizer::PAD_ID; batch_size * w];
        for b in 0..batch_size.min(ds.n_dev - row) {
            tokens[b * w..(b + 1) * w].copy_from_slice(ds.dev_chunk(row + b));
        }
        row += batch_size;
        out.push(Batch {
            tokens,
            batch_size,
            width: w,
            step,
        });
        step += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Arc<Dataset> {
        let stream: Vec<i32> = (0..33 * 64).map(|i| (i % 200) as i32 + 1).collect();
        Arc::new(Dataset::from_stream(&stream, 32, 0.05, 1))
    }

    #[test]
    fn yields_requested_steps() {
        let ds = dataset();
        let loader = Loader::new(ds, 4, 10, 42);
        let mut n = 0;
        while let Some(b) = loader.next() {
            assert_eq!(b.tokens.len(), 4 * 33);
            assert_eq!(b.step, n);
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn epoch_covers_each_chunk_once() {
        let ds = dataset();
        let n_train = ds.n_train;
        let steps = (n_train / 2) as u64; // one epoch at batch 2 (± the tail row)
        let loader = Loader::new(ds.clone(), 2, steps, 7);
        let mut first_tokens: Vec<i32> = Vec::new();
        while let Some(b) = loader.next() {
            first_tokens.extend(b.tokens.iter().step_by(33)); // first col of each row
        }
        assert_eq!(first_tokens.len(), (n_train / 2) * 2);
    }

    #[test]
    fn deterministic_across_runs() {
        let ds = dataset();
        let collect = |seed| {
            let l = Loader::new(ds.clone(), 2, 5, seed);
            let mut v = Vec::new();
            while let Some(b) = l.next() {
                v.extend(b.tokens);
            }
            v
        };
        assert_eq!(collect(3), collect(3));
        assert_ne!(collect(3), collect(4));
    }

    #[test]
    fn dev_batches_cover_dev_split() {
        let ds = dataset();
        let batches = dev_batches(&ds, 4);
        let rows: usize = batches.len() * 4;
        assert!(rows >= ds.n_dev);
        // every non-pad dev token appears
        let total_nonpad: usize = batches
            .iter()
            .map(|b| b.tokens.iter().filter(|&&t| t != 0).count())
            .sum();
        assert_eq!(total_nonpad, ds.dev_token_count());
    }

    /// Distributed sharding contract: the per-rank bands of every world
    /// size tile the 1-worker global batch exactly once, step for step —
    /// concatenating the bands in rank order reproduces the unsharded
    /// batch bit for bit.
    #[test]
    fn shard_bands_tile_the_global_batch_exactly_once() {
        let ds = dataset();
        let (global_batch, steps, seed) = (4usize, 6u64, 11u64);
        let full: Vec<Batch> = {
            let l = Loader::new(ds.clone(), global_batch, steps, seed);
            std::iter::from_fn(|| l.next()).collect()
        };
        assert_eq!(full.len(), steps as usize);
        for world in [2usize, 4] {
            let per = global_batch / world;
            let mut shards: Vec<Vec<Batch>> = Vec::new();
            for rank in 0..world {
                let band = (rank * per, (rank + 1) * per);
                let l = Loader::new_sharded(ds.clone(), global_batch, steps, seed, band);
                shards.push(std::iter::from_fn(|| l.next()).collect());
            }
            for (si, fb) in full.iter().enumerate() {
                let mut tiled: Vec<i32> = Vec::new();
                for shard in &shards {
                    let b = &shard[si];
                    assert_eq!(b.step, fb.step);
                    assert_eq!(b.batch_size, per);
                    assert_eq!(b.width, fb.width);
                    tiled.extend_from_slice(&b.tokens);
                }
                assert_eq!(tiled, fb.tokens, "world {world} step {si}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "bad shard band")]
    fn shard_band_bounds_are_checked() {
        let ds = dataset();
        let _ = Loader::new_sharded(ds, 4, 1, 0, (2, 5));
    }

    #[test]
    fn backpressure_does_not_deadlock() {
        // consumer that drops the loader early
        let ds = dataset();
        let loader = Loader::new(ds, 2, 1000, 9);
        let _ = loader.next();
        drop(loader); // producer must exit via send error
    }
}
