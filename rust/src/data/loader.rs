//! Batch loader: epoch iteration with background prefetch + backpressure.
//!
//! The training loop consumes `[batch, seq+1]` i32 batches. A producer
//! thread assembles batches ahead of the consumer through a bounded
//! channel (capacity = `PREFETCH`), so host-side batch assembly overlaps
//! device execution and a slow consumer naturally backpressures the
//! producer — the L3 streaming-orchestration pattern.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;

use super::corpus::Rng;
use super::dataset::Dataset;

const PREFETCH: usize = 4;

/// One training batch, row-major `[batch_size][seq_len + 1]`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub batch_size: usize,
    pub width: usize,
    /// global step index this batch is destined for
    pub step: u64,
}

/// Iterate `n_steps` batches over the training split (cycling epochs, each
/// epoch reshuffled deterministically from `seed + epoch`).
pub struct Loader {
    rx: Receiver<Batch>,
    _handle: std::thread::JoinHandle<()>,
}

impl Loader {
    pub fn new(ds: Arc<Dataset>, batch_size: usize, n_steps: u64, seed: u64) -> Self {
        let (tx, rx) = sync_channel::<Batch>(PREFETCH);
        let handle = std::thread::spawn(move || {
            let w = ds.width();
            let n = ds.n_train;
            let mut order: Vec<usize> = (0..n).collect();
            let mut pos = 0usize;
            let mut epoch = 0u64;
            let reshuffle = |order: &mut Vec<usize>, epoch: u64| {
                let mut rng = Rng::new(seed.wrapping_add(epoch));
                for i in (1..order.len()).rev() {
                    order.swap(i, rng.below(i + 1));
                }
            };
            reshuffle(&mut order, epoch);
            for step in 0..n_steps {
                let mut tokens = Vec::with_capacity(batch_size * w);
                for _ in 0..batch_size {
                    if pos >= n {
                        pos = 0;
                        epoch += 1;
                        reshuffle(&mut order, epoch);
                    }
                    tokens.extend_from_slice(ds.train_chunk(order[pos]));
                    pos += 1;
                }
                let batch = Batch {
                    tokens,
                    batch_size,
                    width: w,
                    step,
                };
                if tx.send(batch).is_err() {
                    return; // consumer dropped — stop producing
                }
            }
        });
        Loader {
            rx,
            _handle: handle,
        }
    }

    /// Blocking receive of the next batch; `None` when exhausted.
    pub fn next(&self) -> Option<Batch> {
        self.rx.recv().ok()
    }
}

/// Assemble the dev split into fixed batches (padding the final partial
/// batch with PAD rows so every batch matches the compiled shape).
pub fn dev_batches(ds: &Dataset, batch_size: usize) -> Vec<Batch> {
    let w = ds.width();
    let mut out = Vec::new();
    let mut row = 0usize;
    let mut step = 0u64;
    while row < ds.n_dev {
        let mut tokens = vec![super::tokenizer::PAD_ID; batch_size * w];
        for b in 0..batch_size.min(ds.n_dev - row) {
            tokens[b * w..(b + 1) * w].copy_from_slice(ds.dev_chunk(row + b));
        }
        row += batch_size;
        out.push(Batch {
            tokens,
            batch_size,
            width: w,
            step,
        });
        step += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Arc<Dataset> {
        let stream: Vec<i32> = (0..33 * 64).map(|i| (i % 200) as i32 + 1).collect();
        Arc::new(Dataset::from_stream(&stream, 32, 0.05, 1))
    }

    #[test]
    fn yields_requested_steps() {
        let ds = dataset();
        let loader = Loader::new(ds, 4, 10, 42);
        let mut n = 0;
        while let Some(b) = loader.next() {
            assert_eq!(b.tokens.len(), 4 * 33);
            assert_eq!(b.step, n);
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn epoch_covers_each_chunk_once() {
        let ds = dataset();
        let n_train = ds.n_train;
        let steps = (n_train / 2) as u64; // one epoch at batch 2 (± the tail row)
        let loader = Loader::new(ds.clone(), 2, steps, 7);
        let mut first_tokens: Vec<i32> = Vec::new();
        while let Some(b) = loader.next() {
            first_tokens.extend(b.tokens.iter().step_by(33)); // first col of each row
        }
        assert_eq!(first_tokens.len(), (n_train / 2) * 2);
    }

    #[test]
    fn deterministic_across_runs() {
        let ds = dataset();
        let collect = |seed| {
            let l = Loader::new(ds.clone(), 2, 5, seed);
            let mut v = Vec::new();
            while let Some(b) = l.next() {
                v.extend(b.tokens);
            }
            v
        };
        assert_eq!(collect(3), collect(3));
        assert_ne!(collect(3), collect(4));
    }

    #[test]
    fn dev_batches_cover_dev_split() {
        let ds = dataset();
        let batches = dev_batches(&ds, 4);
        let rows: usize = batches.len() * 4;
        assert!(rows >= ds.n_dev);
        // every non-pad dev token appears
        let total_nonpad: usize = batches
            .iter()
            .map(|b| b.tokens.iter().filter(|&&t| t != 0).count())
            .sum();
        assert_eq!(total_nonpad, ds.dev_token_count());
    }

    #[test]
    fn backpressure_does_not_deadlock() {
        // consumer that drops the loader early
        let ds = dataset();
        let loader = Loader::new(ds, 2, 1000, 9);
        let _ = loader.next();
        drop(loader); // producer must exit via send error
    }
}
