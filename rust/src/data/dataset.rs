//! Dataset assembly: token stream → fixed-length training windows.
//!
//! Mirrors the paper's preprocessing (§A.1): documents are tokenized,
//! concatenated with BOS separators, split into chunks of `seq_len + 1`
//! tokens (input/label overlap), short tails padded with PAD. 1% of chunks
//! become the development set (paper: "We split 1% of the data as the
//! corresponding development set").

use super::corpus::Rng;
use super::tokenizer::PAD_ID;

#[derive(Clone, Debug)]
pub struct Dataset {
    /// flattened [n_chunks × (seq_len+1)] token matrix
    pub chunks: Vec<i32>,
    pub seq_len: usize,
    pub n_train: usize,
    pub n_dev: usize,
}

impl Dataset {
    /// Chunk a token stream; deterministically shuffle; hold out `dev_frac`.
    pub fn from_stream(stream: &[i32], seq_len: usize, dev_frac: f64, seed: u64) -> Self {
        let w = seq_len + 1;
        let n_chunks = stream.len().div_ceil(w);
        let mut chunks = vec![PAD_ID; n_chunks * w];
        for (i, tok) in stream.iter().enumerate() {
            chunks[i] = *tok;
        }
        // shuffle chunk order (Fisher-Yates)
        let mut order: Vec<usize> = (0..n_chunks).collect();
        let mut rng = Rng::new(seed ^ 0x5EED);
        for i in (1..n_chunks).rev() {
            let j = rng.below(i + 1);
            order.swap(i, j);
        }
        let mut shuffled = vec![PAD_ID; chunks.len()];
        for (dst, &src) in order.iter().enumerate() {
            shuffled[dst * w..(dst + 1) * w].copy_from_slice(&chunks[src * w..(src + 1) * w]);
        }
        let n_dev = ((n_chunks as f64 * dev_frac).round() as usize).max(1).min(n_chunks / 2);
        Dataset {
            chunks: shuffled,
            seq_len,
            n_train: n_chunks - n_dev,
            n_dev,
        }
    }

    pub fn width(&self) -> usize {
        self.seq_len + 1
    }

    /// Training chunk `i` (first `n_train` chunks).
    pub fn train_chunk(&self, i: usize) -> &[i32] {
        let w = self.width();
        &self.chunks[i * w..(i + 1) * w]
    }

    /// Dev chunk `i` (the held-out tail).
    pub fn dev_chunk(&self, i: usize) -> &[i32] {
        let w = self.width();
        let base = self.n_train + i;
        &self.chunks[base * w..(base + 1) * w]
    }

    /// Total non-pad tokens in the dev split (for perplexity normalization).
    pub fn dev_token_count(&self) -> usize {
        (0..self.n_dev)
            .map(|i| self.dev_chunk(i).iter().filter(|&&t| t != PAD_ID).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> Vec<i32> {
        (0..n).map(|i| (i % 500) as i32 + 1).collect()
    }

    #[test]
    fn chunking_covers_every_token_once() {
        let s = stream(1000);
        let ds = Dataset::from_stream(&s, 32, 0.01, 1);
        let mut got: Vec<i32> = ds.chunks.iter().copied().filter(|&t| t != PAD_ID).collect();
        let mut want = s.clone();
        got.sort();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn pad_only_in_last_chunk_prepad() {
        let s = stream(100); // 100 tokens, width 33 → 4 chunks, last padded
        let ds = Dataset::from_stream(&s, 32, 0.25, 2);
        assert_eq!(ds.n_train + ds.n_dev, 4);
        let pads = ds.chunks.iter().filter(|&&t| t == PAD_ID).count();
        assert_eq!(pads, 4 * 33 - 100);
    }

    #[test]
    fn deterministic_shuffle() {
        let s = stream(5000);
        let a = Dataset::from_stream(&s, 16, 0.01, 7);
        let b = Dataset::from_stream(&s, 16, 0.01, 7);
        let c = Dataset::from_stream(&s, 16, 0.01, 8);
        assert_eq!(a.chunks, b.chunks);
        assert_ne!(a.chunks, c.chunks);
    }

    #[test]
    fn dev_split_one_percent() {
        let s = stream(33 * 1000);
        let ds = Dataset::from_stream(&s, 32, 0.01, 3);
        assert_eq!(ds.n_dev, 10);
        assert_eq!(ds.n_train, 990);
    }

    #[test]
    fn chunk_accessors_disjoint() {
        let s = stream(33 * 100);
        let ds = Dataset::from_stream(&s, 32, 0.05, 4);
        let t0 = ds.train_chunk(0).to_vec();
        let d0 = ds.dev_chunk(0).to_vec();
        assert_eq!(t0.len(), 33);
        assert_eq!(d0.len(), 33);
        assert_eq!(ds.dev_chunk(ds.n_dev - 1).len(), 33);
    }
}
