//! Alphabet-adaptive BPE tokenizer: trainer + encoder/decoder.
//!
//! GPT-2-style word-internal BPE, but the base alphabet is derived from the
//! training corpus instead of all 256 bytes — so the same implementation
//! serves both the tiny test vocabularies (64 ids) and the full configs
//! (512+ ids). Vocabulary layout:
//!
//!   id 0                      = PAD (matches `model.PAD_ID` in the L2 graph)
//!   id 1                      = BOS / document separator
//!   id 2                      = UNK (bytes unseen at training time)
//!   ids 3 .. 3+|alphabet|     = the corpus alphabet, sorted
//!   ids after                 = learned merges, in rank order
//!
//! The trained tokenizer serializes to JSON so the whole data pipeline is
//! reproducible from a checkpoint directory.

use std::collections::HashMap;

pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;
pub const UNK_ID: i32 = 2;
const BASE: i32 = 3;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// distinct corpus bytes, sorted — ids BASE..BASE+len
    pub alphabet: Vec<u8>,
    /// learned merges in rank order: (left id, right id) → BASE+|alphabet|+rank
    pub merges: Vec<(i32, i32)>,
    pub vocab_size: usize,
}

impl Tokenizer {
    /// Train BPE on `docs` until `vocab_size` ids exist (or no pair repeats).
    pub fn train(docs: &[String], vocab_size: usize) -> Self {
        // derive the alphabet (space handled as the word-boundary byte)
        let mut seen = [false; 256];
        for d in docs {
            for b in d.bytes() {
                seen[b as usize] = true;
            }
        }
        let alphabet: Vec<u8> = (0u16..256)
            .filter(|&b| seen[b as usize])
            .map(|b| b as u8)
            .collect();
        assert!(
            vocab_size > BASE as usize + alphabet.len(),
            "vocab {} too small for alphabet {} (+{BASE} specials)",
            vocab_size,
            alphabet.len()
        );
        let byte_id: HashMap<u8, i32> = alphabet
            .iter()
            .enumerate()
            .map(|(i, &b)| (b, BASE + i as i32))
            .collect();

        // word frequency table; words carry a leading space byte when not
        // document-initial (GPT-2 convention)
        let mut word_freq: HashMap<Vec<i32>, u64> = HashMap::new();
        for doc in docs {
            for (i, w) in doc.split_whitespace().enumerate() {
                let mut ids: Vec<i32> = Vec::with_capacity(w.len() + 1);
                if i > 0 {
                    if let Some(&sp) = byte_id.get(&b' ') {
                        ids.push(sp);
                    }
                }
                ids.extend(w.bytes().map(|b| byte_id.get(&b).copied().unwrap_or(UNK_ID)));
                *word_freq.entry(ids).or_insert(0) += 1;
            }
        }
        let mut words: Vec<(Vec<i32>, u64)> = word_freq.into_iter().collect();
        words.sort(); // deterministic iteration order

        let mut merges = Vec::new();
        let mut next_id = BASE + alphabet.len() as i32;
        while (next_id as usize) < vocab_size {
            let mut pair_counts: HashMap<(i32, i32), u64> = HashMap::new();
            for (ids, f) in &words {
                for p in ids.windows(2) {
                    *pair_counts.entry((p[0], p[1])).or_insert(0) += f;
                }
            }
            // best pair (deterministic tie-break on the pair itself)
            let Some((&best, &count)) = pair_counts
                .iter()
                .max_by_key(|(pair, count)| (**count, std::cmp::Reverse(**pair)))
            else {
                break;
            };
            if count < 2 {
                break;
            }
            merges.push(best);
            for (ids, _) in words.iter_mut() {
                let mut out = Vec::with_capacity(ids.len());
                let mut i = 0;
                while i < ids.len() {
                    if i + 1 < ids.len() && (ids[i], ids[i + 1]) == best {
                        out.push(next_id);
                        i += 2;
                    } else {
                        out.push(ids[i]);
                        i += 1;
                    }
                }
                *ids = out;
            }
            next_id += 1;
        }
        Tokenizer {
            alphabet,
            merges,
            vocab_size,
        }
    }

    fn byte_ids(&self) -> HashMap<u8, i32> {
        self.alphabet
            .iter()
            .enumerate()
            .map(|(i, &b)| (b, BASE + i as i32))
            .collect()
    }

    fn merge_ranks(&self) -> HashMap<(i32, i32), usize> {
        self.merges
            .iter()
            .enumerate()
            .map(|(r, &p)| (p, r))
            .collect()
    }

    /// Encode one document (no BOS added; see [`encode_docs`]).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let byte_id = self.byte_ids();
        let ranks = self.merge_ranks();
        let merge_base = BASE + self.alphabet.len() as i32;
        let mut out = Vec::with_capacity(text.len() / 3 + 1);
        for (i, w) in text.split_whitespace().enumerate() {
            let mut ids: Vec<i32> = Vec::with_capacity(w.len() + 1);
            if i > 0 {
                if let Some(&sp) = byte_id.get(&b' ') {
                    ids.push(sp);
                }
            }
            ids.extend(w.bytes().map(|b| byte_id.get(&b).copied().unwrap_or(UNK_ID)));
            // greedy lowest-rank merge loop
            loop {
                let mut best: Option<(usize, usize)> = None; // (rank, pos)
                for (pos, p) in ids.windows(2).enumerate() {
                    if let Some(&r) = ranks.get(&(p[0], p[1])) {
                        if best.map_or(true, |(br, _)| r < br) {
                            best = Some((r, pos));
                        }
                    }
                }
                let Some((rank, pos)) = best else { break };
                ids.splice(pos..pos + 2, [merge_base + rank as i32]);
            }
            out.extend(ids);
        }
        out
    }

    /// Encode documents into one stream with BOS separators.
    pub fn encode_docs(&self, docs: &[String]) -> Vec<i32> {
        let mut out = Vec::new();
        for d in docs {
            out.push(BOS_ID);
            out.extend(self.encode(d));
        }
        out
    }

    /// id → byte-piece table: PAD/BOS empty, UNK `'?'`, then the alphabet
    /// and each merge's concatenated bytes.
    fn piece_table(&self) -> Vec<Vec<u8>> {
        let mut table: Vec<Vec<u8>> = Vec::with_capacity(self.vocab_size);
        table.push(Vec::new()); // PAD
        table.push(Vec::new()); // BOS
        table.push(vec![b'?']); // UNK
        for &b in &self.alphabet {
            table.push(vec![b]);
        }
        for &(a, b) in &self.merges {
            let mut v = table[a as usize].clone();
            v.extend_from_slice(&table[b as usize]);
            table.push(v);
        }
        table
    }

    /// Decode ids back to text (PAD/BOS dropped, UNK → '?').
    pub fn decode(&self, ids: &[i32]) -> String {
        let table = self.piece_table();
        let mut bytes = Vec::new();
        for &id in ids {
            if id >= 0 && (id as usize) < table.len() {
                bytes.extend_from_slice(&table[id as usize]);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Incremental detokenizer for generation: ids stream in one at a
    /// time and text streams out as soon as its bytes complete — no
    /// re-decoding of the prefix per token (the serving path's
    /// per-token cost is O(piece), not O(sequence)).
    pub fn decode_stream(&self) -> DecodeStream {
        DecodeStream {
            table: self.piece_table(),
            pending: Vec::new(),
        }
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        use crate::util::json::Value;
        let merges = Value::Arr(
            self.merges
                .iter()
                .map(|&(a, b)| Value::Arr(vec![a.into(), b.into()]))
                .collect(),
        );
        let alphabet = Value::Arr(self.alphabet.iter().map(|&b| (b as i32).into()).collect());
        let v = Value::obj()
            .set("vocab_size", self.vocab_size)
            .set("alphabet", alphabet)
            .set("merges", merges);
        std::fs::write(path, v.to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        use anyhow::anyhow;
        let v = crate::util::json::parse(&std::fs::read_to_string(path)?)?;
        let vocab_size = v
            .req("vocab_size")?
            .as_usize()
            .ok_or_else(|| anyhow!("bad vocab_size"))?;
        let alphabet = v
            .req("alphabet")?
            .as_arr()
            .ok_or_else(|| anyhow!("bad alphabet"))?
            .iter()
            .map(|x| x.as_i64().unwrap_or(0) as u8)
            .collect();
        let merges = v
            .req("merges")?
            .as_arr()
            .ok_or_else(|| anyhow!("bad merges"))?
            .iter()
            .map(|pair| {
                let p = pair.as_arr().ok_or_else(|| anyhow!("bad merge pair"))?;
                Ok((
                    p[0].as_i64().ok_or_else(|| anyhow!("bad id"))? as i32,
                    p[1].as_i64().ok_or_else(|| anyhow!("bad id"))? as i32,
                ))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Tokenizer {
            alphabet,
            merges,
            vocab_size,
        })
    }
}

/// Streaming detokenizer (see [`Tokenizer::decode_stream`]). Owns its
/// piece table, so it has no borrow tie to the tokenizer and can live
/// inside a long-running serving sequence.
///
/// Multi-byte UTF-8 sequences may straddle token boundaries (the BPE
/// alphabet is bytes): [`DecodeStream::push`] only releases complete
/// characters and holds the incomplete tail; a byte that can never start
/// a valid sequence is replaced with U+FFFD, matching
/// [`Tokenizer::decode`]'s lossy behavior.
pub struct DecodeStream {
    table: Vec<Vec<u8>>,
    pending: Vec<u8>,
}

impl DecodeStream {
    /// Append one id and return whatever text completed. PAD/BOS decode
    /// to nothing; out-of-table ids are skipped (same as [`Tokenizer::decode`]).
    pub fn push(&mut self, id: i32) -> String {
        if id >= 0 && (id as usize) < self.table.len() {
            self.pending.extend_from_slice(&self.table[id as usize]);
        }
        let mut out = String::new();
        loop {
            match std::str::from_utf8(&self.pending) {
                Ok(s) => {
                    out.push_str(s);
                    self.pending.clear();
                    break;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(std::str::from_utf8(&self.pending[..valid]).unwrap());
                    match e.error_len() {
                        // incomplete trailing sequence — wait for more bytes
                        None => {
                            self.pending.drain(..valid);
                            break;
                        }
                        // definitely invalid — replace and continue
                        Some(n) => {
                            out.push('\u{FFFD}');
                            self.pending.drain(..valid + n);
                        }
                    }
                }
            }
        }
        out
    }

    /// Flush any incomplete trailing bytes (lossy), ending the stream.
    pub fn finish(&mut self) -> String {
        if self.pending.is_empty() {
            return String::new();
        }
        let s = String::from_utf8_lossy(&self.pending).into_owned();
        self.pending.clear();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_docs() -> Vec<String> {
        vec![
            "the cat sat on the mat. the cat ran".to_string(),
            "a cat and a dog sat on a log".to_string(),
            "the dog ran to the cat on the mat".to_string(),
        ]
    }

    #[test]
    fn train_produces_merges_and_alphabet() {
        let tok = Tokenizer::train(&sample_docs(), 64);
        assert!(!tok.merges.is_empty());
        // corpus alphabet: lowercase letters + space + '.'
        assert!(tok.alphabet.contains(&b' '));
        assert!(tok.alphabet.contains(&b'.'));
        assert!(tok.alphabet.len() < 30);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let docs = sample_docs();
        let tok = Tokenizer::train(&docs, 64);
        for d in &docs {
            let ids = tok.encode(d);
            // single-space normalization is the only permitted loss
            let norm: String = d.split_whitespace().collect::<Vec<_>>().join(" ");
            assert_eq!(tok.decode(&ids), norm);
        }
    }

    #[test]
    fn unknown_bytes_become_unk() {
        let tok = Tokenizer::train(&sample_docs(), 64);
        let ids = tok.encode("cat zèbre");
        assert!(ids.contains(&UNK_ID));
    }

    #[test]
    fn compression_beats_bytes() {
        let docs = sample_docs();
        let tok = Tokenizer::train(&docs, 64);
        let text = &docs[0];
        assert!(tok.encode(text).len() < text.len());
    }

    #[test]
    fn ids_within_vocab() {
        let docs = sample_docs();
        for vocab in [40usize, 64, 200] {
            let tok = Tokenizer::train(&docs, vocab);
            for d in &docs {
                for id in tok.encode(d) {
                    assert!((0..vocab as i32).contains(&id), "vocab {vocab} id {id}");
                }
            }
        }
    }

    #[test]
    fn deterministic_training() {
        let a = Tokenizer::train(&sample_docs(), 64);
        let b = Tokenizer::train(&sample_docs(), 64);
        assert_eq!(a.merges, b.merges);
        assert_eq!(a.alphabet, b.alphabet);
    }

    /// Streaming decode equals batch decode over ids that include the
    /// specials — UNK ('?'), BOS (empty) and PAD (empty) — plus
    /// out-of-table ids (skipped) — the round-trip contract of the
    /// incremental serving detokenizer.
    #[test]
    fn decode_stream_matches_batch_decode_with_specials() {
        let docs = sample_docs();
        let tok = Tokenizer::train(&docs, 64);
        let mut ids = vec![BOS_ID];
        ids.extend(tok.encode("the cat zqx ran")); // zqx → UNKs
        ids.push(PAD_ID);
        ids.push(BOS_ID);
        ids.extend(tok.encode("a dog"));
        ids.push(9999); // out of table — skipped by both paths
        let mut stream = tok.decode_stream();
        let mut streamed = String::new();
        for &id in &ids {
            streamed.push_str(&stream.push(id));
        }
        streamed.push_str(&stream.finish());
        assert_eq!(streamed, tok.decode(&ids));
        assert!(streamed.contains('?'), "UNK must surface: {streamed:?}");
    }

    /// Multi-byte UTF-8 straddling token boundaries: the stream holds the
    /// incomplete tail instead of emitting garbage, and flushes losslessly
    /// once the sequence completes.
    #[test]
    fn decode_stream_holds_split_utf8() {
        let docs = vec!["héllo héllo wörld wörld".to_string(); 3];
        let tok = Tokenizer::train(&docs, 96);
        // every id decodes one alphabet byte at a time in the worst case;
        // streaming over single-byte pieces must still form é correctly
        let ids = tok.encode("héllo wörld");
        let mut stream = tok.decode_stream();
        let mut streamed = String::new();
        for &id in &ids {
            let chunk = stream.push(id);
            // chunks are always valid UTF-8 (guaranteed by the String type)
            streamed.push_str(&chunk);
        }
        streamed.push_str(&stream.finish());
        assert_eq!(streamed, tok.decode(&ids));
        assert!(streamed.contains('é') || streamed.contains('?'));
    }

    /// A byte that cannot start a UTF-8 sequence is replaced, not held
    /// forever.
    #[test]
    fn decode_stream_replaces_invalid_bytes() {
        let mut stream = DecodeStream {
            table: vec![vec![0xFFu8], vec![b'a']],
            pending: Vec::new(),
        };
        let a = stream.push(0);
        let b = stream.push(1);
        assert_eq!(a, "\u{FFFD}");
        assert_eq!(b, "a");
        assert_eq!(stream.finish(), "");
    }

    #[test]
    fn save_load_roundtrip() {
        let tok = Tokenizer::train(&sample_docs(), 64);
        let dir = std::env::temp_dir().join("dqt_tok_test.json");
        tok.save(&dir).unwrap();
        let tok2 = Tokenizer::load(&dir).unwrap();
        assert_eq!(tok.merges, tok2.merges);
        assert_eq!(tok.alphabet, tok2.alphabet);
        assert_eq!(tok.decode(&tok.encode("the cat")), "the cat");
        assert_eq!(tok2.decode(&tok2.encode("the cat")), "the cat");
        std::fs::remove_file(dir).ok();
    }
}
