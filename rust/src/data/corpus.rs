//! Synthetic corpus generator — the hermetic stand-in for Wikipedia/FineWeb.
//!
//! DQT's experiments need text with *learnable structure* (so loss curves
//! separate the methods), not any particular natural-language distribution.
//! The generator produces documents from a two-level process:
//!
//!   1. a synthetic lexicon of pronounceable words (CV-syllable strings),
//!      ranked with a Zipf distribution (like natural vocabularies);
//!   2. a first-order Markov chain over words: each word has a small set of
//!      hash-determined preferred successors mixed with global Zipf noise,
//!      plus topic drift per document (like topical web text).
//!
//! A model can therefore reduce loss well below the unigram entropy by
//! learning bigram structure — giving the same qualitative loss-curve
//! ordering across quantization modes the paper observes on real corpora.
//!
//! Presets: `wiki` (smaller vocab, shorter docs — stands in for the paper's
//! Wikipedia set) and `fineweb` (larger vocab, longer docs, more tokens).
//! Everything is deterministic in the seed.

/// xorshift64* PRNG — deterministic, dependency-free.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}

/// Corpus preset parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct CorpusSpec {
    pub name: String,
    pub vocab_words: usize,
    pub n_docs: usize,
    pub doc_len_words: (usize, usize), // min..max
    pub zipf_exponent: f64,
    /// weight of the Markov successor component vs global Zipf draw
    pub markov_weight: f64,
    pub n_successors: usize,
    pub seed: u64,
}

impl CorpusSpec {
    /// Stand-in for the paper's English Wikipedia set (§4.1).
    pub fn wiki(seed: u64) -> Self {
        CorpusSpec {
            name: "wiki-syn".into(),
            vocab_words: 2000,
            n_docs: 4000,
            doc_len_words: (60, 400),
            zipf_exponent: 1.05,
            markov_weight: 0.75,
            n_successors: 3,
            seed,
        }
    }
    /// Stand-in for the FineWeb 10B-token sample — larger vocab, more text.
    pub fn fineweb(seed: u64) -> Self {
        CorpusSpec {
            name: "fineweb-syn".into(),
            vocab_words: 6000,
            n_docs: 12000,
            doc_len_words: (100, 600),
            zipf_exponent: 1.02,
            markov_weight: 0.7,
            n_successors: 4,
            seed,
        }
    }
    /// Tiny preset for unit tests.
    pub fn tiny(seed: u64) -> Self {
        CorpusSpec {
            name: "tiny-syn".into(),
            vocab_words: 200,
            n_docs: 50,
            doc_len_words: (20, 60),
            zipf_exponent: 1.1,
            markov_weight: 0.8,
            n_successors: 3,
            seed,
        }
    }

    pub fn by_name(name: &str, seed: u64) -> Option<Self> {
        match name {
            "wiki" | "wiki-syn" => Some(Self::wiki(seed)),
            "fineweb" | "fineweb-syn" => Some(Self::fineweb(seed)),
            "tiny" | "tiny-syn" => Some(Self::tiny(seed)),
            _ => None,
        }
    }
}

const ONSETS: &[&str] = &[
    "b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z",
    "br", "dr", "gr", "kr", "pl", "st", "tr", "sh", "ch", "th",
];
const VOWELS: &[&str] = &["a", "e", "i", "o", "u", "ai", "ea", "io", "ou"];
const CODAS: &[&str] = &["", "", "", "n", "r", "s", "t", "l", "m", "nd", "st"];

/// Build the synthetic lexicon: `vocab_words` distinct pronounceable words.
pub fn build_lexicon(spec: &CorpusSpec) -> Vec<String> {
    let mut rng = Rng::new(spec.seed ^ 0xABCD);
    let mut seen = std::collections::HashSet::new();
    let mut words = Vec::with_capacity(spec.vocab_words);
    while words.len() < spec.vocab_words {
        let syllables = 1 + rng.below(3);
        let mut w = String::new();
        for _ in 0..=syllables {
            w.push_str(ONSETS[rng.below(ONSETS.len())]);
            w.push_str(VOWELS[rng.below(VOWELS.len())]);
            w.push_str(CODAS[rng.below(CODAS.len())]);
        }
        if seen.insert(w.clone()) {
            words.push(w);
        }
    }
    words
}

/// Zipf sampler over ranks 0..n (rank 0 most frequent) via inverse CDF.
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }
    fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// Deterministic preferred successors of a word (the Markov structure).
fn successors(word_id: usize, spec: &CorpusSpec) -> Vec<usize> {
    (0..spec.n_successors)
        .map(|j| {
            let h = crate::quant::sr::hash_u32(
                word_id as u32 * 31 + j as u32,
                spec.seed as u32,
            );
            h as usize % spec.vocab_words
        })
        .collect()
}

/// Generate the full corpus: a vec of documents (plain text).
pub fn generate(spec: &CorpusSpec) -> Vec<String> {
    let lexicon = build_lexicon(spec);
    let zipf = Zipf::new(spec.vocab_words, spec.zipf_exponent);
    let mut rng = Rng::new(spec.seed);
    let mut docs = Vec::with_capacity(spec.n_docs);
    for _ in 0..spec.n_docs {
        let len = spec.doc_len_words.0
            + rng.below(spec.doc_len_words.1 - spec.doc_len_words.0 + 1);
        let mut doc = String::with_capacity(len * 7);
        let mut cur = zipf.sample(&mut rng);
        let mut sentence_left = 6 + rng.below(12);
        for i in 0..len {
            if i > 0 {
                doc.push(' ');
            }
            doc.push_str(&lexicon[cur]);
            sentence_left -= 1;
            if sentence_left == 0 {
                doc.push('.');
                sentence_left = 6 + rng.below(12);
            }
            // next word: Markov successor or global Zipf
            cur = if rng.next_f64() < spec.markov_weight {
                let succ = successors(cur, spec);
                succ[rng.below(succ.len())]
            } else {
                zipf.sample(&mut rng)
            };
        }
        docs.push(doc);
    }
    docs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let spec = CorpusSpec::tiny(7);
        assert_eq!(generate(&spec), generate(&spec));
        let mut spec2 = spec.clone();
        spec2.seed = 8;
        assert_ne!(generate(&spec), generate(&spec2));
    }

    #[test]
    fn lexicon_distinct_and_sized() {
        let spec = CorpusSpec::tiny(1);
        let lex = build_lexicon(&spec);
        assert_eq!(lex.len(), spec.vocab_words);
        let set: std::collections::HashSet<_> = lex.iter().collect();
        assert_eq!(set.len(), lex.len());
    }

    #[test]
    fn doc_lengths_in_range() {
        let spec = CorpusSpec::tiny(3);
        for doc in generate(&spec) {
            let n = doc.split_whitespace().count();
            assert!(n >= spec.doc_len_words.0 && n <= spec.doc_len_words.1 + 1);
        }
    }

    #[test]
    fn zipf_head_is_heavy() {
        let z = Zipf::new(100, 1.2);
        let mut rng = Rng::new(42);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[50] * 5);
    }

    #[test]
    fn markov_structure_present() {
        // successor bigrams must be far more frequent than chance
        let spec = CorpusSpec::tiny(11);
        let lex = build_lexicon(&spec);
        let idx: std::collections::HashMap<&str, usize> =
            lex.iter().map(|w| w.as_str()).zip(0..).collect();
        let docs = generate(&spec);
        let mut hits = 0usize;
        let mut total = 0usize;
        for doc in &docs {
            let words: Vec<&str> = doc
                .split_whitespace()
                .map(|w| w.trim_end_matches('.'))
                .collect();
            for pair in words.windows(2) {
                if let (Some(&a), Some(&b)) = (idx.get(pair[0]), idx.get(pair[1])) {
                    total += 1;
                    if successors(a, &spec).contains(&b) {
                        hits += 1;
                    }
                }
            }
        }
        let rate = hits as f64 / total as f64;
        // chance level ≈ n_successors / vocab = 1.5%; markov_weight = 0.8
        assert!(rate > 0.5, "bigram hit rate {rate}");
    }
}
