//! Data pipeline substrate: synthetic corpus → BPE tokenizer → chunked
//! dataset → prefetching batch loader (paper §4.1 / §A.1 preprocessing).

pub mod corpus;
pub mod dataset;
pub mod loader;
pub mod tokenizer;

use std::sync::Arc;

use anyhow::Result;

/// Everything the trainer needs for one dataset preset, built end to end.
pub struct Pipeline {
    pub spec: corpus::CorpusSpec,
    pub tokenizer: tokenizer::Tokenizer,
    pub dataset: Arc<dataset::Dataset>,
}

impl Pipeline {
    /// Generate the corpus, train the tokenizer, chunk the stream.
    ///
    /// `vocab_size` must match the model config's vocabulary; `seq_len`
    /// the compiled sequence length.
    pub fn build(preset: &str, seed: u64, vocab_size: usize, seq_len: usize) -> Result<Self> {
        let spec = corpus::CorpusSpec::by_name(preset, seed)
            .ok_or_else(|| anyhow::anyhow!("unknown corpus preset {preset:?}"))?;
        let docs = corpus::generate(&spec);
        // train the tokenizer on a deterministic sample (caps training cost)
        let sample: Vec<String> = docs.iter().take(500).cloned().collect();
        let tok = tokenizer::Tokenizer::train(&sample, vocab_size);
        let stream = tok.encode_docs(&docs);
        let ds = dataset::Dataset::from_stream(&stream, seq_len, 0.01, seed);
        Ok(Pipeline {
            spec,
            tokenizer: tok,
            dataset: Arc::new(ds),
        })
    }

    pub fn loader(&self, batch_size: usize, n_steps: u64, seed: u64) -> loader::Loader {
        loader::Loader::new(self.dataset.clone(), batch_size, n_steps, seed)
    }

    /// Shard-aware loader for one rank of a distributed run: yields only
    /// the contiguous row band `[band.0, band.1)` of each `global_batch`-
    /// row batch while walking the same epoch/shuffle stream as every
    /// other rank (see [`loader::Loader::new_sharded`]).
    pub fn loader_sharded(
        &self,
        global_batch: usize,
        n_steps: u64,
        seed: u64,
        band: (usize, usize),
    ) -> loader::Loader {
        loader::Loader::new_sharded(self.dataset.clone(), global_batch, n_steps, seed, band)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_builds_tiny() {
        let p = Pipeline::build("tiny", 1, 300, 32).unwrap();
        assert!(p.dataset.n_train > 10);
        assert!(p.dataset.n_dev >= 1);
        // all tokens within vocab
        assert!(p.dataset.chunks.iter().all(|&t| (0..300).contains(&t)));
    }

    #[test]
    fn unknown_preset_errors() {
        assert!(Pipeline::build("nope", 1, 300, 32).is_err());
    }
}
