//! # dqt — Direct Quantized Training, reproduced as a rust+JAX+Pallas stack
//!
//! Reproduction of *"Direct Quantized Training of Language Models with
//! Stochastic Rounding"* (Zhao et al., 2024) as a three-layer system:
//!
//! * **L3 (this crate)** — training coordinator: experiment orchestration,
//!   data pipeline (synthetic corpus → BPE → batches), LR scheduling,
//!   metrics, format-true checkpointing, memory model, eval harness, and
//!   the [`runtime::Backend`] abstraction with two implementations: the
//!   pure-Rust CPU reference backend ([`runtime::native`], trains with
//!   zero external dependencies) and the PJRT artifact path
//!   ([`runtime::pjrt`]). Selection via `--backend auto|native|pjrt`
//!   ([`config::BackendKind`]; see `docs/BACKENDS.md`).
//!   Storage formats are unified behind the codec registry in
//!   [`quant::codec`]: [`quant::codec::Format`] + [`quant::codec::Codec`]
//!   own all per-format dispatch (wire tags, packed sizes, encode/decode),
//!   and [`quant::codec::PackedTensor`] is the canonical packed tensor that
//!   checkpoints serialize and `runtime::State`'s packed-grid mode keeps
//!   resident (`.dqt` wire format: `docs/CHECKPOINT_FORMAT.md`).
//! * **L2 (python/compile, build-time only)** — LLaMA-structured model +
//!   optimizers in JAX, AOT-lowered to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the paper's hot
//!   spots: AbsMean quantization, stochastic rounding, fused int8-activation
//!   linear, RMSNorm, fused AdamW+SR.
//!
//! Python never runs at training time: the [`runtime`] module executes the
//! variant through the selected backend and the [`train`] loop drives it.
//!
//! Every matmul — training forward/backward, eval, and serving decode —
//! dispatches through the [`kernels`] layer: a zero-dependency scoped
//! thread pool plus cache-blocked dense and packed-ternary GEMMs that are
//! bitwise-deterministic across thread counts (`--threads` /
//! `DQT_THREADS`; see `docs/PERFORMANCE.md`).
//!
//! Training scales across processes and hosts through the [`dist`]
//! subsystem: zero-dependency TCP data parallelism whose fixed-tree
//! gradient reduction makes an N-worker run bitwise equal to the
//! 1-worker run, and whose periodic weight resync ships the 2-bit packed
//! grids (~16× less traffic than f32) — `dqt train --workers N` /
//! `dqt worker --join ADDR` (see `docs/DISTRIBUTED.md`).
//!
//! Every subsystem reports into the [`obs`] observability plane: a
//! zero-dependency Prometheus-text registry served as `GET /metrics` by
//! the serve HTTP server and by standalone endpoints on train/dist runs
//! (`--metrics-addr`), plus a push channel that streams per-step training
//! telemetry to `dqt watch --join ADDR` (`--watch-addr`) — the metric
//! and wire contract lives in `docs/OBSERVABILITY.md`.
//!
//! Deployment is the [`serve`] subsystem: KV-cached incremental decoding
//! ([`runtime::Decoder`], decode-free off 2-bit packed ternary grids via
//! the fused GEMV in [`quant::ternary`]), deterministic sampling,
//! continuous batching, and a zero-dependency HTTP server — the `generate`
//! and `serve` CLI subcommands (see `docs/SERVING.md`).
//!
//! Quickstart (no artifacts, no PJRT, no Python):
//! `cargo run --release --example quickstart`.

pub mod config;
pub mod util;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod eval;
pub mod kernels;
pub mod memory;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod train;

use std::path::PathBuf;

/// Repository-relative default artifact root (next to Cargo.toml).
pub fn default_artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Repository-relative default results root.
pub fn default_results_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results")
}
