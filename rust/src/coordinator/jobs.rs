//! Experiment definitions: every paper figure/table as a set of jobs.
//!
//! One `JobSpec` = one training/eval run of one variant. `experiment_jobs`
//! is the single source of truth mapping experiment ids (fig2 … table1,
//! abl1/abl2) to the variants, datasets and schedules that regenerate them;
//! `python/compile/aot.py::suite_variants` must provide the matching
//! artifacts (covered by an integration test).

use crate::config::{Env, Mode, Optimizer, TrainConfig, VariantSpec};

#[derive(Clone, Debug)]
pub struct JobSpec {
    pub exp: String,
    pub variant: VariantSpec,
    pub train: TrainConfig,
    /// run the zero-shot suite + perplexity after training
    pub eval_tasks: bool,
    /// additionally evaluate with deploy-time ternary projection (§A.2)
    pub ternary_eval: bool,
}

impl JobSpec {
    fn new(exp: &str, variant: VariantSpec, steps: u64, dataset: &str) -> Self {
        let train = TrainConfig {
            steps,
            warmup_steps: (steps / 10).max(10),
            dataset: dataset.into(),
            ..TrainConfig::default()
        };
        JobSpec {
            exp: exp.into(),
            variant,
            train,
            eval_tasks: false,
            ternary_eval: false,
        }
    }

    pub fn job_name(&self) -> String {
        format!("{}-{}", self.variant.variant_name(), self.train.dataset)
    }
}

/// The four Fig. 2 curve variants for one model size.
fn fig2_variants(size: &str) -> Vec<VariantSpec> {
    vec![
        VariantSpec::new(size, Mode::Fp32, 1.58),
        VariantSpec::new(size, Mode::Bitnet158, 1.58),
        VariantSpec::new(size, Mode::Dqt, 1.58),
        VariantSpec::new(size, Mode::Dqt, 8.0),
    ]
}

/// All jobs for an experiment id. `steps` scales every run (0 = default).
pub fn experiment_jobs(exp: &str, steps: u64) -> Option<Vec<JobSpec>> {
    let s = |d: u64| if steps == 0 { d } else { steps };
    let jobs = match exp {
        // Fig. 2: loss curves, 4 modes × 3 sizes on wiki + t1b on fineweb
        "fig2" => {
            let mut v = Vec::new();
            for size in ["t130", "t320", "t1b"] {
                for spec in fig2_variants(size) {
                    v.push(JobSpec::new("fig2", spec, s(300), "wiki"));
                }
            }
            for spec in fig2_variants("t1b") {
                v.push(JobSpec::new("fig2", spec, s(300), "fineweb"));
            }
            v
        }
        // Fig. 3: memory vs dev loss under precision envs + adafactor
        "fig3" => {
            let mut v = Vec::new();
            for size in ["t130", "t1b"] {
                for (mode, bits) in [(Mode::Bitnet158, 1.58), (Mode::Dqt, 8.0)] {
                    for env in [Env::Fp32, Env::Bf16, Env::Fp8] {
                        v.push(JobSpec::new(
                            "fig3",
                            VariantSpec::new(size, mode, bits).with_env(env),
                            s(300),
                            "wiki",
                        ));
                    }
                    for env in [Env::Bf16, Env::Fp8] {
                        v.push(JobSpec::new(
                            "fig3",
                            VariantSpec::new(size, mode, bits)
                                .with_env(env)
                                .with_optimizer(Optimizer::Adafactor),
                            s(300),
                            "wiki",
                        ));
                    }
                }
            }
            v
        }
        // Fig. 4: bit-width sweep
        "fig4" => {
            let mut v = Vec::new();
            for size in ["t130", "t1b"] {
                let data = if size == "t1b" { "fineweb" } else { "wiki" };
                for bits in [1.58, 3.0, 4.0, 8.0] {
                    v.push(JobSpec::new(
                        "fig4",
                        VariantSpec::new(size, Mode::Dqt, bits),
                        s(300),
                        data,
                    ));
                }
            }
            v
        }
        // Fig. 5: SR vs absmax re-quantization (same lr)
        "fig5" => vec![
            JobSpec::new("fig5", VariantSpec::new("t130", Mode::Dqt, 1.58), s(300), "wiki"),
            JobSpec::new(
                "fig5",
                VariantSpec::new("t130", Mode::DqtAbsmax, 1.58),
                s(300),
                "wiki",
            ),
        ],
        // Fig. 6: weight-update frequency (same lr + batch)
        "fig6" => vec![
            JobSpec::new("fig6", VariantSpec::new("t130", Mode::Dqt, 1.58), s(300), "wiki"),
            JobSpec::new(
                "fig6",
                VariantSpec::new("t130", Mode::Bitnet158, 1.58),
                s(300),
                "wiki",
            ),
            JobSpec::new("fig6", VariantSpec::new("t130", Mode::Dqt, 8.0), s(300), "wiki"),
        ],
        // Fig. 7: bottom-20% interventions
        "fig7" => vec![
            JobSpec::new("fig7", VariantSpec::new("t130", Mode::Dqt, 1.58), s(300), "wiki"),
            JobSpec::new(
                "fig7",
                VariantSpec::new("t130", Mode::Dqt, 1.58).with_intervention("force_remain"),
                s(300),
                "wiki",
            ),
            JobSpec::new(
                "fig7",
                VariantSpec::new("t130", Mode::Dqt, 1.58).with_intervention("force_update"),
                s(300),
                "wiki",
            ),
        ],
        // Fig. 9: DQT-8bit vs DQT-8bit trained for ternary inference
        "fig9" => vec![
            JobSpec::new("fig9", VariantSpec::new("t130", Mode::Dqt, 8.0), s(300), "wiki"),
            JobSpec::new(
                "fig9",
                VariantSpec::new("t130", Mode::DqtTernaryInf, 8.0),
                s(300),
                "wiki",
            ),
        ],
        // Table 1: eval (ppl + zero-shot) on t1b over both datasets
        "table1" => {
            let mut v = Vec::new();
            for data in ["wiki", "fineweb"] {
                for (mode, bits) in [
                    (Mode::Fp32, 1.58),
                    (Mode::Bitnet158, 1.58),
                    (Mode::Dqt, 8.0),
                ] {
                    let mut j = JobSpec::new(
                        "table1",
                        VariantSpec::new("t1b", mode, bits),
                        s(300),
                        data,
                    );
                    j.eval_tasks = true;
                    j.ternary_eval = mode == Mode::Dqt; // "ternary Inf." row
                    v.push(j);
                }
            }
            v
        }
        // abl1: fixed vs recomputed grid scale
        "abl1" => vec![
            JobSpec::new("abl1", VariantSpec::new("t130", Mode::Dqt, 1.58), s(300), "wiki"),
            JobSpec::new(
                "abl1",
                VariantSpec::new("t130", Mode::Dqt, 1.58).with_recompute_scale(),
                s(300),
                "wiki",
            ),
        ],
        // abl2: AdamW+SR vs Adafactor+SR at fp32 env
        "abl2" => vec![
            JobSpec::new("abl2", VariantSpec::new("t130", Mode::Dqt, 1.58), s(300), "wiki"),
            JobSpec::new(
                "abl2",
                VariantSpec::new("t130", Mode::Dqt, 1.58).with_optimizer(Optimizer::Adafactor),
                s(300),
                "wiki",
            ),
        ],
        _ => return None,
    };
    Some(jobs)
}

pub fn known_experiments() -> &'static [&'static str] {
    &["fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig9", "table1", "abl1", "abl2"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_experiments_defined() {
        for exp in known_experiments() {
            let jobs = experiment_jobs(exp, 0).unwrap();
            assert!(!jobs.is_empty(), "{exp}");
            for j in &jobs {
                assert!(j.variant.model_config().is_some(), "{}", j.job_name());
                assert!(j.train.steps > 0);
            }
        }
        assert!(experiment_jobs("nope", 0).is_none());
    }

    #[test]
    fn steps_override() {
        let jobs = experiment_jobs("fig5", 7).unwrap();
        assert!(jobs.iter().all(|j| j.train.steps == 7));
    }

    #[test]
    fn fig2_has_16_jobs() {
        assert_eq!(experiment_jobs("fig2", 0).unwrap().len(), 16);
    }

    #[test]
    fn table1_marks_ternary_eval() {
        let jobs = experiment_jobs("table1", 0).unwrap();
        assert!(jobs.iter().any(|j| j.ternary_eval));
        assert!(jobs.iter().all(|j| j.eval_tasks));
    }

    #[test]
    fn job_names_unique_within_experiment() {
        for exp in known_experiments() {
            let jobs = experiment_jobs(exp, 0).unwrap();
            let mut names: Vec<String> = jobs.iter().map(|j| j.job_name()).collect();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), jobs.len(), "{exp}");
        }
    }
}
