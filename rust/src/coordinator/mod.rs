//! Experiment orchestrator — the L3 coordination layer.
//!
//! A work-stealing job queue feeds N worker threads; each worker owns its
//! own PJRT client (XLA CPU executables already parallelize internally, so
//! the default is one worker; sweeps can raise it). Workers share a
//! pipeline cache keyed by (dataset, vocab, seq) so each corpus is
//! generated and tokenized once per process. Results land under
//! `results/<exp>/<job>/` as metrics.json + curve.csv (+ eval.json).

pub mod jobs;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

pub use jobs::{experiment_jobs, known_experiments, JobSpec};

use crate::config::BackendKind;
use crate::data::corpus::CorpusSpec;
use crate::data::Pipeline;
use crate::eval;
use crate::memory;
use crate::runtime::{pjrt_available, Runtime, VariantRuntime};
use crate::train::{checkpoint, Trainer};

/// Pipeline cache shared across jobs (corpus+tokenizer are deterministic).
#[derive(Default)]
pub struct PipelineCache {
    inner: Mutex<HashMap<(String, usize, usize, u64), Arc<Pipeline>>>,
}

impl PipelineCache {
    pub fn get(
        &self,
        dataset: &str,
        seed: u64,
        vocab: usize,
        seq_len: usize,
    ) -> Result<Arc<Pipeline>> {
        let key = (dataset.to_string(), vocab, seq_len, seed);
        {
            let map = self.inner.lock().unwrap();
            if let Some(p) = map.get(&key) {
                return Ok(p.clone());
            }
        }
        // build outside the lock (expensive), then race-tolerantly insert
        let built = Arc::new(Pipeline::build(dataset, seed, vocab, seq_len)?);
        let mut map = self.inner.lock().unwrap();
        Ok(map.entry(key).or_insert(built).clone())
    }
}

/// Result summary of one finished job.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub job: String,
    pub exp: String,
    pub variant: String,
    pub dataset: String,
    pub final_train_loss: Option<f32>,
    pub final_dev_loss: Option<f32>,
    pub peak_upd_frac: Option<f32>,
    pub wall_secs: f64,
    pub mem_model_mb: f64,
    pub rss_mb: f64,
    pub out_dir: PathBuf,
}

impl JobResult {
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let opt = |v: Option<f32>| v.map(Value::from).unwrap_or(Value::Null);
        Value::obj()
            .set("job", self.job.as_str())
            .set("exp", self.exp.as_str())
            .set("variant", self.variant.as_str())
            .set("dataset", self.dataset.as_str())
            .set("final_train_loss", opt(self.final_train_loss))
            .set("final_dev_loss", opt(self.final_dev_loss))
            .set("peak_upd_frac", opt(self.peak_upd_frac))
            .set("wall_secs", self.wall_secs)
            .set("mem_model_mb", self.mem_model_mb)
            .set("rss_mb", self.rss_mb)
            .set("out_dir", self.out_dir.display().to_string())
    }
}

/// Run one job to completion: train → metrics → checkpoint → optional eval.
/// `backend` must already be resolved (no `Auto`); the PJRT path requires
/// `rt`, the native path ignores it.
pub fn run_job(
    backend: BackendKind,
    rt: Option<&Runtime>,
    cache: &PipelineCache,
    artifacts_root: &Path,
    results_root: &Path,
    job: &JobSpec,
) -> Result<JobResult> {
    let t0 = Instant::now();
    let variant_name = job.variant.variant_name();
    let cfg = job
        .variant
        .model_config()
        .ok_or_else(|| anyhow!("unknown model {:?}", job.variant.model))?;
    let vrt = match backend {
        BackendKind::Native => VariantRuntime::native(&job.variant)?,
        _ => {
            let rt = rt.ok_or_else(|| anyhow!("PJRT backend needs a runtime"))?;
            VariantRuntime::load(rt, artifacts_root, &variant_name)?
        }
    };
    let pipeline = cache.get(
        &job.train.dataset,
        job.train.seed,
        cfg.vocab_size,
        cfg.max_seq_len,
    )?;

    let out_dir = results_root.join(&job.exp).join(job.job_name());
    std::fs::create_dir_all(&out_dir)?;

    let mut trainer = Trainer::new(&vrt, &pipeline, job.train.clone());
    let vn = variant_name.clone();
    trainer.progress = Some(Box::new(move |step, loss| {
        eprintln!("    [{vn}] step {step}: loss {loss:.4}");
    }));
    let (state, metrics) = trainer.run()?;
    metrics.save(&out_dir)?;
    // per-layer quant telemetry rides the default obs handle; a no-op for
    // variants without grid-quantized layers
    trainer.obs.save_quant_health(&out_dir)?;
    checkpoint::save(
        &out_dir.join("model.dqt"),
        vrt.manifest(),
        &state,
        checkpoint::Codec::F32,
        false,
    )?;

    // optional Table-1 evaluation
    if job.eval_tasks {
        let spec = CorpusSpec::by_name(&job.train.dataset, job.train.seed)
            .ok_or_else(|| anyhow!("unknown dataset"))?;
        let mut evals = Vec::new();
        evals.push(eval::evaluate(&vrt, &state, &pipeline, &spec, 100, false, 7)?);
        if job.ternary_eval && vrt.has_ternary_inference() {
            evals.push(eval::evaluate(&vrt, &state, &pipeline, &spec, 100, true, 7)?);
        }
        let arr = crate::util::json::Value::Arr(evals.iter().map(|e| e.to_json()).collect());
        std::fs::write(out_dir.join("eval.json"), arr.to_string_pretty())?;
    }

    let mem = memory::estimate(&job.variant, true)
        .map(|m| m.total_mb())
        .unwrap_or(f64::NAN);
    Ok(JobResult {
        job: job.job_name(),
        exp: job.exp.clone(),
        variant: variant_name,
        dataset: job.train.dataset.clone(),
        final_train_loss: metrics.tail_loss(10),
        final_dev_loss: metrics.final_dev_loss,
        peak_upd_frac: metrics.peak_upd_frac(),
        wall_secs: t0.elapsed().as_secs_f64(),
        mem_model_mb: mem,
        rss_mb: memory::process_rss_bytes().unwrap_or(0) as f64 / 1e6,
        out_dir,
    })
}

/// Run an experiment's jobs across `workers` threads; returns results in
/// job order. Failed jobs are reported, not fatal (the sweep continues).
pub fn run_experiment(
    exp: &str,
    steps: u64,
    workers: usize,
    backend: BackendKind,
    artifacts_root: &Path,
    results_root: &Path,
) -> Result<Vec<Result<JobResult>>> {
    let jobs =
        experiment_jobs(exp, steps).ok_or_else(|| anyhow!("unknown experiment {exp:?}"))?;
    run_jobs(&jobs, workers, backend, artifacts_root, results_root)
}

pub fn run_jobs(
    jobs: &[JobSpec],
    workers: usize,
    backend: BackendKind,
    artifacts_root: &Path,
    results_root: &Path,
) -> Result<Vec<Result<JobResult>>> {
    let backend = backend.resolve(pjrt_available());
    let cache = Arc::new(PipelineCache::default());
    let queue = Arc::new(Mutex::new(
        jobs.iter().cloned().enumerate().collect::<Vec<_>>(),
    ));
    let n = jobs.len();
    let results: Arc<Mutex<Vec<Option<Result<JobResult>>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));

    let workers = workers.max(1).min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = queue.clone();
            let results = results.clone();
            let cache = cache.clone();
            let artifacts_root = artifacts_root.to_path_buf();
            let results_root = results_root.to_path_buf();
            scope.spawn(move || {
                // one PJRT client per worker; the native backend needs none
                let rt = if backend == BackendKind::Native {
                    None
                } else {
                    match Runtime::cpu() {
                        Ok(rt) => Some(rt),
                        Err(e) => {
                            let mut res = results.lock().unwrap();
                            for slot in res.iter_mut().filter(|s| s.is_none()) {
                                *slot = Some(Err(anyhow!("PJRT init failed: {e}")));
                            }
                            return;
                        }
                    }
                };
                loop {
                    let item = { queue.lock().unwrap().pop() };
                    let Some((idx, job)) = item else { break };
                    eprintln!("  [job {}/{}] {}", idx + 1, n, job.job_name());
                    let r = run_job(
                        backend,
                        rt.as_ref(),
                        &cache,
                        &artifacts_root,
                        &results_root,
                        &job,
                    );
                    results.lock().unwrap()[idx] = Some(r);
                }
            });
        }
    });

    let collected = Arc::try_unwrap(results)
        .map_err(|_| anyhow!("worker leak"))?
        .into_inner()
        .unwrap();
    Ok(collected
        .into_iter()
        .map(|o| o.unwrap_or_else(|| Err(anyhow!("job never ran"))))
        .collect())
}

/// Persist a sweep summary (one row per job) for the report renderer.
pub fn write_summary(
    results_root: &Path,
    exp: &str,
    results: &[Result<JobResult>],
) -> Result<PathBuf> {
    use crate::util::json::Value;
    let rows: Vec<Value> = results
        .iter()
        .map(|r| match r {
            Ok(jr) => jr.to_json(),
            Err(e) => Value::obj().set("error", e.to_string()),
        })
        .collect();
    let dir = results_root.join(exp);
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("summary.json");
    std::fs::write(&path, Value::Arr(rows).to_string_pretty())?;
    Ok(path)
}
