//! `repro` — CLI for the DQT reproduction.
//!
//! Subcommands:
//!   train    train one variant, save metrics + checkpoint
//!   eval     evaluate a checkpoint (perplexity + zero-shot, ±ternary)
//!   generate KV-cached sampled decoding from a checkpoint
//!   serve    HTTP inference server (continuous batching) on a checkpoint
//!   watch    tail a live training run's step stream (`--watch-addr`)
//!   sweep    run a paper experiment (fig2 … table1, abl1/abl2)
//!   report   render paper-style tables/figures from results/
//!   list     show available artifacts and experiments
//!   memory   print the memory model for a variant
//!
//! Argument parsing is the in-tree `util::cli` (offline build, no clap).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use dqt::config::{
    BackendKind, DistConfig, Env, Mode, ObsConfig, Optimizer, TrainConfig, VariantSpec,
};
use dqt::coordinator;
use dqt::data::corpus::CorpusSpec;
use dqt::data::Pipeline;
use dqt::obs::{MetricsServer, Publisher, StreamFrame, TrainObs};
use dqt::runtime::VariantRuntime;
use dqt::train::{checkpoint, Trainer};
use dqt::util::cli::Args;
use dqt::{eval, memory, report};

const USAGE: &str = "\
repro — Direct Quantized Training reproduction

USAGE: repro <command> [flags]
GLOBAL: --artifacts <dir>  --results <dir>
        --backend auto|native|pjrt   (auto = pjrt when linked, else the
                                      pure-Rust native CPU backend)
        --threads N   kernel worker threads for the native backend
                      (default: DQT_THREADS env, else all cores; results
                      are bitwise identical at every thread count)
        --precision exact|fast   kernel numeric tier (default:
                      DQT_PRECISION env, else exact). exact keeps the
                      bitwise-deterministic chains; fast opts into
                      SIMD-friendly reassociated kernels, identical to
                      exact within f32 tolerance and still deterministic
                      per thread count (docs/PERFORMANCE.md)
        --trace-out FILE   span tracing (train/worker/serve/generate):
                      write a Chrome trace-event JSON there at run end
                      and print a per-phase profile table (default:
                      DQT_TRACE_OUT env, else off; docs/OBSERVABILITY.md
                      has the span-name contract). Off = one atomic
                      load per span site, results bitwise unchanged

COMMANDS
  train   --model t130 --mode dqt --bits 1.58 [--env fp32] [--optimizer adamw]
          [--intervention none] [--recompute-scale] [--steps 300]
          [--dataset wiki] [--lr 1e-3] [--seed 42] [--out <dir>]
          [--workers N]        distributed data-parallel run: rank 0 hosts
                               the rendezvous and spawns N-1 local worker
                               processes; bitwise equal to --workers 1
                               (power-of-two N dividing the batch)
          [--dist-addr 127.0.0.1:0]  rendezvous bind address
          [--no-spawn]         multi-host: wait for external `worker`s
          [--sync-every 25]    packed grid-weight resync period (0 = off)
          [--sync-format packed|f32]
          [--grad-format f32|int8|ternary]  per-step gradient wire: f32
                               keeps the bitwise contract; int8/ternary
                               stochastically round the exchange (~4x/16x
                               less wire) with error-feedback residuals
                               and a pinned convergence contract
                               (docs/DISTRIBUTED.md)
          [--metrics-addr H:P] serve GET /metrics (Prometheus text) for
                               this rank (env: DQT_METRICS_ADDR)
          [--watch-addr H:P]   stream per-step frames for `repro watch`
                               (env: DQT_WATCH_ADDR; docs/OBSERVABILITY.md).
                               Runs with grid-quantized layers also stream
                               a per-layer QuantHealth frame every
                               DQT_QUANT_FRAME_EVERY steps (default 10,
                               0 = off)
  worker  --rank R --workers N --join HOST:PORT (same variant/train flags
          as the coordinator, plus --metrics-addr/--watch-addr) — one
          rank of a multi-host run
  watch   --join HOST:PORT [--timeout 30]   tail a live run's step stream
          (the run's --watch-addr); prints one line per optimizer step
  eval    --checkpoint <model.dqt> (same variant flags) [--dataset wiki]
          [--ternary] [--items 100]
  generate --checkpoint <model.dqt> (variant flags) --prompt \"text\"
          [--max-new 48] [--temperature 0] [--top-k 0] [--top-p 1.0]
          [--seed 0] [--ternary] [--dataset wiki]
          [--data-seed 42  (must match the training --seed)]
  serve   --checkpoint <model.dqt> (variant flags) [--addr 127.0.0.1:8080]
          [--max-batch 8] [--max-queue 0  reject new requests with 429
          when this many are queued (0 = unbounded)] [--ternary]
          [--dataset wiki] [--data-seed 42]  — also serves GET /metrics
  sweep   --exp fig2|fig3|fig4|fig5|fig6|fig7|fig9|table1|abl1|abl2|all
          [--steps N] [--workers 1]
  report  --exp table2|table3|memory|serving|dist|profile|quant-health|
          <exp-id with results>
          (profile: [--trace trace.json] re-renders the per-phase table
          from a --trace-out file; quant-health: [--run DIR] renders a
          train run's quant_health.json per-layer table + anomaly
          verdicts — defaults to the newest run under results/train)
  list
  memory  (variant flags) [--batch 1] [--workers N  distributed estimate:
          per-rank resident bytes + wire bytes per sync, f32 vs packed]
";

fn backend_kind(a: &Args) -> Result<BackendKind> {
    let s = a.str_or("backend", "auto");
    BackendKind::parse(&s).ok_or_else(|| anyhow!("bad --backend {s:?} (auto|native|pjrt)"))
}

/// Explicit kernel pool from `--threads` / `--precision` (None = let the
/// backend size itself from `DQT_THREADS` / `DQT_PRECISION` / cores).
fn pool_from_args(a: &Args) -> Result<Option<std::sync::Arc<dqt::kernels::Pool>>> {
    if !a.has("threads") && !a.has("precision") {
        return Ok(None);
    }
    let threads = if a.has("threads") {
        dqt::config::effective_threads(Some(a.parse_or("threads", 0)?))
    } else {
        dqt::config::effective_threads(None)
    };
    let precision = if a.has("precision") {
        let s = a.str_or("precision", "exact");
        Some(
            dqt::config::Precision::parse(&s)
                .ok_or_else(|| anyhow!("bad --precision {s:?} (exact|fast)"))?,
        )
    } else {
        None
    };
    Ok(Some(std::sync::Arc::new(
        dqt::kernels::Pool::with_precision(
            threads,
            dqt::config::effective_precision(precision),
        ),
    )))
}

fn variant_spec(a: &Args) -> Result<VariantSpec> {
    let model = a.str_or("model", "t130");
    let mode_s = a.str_or("mode", "dqt");
    let mode = Mode::parse(&mode_s).ok_or_else(|| anyhow!("bad --mode {mode_s:?}"))?;
    let bits: f64 = a.parse_or("bits", 1.58)?;
    let env_s = a.str_or("env", "fp32");
    let env = Env::parse(&env_s).ok_or_else(|| anyhow!("bad --env {env_s:?}"))?;
    let opt_s = a.str_or("optimizer", "adamw");
    let opt =
        Optimizer::parse(&opt_s).ok_or_else(|| anyhow!("bad --optimizer {opt_s:?}"))?;
    let mut v = VariantSpec::new(&model, mode, bits)
        .with_env(env)
        .with_optimizer(opt);
    let iv = a.str_or("intervention", "none");
    if iv != "none" {
        v = v.with_intervention(&iv);
    }
    if a.has("recompute-scale") {
        v = v.with_recompute_scale();
    }
    Ok(v)
}

/// Build a serving engine from a checkpoint: variant flags → backend →
/// packed-grid state load (ternary grids stay 2-bit resident end to end)
/// → tokenizer pipeline → prepared decoder.
///
/// The tokenizer is rebuilt deterministically from `--dataset` +
/// `--data-seed`, which must match the `--seed` the checkpoint was
/// trained with (the synthetic corpus — and therefore the BPE vocabulary
/// — derives from that seed). Both default to 42, `repro train`'s
/// default.
fn open_engine(a: &Args, artifacts: &std::path::Path) -> Result<(dqt::serve::Engine, String)> {
    let spec = variant_spec(a)?;
    let cfg = spec
        .model_config()
        .ok_or_else(|| anyhow!("unknown model {:?}", spec.model))?;
    let ckpt = PathBuf::from(a.req("checkpoint")?);
    let dataset = a.str_or("dataset", "wiki");
    let data_seed: u64 = a.parse_or("data-seed", 42)?;
    let vrt =
        VariantRuntime::open_with_pool(backend_kind(a)?, None, artifacts, &spec, pool_from_args(a)?)?;
    eprintln!(
        "backend: {} ({} kernel threads, {} precision)",
        vrt.backend_name(),
        vrt.threads(),
        vrt.precision().as_str()
    );
    let state = checkpoint::load_packed(&ckpt, vrt.manifest())?;
    let pipeline = Pipeline::build(&dataset, data_seed, cfg.vocab_size, cfg.max_seq_len)?;
    let engine =
        dqt::serve::Engine::new(&vrt, &state, pipeline.tokenizer.clone(), a.has("ternary"))?;
    Ok((engine, spec.variant_name()))
}

/// Training-loop config shared by `train` and `worker` — every rank of a
/// distributed run must derive the *identical* schedule (warmup included)
/// from the same flags.
fn train_config_from(a: &Args) -> Result<TrainConfig> {
    let steps: u64 = a.parse_or("steps", 300)?;
    Ok(TrainConfig {
        steps,
        warmup_steps: (steps / 10).max(1),
        peak_lr: a.parse_or("lr", 1e-3)?,
        dataset: a.str_or("dataset", "wiki"),
        seed: a.parse_or("seed", 42)?,
        ..TrainConfig::default()
    })
}

/// Distributed flags shared by `train --workers` and `worker`.
fn dist_config_from(a: &Args, world: usize, rank: usize, addr: String) -> Result<DistConfig> {
    let fmt = a.str_or("sync-format", "packed");
    let packed_sync = match fmt.as_str() {
        "packed" => true,
        "f32" => false,
        other => return Err(anyhow!("bad --sync-format {other:?} (packed|f32)")),
    };
    let gf = a.str_or("grad-format", "f32");
    let grad_format = dqt::config::GradFormat::parse(&gf)
        .ok_or_else(|| anyhow!("bad --grad-format {gf:?} (f32|int8|ternary)"))?;
    Ok(DistConfig {
        world,
        rank,
        addr,
        sync_every: a.parse_or("sync-every", DistConfig::default().sync_every)?,
        packed_sync,
        grad_format,
    })
}

/// Stand up the configured observability endpoints for a training run:
/// the shared `TrainObs` handle, its registry on `--metrics-addr`
/// (`GET /metrics`), and a step-stream publisher on `--watch-addr`.
/// Returns `None` when neither endpoint is configured — the trainer then
/// keeps its default handle (pure atomics, no sockets, no threads).
fn train_obs_from(a: &Args) -> Result<Option<Arc<TrainObs>>> {
    let ocfg = ObsConfig::resolve(
        a.get("metrics-addr").map(|s| s.to_string()),
        a.get("watch-addr").map(|s| s.to_string()),
        None, // the trace sink is process-wide: see trace_from
    );
    if !ocfg.enabled() {
        return Ok(None);
    }
    let obs = Arc::new(TrainObs::new());
    if let Some(addr) = &ocfg.metrics_addr {
        let srv = MetricsServer::spawn(addr, obs.registry())?;
        eprintln!("metrics: GET http://{}/metrics", srv.local_addr());
    }
    if let Some(addr) = &ocfg.watch_addr {
        let p = Publisher::bind(addr)?;
        eprintln!(
            "watch: step stream on {} (tail with `repro watch --join {}`)",
            p.local_addr(),
            p.local_addr()
        );
        obs.set_publisher(p);
    }
    Ok(Some(obs))
}

/// Enable the span tracer when `--trace-out` / `DQT_TRACE_OUT` is set
/// (docs/OBSERVABILITY.md §Tracing). One-shot commands pair this with
/// [`finish_trace`]; the serve decode loop instead flushes the file
/// incrementally whenever it drains to idle.
fn trace_from(a: &Args) {
    let ocfg = ObsConfig::resolve(None, None, a.get("trace-out").map(|s| s.to_string()));
    if let Some(path) = &ocfg.trace_out {
        dqt::obs::trace::set_out_path(path);
        dqt::obs::trace::enable();
        eprintln!("trace: spans → {path} (Chrome trace-event JSON)");
    }
}

/// Run-end half of [`trace_from`]: write the trace file and print the
/// per-phase profile table. No-op when tracing is off.
fn finish_trace() {
    if let Some(table) = dqt::obs::trace::finish() {
        eprintln!("trace profile (docs/OBSERVABILITY.md §Tracing):\n{table}");
    }
}

/// The flags a spawned local worker must replay so every rank agrees on
/// the variant, the schedule and the sync policy (`--rank`/`--join` are
/// appended per worker by the spawner). `--metrics-addr`/`--watch-addr`/
/// `--trace-out` are deliberately *not* forwarded: every spawned rank
/// would race to bind the same addresses (or clobber the same trace
/// file) — multi-host workers opt in per rank instead.
fn dist_passthrough(a: &Args) -> Vec<String> {
    let mut v = Vec::new();
    for k in [
        "model",
        "mode",
        "bits",
        "env",
        "optimizer",
        "intervention",
        "steps",
        "dataset",
        "lr",
        "seed",
        "sync-every",
        "sync-format",
        "grad-format",
        "threads",
        "precision",
    ] {
        if let Some(val) = a.get(k) {
            v.push(format!("--{k}"));
            v.push(val.to_string());
        }
    }
    if a.has("recompute-scale") {
        v.push("--recompute-scale".into());
    }
    v
}

fn main() -> Result<()> {
    let a = Args::from_env()?;
    let Some(cmd) = a.positional.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let artifacts = a
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(dqt::default_artifacts_root);
    let results = a
        .get("results")
        .map(PathBuf::from)
        .unwrap_or_else(dqt::default_results_root);

    match cmd.as_str() {
        "train" => {
            let spec = variant_spec(&a)?;
            let name = spec.variant_name();
            let cfg = spec
                .model_config()
                .ok_or_else(|| anyhow!("unknown model {:?}", spec.model))?;
            let tcfg = train_config_from(&a)?;
            let out_dir = a
                .get("out")
                .map(PathBuf::from)
                .unwrap_or_else(|| results.join("train").join(&name));
            trace_from(&a);
            if a.has("workers") {
                // distributed data-parallel path (native backend only —
                // the PJRT path has no sharded train entry): rank 0 hosts
                // rendezvous + spawns local workers; bitwise equal to the
                // --workers 1 run by the dist determinism contract
                if backend_kind(&a)? == BackendKind::Pjrt {
                    bail!("--workers needs the native backend (PJRT has no sharded train entry)");
                }
                let world: usize = a.parse_or("workers", 1)?;
                let dcfg =
                    dist_config_from(&a, world, 0, a.str_or("dist-addr", "127.0.0.1:0"))?;
                let passthrough = dist_passthrough(&a);
                let spawn = if a.has("no-spawn") {
                    None
                } else {
                    Some(passthrough.as_slice())
                };
                // always hand rank 0 a concrete TrainObs so quant health
                // aggregates (and persists below) even with no endpoints
                let obs = train_obs_from(&a)?.unwrap_or_else(|| Arc::new(TrainObs::new()));
                let (vrt, state, metrics, dr) = dqt::dist::train_distributed(
                    &spec,
                    &tcfg,
                    &dcfg,
                    pool_from_args(&a)?,
                    spawn,
                    Some(obs.clone()),
                )?;
                metrics.save(&out_dir)?;
                obs.save_quant_health(&out_dir)?;
                checkpoint::save(
                    &out_dir.join("model.dqt"),
                    vrt.manifest(),
                    &state,
                    checkpoint::Codec::F32,
                    true,
                )?;
                // wire-traffic report — the dist-smoke CI legs assert the
                // quantized formats' shrinkage against this file
                std::fs::write(
                    out_dir.join("dist.json"),
                    dr.to_json().to_string_pretty(),
                )?;
                println!(
                    "trained {name} on {} workers: final loss {:.4}, dev loss {:.4} \
                     ({} grad exchange, {} all-reduce bytes, {} grid resyncs, \
                     {} sync bytes on the wire) → {}",
                    dr.world,
                    metrics.tail_loss(10).unwrap_or(f32::NAN),
                    metrics.final_dev_loss.unwrap_or(f32::NAN),
                    dr.grad_format,
                    dr.allreduce_bytes,
                    dr.syncs,
                    dr.sync_bytes,
                    out_dir.display()
                );
                finish_trace();
                return Ok(());
            }
            let vrt = VariantRuntime::open_with_pool(
                backend_kind(&a)?,
                None,
                &artifacts,
                &spec,
                pool_from_args(&a)?,
            )?;
            eprintln!(
                "backend: {} ({} kernel threads, {} precision)",
                vrt.backend_name(),
                vrt.threads(),
                vrt.precision().as_str()
            );
            let pipeline =
                Pipeline::build(&tcfg.dataset, tcfg.seed, cfg.vocab_size, cfg.max_seq_len)?;
            let mut tr = Trainer::new(&vrt, &pipeline, tcfg);
            if let Some(obs) = train_obs_from(&a)? {
                tr.obs = obs;
            }
            tr.progress = Some(Box::new(|step, loss| {
                eprintln!("step {step}: loss {loss:.4}");
            }));
            let (state, metrics) = tr.run()?;
            metrics.save(&out_dir)?;
            tr.obs.save_quant_health(&out_dir)?;
            checkpoint::save(
                &out_dir.join("model.dqt"),
                vrt.manifest(),
                &state,
                checkpoint::Codec::F32,
                true,
            )?;
            println!(
                "trained {name}: final loss {:.4}, dev loss {:.4} → {}",
                metrics.tail_loss(10).unwrap_or(f32::NAN),
                metrics.final_dev_loss.unwrap_or(f32::NAN),
                out_dir.display()
            );
            finish_trace();
        }
        "worker" => {
            let spec = variant_spec(&a)?;
            let world: usize = a.parse_or("workers", 0)?;
            let rank: usize = a.parse_or("rank", 0)?;
            let join = a.req("join")?;
            if world < 2 {
                bail!("worker needs --workers N (N >= 2) matching the coordinator");
            }
            let tcfg = train_config_from(&a)?;
            let dcfg = dist_config_from(&a, world, rank, join)?;
            trace_from(&a);
            dqt::dist::worker::run(&spec, &tcfg, &dcfg, pool_from_args(&a)?, train_obs_from(&a)?)?;
            finish_trace();
        }
        "watch" => {
            let addr = a.req("join")?;
            let timeout: u64 = a.parse_or("timeout", 30)?;
            dqt::obs::stream::watch(&addr, Duration::from_secs(timeout), |f| match f {
                StreamFrame::RunStart {
                    variant,
                    dataset,
                    world,
                    total_steps,
                } => println!(
                    "run start: {variant} on {dataset} (world {world}, {total_steps} steps)"
                ),
                StreamFrame::Step {
                    step,
                    loss,
                    lr,
                    upd_frac,
                    gnorm,
                    step_ms,
                } => println!(
                    "step {step}: loss {loss:.4}  lr {lr:.2e}  upd {upd_frac:.4}  \
                     gnorm {gnorm:.3}  ({step_ms:.1} ms)"
                ),
                StreamFrame::RunEnd {
                    final_dev_loss,
                    wall_secs,
                } => {
                    if final_dev_loss.is_nan() {
                        println!("run end: {wall_secs:.1}s wall (no dev loss)");
                    } else {
                        println!("run end: dev loss {final_dev_loss:.4}, {wall_secs:.1}s wall");
                    }
                }
                StreamFrame::QuantHealth { step, layers } => {
                    println!("quant health @ step {step}:");
                    println!(
                        "  {:<18} {:>7} {:>8} {:>8} {:>7} {:>7} {:>6} {:>9}",
                        "layer", "flips", "flip%/st", "|d|gs", "sat%", "zero%", "osc", "gnorm"
                    );
                    for l in layers {
                        println!(
                            "  {:<18} {:>7} {:>8.3} {:>8.4} {:>7.1} {:>7.1} {:>6.2} {:>9.4}",
                            l.name,
                            l.flips,
                            l.flip_rate * 100.0,
                            l.abs_upd,
                            l.saturation * 100.0,
                            l.zero_frac * 100.0,
                            l.oscillation,
                            l.grad_norm
                        );
                    }
                }
            })?;
        }
        "eval" => {
            let spec = variant_spec(&a)?;
            let cfg = spec
                .model_config()
                .ok_or_else(|| anyhow!("unknown model {:?}", spec.model))?;
            let ckpt = PathBuf::from(a.req("checkpoint")?);
            let dataset = a.str_or("dataset", "wiki");
            let items: usize = a.parse_or("items", 100)?;
            let vrt = VariantRuntime::open_with_pool(
                backend_kind(&a)?,
                None,
                &artifacts,
                &spec,
                pool_from_args(&a)?,
            )?;
            eprintln!(
                "backend: {} ({} kernel threads, {} precision)",
                vrt.backend_name(),
                vrt.threads(),
                vrt.precision().as_str()
            );
            let state = checkpoint::load(&ckpt, vrt.manifest())?;
            let pipeline = Pipeline::build(&dataset, 42, cfg.vocab_size, cfg.max_seq_len)?;
            let cspec = CorpusSpec::by_name(&dataset, 42)
                .ok_or_else(|| anyhow!("unknown dataset {dataset:?}"))?;
            let r = eval::evaluate(&vrt, &state, &pipeline, &cspec, items, false, 7)?;
            println!("{}", r.to_json().to_string_pretty());
            if a.has("ternary") {
                let r3 = eval::evaluate(&vrt, &state, &pipeline, &cspec, items, true, 7)?;
                println!("{}", r3.to_json().to_string_pretty());
            }
        }
        "generate" => {
            trace_from(&a);
            let (engine, name) = open_engine(&a, &artifacts)?;
            let prompt = a.str_or("prompt", "");
            let params = dqt::serve::GenParams {
                max_new_tokens: a.parse_or("max-new", 48)?,
                temperature: a.parse_or("temperature", 0.0f32)?,
                top_k: a.parse_or("top-k", 0usize)?,
                top_p: a.parse_or("top-p", 1.0f32)?,
                seed: a.parse_or("seed", 0u32)?,
            };
            let t0 = std::time::Instant::now();
            let g = engine.generate(&prompt, &params)?;
            let secs = t0.elapsed().as_secs_f64();
            println!("{}", g.text);
            eprintln!(
                "{name}: {} prompt + {} generated tokens in {:.2}s ({:.1} tok/s), finish: {}",
                g.prompt_tokens,
                g.token_ids.len(),
                secs,
                (g.prompt_tokens + g.token_ids.len()) as f64 / secs.max(1e-9),
                g.finish.as_str()
            );
            finish_trace();
        }
        "serve" => {
            trace_from(&a);
            let (engine, name) = open_engine(&a, &artifacts)?;
            let threads = engine.decoder().threads();
            let precision = engine.decoder().precision().as_str();
            let addr = a.str_or("addr", "127.0.0.1:8080");
            let max_batch: usize = a.parse_or("max-batch", 8)?;
            let max_queue: usize = a.parse_or("max-queue", 0)?;
            let server = dqt::serve::Server::bind_with(&addr, engine, max_batch, max_queue)?;
            eprintln!(
                "serving {name} at http://{} (POST /v1/generate, GET /healthz, \
                 GET /v1/stats, GET /metrics; batch {max_batch}, queue cap \
                 {max_queue}, {threads} kernel threads, {precision} precision)",
                server.local_addr()?
            );
            server.run()?;
        }
        "sweep" => {
            let exp = a.req("exp")?;
            let steps: u64 = a.parse_or("steps", 0)?;
            let workers: usize = a.parse_or("workers", 1)?;
            let backend = backend_kind(&a)?;
            let exps: Vec<&str> = if exp == "all" {
                coordinator::known_experiments().to_vec()
            } else {
                vec![exp.as_str()]
            };
            for e in exps {
                eprintln!("=== experiment {e} ===");
                let rs =
                    coordinator::run_experiment(e, steps, workers, backend, &artifacts, &results)?;
                let summary = coordinator::write_summary(&results, e, &rs)?;
                let ok = rs.iter().filter(|r| r.is_ok()).count();
                println!("{e}: {ok}/{} jobs ok → {}", rs.len(), summary.display());
                for r in rs.iter().filter_map(|r| r.as_ref().err()) {
                    eprintln!("  FAILED: {r}");
                }
            }
        }
        "report" => {
            let exp = a.req("exp")?;
            match exp.as_str() {
                "table2" => println!("{}", report::table2()),
                "table3" => println!("{}", report::table3()),
                "memory" => println!("{}", report::memory_comparison("p1b")?),
                "serving" => println!("{}", report::serving_memory("p1b")?),
                "dist" => println!(
                    "{}",
                    report::dist_memory("p1b", a.parse_or("workers", 4)?)?
                ),
                "profile" => println!(
                    "{}",
                    report::profile_from_trace(&PathBuf::from(a.str_or("trace", "trace.json")))?
                ),
                "quant-health" => {
                    let dir = match a.get("run") {
                        Some(d) => PathBuf::from(d),
                        None => report::latest_quant_health_run(&results)?,
                    };
                    println!("{}", report::quant_health(&dir)?);
                }
                e => {
                    let runs = report::load_runs(&results, e)?;
                    println!("{}", report::summary_table(&runs));
                    println!("{}", report::ascii_curves(&runs, 90, 22));
                }
            }
        }
        "list" => {
            println!("experiments: {}", coordinator::known_experiments().join(", "));
            match dqt::runtime::artifact::read_index(&artifacts) {
                Ok(v) => {
                    println!("artifacts ({}):", v.len());
                    for name in v {
                        println!("  {name}");
                    }
                }
                Err(_) => println!("artifacts: none built (run `make artifacts`)"),
            }
        }
        "memory" => {
            let spec = variant_spec(&a)?;
            let b = memory::estimate(&spec, true).ok_or_else(|| anyhow!("unknown model"))?;
            println!("{}", b.to_json().to_string_pretty());
            println!("total: {:.1} MB", b.total_mb());
            let batch: usize = a.parse_or("batch", 1)?;
            let s = memory::serving_estimate(&spec, batch, a.has("ternary"))
                .ok_or_else(|| anyhow!("unknown model"))?;
            println!("serving (batch {batch}): {}", s.to_json().to_string_pretty());
            if a.has("workers") {
                let workers: usize = a.parse_or("workers", 1)?;
                let d = memory::dist_estimate(&spec, workers)
                    .ok_or_else(|| anyhow!("unknown model"))?;
                println!(
                    "distributed ({workers} workers): {}",
                    d.to_json().to_string_pretty()
                );
            }
        }
        other => {
            print!("{USAGE}");
            bail!("unknown command {other:?}");
        }
    }
    Ok(())
}
