//! Evaluation harness: held-out perplexity + the zero-shot suite (Table 1).

pub mod tasks;

use anyhow::Result;

use crate::data::corpus::CorpusSpec;
use crate::data::loader::dev_batches;
use crate::data::Pipeline;
use crate::runtime::{State, VariantRuntime};

/// Table-1-shaped evaluation result for one model.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub variant: String,
    pub ternary_inference: bool,
    /// WikiText-2 analogue: dev-split perplexity
    pub perplexity: f64,
    /// task name → accuracy
    pub task_acc: Vec<(String, f64)>,
}

impl EvalResult {
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let acc = Value::Obj(
            self.task_acc
                .iter()
                .map(|(k, v)| (k.clone(), Value::Num(*v)))
                .collect(),
        );
        Value::obj()
            .set("variant", self.variant.as_str())
            .set("ternary_inference", self.ternary_inference)
            .set("perplexity", self.perplexity)
            .set("task_acc", acc)
    }
}

/// Dev-split perplexity (the WikiText-2 column's stand-in).
pub fn perplexity(
    vrt: &VariantRuntime,
    state: &State,
    pipeline: &Pipeline,
    ternary: bool,
) -> Result<f64> {
    let bs = vrt.manifest().variant.model.batch_size;
    let mut nll = 0f64;
    let mut count = 0f64;
    for b in dev_batches(&pipeline.dataset, bs) {
        let (s, c) = vrt.eval_step(state, &b.tokens, ternary)?;
        nll += s as f64;
        count += c as f64;
    }
    Ok((nll / count.max(1.0)).exp())
}

/// Accuracy on one zero-shot task via length-normalized log-likelihood.
pub fn task_accuracy(
    vrt: &VariantRuntime,
    state: &State,
    pipeline: &Pipeline,
    task: &tasks::Task,
    ternary: bool,
) -> Result<f64> {
    let m = vrt.manifest();
    let bs = m.logits_tokens_shape[0];
    let seq = m.logits_tokens_shape[1];
    let vocab = m.variant.model.vocab_size;

    // flatten all (item, choice) rows, batch them through logits_step
    let mut rows: Vec<tasks::ScoredRow> = Vec::new();
    let mut owners: Vec<(usize, usize)> = Vec::new(); // (item, choice)
    for (ii, item) in task.items.iter().enumerate() {
        for (ci, row) in tasks::rows_for_item(item, &pipeline.tokenizer, seq)
            .into_iter()
            .enumerate()
        {
            rows.push(row);
            owners.push((ii, ci));
        }
    }
    let mut scores = vec![vec![f64::NEG_INFINITY; 2]; task.items.len()];
    for (chunk_rows, chunk_owners) in rows.chunks(bs).zip(owners.chunks(bs)) {
        let mut tokens = vec![crate::data::tokenizer::PAD_ID; bs * seq];
        for (r, row) in chunk_rows.iter().enumerate() {
            tokens[r * seq..(r + 1) * seq].copy_from_slice(&row.tokens);
        }
        let logits = vrt.logits(state, &tokens, ternary)?;
        for (r, (row, &(ii, ci))) in chunk_rows.iter().zip(chunk_owners.iter()).enumerate() {
            let row_logits = &logits[r * seq * vocab..(r + 1) * seq * vocab];
            scores[ii][ci] = tasks::span_loglik(row_logits, vocab, &row.tokens, row.span);
        }
    }
    let correct = task
        .items
        .iter()
        .enumerate()
        .filter(|(ii, item)| {
            let s = &scores[*ii];
            let pred = if s[0] >= s[1] { 0 } else { 1 };
            pred == item.answer
        })
        .count();
    Ok(correct as f64 / task.items.len().max(1) as f64)
}

/// Full Table-1 row: perplexity + all four zero-shot tasks.
pub fn evaluate(
    vrt: &VariantRuntime,
    state: &State,
    pipeline: &Pipeline,
    spec: &CorpusSpec,
    n_items: usize,
    ternary: bool,
    seed: u64,
) -> Result<EvalResult> {
    let ppl = perplexity(vrt, state, pipeline, ternary)?;
    let suite = tasks::generate_suite(spec, n_items, seed);
    let mut task_acc = Vec::new();
    for t in &suite {
        task_acc.push((t.name.clone(), task_accuracy(vrt, state, pipeline, t, ternary)?));
    }
    Ok(EvalResult {
        variant: vrt.manifest().variant.variant_name.clone(),
        ternary_inference: ternary,
        perplexity: ppl,
        task_acc,
    })
}
