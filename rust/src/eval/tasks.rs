//! Synthetic zero-shot benchmark suite (Table 1's task columns).
//!
//! Four multiple-choice task families stand in for WinoGrande / ARC-easy /
//! ARC-challenge / PIQA / SciQ. Each item is `(context, choices, answer)`
//! where the ground truth comes from the corpus generator's *known* Markov
//! structure — so task difficulty is controlled and graded:
//!
//!   succ_easy   pick the true Markov successor vs a random word (ARC-easy)
//!   succ_hard   distractor is itself a plausible word (a successor of a
//!               different word) — requires sharper bigram estimates
//!               (ARC-challenge)
//!   cloze       mid-sequence cloze: full-sequence likelihood comparison
//!               (WinoGrande-style pairwise scoring)
//!   copy_recall a marker word appears earlier in the context; the correct
//!               continuation repeats it vs a frequency-matched distractor
//!               (SciQ-style recall)
//!
//! Scoring follows lm_eval: length-normalized sum log-likelihood of the
//! choice tokens given the context; accuracy = argmax over choices.

use crate::data::corpus::{self, CorpusSpec, Rng};
use crate::data::tokenizer::Tokenizer;

#[derive(Clone, Debug)]
pub struct Item {
    /// shared context text
    pub context: String,
    /// candidate continuations (first = correct before shuffling; see `answer`)
    pub choices: Vec<String>,
    pub answer: usize,
}

#[derive(Clone, Debug)]
pub struct Task {
    pub name: String,
    pub items: Vec<Item>,
}

fn successors(word_id: usize, spec: &CorpusSpec) -> Vec<usize> {
    (0..spec.n_successors)
        .map(|j| {
            let h = crate::quant::sr::hash_u32(word_id as u32 * 31 + j as u32, spec.seed as u32);
            h as usize % spec.vocab_words
        })
        .collect()
}

fn random_walk(lex: &[String], spec: &CorpusSpec, rng: &mut Rng, len: usize) -> Vec<usize> {
    let mut cur = rng.below(spec.vocab_words);
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(cur);
        cur = if rng.next_f64() < spec.markov_weight {
            let s = successors(cur, spec);
            s[rng.below(s.len())]
        } else {
            rng.below(spec.vocab_words)
        };
    }
    let _ = lex;
    out
}

fn words_text(lex: &[String], ids: &[usize]) -> String {
    ids.iter().map(|&i| lex[i].as_str()).collect::<Vec<_>>().join(" ")
}

/// Generate the 4-task suite (deterministic in `seed`).
pub fn generate_suite(spec: &CorpusSpec, n_items: usize, seed: u64) -> Vec<Task> {
    let lex = corpus::build_lexicon(spec);
    let mut rng = Rng::new(seed ^ 0x7A5C);
    let mut succ_easy = Vec::new();
    let mut succ_hard = Vec::new();
    let mut cloze = Vec::new();
    let mut copy_recall = Vec::new();

    while succ_easy.len() < n_items {
        let walk_len = 8 + rng.below(8);
        let ctx_ids = random_walk(&lex, spec, &mut rng, walk_len);
        let last = *ctx_ids.last().unwrap();
        let succ = successors(last, spec);
        let correct = succ[rng.below(succ.len())];

        // easy: random distractor (not a successor of `last`)
        let mut d = rng.below(spec.vocab_words);
        while succ.contains(&d) || d == correct {
            d = rng.below(spec.vocab_words);
        }
        let (answer, choices) = shuffle2(&lex[correct], &lex[d], &mut rng);
        succ_easy.push(Item {
            context: words_text(&lex, &ctx_ids),
            choices,
            answer,
        });

        // hard: distractor is a successor of a different word
        let other = rng.below(spec.vocab_words);
        let od = successors(other, spec)[0];
        if od != correct && !succ.contains(&od) {
            let (answer, choices) = shuffle2(&lex[correct], &lex[od], &mut rng);
            succ_hard.push(Item {
                context: words_text(&lex, &ctx_ids),
                choices,
                answer,
            });
        }

        // cloze: context …w X w'… — compare likelihood of the two fills
        let mid = ctx_ids[ctx_ids.len() / 2];
        let fill_true = successors(mid, spec)[0];
        let mut fill_false = rng.below(spec.vocab_words);
        while successors(mid, spec).contains(&fill_false) {
            fill_false = rng.below(spec.vocab_words);
        }
        let prefix = words_text(&lex, &ctx_ids[..ctx_ids.len() / 2 + 1]);
        let (answer, choices) = shuffle2(&lex[fill_true], &lex[fill_false], &mut rng);
        cloze.push(Item {
            context: prefix,
            choices,
            answer,
        });

        // copy/recall: marker word repeated vs novel
        let marker = ctx_ids[1 + rng.below(2)];
        let mut novel = rng.below(spec.vocab_words);
        while ctx_ids.contains(&novel) {
            novel = rng.below(spec.vocab_words);
        }
        let (answer, choices) = shuffle2(&lex[marker], &lex[novel], &mut rng);
        copy_recall.push(Item {
            context: words_text(&lex, &ctx_ids),
            choices,
            answer,
        });
    }
    succ_hard.truncate(n_items);
    cloze.truncate(n_items);
    copy_recall.truncate(n_items);

    vec![
        Task { name: "succ_easy".into(), items: succ_easy },
        Task { name: "succ_hard".into(), items: succ_hard },
        Task { name: "cloze".into(), items: cloze },
        Task { name: "copy_recall".into(), items: copy_recall },
    ]
}

fn shuffle2(correct: &str, wrong: &str, rng: &mut Rng) -> (usize, Vec<String>) {
    if rng.next_f64() < 0.5 {
        (0, vec![correct.to_string(), wrong.to_string()])
    } else {
        (1, vec![wrong.to_string(), correct.to_string()])
    }
}

/// One scoring request row: tokens + the span holding the choice.
#[derive(Clone, Debug)]
pub struct ScoredRow {
    pub tokens: Vec<i32>,
    /// label positions [start, end): predicted at positions-1 of logits
    pub span: (usize, usize),
}

/// Tokenize an item's (context, choice) pairs into fixed-length rows.
pub fn rows_for_item(item: &Item, tok: &Tokenizer, seq_len: usize) -> Vec<ScoredRow> {
    item.choices
        .iter()
        .map(|choice| {
            let ctx_ids = tok.encode(&item.context);
            let full_ids = tok.encode(&format!("{} {}", item.context, choice));
            let mut tokens = Vec::with_capacity(seq_len + 1);
            tokens.push(crate::data::tokenizer::BOS_ID);
            tokens.extend(&full_ids);
            tokens.truncate(seq_len);
            let span_start = (1 + ctx_ids.len()).min(tokens.len());
            let span_end = tokens.len();
            while tokens.len() < seq_len {
                tokens.push(crate::data::tokenizer::PAD_ID);
            }
            ScoredRow {
                tokens,
                span: (span_start, span_end),
            }
        })
        .collect()
}

/// Length-normalized log-likelihood of the span under row logits
/// (`logits` is [seq, vocab] for this row's inputs).
pub fn span_loglik(logits: &[f32], vocab: usize, tokens: &[i32], span: (usize, usize)) -> f64 {
    let (start, end) = span;
    if end <= start {
        return f64::NEG_INFINITY;
    }
    let mut total = 0f64;
    for pos in start..end {
        // logits at pos-1 predict token at pos
        let row = &logits[(pos - 1) * vocab..pos * vocab];
        let target = tokens[pos] as usize;
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz =
            max as f64 + row.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>().ln();
        total += row[target] as f64 - logz;
    }
    total / (end - start) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CorpusSpec {
        CorpusSpec::tiny(5)
    }

    #[test]
    fn suite_generation_deterministic() {
        let a = generate_suite(&spec(), 20, 1);
        let b = generate_suite(&spec(), 20, 1);
        assert_eq!(a.len(), 4);
        for (ta, tb) in a.iter().zip(b.iter()) {
            assert_eq!(ta.items.len(), 20);
            for (ia, ib) in ta.items.iter().zip(tb.items.iter()) {
                assert_eq!(ia.context, ib.context);
                assert_eq!(ia.choices, ib.choices);
                assert_eq!(ia.answer, ib.answer);
            }
        }
    }

    #[test]
    fn answers_balanced() {
        let tasks = generate_suite(&spec(), 100, 3);
        for t in &tasks {
            let ones = t.items.iter().filter(|i| i.answer == 1).count();
            assert!((20..=80).contains(&ones), "{}: {ones}", t.name);
        }
    }

    #[test]
    fn rows_have_valid_spans() {
        let s = spec();
        let docs = corpus::generate(&s);
        let tok = Tokenizer::train(&docs[..20.min(docs.len())].to_vec(), 300);
        let tasks = generate_suite(&s, 10, 2);
        for t in &tasks {
            for item in &t.items {
                for row in rows_for_item(item, &tok, 64) {
                    assert_eq!(row.tokens.len(), 64);
                    assert!(row.span.0 >= 1);
                    assert!(row.span.1 <= 64);
                    assert!(row.span.0 <= row.span.1);
                }
            }
        }
    }

    #[test]
    fn span_loglik_prefers_peaked_logits() {
        let vocab = 4;
        // seq 3, logits at pos 0/1 predict tokens 1/2
        let tokens = vec![1i32, 2, 3];
        let mut logits = vec![0f32; 3 * vocab];
        logits[0 * vocab + 2] = 5.0; // pos0 strongly predicts token 2
        logits[1 * vocab + 3] = 5.0; // pos1 strongly predicts token 3
        let ll_good = span_loglik(&logits, vocab, &tokens, (1, 3));
        let tokens_bad = vec![1i32, 0, 0];
        let ll_bad = span_loglik(&logits, vocab, &tokens_bad, (1, 3));
        assert!(ll_good > ll_bad);
    }
}
