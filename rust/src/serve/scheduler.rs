//! Continuous batching: many requests, one decode loop.
//!
//! The scheduler keeps up to `max_batch` sequences *active* and advances
//! every one of them by exactly one token per [`Scheduler::step`] — a
//! sequence still consuming its prompt is prefilled, one that has sampled
//! tokens decodes, and both kinds ride the same batched
//! [`Decoder::step_batch`] call (token-level batching). Requests are
//! admitted mid-flight the moment a slot frees up and evicted the moment
//! they finish, so the batch never drains to refill.
//!
//! Because decode rows are numerically independent, a sequence's output
//! is identical whether it ran solo or packed with fifteen others — the
//! invariant `tests/serve_e2e.rs` pins. Throughput is reported as
//! aggregate tokens/sec over all rows of all steps.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::{Engine, FinishReason, GenParams, Generation};
use super::metrics::ServeMetrics;
use super::sampler::Sampler;
use crate::data::tokenizer::DecodeStream;
use crate::obs::trace;
use crate::runtime::{Decoder, DecoderCache};

/// Finished-request summaries kept for `/v1/stats` (`recent_requests`) —
/// newest last, oldest evicted past this cap.
pub const RECENT_REQUESTS_CAP: usize = 32;

/// Per-request span summary surfaced by `/v1/stats`: the serve-side
/// request hierarchy (queue-wait → prefill → decode steps) folded to the
/// numbers a client-side latency investigation reaches for first.
#[derive(Clone, Debug)]
pub struct RequestSummary {
    pub id: u64,
    /// ms from submission to the first sampled token (`None` when the
    /// request finished without sampling — empty prompt, `max_new 0`,
    /// decode error before the first token)
    pub ttft_ms: Option<f64>,
    /// batched decode steps this request rode (its serve.decode span
    /// count, prefill steps included)
    pub decode_steps: u64,
    /// ms from submission to eviction
    pub total_ms: f64,
    pub finish: &'static str,
}

/// Aggregate serving counters (monotonic since scheduler creation).
#[derive(Clone, Copy, Debug, Default)]
pub struct SchedulerStats {
    pub submitted: u64,
    pub completed: u64,
    /// batched decode calls issued
    pub steps: u64,
    /// tokens pushed through the model (prefill + decode rows)
    pub tokens_processed: u64,
    /// tokens sampled
    pub tokens_generated: u64,
    pub peak_batch: usize,
    /// wall-clock nanoseconds spent inside batched model forwards
    /// (the lock-released phase of [`Scheduler::step`])
    pub decode_ns: u64,
}

impl SchedulerStats {
    /// Cumulative decode throughput: tokens pushed through the model per
    /// second of model-forward wall time (0.0 before the first step).
    /// Together with the thread count reported by `/v1/stats`, this makes
    /// bench numbers attributable to a configuration.
    pub fn decode_tokens_per_sec(&self) -> f64 {
        if self.decode_ns == 0 {
            0.0
        } else {
            self.tokens_processed as f64 * 1e9 / self.decode_ns as f64
        }
    }
}

/// One in-flight sequence.
struct Seq {
    id: u64,
    prompt: Vec<i32>,
    /// prompt tokens fed so far; == prompt.len() once decoding
    fed: usize,
    generated: Vec<i32>,
    text: String,
    stream: DecodeStream,
    sampler: Sampler,
    params: GenParams,
    /// allocated at admission, not at submission (queued requests cost
    /// nothing until a batch slot frees up)
    cache: Option<Box<dyn DecoderCache>>,
    tx: Option<Sender<(u64, Generation)>>,
    /// when the request entered the queue (TTFT / request latency)
    submitted: Instant,
    /// when the request was first checked out into a decode batch
    /// (serve.queue_wait ends, serve.prefill starts)
    first_checkout: Option<Instant>,
    /// submission → first sampled token, for the request summary
    ttft_ms: Option<f64>,
    /// batched decode steps this sequence rode
    decode_steps: u64,
}

struct Inner {
    queue: VecDeque<Seq>,
    active: Vec<Seq>,
    /// sequences checked out by an in-progress [`Scheduler::step`] (the
    /// model forward runs with the lock released; this keeps
    /// [`Scheduler::pending`] honest and guards against a second stepper)
    in_flight: usize,
    /// finished generations awaiting [`Scheduler::take_finished`]
    /// (channel-less submissions only)
    finished: Vec<(u64, Generation)>,
    next_id: u64,
    stats: SchedulerStats,
    /// last [`RECENT_REQUESTS_CAP`] finished-request summaries
    recent: VecDeque<RequestSummary>,
}

impl Inner {
    fn push_recent(&mut self, r: RequestSummary) {
        if self.recent.len() >= RECENT_REQUESTS_CAP {
            self.recent.pop_front();
        }
        self.recent.push_back(r);
    }
}

/// The continuous-batching scheduler. Shared across submitter threads and
/// one decode-loop thread; all coordination is one mutex + condvar.
pub struct Scheduler {
    engine: Arc<Engine>,
    max_batch: usize,
    /// queue cap enforced by [`Scheduler::try_submit_channel`]; 0 means
    /// unbounded (the non-`try` submit paths are always unbounded)
    max_queue: usize,
    metrics: Arc<ServeMetrics>,
    inner: Mutex<Inner>,
    work: Condvar,
}

impl Scheduler {
    pub fn new(engine: Arc<Engine>, max_batch: usize) -> Scheduler {
        Self::with_queue_limit(engine, max_batch, 0)
    }

    /// A scheduler whose [`Scheduler::try_submit_channel`] rejects
    /// submissions once `max_queue` requests are waiting (0 = unbounded).
    pub fn with_queue_limit(engine: Arc<Engine>, max_batch: usize, max_queue: usize) -> Scheduler {
        Scheduler {
            engine,
            max_batch: max_batch.max(1),
            max_queue,
            metrics: Arc::new(ServeMetrics::new()),
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                active: Vec::new(),
                in_flight: 0,
                finished: Vec::new(),
                next_id: 0,
                stats: SchedulerStats::default(),
                recent: VecDeque::new(),
            }),
            work: Condvar::new(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The serving metrics bundle `GET /metrics` renders.
    pub fn metrics(&self) -> Arc<ServeMetrics> {
        self.metrics.clone()
    }

    /// Queue a text prompt; poll [`Scheduler::take_finished`] for the
    /// result.
    pub fn submit(&self, prompt: &str, params: GenParams) -> u64 {
        self.enqueue(self.engine.prompt_ids(prompt), params, None, false)
            .expect("unbounded submit cannot be rejected")
    }

    /// Queue a text prompt and get a channel the result is delivered on
    /// (the HTTP handler path).
    pub fn submit_channel(
        &self,
        prompt: &str,
        params: GenParams,
    ) -> (u64, Receiver<(u64, Generation)>) {
        let (tx, rx) = channel();
        let id = self
            .enqueue(self.engine.prompt_ids(prompt), params, Some(tx), false)
            .expect("unbounded submit cannot be rejected");
        (id, rx)
    }

    /// Like [`Scheduler::submit_channel`], but honors the scheduler's
    /// queue cap: returns `None` (and counts an admission rejection) when
    /// `max_queue` requests are already waiting.
    pub fn try_submit_channel(
        &self,
        prompt: &str,
        params: GenParams,
    ) -> Option<(u64, Receiver<(u64, Generation)>)> {
        let (tx, rx) = channel();
        self.enqueue(self.engine.prompt_ids(prompt), params, Some(tx), true)
            .map(|id| (id, rx))
    }

    /// Queue pre-tokenized ids (no BOS prepend, no truncation — the
    /// caller owns the framing).
    pub fn submit_ids(&self, prompt: Vec<i32>, params: GenParams) -> u64 {
        self.enqueue(prompt, params, None, false)
            .expect("unbounded submit cannot be rejected")
    }

    fn enqueue(
        &self,
        prompt: Vec<i32>,
        params: GenParams,
        tx: Option<Sender<(u64, Generation)>>,
        bounded: bool,
    ) -> Option<u64> {
        let submitted = Instant::now();
        let mut g = self.inner.lock().unwrap();
        if bounded && self.max_queue > 0 && g.queue.len() >= self.max_queue {
            self.metrics.admission_rejections_total.inc();
            return None;
        }
        let id = g.next_id;
        g.next_id += 1;
        g.stats.submitted += 1;
        self.metrics.requests_total.inc();
        if prompt.is_empty() || params.max_new_tokens == 0 {
            // nothing to condition on / nothing to produce — finish
            // immediately, matching `Engine::generate_ids`'s behavior
            let gen = Generation {
                prompt_tokens: prompt.len(),
                token_ids: Vec::new(),
                text: String::new(),
                finish: FinishReason::Length,
            };
            g.stats.completed += 1;
            self.metrics.completed_total.inc();
            self.metrics
                .request_seconds
                .observe(submitted.elapsed().as_secs_f64());
            trace::record_interval(
                "serve",
                trace::names::SERVE_REQUEST,
                submitted,
                Instant::now(),
            );
            g.push_recent(RequestSummary {
                id,
                ttft_ms: None,
                decode_steps: 0,
                total_ms: submitted.elapsed().as_secs_f64() * 1e3,
                finish: gen.finish.as_str(),
            });
            match tx {
                Some(tx) => {
                    let _ = tx.send((id, gen));
                }
                None => g.finished.push((id, gen)),
            }
            return Some(id);
        }
        let seq = Seq {
            id,
            prompt,
            fed: 0,
            generated: Vec::new(),
            text: String::new(),
            stream: self.engine.tokenizer().decode_stream(),
            sampler: Sampler::new(&params),
            params,
            cache: None,
            tx,
            submitted,
            first_checkout: None,
            ttft_ms: None,
            decode_steps: 0,
        };
        g.queue.push_back(seq);
        self.metrics.queue_depth.set(g.queue.len() as f64);
        drop(g);
        self.work.notify_all();
        Some(id)
    }

    /// Queued + active (including checked-out) sequences.
    pub fn pending(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.queue.len() + g.active.len() + g.in_flight
    }

    pub fn stats(&self) -> SchedulerStats {
        self.inner.lock().unwrap().stats
    }

    /// Summaries of the last [`RECENT_REQUESTS_CAP`] finished requests,
    /// oldest first (the `/v1/stats` `recent_requests` payload).
    pub fn recent_requests(&self) -> Vec<RequestSummary> {
        self.inner.lock().unwrap().recent.iter().cloned().collect()
    }

    /// Results of channel-less submissions finished since the last call.
    pub fn take_finished(&self) -> Vec<(u64, Generation)> {
        std::mem::take(&mut self.inner.lock().unwrap().finished)
    }

    /// Block until there is work to step (or `timeout` elapses) — a
    /// bounded idle wait for callers that need to regain control.
    pub fn wait_for_work(&self, timeout: Duration) {
        let g = self.inner.lock().unwrap();
        if g.queue.is_empty() && g.active.is_empty() {
            let _ = self.work.wait_timeout(g, timeout).unwrap();
        }
    }

    /// Park the calling thread until there is work to step — the decode
    /// loop's idle wait. A true condvar park (no poll interval): an idle
    /// server burns no CPU, and a submission wakes the loop immediately,
    /// so admission latency is the model forward, not a sleep quantum.
    /// Loops on the condition, so spurious wakeups are harmless.
    pub fn park_until_work(&self) {
        let mut g = self.inner.lock().unwrap();
        while g.queue.is_empty() && g.active.is_empty() && g.in_flight == 0 {
            g = self.work.wait(g).unwrap();
        }
    }

    /// One batched decode step: admit queued requests into free slots,
    /// advance every active sequence one token, sample where the prompt
    /// is exhausted, evict finished sequences. Returns the number of
    /// tokens processed (0 = nothing to do).
    ///
    /// The batch is *checked out* under the lock and the model forward
    /// runs with the lock released, so submissions and health probes
    /// never wait on a decode step; sampling and eviction commit under a
    /// second short critical section. A decode error finishes the whole
    /// checked-out batch with [`FinishReason::Error`] before
    /// propagating, so no request can hang.
    pub fn step(&self) -> Result<usize> {
        // --- phase 1 (locked): admit, then check the batch out ---
        let (mut batch, tokens) = {
            let mut g = self.inner.lock().unwrap();
            if g.in_flight > 0 {
                // another step() is mid-flight — one stepper at a time
                return Ok(0);
            }
            while g.active.len() < self.max_batch {
                let Some(mut seq) = g.queue.pop_front() else {
                    break;
                };
                seq.cache = Some(self.engine.decoder().new_cache());
                let now = Instant::now();
                seq.first_checkout = Some(now);
                trace::record_interval(
                    "serve",
                    trace::names::SERVE_QUEUE_WAIT,
                    seq.submitted,
                    now,
                );
                g.active.push(seq);
            }
            if g.active.is_empty() {
                return Ok(0);
            }
            g.stats.peak_batch = g.stats.peak_batch.max(g.active.len());
            let batch = std::mem::take(&mut g.active);
            g.in_flight = batch.len();
            self.metrics.queue_depth.set(g.queue.len() as f64);
            self.metrics.active_sequences.set(batch.len() as f64);
            self.metrics.batch_size.observe(batch.len() as f64);
            // one input token per sequence: next prompt token while
            // prefilling, else the last sampled token
            let tokens: Vec<i32> = batch
                .iter()
                .map(|s| {
                    if s.fed < s.prompt.len() {
                        s.prompt[s.fed]
                    } else {
                        *s.generated.last().expect("decoding sequence has tokens")
                    }
                })
                .collect();
            (batch, tokens)
        };

        // --- phase 2 (unlocked): the batched model forward ---
        let n = batch.len();
        let t0 = std::time::Instant::now();
        let step_result = {
            let _sp = trace::span_arg("serve", trace::names::SERVE_DECODE, "rows", n as u64);
            let mut caches: Vec<&mut dyn DecoderCache> = batch
                .iter_mut()
                .map(|s| &mut **s.cache.as_mut().expect("active sequence has a cache"))
                .collect();
            self.engine.decoder().step_batch(&mut caches[..], &tokens)
        };
        let decode_ns = t0.elapsed().as_nanos() as u64;

        // --- phase 3 (locked): sample, evict, return survivors ---
        let mut g = self.inner.lock().unwrap();
        let g = &mut *g;
        g.in_flight = 0;
        g.stats.decode_ns += decode_ns;
        self.metrics
            .decode_seconds_total
            .add(decode_ns as f64 / 1e9);
        let logits = match step_result {
            Ok(l) => l,
            Err(e) => {
                // fail every checked-out request instead of hanging clients
                for mut s in batch {
                    let gen = Generation {
                        prompt_tokens: s.prompt.len(),
                        token_ids: std::mem::take(&mut s.generated),
                        text: std::mem::take(&mut s.text),
                        finish: FinishReason::Error,
                    };
                    g.stats.completed += 1;
                    self.metrics.completed_total.inc();
                    self.metrics
                        .request_seconds
                        .observe(s.submitted.elapsed().as_secs_f64());
                    trace::record_interval(
                        "serve",
                        trace::names::SERVE_REQUEST,
                        s.submitted,
                        Instant::now(),
                    );
                    g.push_recent(RequestSummary {
                        id: s.id,
                        ttft_ms: s.ttft_ms,
                        decode_steps: s.decode_steps + 1,
                        total_ms: s.submitted.elapsed().as_secs_f64() * 1e3,
                        finish: gen.finish.as_str(),
                    });
                    match s.tx.take() {
                        Some(tx) => {
                            let _ = tx.send((s.id, gen));
                        }
                        None => g.finished.push((s.id, gen)),
                    }
                }
                self.metrics.active_sequences.set(0.0);
                return Err(e);
            }
        };
        g.stats.steps += 1;
        g.stats.tokens_processed += n as u64;
        self.metrics.decode_steps_total.inc();
        self.metrics.tokens_processed_total.inc_by(n as u64);
        let v = self.engine.decoder().vocab_size();
        let max_pos = self.engine.decoder().max_positions();
        let eos = self.engine.eos_id();

        for (i, mut s) in batch.into_iter().enumerate() {
            s.decode_steps += 1;
            let prefilling = s.fed < s.prompt.len();
            if prefilling {
                s.fed += 1;
            }
            if prefilling && s.fed < s.prompt.len() {
                g.active.push(s); // still prefilling — logits row unused
                continue;
            }
            let next = {
                let _sp = trace::span("serve", trace::names::SERVE_SAMPLE);
                s.sampler.sample(&logits[i * v..(i + 1) * v]) as i32
            };
            s.generated.push(next);
            g.stats.tokens_generated += 1;
            self.metrics.tokens_generated_total.inc();
            if s.generated.len() == 1 {
                let ttft = s.submitted.elapsed();
                s.ttft_ms = Some(ttft.as_secs_f64() * 1e3);
                self.metrics.ttft_seconds.observe(ttft.as_secs_f64());
                if let Some(fc) = s.first_checkout {
                    // first checkout → first sampled token
                    trace::record_interval(
                        "serve",
                        trace::names::SERVE_PREFILL,
                        fc,
                        Instant::now(),
                    );
                }
            }
            let finish = if next == eos {
                Some(FinishReason::Eos)
            } else {
                let piece = {
                    let _sp = trace::span("serve", trace::names::SERVE_DETOKENIZE);
                    s.stream.push(next)
                };
                s.text.push_str(&piece);
                if s.generated.len() >= s.params.max_new_tokens {
                    Some(FinishReason::Length)
                } else if s.cache.as_ref().map(|c| c.position()).unwrap_or(0) >= max_pos {
                    Some(FinishReason::CacheFull)
                } else {
                    None
                }
            };
            match finish {
                None => g.active.push(s),
                Some(finish) => {
                    let mut text = std::mem::take(&mut s.text);
                    {
                        let _sp = trace::span("serve", trace::names::SERVE_DETOKENIZE);
                        text.push_str(&s.stream.finish());
                    }
                    let gen = Generation {
                        prompt_tokens: s.prompt.len(),
                        token_ids: std::mem::take(&mut s.generated),
                        text,
                        finish,
                    };
                    g.stats.completed += 1;
                    self.metrics.completed_total.inc();
                    self.metrics
                        .request_seconds
                        .observe(s.submitted.elapsed().as_secs_f64());
                    trace::record_interval(
                        "serve",
                        trace::names::SERVE_REQUEST,
                        s.submitted,
                        Instant::now(),
                    );
                    g.push_recent(RequestSummary {
                        id: s.id,
                        ttft_ms: s.ttft_ms,
                        decode_steps: s.decode_steps,
                        total_ms: s.submitted.elapsed().as_secs_f64() * 1e3,
                        finish: gen.finish.as_str(),
                    });
                    match s.tx.take() {
                        Some(tx) => {
                            let _ = tx.send((s.id, gen));
                        }
                        None => g.finished.push((s.id, gen)),
                    }
                }
            }
        }
        self.metrics.active_sequences.set(g.active.len() as f64);
        self.metrics
            .decode_tokens_per_sec
            .set(g.stats.decode_tokens_per_sec());
        if !g.active.is_empty() || !g.queue.is_empty() {
            // a thread parked in `park_until_work` while this step was
            // mid-flight (in_flight > 0) must be re-woken for the survivors
            self.work.notify_all();
        }
        Ok(n)
    }

    /// Drive [`Scheduler::step`] until queue and batch are empty; returns
    /// total tokens processed. (Tests and batch jobs — the HTTP server
    /// runs the loop on its own thread instead.)
    pub fn run_until_idle(&self) -> Result<u64> {
        let mut total = 0u64;
        loop {
            let n = self.step()? as u64;
            if n == 0 {
                return Ok(total);
            }
            total += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::Tokenizer;
    use crate::runtime::Decoder;

    /// Deterministic mock model: logits peak at `(token + 1) % vocab`, so
    /// greedy decoding counts upward and hits the EOS id (1) predictably.
    struct MockDecoder {
        vocab: usize,
        max_pos: usize,
    }

    struct MockCache {
        pos: usize,
    }

    impl DecoderCache for MockCache {
        fn position(&self) -> usize {
            self.pos
        }
        fn reset(&mut self) {
            self.pos = 0;
        }
        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }
    }

    impl Decoder for MockDecoder {
        fn max_positions(&self) -> usize {
            self.max_pos
        }
        fn vocab_size(&self) -> usize {
            self.vocab
        }
        fn kv_bytes_per_position(&self) -> usize {
            8
        }
        fn weight_bytes(&self) -> usize {
            64
        }
        fn packed_projections(&self) -> usize {
            0
        }
        fn n_projections(&self) -> usize {
            0
        }
        fn new_cache(&self) -> Box<dyn DecoderCache> {
            Box::new(MockCache { pos: 0 })
        }
        fn step_batch(
            &self,
            caches: &mut [&mut dyn DecoderCache],
            tokens: &[i32],
        ) -> Result<Vec<f32>> {
            let mut out = vec![0f32; tokens.len() * self.vocab];
            for (r, &t) in tokens.iter().enumerate() {
                if !(0..self.vocab as i32).contains(&t) {
                    return Err(anyhow::anyhow!("token {t} out of vocab"));
                }
                let peak = (t as usize + 1) % self.vocab;
                out[r * self.vocab + peak] = 10.0;
            }
            for c in caches.iter_mut() {
                let mc = c.as_any_mut().downcast_mut::<MockCache>().unwrap();
                mc.pos += 1;
            }
            Ok(out)
        }
    }

    fn mock_engine(vocab: usize, max_pos: usize) -> Arc<Engine> {
        let docs = vec!["a b c a b c".to_string(); 3];
        let tok = Tokenizer::train(&docs, 16);
        Arc::new(Engine::from_decoder(
            Box::new(MockDecoder { vocab, max_pos }),
            tok,
        ))
    }

    #[test]
    fn greedy_mock_counts_up_to_eos() {
        let engine = mock_engine(8, 64);
        let g = engine
            .generate_ids(vec![3], &GenParams { max_new_tokens: 20, ..Default::default() })
            .unwrap();
        // 3 → 4 5 6 7 0 1(eos)
        assert_eq!(g.token_ids, vec![4, 5, 6, 7, 0, 1]);
        assert_eq!(g.finish, FinishReason::Eos);
        assert_eq!(g.prompt_tokens, 1);
    }

    #[test]
    fn max_new_tokens_caps_generation() {
        let engine = mock_engine(8, 64);
        let g = engine
            .generate_ids(vec![2], &GenParams { max_new_tokens: 3, ..Default::default() })
            .unwrap();
        assert_eq!(g.token_ids, vec![3, 4, 5]);
        assert_eq!(g.finish, FinishReason::Length);
    }

    #[test]
    fn cache_full_stops_generation() {
        let engine = mock_engine(8, 4);
        let g = engine
            .generate_ids(vec![2], &GenParams { max_new_tokens: 100, ..Default::default() })
            .unwrap();
        assert_eq!(g.finish, FinishReason::CacheFull);
        assert!(g.token_ids.len() < 100);
    }

    #[test]
    fn continuous_batching_matches_solo_and_admits_midflight() {
        let engine = mock_engine(16, 256);
        let sched = Scheduler::new(engine.clone(), 2); // force queueing
        let prompts: Vec<Vec<i32>> = vec![vec![3], vec![5, 6], vec![9], vec![2, 3, 4], vec![11]];
        let params = GenParams { max_new_tokens: 12, ..Default::default() };
        let ids: Vec<u64> = prompts
            .iter()
            .map(|p| sched.submit_ids(p.clone(), params.clone()))
            .collect();
        sched.run_until_idle().unwrap();
        let mut finished = sched.take_finished();
        finished.sort_by_key(|(id, _)| *id);
        assert_eq!(finished.len(), prompts.len());
        for ((id, gen), prompt) in finished.iter().zip(prompts.iter()) {
            let solo = engine.generate_ids(prompt.clone(), &params).unwrap();
            assert!(ids.contains(id));
            assert_eq!(gen.token_ids, solo.token_ids, "request {id}");
            assert_eq!(gen.text, solo.text, "request {id}");
            assert_eq!(gen.finish, solo.finish, "request {id}");
        }
        let st = sched.stats();
        assert_eq!(st.submitted, 5);
        assert_eq!(st.completed, 5);
        assert_eq!(st.peak_batch, 2);
        assert!(st.tokens_generated >= 5);
        assert!(st.tokens_processed >= st.tokens_generated);
    }

    #[test]
    fn channel_submission_delivers() {
        let engine = mock_engine(8, 64);
        let sched = Scheduler::new(engine, 4);
        let (id, rx) = sched.submit_channel("a", GenParams::default());
        sched.run_until_idle().unwrap();
        let (rid, gen) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rid, id);
        assert!(!gen.token_ids.is_empty());
        assert!(sched.take_finished().is_empty()); // delivered via channel
    }

    #[test]
    fn decode_error_fails_requests_instead_of_hanging() {
        let engine = mock_engine(8, 64);
        let sched = Scheduler::new(engine, 4);
        let id = sched.submit_ids(vec![99], GenParams::default()); // out of vocab
        assert!(sched.step().is_err());
        let finished = sched.take_finished();
        assert_eq!(finished.len(), 1);
        assert_eq!(finished[0].0, id);
        assert_eq!(finished[0].1.finish, FinishReason::Error);
        assert_eq!(sched.pending(), 0);
    }

    /// `max_new_tokens: 0` behaves identically on the scheduler and the
    /// one-shot engine path: an empty generation finished with `length`.
    #[test]
    fn zero_max_new_matches_engine_path() {
        let engine = mock_engine(8, 64);
        let sched = Scheduler::new(engine.clone(), 4);
        let p = GenParams { max_new_tokens: 0, ..Default::default() };
        let id = sched.submit_ids(vec![3], p.clone());
        assert_eq!(sched.run_until_idle().unwrap(), 0);
        let f = sched.take_finished();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].0, id);
        let solo = engine.generate_ids(vec![3], &p).unwrap();
        assert_eq!(f[0].1.token_ids, solo.token_ids);
        assert_eq!(f[0].1.finish, solo.finish);
        assert!(f[0].1.token_ids.is_empty());
    }

    #[test]
    fn empty_prompt_finishes_immediately() {
        let engine = mock_engine(8, 64);
        let sched = Scheduler::new(engine, 4);
        let id = sched.submit_ids(vec![], GenParams::default());
        let f = sched.take_finished();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].0, id);
        assert!(f[0].1.token_ids.is_empty());
        assert_eq!(f[0].1.finish, FinishReason::Length);
        assert_eq!(sched.pending(), 0);
    }

    #[test]
    fn park_until_work_wakes_on_submit_and_decode_time_is_accounted() {
        let engine = mock_engine(8, 64);
        let sched = Arc::new(Scheduler::new(engine, 4));
        let s2 = sched.clone();
        let submitter = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            s2.submit_ids(vec![3], GenParams::default());
        });
        sched.park_until_work(); // no timeout: returns only once work exists
        assert!(sched.pending() > 0);
        submitter.join().unwrap();
        sched.run_until_idle().unwrap();
        let st = sched.stats();
        assert!(st.decode_ns > 0, "model-forward time must be accounted");
        assert!(st.decode_tokens_per_sec() > 0.0);
        assert_eq!(SchedulerStats::default().decode_tokens_per_sec(), 0.0);
    }

    /// `try_submit_channel` honors the queue cap and counts rejections;
    /// the unbounded submit paths are unaffected by the cap.
    #[test]
    fn queue_cap_rejects_and_counts() {
        let engine = mock_engine(8, 64);
        let sched = Scheduler::with_queue_limit(engine, 1, 2);
        let params = GenParams { max_new_tokens: 4, ..Default::default() };
        let a = sched.try_submit_channel("a", params.clone());
        let b = sched.try_submit_channel("b", params.clone());
        assert!(a.is_some() && b.is_some());
        let rejected = sched.try_submit_channel("c", params.clone());
        assert!(rejected.is_none(), "third submission must hit the cap");
        assert_eq!(sched.metrics().admission_rejections_total.value(), 1.0);
        // the cap does not apply to the unbounded paths
        sched.submit_ids(vec![3], params);
        sched.run_until_idle().unwrap();
        assert_eq!(sched.stats().completed, 3);
        let text = sched.metrics().registry().render();
        assert!(
            text.contains("dqt_serve_admission_rejections_total 1\n"),
            "{text}"
        );
        assert!(text.contains("dqt_serve_completed_total 3\n"), "{text}");
    }

    /// The metrics bundle tracks the decode loop: steps, tokens, TTFT
    /// and request-latency observations all move after a run.
    #[test]
    fn metrics_move_with_the_decode_loop() {
        let engine = mock_engine(8, 64);
        let sched = Scheduler::new(engine, 4);
        let (_, rx) = sched.submit_channel("a", GenParams::default());
        sched.run_until_idle().unwrap();
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let m = sched.metrics();
        assert!(m.decode_steps_total.value() > 0.0);
        assert!(m.tokens_generated_total.value() > 0.0);
        assert!(m.tokens_processed_total.value() >= m.tokens_generated_total.value());
        assert_eq!(m.ttft_seconds.count(), 1);
        assert_eq!(m.request_seconds.count(), 1);
        assert!(m.decode_tokens_per_sec.value() > 0.0);
        assert_eq!(m.queue_depth.value(), 0.0);
        assert_eq!(m.active_sequences.value(), 0.0);
    }

    /// Finished requests leave a summary behind: TTFT observed, decode
    /// steps counted, finish reason recorded (the `/v1/stats`
    /// `recent_requests` payload).
    #[test]
    fn recent_request_summaries_surface_ttft_and_decode_steps() {
        let engine = mock_engine(16, 256);
        let sched = Scheduler::new(engine, 4);
        let params = GenParams { max_new_tokens: 3, ..Default::default() };
        let id = sched.submit_ids(vec![2, 3], params);
        sched.run_until_idle().unwrap();
        let rs = sched.recent_requests();
        assert_eq!(rs.len(), 1);
        let r = &rs[0];
        assert_eq!(r.id, id);
        assert!(r.ttft_ms.is_some(), "sampled requests must record TTFT");
        // 2-token prompt + 3 sampled tokens = 1 prefill-only step + 3
        // sampling steps ridden
        assert!(r.decode_steps >= 3, "decode_steps = {}", r.decode_steps);
        assert!(r.total_ms >= r.ttft_ms.unwrap());
        assert_eq!(r.finish, "length");
    }

    /// The summary ring holds the last [`RECENT_REQUESTS_CAP`] requests —
    /// oldest evicted, newest kept, order preserved.
    #[test]
    fn recent_requests_ring_is_bounded() {
        let engine = mock_engine(8, 64);
        let sched = Scheduler::new(engine, 4);
        let n = RECENT_REQUESTS_CAP + 8;
        for _ in 0..n {
            // empty prompt: finishes immediately, no decode loop needed
            sched.submit_ids(vec![], GenParams::default());
        }
        let rs = sched.recent_requests();
        assert_eq!(rs.len(), RECENT_REQUESTS_CAP);
        assert_eq!(rs[0].id, 8, "oldest 8 summaries evicted");
        assert_eq!(rs.last().unwrap().id, n as u64 - 1);
        for r in &rs {
            assert_eq!(r.ttft_ms, None);
            assert_eq!(r.decode_steps, 0);
        }
    }

    #[test]
    fn idle_scheduler_steps_zero() {
        let engine = mock_engine(8, 64);
        let sched = Scheduler::new(engine, 4);
        assert_eq!(sched.step().unwrap(), 0);
        assert_eq!(sched.run_until_idle().unwrap(), 0);
        sched.wait_for_work(Duration::from_millis(10)); // returns on timeout
    }
}
