//! `ServeMetrics`: the serving-path instrumentation bundle.
//!
//! One `Arc<ServeMetrics>` is created by the [`Scheduler`] (default-on)
//! and shared with the HTTP server; `GET /metrics` renders its registry.
//! Handles are the `obs` atomics, so recording from the decode loop's
//! locked phases is allocation-free. Names and units are the documented
//! contract in `docs/OBSERVABILITY.md` (pinned by `tests/obs_contract.rs`).
//!
//! [`Scheduler`]: super::scheduler::Scheduler

use std::sync::Arc;

use crate::obs::{Counter, Gauge, Histogram, Registry, TIME_BUCKETS};

/// Batch-size histogram bounds: powers of two up to the plausible
/// `--max-batch` range.
pub const BATCH_BUCKETS: [f64; 8] = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];

/// HTTP status codes pre-registered so their series render at zero.
const PRE_REGISTERED_CODES: [&str; 6] = ["200", "400", "404", "429", "500", "504"];

/// Serving metrics: queue/admission, latency, and decode throughput.
pub struct ServeMetrics {
    registry: Arc<Registry>,

    /// requests waiting for a batch slot
    pub queue_depth: Arc<Gauge>,
    /// sequences currently decoding (including the checked-out batch)
    pub active_sequences: Arc<Gauge>,
    pub requests_total: Arc<Counter>,
    pub completed_total: Arc<Counter>,
    /// submissions refused because the queue was at `--max-queue`
    pub admission_rejections_total: Arc<Counter>,
    /// rows per batched decode step
    pub batch_size: Arc<Histogram>,
    /// submission → first sampled token
    pub ttft_seconds: Arc<Histogram>,
    /// submission → finished generation
    pub request_seconds: Arc<Histogram>,
    pub decode_steps_total: Arc<Counter>,
    pub tokens_processed_total: Arc<Counter>,
    pub tokens_generated_total: Arc<Counter>,
    /// wall time inside batched model forwards
    pub decode_seconds_total: Arc<Counter>,
    /// cumulative tokens_processed / decode_seconds, mirrored from
    /// `SchedulerStats::decode_tokens_per_sec`
    pub decode_tokens_per_sec: Arc<Gauge>,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        let r = Arc::new(Registry::new());
        for code in PRE_REGISTERED_CODES {
            r.counter_with(
                "dqt_serve_http_responses_total",
                "HTTP responses sent, by status code.",
                &[("code", code)],
            );
        }
        ServeMetrics {
            queue_depth: r.gauge(
                "dqt_serve_queue_depth",
                "Requests queued and waiting for a batch slot.",
            ),
            active_sequences: r.gauge(
                "dqt_serve_active_sequences",
                "Sequences currently being decoded (including the checked-out batch).",
            ),
            requests_total: r.counter("dqt_serve_requests_total", "Generation requests accepted."),
            completed_total: r.counter(
                "dqt_serve_completed_total",
                "Generation requests finished (any finish reason, including errors).",
            ),
            admission_rejections_total: r.counter(
                "dqt_serve_admission_rejections_total",
                "Submissions rejected because the queue was at its --max-queue cap.",
            ),
            batch_size: r.histogram(
                "dqt_serve_batch_size",
                "Rows per batched decode step.",
                &BATCH_BUCKETS,
            ),
            ttft_seconds: r.histogram(
                "dqt_serve_ttft_seconds",
                "Time from submission to first sampled token (seconds).",
                &TIME_BUCKETS,
            ),
            request_seconds: r.histogram(
                "dqt_serve_request_seconds",
                "Time from submission to finished generation (seconds).",
                &TIME_BUCKETS,
            ),
            decode_steps_total: r.counter(
                "dqt_serve_decode_steps_total",
                "Batched decode steps issued.",
            ),
            tokens_processed_total: r.counter(
                "dqt_serve_tokens_processed_total",
                "Tokens pushed through the model (prefill + decode rows).",
            ),
            tokens_generated_total: r.counter(
                "dqt_serve_tokens_generated_total",
                "Tokens sampled and returned to requests.",
            ),
            decode_seconds_total: r.counter(
                "dqt_serve_decode_seconds_total",
                "Wall seconds inside batched model forwards.",
            ),
            decode_tokens_per_sec: r.gauge(
                "dqt_serve_decode_tokens_per_sec",
                "Cumulative decode throughput: tokens processed per second of model-forward wall time.",
            ),
            registry: r,
        }
    }

    /// The registry `GET /metrics` renders.
    pub fn registry(&self) -> Arc<Registry> {
        self.registry.clone()
    }

    /// Count one HTTP response by status code. Common codes are
    /// pre-registered; an unusual code registers its series on first use.
    pub fn on_http_response(&self, code: u16) {
        let text: &str = match code {
            200 => "200",
            400 => "400",
            404 => "404",
            429 => "429",
            500 => "500",
            504 => "504",
            _ => {
                let owned = code.to_string();
                self.registry
                    .counter_with(
                        "dqt_serve_http_responses_total",
                        "HTTP responses sent, by status code.",
                        &[("code", &owned)],
                    )
                    .inc();
                return;
            }
        };
        self.registry
            .counter_with(
                "dqt_serve_http_responses_total",
                "HTTP responses sent, by status code.",
                &[("code", text)],
            )
            .inc();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_render_with_pre_registered_codes() {
        let m = ServeMetrics::new();
        m.requests_total.inc();
        m.on_http_response(200);
        m.on_http_response(200);
        m.on_http_response(429);
        m.on_http_response(418); // unusual: registered on first use
        let text = m.registry().render();
        assert!(text.contains("dqt_serve_requests_total 1\n"), "{text}");
        assert!(
            text.contains("dqt_serve_http_responses_total{code=\"200\"} 2\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_serve_http_responses_total{code=\"429\"} 1\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_serve_http_responses_total{code=\"418\"} 1\n"),
            "{text}"
        );
        // pre-registered codes render even when untouched
        assert!(
            text.contains("dqt_serve_http_responses_total{code=\"504\"} 0\n"),
            "{text}"
        );
    }
}
