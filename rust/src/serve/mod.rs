//! Serving subsystem: ternary inference as a running system.
//!
//! The paper's deployment claim (§1, §A.2) is that DQT models support
//! inference directly from ternary weights; this module cashes that in as
//! a four-part serving stack over the [`crate::runtime::Decoder`] entry
//! point (KV-cached incremental decoding, decode-free on 2-bit packed
//! grids):
//!
//! * [`engine`]    — [`Engine`]: prompt encoding → prefill → sampled
//!                   decode → streamed detokenization, one call per
//!                   request.
//! * [`sampler`]   — [`Sampler`]: greedy / temperature / top-k / top-p,
//!                   drawn from the same counter-hash stream as the SR
//!                   kernels (`quant::sr::hash_u32`), so generations are
//!                   deterministic per request seed.
//! * [`scheduler`] — [`Scheduler`]: continuous batching. Requests are
//!                   admitted mid-flight, every active sequence advances
//!                   one token per batched decode step (prefill and
//!                   decode interleave in the same batch), finished
//!                   sequences are evicted immediately. Rows are
//!                   numerically independent, so batching never changes
//!                   a sequence's output.
//! * [`http`]      — [`Server`]: a zero-dependency HTTP/1.1 server on
//!                   `std::net::TcpListener` exposing `POST /v1/generate`,
//!                   `GET /healthz`, `GET /v1/stats` (JSON via the
//!                   in-tree `util::json`) and `GET /metrics` (Prometheus
//!                   text, the contract in `docs/OBSERVABILITY.md`).
//! * [`metrics`]   — [`ServeMetrics`]: the `obs`-backed instrumentation
//!                   bundle behind `/metrics` — queue depth, admission
//!                   rejections (`--max-queue` → HTTP 429), TTFT /
//!                   request-latency / batch-size histograms, decode
//!                   throughput.
//!
//! Serving memory is grid bytes + KV cache: the decode hot path performs
//! no f32 weight unpacking — every projection matmul goes through the
//! channel-parallel fused packed-ternary GEMM
//! (`kernels::ternary::gemm_nt` on the backend's thread pool) prepared
//! once at engine build, so batched decode scales with cores while
//! staying bitwise-deterministic across thread counts. The decode loop
//! parks on a condvar when idle and `/v1/stats` reports the active
//! thread count plus cumulative decode tokens/sec. See
//! `docs/SERVING.md` and `docs/PERFORMANCE.md`.

pub mod engine;
pub mod http;
pub mod metrics;
pub mod sampler;
pub mod scheduler;

pub use engine::{Engine, FinishReason, GenParams, Generation};
pub use http::Server;
pub use metrics::ServeMetrics;
pub use sampler::Sampler;
pub use scheduler::{Scheduler, SchedulerStats};
