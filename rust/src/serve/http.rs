//! Zero-dependency HTTP/1.1 inference server on `std::net::TcpListener`.
//!
//! Hand-rolled request parsing (request line + headers + Content-Length
//! body), JSON via the in-tree `util::json`, one thread per connection,
//! one decode-loop thread driving the continuous-batching scheduler.
//!
//! Endpoints:
//!
//! * `GET /healthz`       → `{"status":"ok", ...}` — liveness + model info
//! * `GET /v1/stats`      → scheduler counters (tokens/sec bookkeeping)
//! * `GET /metrics`       → Prometheus text exposition (the `obs`
//!   registry behind [`ServeMetrics`]: queue depth, TTFT, batch-size and
//!   latency histograms, decode throughput — see `docs/OBSERVABILITY.md`)
//! * `POST /v1/generate`  → request `{"prompt": "...", "max_new_tokens"?,
//!   "temperature"?, "top_k"?, "top_p"?, "seed"?}`, response `{"id",
//!   "text", "token_ids", "prompt_tokens", "gen_tokens",
//!   "finish_reason"}`; `429` when the scheduler queue is at its
//!   `--max-queue` cap
//!
//! The full schema is documented in `docs/SERVING.md`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs::METRICS_CONTENT_TYPE;
use crate::runtime::Decoder;
use crate::util::json::{parse, Value};

use super::engine::{Engine, GenParams};
use super::metrics::ServeMetrics;
use super::scheduler::Scheduler;

const MAX_HEADER_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 1024 * 1024;
/// Worst-case wait for a generation to schedule + decode.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(120);

/// The serving endpoint: a bound listener plus the shared scheduler.
pub struct Server {
    listener: TcpListener,
    scheduler: Arc<Scheduler>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:8080`; port 0 picks a free port) and
    /// wrap `engine` in a continuous-batching scheduler of width
    /// `max_batch` with an unbounded queue.
    pub fn bind(addr: &str, engine: Engine, max_batch: usize) -> Result<Server> {
        Self::bind_with(addr, engine, max_batch, 0)
    }

    /// [`Server::bind`] with an admission cap: once `max_queue` requests
    /// are waiting for a batch slot, `POST /v1/generate` returns 429
    /// instead of queueing (0 = unbounded).
    pub fn bind_with(
        addr: &str,
        engine: Engine,
        max_batch: usize,
        max_queue: usize,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let scheduler = Arc::new(Scheduler::with_queue_limit(
            Arc::new(engine),
            max_batch,
            max_queue,
        ));
        Ok(Server { listener, scheduler })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn scheduler(&self) -> Arc<Scheduler> {
        self.scheduler.clone()
    }

    /// Serve forever: spawns the decode loop, then one handler thread per
    /// connection. (Process lifetime is the server lifetime — kill the
    /// process to stop, as the smoke test does.)
    pub fn run(self) -> Result<()> {
        let sched = self.scheduler.clone();
        std::thread::spawn(move || decode_loop(&sched));
        for conn in self.listener.incoming() {
            match conn {
                Ok(stream) => {
                    let sched = self.scheduler.clone();
                    std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, &sched) {
                            eprintln!("serve: connection error: {e}");
                        }
                    });
                }
                Err(e) => eprintln!("serve: accept error: {e}"),
            }
        }
        Ok(())
    }
}

/// The single decode thread: batched steps while there is work, a true
/// condvar park while idle — `Scheduler::park_until_work` blocks with no
/// poll interval, so an idle server burns no CPU and a submission wakes
/// the loop immediately. Step errors are logged and already failed the
/// affected requests (the scheduler evicts them with
/// `finish_reason = "error"`); the short sleep only rate-limits a
/// persistently failing model.
fn decode_loop(sched: &Scheduler) {
    loop {
        match sched.step() {
            Ok(0) => {
                // idle moment: persist any buffered trace spans before
                // parking (no-op unless `--trace-out` is set), so a
                // long-lived server's trace file stays current without a
                // flush on the request path
                crate::obs::trace::flush_if_dirty();
                sched.park_until_work()
            }
            Ok(_) => {}
            Err(e) => {
                eprintln!("serve: decode step failed: {e}");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn handle_conn(mut stream: TcpStream, sched: &Scheduler) -> Result<()> {
    let metrics = sched.metrics();
    let metrics = &*metrics;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            return respond(&mut stream, metrics, 400, &error_json(&e));
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let engine = sched.engine();
            let body = Value::obj()
                .set("status", "ok")
                .set("vocab_size", engine.decoder().vocab_size())
                .set("max_positions", engine.decoder().max_positions())
                .set("weight_bytes", engine.decoder().weight_bytes())
                .set(
                    "kv_bytes_per_position",
                    engine.decoder().kv_bytes_per_position(),
                )
                .set("packed_projections", engine.decoder().packed_projections())
                .set("n_projections", engine.decoder().n_projections())
                .set("threads", engine.decoder().threads())
                .set("precision", engine.decoder().precision().as_str())
                .set("pending", sched.pending());
            respond(&mut stream, metrics, 200, &body)
        }
        ("GET", "/metrics") => {
            let text = metrics.registry().render();
            respond_text(&mut stream, metrics, 200, METRICS_CONTENT_TYPE, &text)
        }
        ("GET", "/v1/stats") => {
            let st = sched.stats();
            let body = Value::obj()
                .set("submitted", st.submitted)
                .set("completed", st.completed)
                .set("steps", st.steps)
                .set("tokens_processed", st.tokens_processed)
                .set("tokens_generated", st.tokens_generated)
                .set("peak_batch", st.peak_batch)
                // configuration attribution: kernel threads, numeric tier
                // + cumulative decode throughput, so recorded numbers are
                // comparable
                .set("threads", sched.engine().decoder().threads())
                .set("precision", sched.engine().decoder().precision().as_str())
                .set("decode_ns", st.decode_ns)
                .set("decode_tokens_per_sec", st.decode_tokens_per_sec())
                .set("pending", sched.pending());
            // per-request span summaries (oldest first): the serve-side
            // request hierarchy folded to TTFT / decode-step counts
            let recent = Value::Arr(
                sched
                    .recent_requests()
                    .into_iter()
                    .map(|r| {
                        Value::obj()
                            .set("id", r.id)
                            .set(
                                "ttft_ms",
                                r.ttft_ms.map(Value::from).unwrap_or(Value::Null),
                            )
                            .set("decode_steps", r.decode_steps)
                            .set("total_ms", r.total_ms)
                            .set("finish", r.finish)
                    })
                    .collect(),
            );
            let body = body.set("recent_requests", recent);
            respond(&mut stream, metrics, 200, &body)
        }
        ("POST", "/v1/generate") => {
            let (prompt, params) = match parse_generate(&req.body) {
                Ok(pp) => pp,
                Err(e) => return respond(&mut stream, metrics, 400, &error_json(&e)),
            };
            let Some((_, rx)) = sched.try_submit_channel(&prompt, params) else {
                return respond(
                    &mut stream,
                    metrics,
                    429,
                    &error_json("queue full: the scheduler is at its --max-queue cap"),
                );
            };
            match rx.recv_timeout(REQUEST_TIMEOUT) {
                Ok((id, gen)) => {
                    let ids =
                        Value::Arr(gen.token_ids.iter().map(|&t| Value::from(t)).collect());
                    let body = Value::obj()
                        .set("id", id)
                        .set("text", gen.text.as_str())
                        .set("token_ids", ids)
                        .set("prompt_tokens", gen.prompt_tokens)
                        .set("gen_tokens", gen.token_ids.len())
                        .set("finish_reason", gen.finish.as_str());
                    let code = if gen.finish == super::FinishReason::Error {
                        500
                    } else {
                        200
                    };
                    respond(&mut stream, metrics, code, &body)
                }
                Err(_) => respond(
                    &mut stream,
                    metrics,
                    504,
                    &error_json("generation timed out in the scheduler"),
                ),
            }
        }
        _ => respond(
            &mut stream,
            metrics,
            404,
            &error_json(&format!("no route {} {}", req.method, req.path)),
        ),
    }
}

fn parse_generate(body: &[u8]) -> std::result::Result<(String, GenParams), String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let v = parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let prompt = v
        .get("prompt")
        .and_then(|p| p.as_str())
        .ok_or_else(|| "missing string field \"prompt\"".to_string())?
        .to_string();
    let d = GenParams::default();
    let params = GenParams {
        max_new_tokens: v
            .get("max_new_tokens")
            .and_then(|x| x.as_usize())
            .unwrap_or(d.max_new_tokens),
        temperature: v
            .get("temperature")
            .and_then(|x| x.as_f64())
            .map(|x| x as f32)
            .unwrap_or(d.temperature),
        top_k: v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(d.top_k),
        top_p: v
            .get("top_p")
            .and_then(|x| x.as_f64())
            .map(|x| x as f32)
            .unwrap_or(d.top_p),
        seed: v
            .get("seed")
            .and_then(|x| x.as_u64())
            .map(|x| x as u32)
            .unwrap_or(d.seed),
    };
    Ok((prompt, params))
}

fn error_json(msg: &str) -> Value {
    Value::obj().set("error", msg)
}

/// Parse one HTTP/1.1 request: request line, headers (only
/// Content-Length is honored), then exactly Content-Length body bytes.
fn read_request(stream: &mut TcpStream) -> std::result::Result<Request, String> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    let header_end = loop {
        if let Some(p) = find_subslice(&buf, b"\r\n\r\n") {
            break p;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err("headers too large".into());
        }
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed before headers completed".into());
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head =
        std::str::from_utf8(&buf[..header_end]).map_err(|_| "non-UTF-8 headers".to_string())?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(format!("malformed request line {request_line:?}"));
    }
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, val)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = val
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad content-length {val:?}"))?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(format!("body of {content_length} bytes exceeds the limit"));
    }
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-body".into());
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(Request { method, path, body })
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack
        .windows(needle.len())
        .position(|w| w == needle)
}

fn respond(stream: &mut TcpStream, metrics: &ServeMetrics, code: u16, body: &Value) -> Result<()> {
    respond_text(stream, metrics, code, "application/json", &body.to_string())
}

fn respond_text(
    stream: &mut TcpStream,
    metrics: &ServeMetrics,
    code: u16,
    content_type: &str,
    text: &str,
) -> Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        504 => "Gateway Timeout",
        _ => "Unknown",
    };
    metrics.on_http_response(code);
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        text.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(text.as_bytes())?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate_defaults_and_overrides() {
        let (p, g) = parse_generate(br#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(p, "hi");
        assert_eq!(g.max_new_tokens, GenParams::default().max_new_tokens);
        assert_eq!(g.temperature, 0.0);
        let (_, g) = parse_generate(
            br#"{"prompt": "x", "max_new_tokens": 7, "temperature": 1.5, "top_k": 3, "top_p": 0.9, "seed": 42}"#,
        )
        .unwrap();
        assert_eq!(g.max_new_tokens, 7);
        assert!((g.temperature - 1.5).abs() < 1e-6);
        assert_eq!(g.top_k, 3);
        assert!((g.top_p - 0.9).abs() < 1e-6);
        assert_eq!(g.seed, 42);
    }

    #[test]
    fn parse_generate_rejects_garbage() {
        assert!(parse_generate(b"not json").is_err());
        assert!(parse_generate(br#"{"no_prompt": 1}"#).is_err());
        assert!(parse_generate(br#"{"prompt": 5}"#).is_err());
    }

    #[test]
    fn find_subslice_works() {
        assert_eq!(find_subslice(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subslice(b"abcd", b"\r\n\r\n"), None);
    }
}
