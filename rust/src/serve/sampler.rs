//! Token sampling: greedy, temperature, top-k and top-p (nucleus),
//! drawn from the counter-hash PRNG the SR kernels share
//! (`quant::sr::uniform01`), so a generation is a pure function of
//! `(weights, prompt, seed)` — reproducible across machines and across
//! batch compositions.

use crate::quant::sr::uniform01;

use super::engine::GenParams;

/// One request's sampling state: the knobs plus the PRNG cursor (one
/// uniform draw per sampled token).
pub struct Sampler {
    /// 0 (or below) = greedy argmax
    pub temperature: f32,
    /// keep only the k highest-logit candidates (0 = disabled)
    pub top_k: usize,
    /// nucleus: smallest probability mass ≥ top_p (≥ 1.0 = disabled)
    pub top_p: f32,
    seed: u32,
    counter: u32,
}

impl Sampler {
    pub fn new(p: &GenParams) -> Sampler {
        Sampler {
            temperature: p.temperature,
            top_k: p.top_k,
            top_p: p.top_p,
            seed: p.seed,
            counter: 0,
        }
    }

    /// Sample one token id from `logits`. Greedy when temperature ≤ 0;
    /// otherwise softmax-with-temperature over the top-k set, truncated
    /// to the top-p nucleus, inverse-CDF'd with the next uniform from the
    /// `(counter, seed)` hash stream. Ties break toward the lower id, so
    /// the choice is deterministic even with equal logits.
    ///
    /// Cost: plain temperature sampling is two O(V) passes with no
    /// allocation of candidate order; top-k uses a partial selection
    /// (`select_nth_unstable_by`) and only sorts the k survivors; a full
    /// sort happens only for top-p without top-k (nucleus needs a global
    /// order). This keeps per-token host work far below the decode GEMV.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        assert!(!logits.is_empty(), "sampling from empty logits");
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        let u = uniform01(self.counter, self.seed);
        self.counter = self.counter.wrapping_add(1);
        let no_top_k = self.top_k == 0 || self.top_k >= logits.len();

        // fast path: no candidate ordering needed at all
        if no_top_k && self.top_p >= 1.0 {
            let mut mx = f32::NEG_INFINITY;
            for &v in logits {
                mx = mx.max(v);
            }
            let mut sum = 0f32;
            for &v in logits {
                sum += ((v - mx) / self.temperature).exp();
            }
            let mut acc = 0f32;
            for (i, &v) in logits.iter().enumerate() {
                acc += ((v - mx) / self.temperature).exp() / sum;
                if u < acc {
                    return i;
                }
            }
            return logits.len() - 1;
        }

        // candidates by logit descending (index ascending on ties makes
        // the order — and therefore the draw — fully deterministic)
        let by_logit_desc = |a: &usize, b: &usize| {
            logits[*b]
                .partial_cmp(&logits[*a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        };
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if !no_top_k {
            // partial selection: top_k survivors, then sort only those
            let _ = idx.select_nth_unstable_by(self.top_k - 1, by_logit_desc);
            idx.truncate(self.top_k);
        }
        idx.sort_by(by_logit_desc);

        // softmax with temperature over the kept set
        let mx = logits[idx[0]];
        let mut probs: Vec<f32> = idx
            .iter()
            .map(|&i| ((logits[i] - mx) / self.temperature).exp())
            .collect();
        let sum: f32 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }

        // nucleus: smallest prefix whose mass reaches top_p
        if self.top_p < 1.0 {
            let mut acc = 0f32;
            let mut keep = probs.len();
            for (i, &p) in probs.iter().enumerate() {
                acc += p;
                if acc >= self.top_p {
                    keep = i + 1;
                    break;
                }
            }
            idx.truncate(keep);
            probs.truncate(keep);
            let s: f32 = probs.iter().sum();
            for p in probs.iter_mut() {
                *p /= s;
            }
        }

        // inverse CDF
        let mut acc = 0f32;
        for (&i, &p) in idx.iter().zip(probs.iter()) {
            acc += p;
            if u < acc {
                return i;
            }
        }
        *idx.last().unwrap()
    }
}

fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(temperature: f32, top_k: usize, top_p: f32, seed: u32) -> GenParams {
        GenParams {
            temperature,
            top_k,
            top_p,
            seed,
            ..GenParams::default()
        }
    }

    #[test]
    fn greedy_is_argmax_with_low_index_ties() {
        let mut s = Sampler::new(&params(0.0, 0, 1.0, 9));
        assert_eq!(s.sample(&[0.1, 3.0, -1.0, 3.0]), 1);
        assert_eq!(s.sample(&[5.0, 5.0]), 0);
    }

    #[test]
    fn same_seed_same_draws_different_seed_differs() {
        let logits: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();
        let draw = |seed: u32| -> Vec<usize> {
            let mut s = Sampler::new(&params(1.0, 0, 1.0, seed));
            (0..64).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(draw(5), draw(5));
        assert_ne!(draw(5), draw(6));
    }

    /// uniform01(0, 0) == 0 is pinned in `quant::sr`, so the first draw
    /// of a seed-0 sampler over equal logits lands on the lowest index —
    /// a cross-platform golden value for the sampling stream.
    #[test]
    fn pinned_first_draw_seed_zero() {
        let mut s = Sampler::new(&params(1.0, 0, 1.0, 0));
        assert_eq!(s.sample(&[1.0, 1.0, 1.0, 1.0]), 0);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [0.0f32, 1.0, 2.0, 3.0, 4.0];
        let mut s = Sampler::new(&params(2.0, 2, 1.0, 3));
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 4 || t == 3, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn tiny_top_p_degenerates_to_argmax() {
        let logits = [0.0f32, 1.0, 5.0, 2.0];
        let mut s = Sampler::new(&params(1.0, 0, 1e-6, 7));
        for _ in 0..50 {
            assert_eq!(s.sample(&logits), 2);
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = [0.0f32, 0.1, 0.2, 0.3];
        let mut s = Sampler::new(&params(50.0, 0, 1.0, 11));
        let mut seen = [false; 4];
        for _ in 0..400 {
            seen[s.sample(&logits)] = true;
        }
        assert!(seen.iter().all(|&x| x), "{seen:?}");
    }

    #[test]
    fn unbiased_at_temperature_one() {
        // two-way 73/27 split: empirical frequency must track the softmax
        let logits = [1.0f32, 0.0];
        let p0 = (1f32.exp()) / (1f32.exp() + 1.0);
        let mut s = Sampler::new(&params(1.0, 0, 1.0, 13));
        let n = 20_000;
        let hits = (0..n).filter(|_| s.sample(&logits) == 0).count();
        let freq = hits as f32 / n as f32;
        assert!((freq - p0).abs() < 0.02, "freq {freq} vs p {p0}");
    }
}
