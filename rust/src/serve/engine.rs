//! The generation engine: prompt → prefill → sampled decode → text.
//!
//! An [`Engine`] binds a prepared [`Decoder`] (weights resident in serving
//! form — 2-bit packed grids for ternary projections) to a tokenizer. It
//! is immutable and `Sync`: every request gets its own KV cache and
//! sampler, so one engine serves any number of concurrent sequences (the
//! scheduler batches them; `generate` here is the one-shot convenience
//! path the CLI uses).

use anyhow::Result;

use crate::data::tokenizer::{Tokenizer, BOS_ID};
use crate::obs::trace;
use crate::runtime::{Decoder, DecoderCache, State, VariantRuntime};

use super::sampler::Sampler;

/// Per-request generation parameters (the `POST /v1/generate` knobs).
#[derive(Clone, Debug)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// 0 = greedy
    pub temperature: f32,
    /// 0 = disabled
    pub top_k: usize,
    /// ≥ 1.0 = disabled
    pub top_p: f32,
    /// seeds the sampler's hash stream — generations are deterministic
    /// per (checkpoint, prompt, seed)
    pub seed: u32,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            max_new_tokens: 48,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
        }
    }
}

/// Why a generation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// sampled the EOS/document-separator id
    Eos,
    /// produced `max_new_tokens`
    Length,
    /// hit the model's trained context length
    CacheFull,
    /// the decode step failed (the error is logged server-side)
    Error,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::Length => "length",
            FinishReason::CacheFull => "cache_full",
            FinishReason::Error => "error",
        }
    }
}

/// One finished generation.
#[derive(Clone, Debug)]
pub struct Generation {
    /// prompt ids actually fed (after BOS prepend + left truncation)
    pub prompt_tokens: usize,
    /// generated ids, including a terminal EOS when `finish == Eos`
    pub token_ids: Vec<i32>,
    /// generated text (EOS decodes to nothing)
    pub text: String,
    pub finish: FinishReason,
}

/// A model bound to a tokenizer, ready to generate.
pub struct Engine {
    decoder: Box<dyn Decoder>,
    tokenizer: Tokenizer,
    eos_id: i32,
}

impl Engine {
    /// Prepare the serving engine for `state` on `vrt`'s backend.
    /// `ternary` forces §A.2 deploy-time ternary projection (errors on
    /// variants without a ternary-inference entry).
    pub fn new(
        vrt: &VariantRuntime,
        state: &State,
        tokenizer: Tokenizer,
        ternary: bool,
    ) -> Result<Engine> {
        Ok(Engine::from_decoder(vrt.decoder(state, ternary)?, tokenizer))
    }

    /// Wrap an already-built decoder (tests, custom backends). The EOS id
    /// is the tokenizer's BOS/document-separator — the only "document
    /// ends here" signal the training stream contains.
    pub fn from_decoder(decoder: Box<dyn Decoder>, tokenizer: Tokenizer) -> Engine {
        Engine {
            decoder,
            tokenizer,
            eos_id: BOS_ID,
        }
    }

    pub fn decoder(&self) -> &dyn Decoder {
        self.decoder.as_ref()
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn eos_id(&self) -> i32 {
        self.eos_id
    }

    pub fn max_positions(&self) -> usize {
        self.decoder.max_positions()
    }

    /// Encode a prompt for generation: BOS (document start) + BPE ids,
    /// left-truncated to leave at least one position for decoding.
    pub fn prompt_ids(&self, prompt: &str) -> Vec<i32> {
        let mut ids = vec![self.eos_id];
        ids.extend(self.tokenizer.encode(prompt));
        let cap = self.decoder.max_positions().saturating_sub(1).max(1);
        if ids.len() > cap {
            ids.drain(..ids.len() - cap);
        }
        ids
    }

    /// One-shot generation from a text prompt (prefill + decode loop on a
    /// fresh cache). For concurrent serving use [`super::Scheduler`].
    pub fn generate(&self, prompt: &str, params: &GenParams) -> Result<Generation> {
        self.generate_ids(self.prompt_ids(prompt), params)
    }

    /// One-shot generation from pre-tokenized ids.
    pub fn generate_ids(&self, prompt: Vec<i32>, params: &GenParams) -> Result<Generation> {
        // one serve.request span per one-shot generation, with the same
        // prefill/decode/sample/detokenize children the scheduler emits
        // (all no-ops unless `--trace-out` is set)
        let _req_sp = trace::span("serve", trace::names::SERVE_REQUEST);
        let mut cache = self.decoder.new_cache();
        let mut logits = Vec::new();
        {
            let _sp =
                trace::span_arg("serve", trace::names::SERVE_PREFILL, "tokens", prompt.len() as u64);
            for &t in &prompt {
                logits = self.decoder.step(cache.as_mut(), t)?;
            }
        }
        let mut sampler = Sampler::new(params);
        let mut stream = self.tokenizer.decode_stream();
        let mut out = Vec::new();
        let mut text = String::new();
        let finish = if params.max_new_tokens == 0 || logits.is_empty() {
            FinishReason::Length
        } else {
            loop {
                let next = {
                    let _sp = trace::span("serve", trace::names::SERVE_SAMPLE);
                    sampler.sample(&logits) as i32
                };
                out.push(next);
                if next == self.eos_id {
                    break FinishReason::Eos;
                }
                {
                    let _sp = trace::span("serve", trace::names::SERVE_DETOKENIZE);
                    text.push_str(&stream.push(next));
                }
                if out.len() >= params.max_new_tokens {
                    break FinishReason::Length;
                }
                if cache.position() >= self.decoder.max_positions() {
                    break FinishReason::CacheFull;
                }
                let _sp = trace::span_arg("serve", trace::names::SERVE_DECODE, "rows", 1);
                logits = self.decoder.step(cache.as_mut(), next)?;
            }
        };
        {
            let _sp = trace::span("serve", trace::names::SERVE_DETOKENIZE);
            text.push_str(&stream.finish());
        }
        Ok(Generation {
            prompt_tokens: prompt.len(),
            token_ids: out,
            text,
            finish,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_reason_strings() {
        assert_eq!(FinishReason::Eos.as_str(), "eos");
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::CacheFull.as_str(), "cache_full");
        assert_eq!(FinishReason::Error.as_str(), "error");
    }

    #[test]
    fn default_params_are_greedy() {
        let p = GenParams::default();
        assert_eq!(p.temperature, 0.0);
        assert!(p.max_new_tokens > 0);
        assert_eq!(p.top_k, 0);
        assert_eq!(p.top_p, 1.0);
    }
}
