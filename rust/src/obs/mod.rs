//! Observability plane: metrics registry, `/metrics` endpoints, and
//! live step streaming.
//!
//! Three zero-dependency pieces, all documented as a contract in
//! `docs/OBSERVABILITY.md`:
//!
//! * [`registry`] — a Prometheus-text-format registry (counters, gauges,
//!   fixed-bucket histograms) whose handles are `Arc`-shared atomics:
//!   hot-path recording is lock-free and allocation-free, and rendered
//!   output is deterministic for deterministic input.
//! * [`http`] — [`MetricsServer`], a tiny `GET /metrics` listener for
//!   processes with no HTTP surface of their own (train runs, the dist
//!   coordinator and workers). The serve subsystem instead mounts
//!   `/metrics` on its existing server (`serve::http`), backed by
//!   `serve::ServeMetrics`.
//! * [`stream`] — length-prefixed [`StreamFrame`]s pushed over TCP by a
//!   [`Publisher`] (`--watch-addr`) and tailed by [`stream::watch`]
//!   (`repro watch --join ADDR`): one frame per optimizer step, so a
//!   live run's loss curve can be followed from another terminal.
//! * [`trace`] — the span tracer (`--trace-out trace.json`): RAII-guard
//!   phase spans folded into a per-phase profile and exported as Chrome
//!   trace-event JSON. Off by default; one atomic load per span site
//!   when disabled.
//! * [`quant`] — quantization-health telemetry: per-layer SR/grid
//!   introspection recorded inside the optimizer pass, aggregated into
//!   per-layer-labeled metrics, a `QuantHealth` stream frame,
//!   `quant_health.json`, and the three documented anomaly verdicts.
//!
//! [`TrainObs`] bundles the training/distributed metrics and the
//! publisher behind one handle that rides through `Trainer` the way
//! `kernels::Pool` does — default-on, and inert (pure atomics) unless a
//! metrics or watch address is configured.

pub mod http;
pub mod quant;
pub mod registry;
pub mod stream;
pub mod trace;
pub mod train;

pub use http::{MetricsServer, METRICS_CONTENT_TYPE};
pub use quant::{QuantHealth, QuantStepRecord};
pub use registry::{Counter, Gauge, Histogram, Registry};
pub use stream::{Publisher, StreamFrame};
pub use train::{TrainObs, TIME_BUCKETS};
