//! Quantization-health telemetry: per-layer introspection of the §3
//! stochastic-rounding projection.
//!
//! The optimizer already walks every grid tensor once per step
//! (`runtime::native::optim::apply_updates`); [`LayerStep::record`]
//! piggybacks on that pass and is strictly read-only with respect to
//! training state — it observes `(w_old, w_new, s_new, g)` and touches
//! nothing else, so the bitwise-determinism contracts
//! (`rust/tests/parallel_determinism.rs`, `rust/tests/dist.rs`) hold
//! with recording on. The hot path is allocation-free: the trainer
//! pre-sizes one [`QuantStepRecord`] slot per grid tensor from the
//! manifest at run start and resets it in place every step.
//!
//! Per-run aggregation ([`QuantHealth`]) adds the derived signals the
//! paper's stability story needs per layer: flip rate, update magnitude
//! in grid-step units, level-occupancy histogram, scale drift,
//! saturation, and an oscillation score — plus the three documented
//! anomaly verdicts (dead layer / saturation / oscillation; see
//! `docs/OBSERVABILITY.md` §Quant health). Thresholds are warnings,
//! never hard failures.

use std::path::Path;

use anyhow::Result;

use crate::util::json::{parse, Value};

/// Smoothing factor of the per-layer oscillation EMA (per step).
pub const QUANT_EMA_ALPHA: f64 = 0.1;
/// Dead-layer verdict: run-average flips per weight per step below this
/// while the layer still receives gradient.
pub const DEAD_FLIP_RATE: f64 = 1e-4;
/// Gradient-norm floor for the dead-layer verdict — below this the layer
/// is legitimately idle, not dead.
pub const DEAD_GNORM_FLOOR: f64 = 1e-12;
/// Saturation verdict: fraction of weights at the extreme grid levels.
/// Healthy ternary AbsMean sits near ~0.7; 0.9 flags absmax blowup or
/// zero-level collapse without tripping on normal runs.
pub const SATURATION_WARN: f64 = 0.9;
/// Oscillation verdict: EMA of sign-alternating flip steps above this.
pub const OSCILLATION_WARN: f64 = 0.6;
/// Level-occupancy bins: `[min level, other < 0, zero, other > 0, max
/// level]` (the middle two are empty for ternary grids).
pub const OCCUPANCY_BINS: usize = 5;

/// Raw single-step stats for one grid tensor, filled by one read-only
/// pass inside the optimizer. All counters reset every step.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerStep {
    /// weights in the tensor
    pub n: u64,
    /// weights whose stored value changed this step
    pub flips: u64,
    /// flips that moved the weight up / down the grid
    pub flips_up: u64,
    pub flips_down: u64,
    /// Σ (w_new − w_old)·s_new — signed update in grid-step units
    pub net_upd: f64,
    /// Σ |w_new − w_old|·s_new — absolute update in grid-step units
    pub abs_upd: f64,
    /// post-update level occupancy, binned per [`OCCUPANCY_BINS`]
    pub occupancy: [u64; OCCUPANCY_BINS],
    /// the stored `.s` companion after the step (inverse scale: grid
    /// level = round(w·s), grid step in weight units = 1/s)
    pub scale: f32,
    /// Σ g² over the tensor's post-clip gradient
    pub gsq: f64,
}

impl LayerStep {
    /// One pass over a grid tensor's update: `w_old` is the stored
    /// weights before the step, `w_new` the projected weights about to
    /// replace them, `s_new` the (inverse) scale being stored, `qn`/`qp`
    /// the grid's level range and `g` the post-clip gradient. Read-only
    /// on every argument; no allocation.
    pub fn record(&mut self, w_old: &[f32], w_new: &[f32], s_new: f32, qn: f32, qp: f32, g: &[f32]) {
        self.n = w_new.len() as u64;
        self.scale = s_new;
        for i in 0..w_new.len() {
            let (a, b) = (w_old[i], w_new[i]);
            if a != b {
                self.flips += 1;
                if b > a {
                    self.flips_up += 1;
                } else {
                    self.flips_down += 1;
                }
            }
            let d = ((b - a) * s_new) as f64;
            self.net_upd += d;
            self.abs_upd += d.abs();
            let lvl = (b * s_new).round();
            let bin = if lvl <= qn {
                0
            } else if lvl < 0.0 {
                1
            } else if lvl == 0.0 {
                2
            } else if lvl >= qp {
                4
            } else {
                3
            };
            self.occupancy[bin] += 1;
            let gv = g[i] as f64;
            self.gsq += gv * gv;
        }
    }
}

/// Pre-sized per-step scratch: one [`LayerStep`] slot per grid tensor,
/// in `Layout::trainables` grid order (== manifest grid-param order).
/// Built once at run start, reset in place every step.
#[derive(Clone, Debug, Default)]
pub struct QuantStepRecord {
    pub slots: Vec<LayerStep>,
}

impl QuantStepRecord {
    pub fn new(layers: usize) -> Self {
        QuantStepRecord {
            slots: vec![LayerStep::default(); layers],
        }
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Zero every slot without reallocating.
    pub fn reset(&mut self) {
        for s in &mut self.slots {
            *s = LayerStep::default();
        }
    }
}

/// Per-layer run aggregate: totals plus the latest step's derived
/// gauges, exactly the fields `quant_health.json` persists.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerHealth {
    /// manifest param name (e.g. `layers.0.wq`) — also the `layer`
    /// metric label value
    pub name: String,
    pub weights: u64,
    /// steps recorded for this layer
    pub steps: u64,
    pub flips_total: u64,
    /// latest step's flip count
    pub last_flips: u64,
    /// latest step's mean signed update per weight, grid-step units
    pub net_upd_grid_steps: f32,
    /// latest step's mean |update| per weight, grid-step units
    pub abs_upd_grid_steps: f32,
    /// latest step's level occupancy (sums to `weights`)
    pub occupancy: [u64; OCCUPANCY_BINS],
    /// latest stored `.s` (inverse scale)
    pub scale: f32,
    /// |s_t − s_{t−1}| / |s_{t−1}|, latest step (0 until two steps seen)
    pub scale_drift: f32,
    /// latest fraction of weights at the extreme grid levels
    pub saturation: f32,
    /// latest fraction of weights at the zero level
    pub zero_frac: f32,
    /// EMA of sign-alternating flip steps (see [`OSCILLATION_WARN`])
    pub oscillation: f32,
    /// latest √(Σg²) over the layer's post-clip gradient
    pub grad_norm: f32,
    prev_dir: i8,
    prev_scale: f32,
}

impl LayerHealth {
    /// Run-average flips per weight per step.
    pub fn flip_rate(&self) -> f64 {
        if self.weights == 0 || self.steps == 0 {
            return 0.0;
        }
        self.flips_total as f64 / (self.weights as f64 * self.steps as f64)
    }
}

/// Whole-run quantization-health aggregate: one [`LayerHealth`] per grid
/// tensor, fed a [`QuantStepRecord`] per optimizer step.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuantHealth {
    pub steps: u64,
    pub layers: Vec<LayerHealth>,
}

impl QuantHealth {
    /// `layers` = (manifest param name, element count) per grid tensor,
    /// in grid order.
    pub fn new(layers: &[(String, u64)]) -> Self {
        QuantHealth {
            steps: 0,
            layers: layers
                .iter()
                .map(|(name, n)| LayerHealth {
                    name: name.clone(),
                    weights: *n,
                    ..LayerHealth::default()
                })
                .collect(),
        }
    }

    /// Fold one step's raw stats into the per-layer aggregates.
    pub fn record_step(&mut self, rec: &QuantStepRecord) {
        self.steps += 1;
        for (l, s) in self.layers.iter_mut().zip(rec.slots.iter()) {
            if l.weights == 0 {
                l.weights = s.n;
            }
            let n = l.weights.max(1) as f64;
            l.steps += 1;
            l.last_flips = s.flips;
            l.flips_total += s.flips;
            l.net_upd_grid_steps = (s.net_upd / n) as f32;
            l.abs_upd_grid_steps = (s.abs_upd / n) as f32;
            l.occupancy = s.occupancy;
            l.saturation = ((s.occupancy[0] + s.occupancy[4]) as f64 / n) as f32;
            l.zero_frac = (s.occupancy[2] as f64 / n) as f32;
            l.grad_norm = s.gsq.sqrt() as f32;
            if l.steps > 1 && l.prev_scale.abs() > 0.0 {
                l.scale_drift = (s.scale - l.prev_scale).abs() / l.prev_scale.abs();
            }
            l.prev_scale = s.scale;
            l.scale = s.scale;
            // oscillation: does this step's net flip direction reverse
            // the previous flipping step's?
            let dir: i8 = match s.flips_up.cmp(&s.flips_down) {
                std::cmp::Ordering::Greater => 1,
                std::cmp::Ordering::Less => -1,
                std::cmp::Ordering::Equal => 0,
            };
            let event = if dir != 0 && l.prev_dir != 0 && dir == -l.prev_dir {
                1.0
            } else {
                0.0
            };
            l.oscillation =
                ((1.0 - QUANT_EMA_ALPHA) * l.oscillation as f64 + QUANT_EMA_ALPHA * event) as f32;
            if dir != 0 {
                l.prev_dir = dir;
            }
        }
    }

    /// The three documented anomaly verdicts, as warning lines (empty on
    /// a healthy run). Thresholds: [`DEAD_FLIP_RATE`],
    /// [`SATURATION_WARN`], [`OSCILLATION_WARN`].
    pub fn anomalies(&self) -> Vec<String> {
        let mut out = Vec::new();
        for l in &self.layers {
            if l.steps == 0 {
                continue;
            }
            if l.flip_rate() < DEAD_FLIP_RATE && l.grad_norm as f64 > DEAD_GNORM_FLOOR {
                out.push(format!(
                    "warn[dead-layer] {}: flip rate {:.2e}/weight/step over {} steps while grad norm {:.3e} > 0 — SR updates are not landing",
                    l.name,
                    l.flip_rate(),
                    l.steps,
                    l.grad_norm
                ));
            }
            if l.saturation as f64 > SATURATION_WARN {
                out.push(format!(
                    "warn[saturation] {}: {:.1}% of weights sit at the extreme grid levels (threshold {:.0}%)",
                    l.name,
                    l.saturation * 100.0,
                    SATURATION_WARN * 100.0
                ));
            }
            if l.oscillation as f64 > OSCILLATION_WARN {
                out.push(format!(
                    "warn[oscillation] {}: oscillation score {:.2} (threshold {:.2}) — flips are dominated by A↔B↔A reversals",
                    l.name, l.oscillation, OSCILLATION_WARN
                ));
            }
        }
        out
    }

    /// The per-layer table `repro watch` and `repro report --exp
    /// quant-health` both render, anomaly verdicts appended.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("quant health ({} steps recorded)\n", self.steps));
        out.push_str(&format!(
            "{:<18} {:>9} {:>8} {:>8} {:>7} {:>7} {:>10} {:>8} {:>6} {:>9}\n",
            "layer", "weights", "flip%/st", "|d|gs", "sat%", "zero%", "scale", "drift", "osc", "gnorm"
        ));
        for l in &self.layers {
            out.push_str(&format!(
                "{:<18} {:>9} {:>8.3} {:>8.4} {:>7.1} {:>7.1} {:>10.4} {:>8.5} {:>6.2} {:>9.4}\n",
                l.name,
                l.weights,
                l.flip_rate() * 100.0,
                l.abs_upd_grid_steps,
                l.saturation * 100.0,
                l.zero_frac * 100.0,
                l.scale,
                l.scale_drift,
                l.oscillation,
                l.grad_norm
            ));
        }
        for a in self.anomalies() {
            out.push_str(&a);
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Value {
        let layers = Value::Arr(
            self.layers
                .iter()
                .map(|l| {
                    Value::obj()
                        .set("name", l.name.as_str())
                        .set("weights", l.weights)
                        .set("steps", l.steps)
                        .set("flips_total", l.flips_total)
                        .set("flip_rate", l.flip_rate())
                        .set("last_flips", l.last_flips)
                        .set("net_upd_grid_steps", l.net_upd_grid_steps)
                        .set("abs_upd_grid_steps", l.abs_upd_grid_steps)
                        .set(
                            "occupancy",
                            Value::Arr(l.occupancy.iter().map(|&c| Value::from(c)).collect()),
                        )
                        .set("scale", l.scale)
                        .set("scale_drift", l.scale_drift)
                        .set("saturation", l.saturation)
                        .set("zero_frac", l.zero_frac)
                        .set("oscillation", l.oscillation)
                        .set("grad_norm", l.grad_norm)
                })
                .collect(),
        );
        let anomalies = Value::Arr(self.anomalies().into_iter().map(Value::from).collect());
        Value::obj()
            .set("version", 1u64)
            .set("steps", self.steps)
            .set("layers", layers)
            .set("anomalies", anomalies)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let u = |x: &Value, k: &str| x.get(k).and_then(|y| y.as_u64()).unwrap_or(0);
        let f = |x: &Value, k: &str| x.get(k).and_then(|y| y.as_f64()).unwrap_or(0.0) as f32;
        let layers = v
            .req("layers")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|x| {
                let mut occupancy = [0u64; OCCUPANCY_BINS];
                if let Some(a) = x.get("occupancy").and_then(|o| o.as_arr()) {
                    for (slot, val) in occupancy.iter_mut().zip(a.iter()) {
                        *slot = val.as_u64().unwrap_or(0);
                    }
                }
                LayerHealth {
                    name: x
                        .get("name")
                        .and_then(|n| n.as_str())
                        .unwrap_or("")
                        .to_string(),
                    weights: u(x, "weights"),
                    steps: u(x, "steps"),
                    flips_total: u(x, "flips_total"),
                    last_flips: u(x, "last_flips"),
                    net_upd_grid_steps: f(x, "net_upd_grid_steps"),
                    abs_upd_grid_steps: f(x, "abs_upd_grid_steps"),
                    occupancy,
                    scale: f(x, "scale"),
                    scale_drift: f(x, "scale_drift"),
                    saturation: f(x, "saturation"),
                    zero_frac: f(x, "zero_frac"),
                    oscillation: f(x, "oscillation"),
                    grad_norm: f(x, "grad_norm"),
                    prev_dir: 0,
                    prev_scale: 0.0,
                }
            })
            .collect();
        Ok(QuantHealth {
            steps: v.req("steps")?.as_u64().unwrap_or(0),
            layers,
        })
    }

    /// Write `quant_health.json` under `dir` (the run's out dir).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join("quant_health.json"),
            self.to_json().to_string_pretty(),
        )?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Self> {
        Self::from_json(&parse(&std::fs::read_to_string(
            dir.join("quant_health.json"),
        )?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(flips_up: u64, flips_down: u64, gsq: f64) -> QuantStepRecord {
        let mut r = QuantStepRecord::new(1);
        r.slots[0] = LayerStep {
            n: 100,
            flips: flips_up + flips_down,
            flips_up,
            flips_down,
            net_upd: flips_up as f64 - flips_down as f64,
            abs_upd: (flips_up + flips_down) as f64,
            occupancy: [30, 0, 40, 0, 30],
            scale: 4.0,
            gsq,
        };
        r
    }

    #[test]
    fn record_counts_flips_occupancy_and_update_magnitude() {
        // ternary grid, s = 2 (grid step 0.5), levels {-0.5, 0, 0.5}
        let w_old = [0.0f32, 0.5, -0.5, 0.5];
        let w_new = [0.5f32, 0.5, 0.0, -0.5];
        let g = [1.0f32, 0.0, 2.0, 2.0];
        let mut ls = LayerStep::default();
        ls.record(&w_old, &w_new, 2.0, -1.0, 1.0, &g);
        assert_eq!(ls.n, 4);
        assert_eq!(ls.flips, 3);
        assert_eq!(ls.flips_up, 2); // 0→0.5 and −0.5→0
        assert_eq!(ls.flips_down, 1); // 0.5→−0.5
        // net = (1 + 1 − 2) grid steps, abs = 4 grid steps
        assert!((ls.net_upd - 0.0).abs() < 1e-9);
        assert!((ls.abs_upd - 4.0).abs() < 1e-9);
        // levels of w_new: [+1, +1, 0, −1]
        assert_eq!(ls.occupancy, [1, 0, 1, 0, 2]);
        assert_eq!(ls.scale, 2.0);
        assert!((ls.gsq - 9.0).abs() < 1e-9);
    }

    #[test]
    fn step_record_resets_in_place() {
        let mut r = QuantStepRecord::new(2);
        r.slots[1].flips = 9;
        r.reset();
        assert_eq!(r.slots[1], LayerStep::default());
        assert_eq!(r.slots.len(), 2);
    }

    #[test]
    fn aggregate_derives_rates_saturation_and_drift() {
        let mut h = QuantHealth::new(&[("layers.0.wq".into(), 100)]);
        h.record_step(&step(6, 4, 4.0));
        let mut s2 = step(5, 5, 4.0);
        s2.slots[0].scale = 5.0;
        h.record_step(&s2);
        let l = &h.layers[0];
        assert_eq!(h.steps, 2);
        assert_eq!(l.flips_total, 20);
        assert!((l.flip_rate() - 0.1).abs() < 1e-9);
        assert!((l.saturation - 0.6).abs() < 1e-6);
        assert!((l.zero_frac - 0.4).abs() < 1e-6);
        assert!((l.scale_drift - 0.25).abs() < 1e-6);
        assert!((l.grad_norm - 2.0).abs() < 1e-6);
    }

    #[test]
    fn oscillation_fires_on_alternation_and_not_on_monotone_flips() {
        // positive: net flip direction reverses every step
        let mut h = QuantHealth::new(&[("layers.0.wq".into(), 100)]);
        for i in 0..30 {
            let s = if i % 2 == 0 { step(8, 2, 1.0) } else { step(2, 8, 1.0) };
            h.record_step(&s);
        }
        assert!(
            h.layers[0].oscillation as f64 > OSCILLATION_WARN,
            "{}",
            h.layers[0].oscillation
        );
        assert!(h
            .anomalies()
            .iter()
            .any(|a| a.contains("warn[oscillation]") && a.contains("layers.0.wq")));
        // negative: the same flip volume, always in one direction
        let mut h = QuantHealth::new(&[("layers.0.wq".into(), 100)]);
        for _ in 0..30 {
            h.record_step(&step(8, 2, 1.0));
        }
        assert!((h.layers[0].oscillation as f64) < OSCILLATION_WARN);
        assert!(!h.anomalies().iter().any(|a| a.contains("oscillation")));
    }

    #[test]
    fn dead_layer_fires_only_when_gradient_flows_but_flips_do_not() {
        // positive: gradient present, zero flips across the run
        let mut h = QuantHealth::new(&[("layers.1.wo".into(), 100)]);
        for _ in 0..10 {
            h.record_step(&step(0, 0, 1.0));
        }
        assert!(h
            .anomalies()
            .iter()
            .any(|a| a.contains("warn[dead-layer]") && a.contains("layers.1.wo")));
        // negative 1: flips landing → healthy
        let mut h = QuantHealth::new(&[("layers.1.wo".into(), 100)]);
        for _ in 0..10 {
            h.record_step(&step(3, 2, 1.0));
        }
        assert!(!h.anomalies().iter().any(|a| a.contains("dead-layer")));
        // negative 2: no flips but no gradient either → idle, not dead
        let mut h = QuantHealth::new(&[("layers.1.wo".into(), 100)]);
        for _ in 0..10 {
            h.record_step(&step(0, 0, 0.0));
        }
        assert!(!h.anomalies().iter().any(|a| a.contains("dead-layer")));
    }

    #[test]
    fn saturation_fires_above_threshold_only() {
        let mut h = QuantHealth::new(&[("layers.0.w_up".into(), 100)]);
        let mut s = step(5, 5, 1.0);
        s.slots[0].occupancy = [95, 0, 5, 0, 0];
        h.record_step(&s);
        assert!(h
            .anomalies()
            .iter()
            .any(|a| a.contains("warn[saturation]") && a.contains("layers.0.w_up")));
        // negative: healthy ternary occupancy (~60% extremes)
        let mut h = QuantHealth::new(&[("layers.0.w_up".into(), 100)]);
        h.record_step(&step(5, 5, 1.0));
        assert!(!h.anomalies().iter().any(|a| a.contains("saturation")));
    }

    #[test]
    fn json_roundtrip_and_save_load() {
        let mut h = QuantHealth::new(&[("layers.0.wq".into(), 100), ("layers.0.wk".into(), 100)]);
        let mut r = QuantStepRecord::new(2);
        r.slots[0] = step(6, 4, 4.0).slots[0];
        r.slots[1] = step(1, 1, 1.0).slots[0];
        h.record_step(&r);
        let back = QuantHealth::from_json(&h.to_json()).unwrap();
        assert_eq!(back.steps, h.steps);
        assert_eq!(back.layers.len(), 2);
        assert_eq!(back.layers[0].name, "layers.0.wq");
        assert_eq!(back.layers[0].flips_total, 10);
        assert_eq!(back.layers[0].occupancy, h.layers[0].occupancy);
        assert_eq!(back.layers[1].scale, 4.0);
        let dir = std::env::temp_dir().join("dqt_quant_health_test");
        h.save(&dir).unwrap();
        let loaded = QuantHealth::load(&dir).unwrap();
        assert_eq!(loaded.layers[0].flips_total, 10);
        assert!(dir.join("quant_health.json").is_file());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn render_table_lists_layers_and_verdicts() {
        let mut h = QuantHealth::new(&[("layers.0.wq".into(), 100)]);
        for _ in 0..5 {
            h.record_step(&step(0, 0, 1.0));
        }
        let t = h.render_table();
        assert!(t.contains("layers.0.wq"));
        assert!(t.contains("warn[dead-layer]"));
        assert!(t.contains("5 steps recorded"));
    }
}
