//! Prometheus-text-format metric registry — counters, gauges, histograms.
//!
//! Zero-dependency and deterministic by construction: families render in
//! registration order, series within a family render in label order, and
//! histogram bucket layouts are fixed at registration, so two registries
//! built and driven identically emit byte-identical exposition text (the
//! property the encoder tests pin). Handles ([`Counter`], [`Gauge`],
//! [`Histogram`]) are `Arc`-shared atomics, so hot-path recording is a
//! few relaxed atomic ops — no locks, no allocation — the same
//! "instrumentation behind a cheap handle" shape as `kernels::Pool`.
//!
//! The output is the Prometheus text exposition format v0.0.4: `# HELP` /
//! `# TYPE` comment lines, one sample per line, histogram series as
//! cumulative `_bucket{le="..."}` counts plus `_sum` and `_count`.
//! `docs/OBSERVABILITY.md` is the documented contract over every name
//! registered here (enforced by `tests/obs_contract.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A monotonically increasing sample (f64 stored as atomic bits).
/// Negative or NaN increments are ignored — counters only go up.
#[derive(Debug, Default)]
pub struct Counter {
    bits: AtomicU64,
}

impl Counter {
    fn new() -> Counter {
        Counter {
            bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn inc(&self) {
        self.add(1.0);
    }

    pub fn inc_by(&self, n: u64) {
        self.add(n as f64);
    }

    pub fn add(&self, d: f64) {
        if d.is_nan() || d < 0.0 {
            return;
        }
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self
                .bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn value(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// A sample that can go up and down (f64 stored as atomic bits).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge {
            bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn value(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// A fixed-bucket histogram. Bucket bounds are set at registration and
/// never change, so the rendered layout is deterministic; counts are
/// stored per bucket (non-cumulative) and summed cumulatively at render
/// time, as the exposition format requires.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// one slot per bound plus the final `+Inf` slot
    buckets: Vec<AtomicU64>,
    sum_bits: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        assert!(
            bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be finite (+Inf is implicit)"
        );
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: f64) {
        let v = if v.is_nan() { 0.0 } else { v };
        let i = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// `(upper bound, cumulative count)` per bucket, ending with the
    /// implicit `(+Inf, total)` bucket.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.buckets.len());
        let mut running = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            running += b.load(Ordering::Relaxed);
            let le = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((le, running));
        }
        out
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Clone)]
enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Series {
    labels: Vec<(String, String)>,
    instrument: Instrument,
}

struct Family {
    name: String,
    help: String,
    kind: Kind,
    series: Vec<Series>,
}

/// The metric registry: families in registration order, each holding one
/// series per distinct label set. Registration is idempotent — asking for
/// an already-registered `(name, labels)` returns the existing handle —
/// and a name collision across kinds panics at registration time (a
/// programmer error, never reachable from the hot path).
#[derive(Default)]
pub struct Registry {
    families: Mutex<Vec<Family>>,
}

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn escape_label_value(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render a sample value: integral values print without a fraction (the
/// common case for counts), everything else via the shortest `f64` form.
fn fmt_value(v: f64) -> String {
    if v.is_finite() && v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn fmt_le(le: f64) -> String {
    if le.is_infinite() {
        "+Inf".to_string()
    } else {
        format!("{le}")
    }
}

fn label_block(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_with(name, help, &[])
    }

    pub fn counter_with(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Counter> {
        match self.register(name, help, Kind::Counter, labels, || {
            Instrument::Counter(Arc::new(Counter::new()))
        }) {
            Instrument::Counter(c) => c,
            _ => unreachable!("registered as counter"),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.gauge_with(name, help, &[])
    }

    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.register(name, help, Kind::Gauge, labels, || {
            Instrument::Gauge(Arc::new(Gauge::new()))
        }) {
            Instrument::Gauge(g) => g,
            _ => unreachable!("registered as gauge"),
        }
    }

    /// Register a histogram with a fixed, strictly ascending bucket
    /// layout (`+Inf` is implicit).
    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Arc<Histogram> {
        match self.register(name, help, Kind::Histogram, &[], || {
            Instrument::Histogram(Arc::new(Histogram::new(bounds)))
        }) {
            Instrument::Histogram(h) => h,
            _ => unreachable!("registered as histogram"),
        }
    }

    fn register(
        &self,
        name: &str,
        help: &str,
        kind: Kind,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Instrument,
    ) -> Instrument {
        assert!(valid_name(name), "invalid metric name {name:?}");
        for (k, _) in labels {
            assert!(valid_name(k), "invalid label name {k:?} on {name:?}");
        }
        let labels: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        let mut fams = self.families.lock().unwrap();
        let fam = match fams.iter_mut().find(|f| f.name == name) {
            Some(f) => {
                assert!(
                    f.kind == kind,
                    "metric {name:?} registered as {} and {}",
                    f.kind.as_str(),
                    kind.as_str()
                );
                f
            }
            None => {
                fams.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    kind,
                    series: Vec::new(),
                });
                fams.last_mut().unwrap()
            }
        };
        if let Some(s) = fam.series.iter().find(|s| s.labels == labels) {
            return s.instrument.clone();
        }
        let instrument = make();
        fam.series.push(Series {
            labels,
            instrument: instrument.clone(),
        });
        instrument
    }

    /// Every registered family name, in registration order — the set the
    /// docs contract test checks against `docs/OBSERVABILITY.md`.
    pub fn metric_names(&self) -> Vec<String> {
        self.families
            .lock()
            .unwrap()
            .iter()
            .map(|f| f.name.clone())
            .collect()
    }

    /// Render the full registry in the Prometheus text exposition format.
    pub fn render(&self) -> String {
        let fams = self.families.lock().unwrap();
        let mut out = String::new();
        for f in fams.iter() {
            out.push_str("# HELP ");
            out.push_str(&f.name);
            out.push(' ');
            out.push_str(&escape_help(&f.help));
            out.push('\n');
            out.push_str("# TYPE ");
            out.push_str(&f.name);
            out.push(' ');
            out.push_str(f.kind.as_str());
            out.push('\n');
            let mut order: Vec<usize> = (0..f.series.len()).collect();
            order.sort_by(|&a, &b| f.series[a].labels.cmp(&f.series[b].labels));
            for i in order {
                let s = &f.series[i];
                match &s.instrument {
                    Instrument::Counter(c) => {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            f.name,
                            label_block(&s.labels),
                            fmt_value(c.value())
                        ));
                    }
                    Instrument::Gauge(g) => {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            f.name,
                            label_block(&s.labels),
                            fmt_value(g.value())
                        ));
                    }
                    Instrument::Histogram(h) => {
                        for (le, cum) in h.cumulative() {
                            let mut labels = s.labels.clone();
                            labels.push(("le".to_string(), fmt_le(le)));
                            out.push_str(&format!(
                                "{}_bucket{} {cum}\n",
                                f.name,
                                label_block(&labels)
                            ));
                        }
                        out.push_str(&format!(
                            "{}_sum{} {}\n",
                            f.name,
                            label_block(&s.labels),
                            fmt_value(h.sum())
                        ));
                        out.push_str(&format!(
                            "{}_count{} {}\n",
                            f.name,
                            label_block(&s.labels),
                            h.count()
                        ));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = Registry::new();
        let c = r.counter("c_total", "a counter");
        c.inc();
        c.inc_by(2);
        c.add(0.5);
        assert_eq!(c.value(), 3.5);
        // counters are monotonic: negative and NaN increments are ignored
        c.add(-10.0);
        c.add(f64::NAN);
        assert_eq!(c.value(), 3.5);
        let g = r.gauge("g", "a gauge");
        g.set(7.25);
        assert_eq!(g.value(), 7.25);
        g.set(-1.0);
        assert_eq!(g.value(), -1.0);
        let text = r.render();
        assert!(text.contains("# TYPE c_total counter\n"), "{text}");
        assert!(text.contains("c_total 3.5\n"), "{text}");
        assert!(text.contains("# TYPE g gauge\n"), "{text}");
        assert!(text.contains("g -1\n"), "{text}");
    }

    #[test]
    fn help_and_label_values_are_escaped() {
        let r = Registry::new();
        r.counter_with(
            "esc_total",
            "line one\nback\\slash",
            &[("path", "a\"b\\c\nd")],
        );
        let text = r.render();
        assert!(
            text.contains("# HELP esc_total line one\\nback\\\\slash\n"),
            "{text}"
        );
        assert!(
            text.contains("esc_total{path=\"a\\\"b\\\\c\\nd\"} 0\n"),
            "{text}"
        );
    }

    /// Manifest param names become `layer` label values verbatim (the
    /// quant-health convention); escaping must keep the exposition
    /// parseable even for hostile names, and leave normal dotted param
    /// names untouched.
    #[test]
    fn layer_label_values_escape_quotes_backslashes_and_newlines() {
        let r = Registry::new();
        r.gauge_with("dqt_train_quant_scale", "s", &[("layer", "layers.0.wq")])
            .set(4.0);
        r.gauge_with("dqt_train_quant_scale", "s", &[("layer", "odd\"layer\\name\nx")])
            .set(1.0);
        let text = r.render();
        assert!(
            text.contains("dqt_train_quant_scale{layer=\"layers.0.wq\"} 4\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_train_quant_scale{layer=\"odd\\\"layer\\\\name\\nx\"} 1\n"),
            "{text}"
        );
        // the escaped series still renders on a single line
        assert_eq!(text.lines().filter(|l| l.starts_with("dqt_train_quant_scale{")).count(), 2);
    }

    #[test]
    fn series_render_in_label_order_regardless_of_registration_order() {
        let r = Registry::new();
        r.counter_with("codes_total", "by code", &[("code", "504")]).inc();
        r.counter_with("codes_total", "by code", &[("code", "200")]).inc_by(3);
        r.counter_with("codes_total", "by code", &[("code", "404")]).inc_by(2);
        let text = r.render();
        let p200 = text.find("code=\"200\"").unwrap();
        let p404 = text.find("code=\"404\"").unwrap();
        let p504 = text.find("code=\"504\"").unwrap();
        assert!(p200 < p404 && p404 < p504, "{text}");
        // one HELP/TYPE header for the whole family
        assert_eq!(text.matches("# TYPE codes_total").count(), 1);
    }

    #[test]
    fn registration_is_idempotent_per_label_set() {
        let r = Registry::new();
        let a = r.counter_with("dup_total", "d", &[("k", "v")]);
        let b = r.counter_with("dup_total", "d", &[("k", "v")]);
        a.inc();
        b.inc();
        // same underlying atomic: both handles saw both increments
        assert_eq!(a.value(), 2.0);
        assert_eq!(
            r.render().matches("dup_total{k=\"v\"}").count(),
            1,
            "one series, not two"
        );
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_inf() {
        let r = Registry::new();
        let h = r.histogram("lat_seconds", "latency", &[0.1, 1.0]);
        for v in [0.05, 0.5, 5.0, 0.5] {
            h.observe(v);
        }
        // boundary values land in their own bucket (le is inclusive)
        h.observe(0.1);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 6.25).abs() < 1e-12);
        assert_eq!(
            h.cumulative(),
            vec![(0.1, 2), (1.0, 4), (f64::INFINITY, 5)]
        );
        let text = r.render();
        assert!(text.contains("lat_seconds_bucket{le=\"0.1\"} 2\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"1\"} 4\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"+Inf\"} 5\n"), "{text}");
        assert!(text.contains("lat_seconds_sum 6.25\n"), "{text}");
        assert!(text.contains("lat_seconds_count 5\n"), "{text}");
        // cumulative counts never decrease across ascending bounds
        let cum = h.cumulative();
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    /// A histogram with zero observations still renders a complete,
    /// well-formed series: every bucket at 0, sum 0, count 0.
    #[test]
    fn histogram_with_zero_observations_renders_empty_buckets() {
        let r = Registry::new();
        let h = r.histogram("idle_seconds", "never observed", &[0.5, 2.0]);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(
            h.cumulative(),
            vec![(0.5, 0), (2.0, 0), (f64::INFINITY, 0)]
        );
        let text = r.render();
        assert!(text.contains("idle_seconds_bucket{le=\"0.5\"} 0\n"), "{text}");
        assert!(text.contains("idle_seconds_bucket{le=\"2\"} 0\n"), "{text}");
        assert!(text.contains("idle_seconds_bucket{le=\"+Inf\"} 0\n"), "{text}");
        assert!(text.contains("idle_seconds_sum 0\n"), "{text}");
        assert!(text.contains("idle_seconds_count 0\n"), "{text}");
    }

    /// `le` is inclusive: a value exactly on a bound lands in that bound's
    /// bucket, not the next one — for every bound in the layout.
    #[test]
    fn histogram_boundary_values_land_in_their_own_bucket() {
        let r = Registry::new();
        let h = r.histogram("edge_seconds", "boundary landings", &[0.25, 1.0, 4.0]);
        h.observe(0.25);
        h.observe(1.0);
        h.observe(4.0);
        // per-bucket (non-cumulative) expectation: one landing each
        assert_eq!(
            h.cumulative(),
            vec![(0.25, 1), (1.0, 2), (4.0, 3), (f64::INFINITY, 3)]
        );
        // the next representable value past a bound spills over
        h.observe(0.25 + f64::EPSILON);
        assert_eq!(
            h.cumulative(),
            vec![(0.25, 1), (1.0, 3), (4.0, 4), (f64::INFINITY, 4)]
        );
    }

    /// Values beyond the last finite bound only move the implicit `+Inf`
    /// bucket, and the cumulative `+Inf` count always equals `_count` —
    /// including for infinite observations.
    #[test]
    fn histogram_overflow_accumulates_in_inf_bucket_only() {
        let r = Registry::new();
        let h = r.histogram("big_seconds", "overflow landings", &[1.0]);
        h.observe(100.0);
        h.observe(1e18);
        h.observe(f64::INFINITY);
        assert_eq!(h.cumulative(), vec![(1.0, 0), (f64::INFINITY, 3)]);
        assert_eq!(h.count(), 3);
        let inf_cum = h.cumulative().last().unwrap().1;
        assert_eq!(inf_cum, h.count(), "+Inf bucket must equal _count");
        let text = r.render();
        assert!(text.contains("big_seconds_bucket{le=\"1\"} 0\n"), "{text}");
        assert!(text.contains("big_seconds_bucket{le=\"+Inf\"} 3\n"), "{text}");
        assert!(text.contains("big_seconds_count 3\n"), "{text}");
    }

    #[test]
    fn render_is_deterministic_under_fixed_input() {
        let build = || {
            let r = Registry::new();
            r.counter("a_total", "a").inc_by(3);
            r.gauge("b", "b").set(1.5);
            let h = r.histogram("c_seconds", "c", &[0.01, 0.1, 1.0]);
            h.observe(0.02);
            h.observe(0.2);
            r.counter_with("d_total", "d", &[("k", "x")]).inc();
            r.render()
        };
        let one = build();
        assert_eq!(one, build(), "identical construction must render identically");
        let r = Registry::new();
        r.counter("a_total", "a");
        assert_eq!(r.render(), r.render(), "repeated renders are stable");
    }

    #[test]
    #[should_panic(expected = "invalid metric name")]
    fn invalid_metric_name_panics_at_registration() {
        Registry::new().counter("bad-name", "hyphens are not allowed");
    }

    #[test]
    #[should_panic(expected = "registered as")]
    fn kind_collision_panics_at_registration() {
        let r = Registry::new();
        r.counter("twice", "as counter");
        r.gauge("twice", "as gauge");
    }

    #[test]
    fn values_render_integral_or_shortest_float() {
        assert_eq!(fmt_value(3.0), "3");
        assert_eq!(fmt_value(0.0), "0");
        assert_eq!(fmt_value(3.5), "3.5");
        assert_eq!(fmt_value(-2.0), "-2");
        assert_eq!(fmt_le(f64::INFINITY), "+Inf");
        assert_eq!(fmt_le(0.25), "0.25");
    }
}
