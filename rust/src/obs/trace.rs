//! Span tracing & profiling: per-phase attribution of where wall time
//! goes inside a train step or a served request.
//!
//! Design, in the house style (zero dependencies, std only):
//!
//! * **One global tracer, off by default.** The only cost a span site
//!   pays while tracing is disabled is a single relaxed atomic load —
//!   [`span`] returns an inert guard without even reading the clock.
//! * **RAII guards** ([`Span`]): entering a phase is
//!   `let _sp = trace::span("train", names::TRAIN_STEP);` and the span
//!   ends when the guard drops — including during a panic unwind, so
//!   enter/exit always stay balanced.
//! * **Monotonic timestamps**: every event is measured with
//!   [`Instant`] against the tracer's epoch (the moment tracing was
//!   enabled); wall-clock time never appears, so traces are immune to
//!   clock steps.
//! * **Bounded ring**: finished spans land in a drop-oldest ring of
//!   [`RING_CAPACITY`] events behind a short mutex push; a runaway run
//!   degrades to losing the *oldest* events (counted, and reported in
//!   the export), never to unbounded memory.
//! * **Two sinks**: [`write_chrome_trace`] emits Chrome trace-event
//!   format JSON (`--trace-out trace.json`, loadable in
//!   `chrome://tracing` or Perfetto, with named per-thread tracks for
//!   kernel-pool workers), and [`profile`] folds the same events into a
//!   per-phase table (count / total / mean / p95 / % of wall) rendered
//!   by [`render_table`] at run end and by `report --exp profile`.
//!
//! Span names are a documented contract (`docs/OBSERVABILITY.md`
//! §Tracing), pinned by `rust/tests/trace_contract.rs` exactly like the
//! metric names — every name the code can record is listed in
//! [`names::ALL`].
//!
//! Observation is read-only: spans wrap calls and never touch the
//! arithmetic inside them, so the bitwise-determinism contracts
//! (`docs/PERFORMANCE.md`, `docs/DISTRIBUTED.md`) hold with tracing on
//! or off.

use std::collections::{BTreeMap, VecDeque};
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Finished spans buffered for export before drop-oldest kicks in
/// (~30 MB worst case; a 20-step smoke train records a few percent of
/// this).
pub const RING_CAPACITY: usize = 1 << 18;

/// The span-name contract. Everything the instrumentation can record
/// is a constant here; [`names::ALL`] is the pinned list the contract
/// test checks against `docs/OBSERVABILITY.md`.
pub mod names {
    /// One optimizer step, fetch to metrics, on the training thread.
    pub const TRAIN_STEP: &str = "train.step";
    /// Pulling the step's token batch from the data pipeline.
    pub const TRAIN_DATA_LOAD: &str = "train.data_load";
    /// The forward pass of the loss computation.
    pub const TRAIN_FORWARD: &str = "train.forward";
    /// The hand-written backward pass (head CE gradient to embeddings).
    pub const TRAIN_BACKWARD: &str = "train.backward";
    /// Optimizer + §3 stochastic-rounding update, all params.
    pub const TRAIN_OPTIMIZER: &str = "train.optimizer";
    /// The SR projection back onto the quantized grid, per grid tensor
    /// (nested inside `train.optimizer`).
    pub const TRAIN_SR_PROJECT: &str = "train.sr_project";
    /// Cross-rank gradient all-reduce (blocked wall time on this rank).
    pub const DIST_ALLREDUCE: &str = "dist.allreduce";
    /// Periodic packed-grid weight resync.
    pub const DIST_GRID_SYNC: &str = "dist.grid_sync";
    /// Per-layer RMSNorm (attention and MLP norms both record it).
    pub const FWD_RMSNORM: &str = "fwd.rmsnorm";
    /// Per-layer attention block: QKV projections, RoPE, scores, WO.
    pub const FWD_ATTENTION: &str = "fwd.attention";
    /// Per-layer SwiGLU MLP: gate/up projections, silu·up, down.
    pub const FWD_SWIGLU: &str = "fwd.swiglu";
    /// Final norm + tied vocabulary head.
    pub const FWD_HEAD: &str = "fwd.head";
    /// One served request, submission to eviction.
    pub const SERVE_REQUEST: &str = "serve.request";
    /// Submission → first checkout into a decode batch.
    pub const SERVE_QUEUE_WAIT: &str = "serve.queue_wait";
    /// First checkout → first sampled token (prompt consumption).
    pub const SERVE_PREFILL: &str = "serve.prefill";
    /// One batched decode step (model forward for all checked-out rows).
    pub const SERVE_DECODE: &str = "serve.decode";
    /// Sampling next tokens from the step's logits.
    pub const SERVE_SAMPLE: &str = "serve.sample";
    /// Incremental detokenization of sampled tokens.
    pub const SERVE_DETOKENIZE: &str = "serve.detokenize";
    /// One kernel-pool worker executing its band of a fanned op
    /// (labelled with the pool's precision tier; its track is the
    /// worker index).
    pub const KERNEL_TASK: &str = "kernel.task";

    /// Every span name the code can record — the contract surface.
    pub const ALL: &[&str] = &[
        TRAIN_STEP,
        TRAIN_DATA_LOAD,
        TRAIN_FORWARD,
        TRAIN_BACKWARD,
        TRAIN_OPTIMIZER,
        TRAIN_SR_PROJECT,
        DIST_ALLREDUCE,
        DIST_GRID_SYNC,
        FWD_RMSNORM,
        FWD_ATTENTION,
        FWD_SWIGLU,
        FWD_HEAD,
        SERVE_REQUEST,
        SERVE_QUEUE_WAIT,
        SERVE_PREFILL,
        SERVE_DECODE,
        SERVE_SAMPLE,
        SERVE_DETOKENIZE,
        KERNEL_TASK,
    ];
}

/// One finished span.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    pub cat: &'static str,
    /// static key=value annotation (e.g. `("precision", "fast")`)
    pub label: Option<(&'static str, &'static str)>,
    /// numeric annotation (e.g. `("layer", 3)` or `("rows", 16)`)
    pub arg: Option<(&'static str, u64)>,
    /// track id: caller threads get small ids, pool workers
    /// [`pool_track`] ids — rendered as separate named Chrome tracks
    pub track: u32,
    /// microseconds since the tracer epoch
    pub start_us: u64,
    pub dur_us: u64,
}

struct Ring {
    events: VecDeque<SpanEvent>,
    /// events discarded because the ring was full
    dropped: u64,
    /// events ever pushed (dirty marker for incremental flushing)
    total: u64,
}

struct TracerState {
    epoch: Instant,
    ring: Mutex<Ring>,
    /// track id → display name for the Chrome thread-name metadata
    tracks: Mutex<BTreeMap<u32, String>>,
    out_path: Mutex<Option<String>>,
    /// `Ring::total` at the last successful write (see [`flush_if_dirty`])
    flushed_total: AtomicU64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_THREAD_TRACK: AtomicU32 = AtomicU32::new(0);

thread_local! {
    static THREAD_TRACK: std::cell::Cell<u32> = const { std::cell::Cell::new(u32::MAX) };
}

fn state() -> &'static TracerState {
    static STATE: OnceLock<TracerState> = OnceLock::new();
    STATE.get_or_init(|| TracerState {
        epoch: Instant::now(),
        ring: Mutex::new(Ring {
            events: VecDeque::new(),
            dropped: 0,
            total: 0,
        }),
        tracks: Mutex::new(BTreeMap::new()),
        out_path: Mutex::new(None),
        flushed_total: AtomicU64::new(0),
    })
}

/// Turn tracing on (sets the epoch on first call). Safe to call more
/// than once.
pub fn enable() {
    state();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn tracing off. Already-buffered events stay exportable.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// The one check every span site pays while tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Remember where [`flush_if_dirty`] / [`finish`] should write the
/// Chrome trace.
pub fn set_out_path(path: &str) {
    *state().out_path.lock().unwrap() = Some(path.to_string());
}

/// The configured `--trace-out` path, if any.
pub fn out_path() -> Option<String> {
    if !enabled() {
        return None;
    }
    state().out_path.lock().unwrap().clone()
}

/// Track id for kernel-pool worker `i` — stable across pool scopes, so
/// every fanned op's band for worker `i` lands on one named Chrome
/// track even though the pool spawns fresh scoped threads per call.
pub fn pool_track(worker: usize) -> u32 {
    100_000 + worker as u32
}

fn register_track(track: u32, name: impl FnOnce() -> String) {
    let mut tracks = state().tracks.lock().unwrap();
    tracks.entry(track).or_insert_with(name);
}

/// Name the current thread's track in the export (e.g.
/// `"serve-decode-loop"`). Cheap no-op while disabled.
pub fn set_thread_name(name: &str) {
    if !enabled() {
        return;
    }
    let track = current_track();
    let owned = name.to_string();
    state()
        .tracks
        .lock()
        .unwrap()
        .insert(track, owned);
}

fn current_track() -> u32 {
    THREAD_TRACK.with(|t| {
        let v = t.get();
        if v != u32::MAX {
            return v;
        }
        let id = NEXT_THREAD_TRACK.fetch_add(1, Ordering::Relaxed);
        t.set(id);
        register_track(id, || {
            std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{id}"))
        });
        id
    })
}

/// A live span: created by [`span`] and friends, recorded when dropped
/// — also during panic unwinds, which is what keeps enter/exit
/// balanced under failure.
#[must_use = "a span measures the scope it lives in — bind it to a variable"]
pub struct Span(Option<ActiveSpan>);

struct ActiveSpan {
    name: &'static str,
    cat: &'static str,
    label: Option<(&'static str, &'static str)>,
    arg: Option<(&'static str, u64)>,
    track: u32,
    start: Instant,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(a) = self.0.take() {
            let end = Instant::now();
            push_event(SpanEvent {
                name: a.name,
                cat: a.cat,
                label: a.label,
                arg: a.arg,
                track: a.track,
                start_us: since_epoch_us(a.start),
                dur_us: end.saturating_duration_since(a.start).as_micros() as u64,
            });
        }
    }
}

/// Start a span on the current thread's track. Inert (no clock read,
/// no allocation) while tracing is disabled.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(ActiveSpan {
        name,
        cat,
        label: None,
        arg: None,
        track: current_track(),
        start: Instant::now(),
    }))
}

/// [`span`] with a numeric annotation (layer index, batch rows, …).
#[inline]
pub fn span_arg(cat: &'static str, name: &'static str, key: &'static str, value: u64) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(ActiveSpan {
        name,
        cat,
        label: None,
        arg: Some((key, value)),
        track: current_track(),
        start: Instant::now(),
    }))
}

/// A kernel-pool worker span: lands on worker `i`'s stable track and
/// carries the pool's precision tier, so exact-vs-fast attribution
/// falls out of the trace for free.
#[inline]
pub fn worker_span(worker: usize, precision: &'static str) -> Span {
    if !enabled() {
        return Span(None);
    }
    let track = pool_track(worker);
    register_track(track, || format!("pool-worker-{worker}"));
    Span(Some(ActiveSpan {
        name: names::KERNEL_TASK,
        cat: "kernel",
        label: Some(("precision", precision)),
        arg: Some(("worker", worker as u64)),
        track,
        start: Instant::now(),
    }))
}

/// Record a span whose endpoints were measured elsewhere (e.g. a
/// request's queue wait, clocked from its submission `Instant`).
pub fn record_interval(cat: &'static str, name: &'static str, start: Instant, end: Instant) {
    if !enabled() {
        return;
    }
    push_event(SpanEvent {
        name,
        cat,
        label: None,
        arg: None,
        track: current_track(),
        start_us: since_epoch_us(start),
        dur_us: end.saturating_duration_since(start).as_micros() as u64,
    });
}

fn since_epoch_us(t: Instant) -> u64 {
    t.saturating_duration_since(state().epoch).as_micros() as u64
}

fn push_event(ev: SpanEvent) {
    let mut ring = state().ring.lock().unwrap();
    if ring.events.len() >= RING_CAPACITY {
        ring.events.pop_front();
        ring.dropped += 1;
    }
    ring.events.push_back(ev);
    ring.total += 1;
}

/// Snapshot of the buffered events (oldest first).
pub fn snapshot() -> Vec<SpanEvent> {
    state().ring.lock().unwrap().events.iter().cloned().collect()
}

/// Events dropped because the ring was full.
pub fn dropped() -> u64 {
    state().ring.lock().unwrap().dropped
}

/// Clear all buffered events and track names (test isolation).
pub fn reset() {
    let st = state();
    let mut ring = st.ring.lock().unwrap();
    ring.events.clear();
    ring.dropped = 0;
    ring.total = 0;
    drop(ring);
    st.tracks.lock().unwrap().clear();
    st.flushed_total.store(0, Ordering::SeqCst);
    *st.out_path.lock().unwrap() = None;
}

// ---------------------------------------------------------------------
// Sink 1: Chrome trace-event-format JSON
// ---------------------------------------------------------------------

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render every buffered event as Chrome trace-event-format JSON —
/// `{"traceEvents": [...]}` with `ph:"X"` complete events (µs
/// timestamps) plus `thread_name` metadata rows for every named track.
/// Loadable in `chrome://tracing` and Perfetto.
pub fn render_chrome_trace() -> String {
    let st = state();
    let ring = st.ring.lock().unwrap();
    let tracks = st.tracks.lock().unwrap();
    let mut out = String::with_capacity(128 + ring.events.len() * 120);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for (tid, name) in tracks.iter() {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"{}\"}}}}",
            json_escape(name)
        ));
    }
    for ev in ring.events.iter() {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"name\":\"{}\",\"cat\":\"{}\",\
             \"ts\":{},\"dur\":{}",
            ev.track, ev.name, ev.cat, ev.start_us, ev.dur_us
        ));
        match (ev.label, ev.arg) {
            (None, None) => {}
            (label, arg) => {
                out.push_str(",\"args\":{");
                let mut inner_first = true;
                if let Some((k, v)) = label {
                    out.push_str(&format!("\"{k}\":\"{v}\""));
                    inner_first = false;
                }
                if let Some((k, v)) = arg {
                    if !inner_first {
                        out.push(',');
                    }
                    out.push_str(&format!("\"{k}\":{v}"));
                }
                out.push('}');
            }
        }
        out.push('}');
    }
    out.push_str(&format!(
        "],\"otherData\":{{\"dropped_events\":{}}}}}",
        ring.dropped
    ));
    out
}

/// Write the Chrome trace to `path` (atomically: tmp file + rename).
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    let text = render_chrome_trace();
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(text.as_bytes())?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    let total = state().ring.lock().unwrap().total;
    state().flushed_total.store(total, Ordering::SeqCst);
    Ok(())
}

/// Write the trace to the configured `--trace-out` path if any events
/// arrived since the last write. The serve decode loop calls this when
/// it drains to idle, so a long-lived server keeps its trace file
/// current without an explicit shutdown hook.
pub fn flush_if_dirty() {
    let Some(path) = out_path() else { return };
    let st = state();
    let total = st.ring.lock().unwrap().total;
    if total == st.flushed_total.load(Ordering::SeqCst) {
        return;
    }
    if let Err(e) = write_chrome_trace(&path) {
        eprintln!("trace: writing {path}: {e}");
    }
}

/// Run-end hook for one-shot commands (train / worker / generate):
/// write the Chrome trace to the configured path and return the
/// rendered per-phase profile table for the caller to print. `None`
/// when tracing was never enabled or nothing was recorded.
pub fn finish() -> Option<String> {
    if !enabled() {
        return None;
    }
    if let Some(path) = out_path() {
        if let Err(e) = write_chrome_trace(&path) {
            eprintln!("trace: writing {path}: {e}");
        }
    }
    let stats = profile();
    if stats.is_empty() {
        return None;
    }
    Some(render_table(&stats))
}

// ---------------------------------------------------------------------
// Sink 2: the per-phase profile
// ---------------------------------------------------------------------

/// Aggregated statistics for one span name.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseStat {
    pub name: String,
    pub count: u64,
    pub total_us: u64,
    pub mean_us: f64,
    pub p95_us: u64,
    /// total span time over the trace's wall span (first start → last
    /// end). Nested and parallel spans each count fully, so the column
    /// can legitimately exceed 100% and the per-layer rows overlap
    /// their parents — it reads as *attribution*, not a partition.
    pub pct_wall: f64,
}

/// Fold `(name, start_us, dur_us)` spans into per-name statistics,
/// sorted by total time descending (ties by name). The generic input
/// shape lets `report --exp profile` feed spans parsed back out of a
/// trace JSON through the same math.
pub fn aggregate(spans: impl IntoIterator<Item = (String, u64, u64)>) -> Vec<PhaseStat> {
    let mut durs: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    let mut wall_lo = u64::MAX;
    let mut wall_hi = 0u64;
    for (name, start, dur) in spans {
        wall_lo = wall_lo.min(start);
        wall_hi = wall_hi.max(start + dur);
        durs.entry(name).or_default().push(dur);
    }
    let wall = wall_hi.saturating_sub(wall_lo).max(1);
    let mut stats: Vec<PhaseStat> = durs
        .into_iter()
        .map(|(name, mut ds)| {
            ds.sort_unstable();
            let count = ds.len() as u64;
            let total: u64 = ds.iter().sum();
            let p95_idx = ((count as f64 * 0.95).ceil() as usize).clamp(1, ds.len()) - 1;
            PhaseStat {
                name,
                count,
                total_us: total,
                mean_us: total as f64 / count as f64,
                p95_us: ds[p95_idx],
                pct_wall: 100.0 * total as f64 / wall as f64,
            }
        })
        .collect();
    stats.sort_by(|a, b| b.total_us.cmp(&a.total_us).then(a.name.cmp(&b.name)));
    stats
}

/// [`aggregate`] over the in-process ring.
pub fn profile() -> Vec<PhaseStat> {
    aggregate(
        state()
            .ring
            .lock()
            .unwrap()
            .events
            .iter()
            .map(|e| (e.name.to_string(), e.start_us, e.dur_us)),
    )
}

/// Render the profile as an aligned text table.
pub fn render_table(stats: &[PhaseStat]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<20} {:>8} {:>12} {:>10} {:>10} {:>8}\n",
        "phase", "count", "total ms", "mean ms", "p95 ms", "% wall"
    ));
    for s in stats {
        out.push_str(&format!(
            "{:<20} {:>8} {:>12.1} {:>10.3} {:>10.3} {:>8.1}\n",
            s.name,
            s.count,
            s.total_us as f64 / 1e3,
            s.mean_us / 1e3,
            s.p95_us as f64 / 1e3,
            s.pct_wall
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tracer is one global; tests that flip it serialize here.
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_mode_records_nothing() {
        let _g = test_lock();
        disable();
        reset();
        {
            let _sp = span(TEST_CAT, names::TRAIN_STEP);
            let _sp2 = worker_span(0, "exact");
            record_interval(TEST_CAT, names::SERVE_QUEUE_WAIT, Instant::now(), Instant::now());
        }
        assert!(our_events().is_empty());
        assert!(finish().is_none());
        assert!(out_path().is_none());
    }

    // Other tests in this binary exercise instrumented code paths; while
    // a tracing test has the global tracer enabled they may record too.
    // Tests below therefore filter on a category only they use and never
    // assert on the total event count.
    const TEST_CAT: &str = "tracetest";

    fn our_events() -> Vec<SpanEvent> {
        snapshot().into_iter().filter(|e| e.cat == TEST_CAT).collect()
    }

    #[test]
    fn nested_spans_record_balanced_and_ordered() {
        let _g = test_lock();
        reset();
        enable();
        {
            let _outer = span(TEST_CAT, names::TRAIN_STEP);
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = span(TEST_CAT, names::TRAIN_FORWARD);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        let evs = our_events();
        disable();
        assert_eq!(evs.len(), 2);
        // inner drops first, so it is recorded first
        assert_eq!(evs[0].name, names::TRAIN_FORWARD);
        assert_eq!(evs[1].name, names::TRAIN_STEP);
        // the outer span covers the inner one
        assert!(evs[1].start_us <= evs[0].start_us);
        assert!(
            evs[1].start_us + evs[1].dur_us >= evs[0].start_us + evs[0].dur_us,
            "outer must end at or after inner: {evs:?}"
        );
        reset();
    }

    #[test]
    fn guard_drop_survives_panics() {
        let _g = test_lock();
        reset();
        enable();
        let result = std::panic::catch_unwind(|| {
            let _sp = span(TEST_CAT, names::TRAIN_OPTIMIZER);
            panic!("mid-span failure");
        });
        assert!(result.is_err());
        let evs = our_events();
        disable();
        assert_eq!(evs.len(), 1, "the unwound span must still be recorded");
        assert_eq!(evs[0].name, names::TRAIN_OPTIMIZER);
        reset();
    }

    #[test]
    fn ring_is_bounded_and_drops_oldest() {
        let _g = test_lock();
        reset();
        enable();
        let n = RING_CAPACITY + 10;
        for i in 0..n {
            push_event(SpanEvent {
                name: names::KERNEL_TASK,
                cat: TEST_CAT,
                label: None,
                arg: Some(("worker", i as u64)),
                track: 0,
                start_us: i as u64,
                dur_us: 1,
            });
        }
        let total = snapshot().len();
        let evs = our_events();
        disable();
        assert_eq!(total, RING_CAPACITY, "ring must clamp at capacity");
        assert!(dropped() >= 10, "at least the 10 overflow events dropped");
        // drops are oldest-first, so the surviving test events are a
        // suffix of what we pushed
        assert!(evs[0].start_us >= 10, "oldest events must be the dropped ones");
        assert_eq!(evs.last().unwrap().start_us, n as u64 - 1);
        reset();
    }

    #[test]
    fn chrome_trace_is_valid_event_json() {
        let _g = test_lock();
        reset();
        enable();
        {
            let _w = worker_span(3, "fast");
        }
        {
            let _sp = span_arg(TEST_CAT, names::FWD_ATTENTION, "layer", 2);
        }
        let text = render_chrome_trace();
        disable();
        let v = crate::util::json::parse(&text).expect("trace must parse as JSON");
        let evs = v.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // ≥2 span events + a thread_name metadata row for the pool track
        assert!(evs.len() >= 3, "{text}");
        let meta: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
            .collect();
        assert!(meta.iter().any(|m| {
            m.get("args")
                .and_then(|a| a.get("name"))
                .and_then(|n| n.as_str())
                == Some("pool-worker-3")
        }));
        let spans: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .collect();
        for s in &spans {
            assert!(s.get("ts").is_some() && s.get("dur").is_some());
        }
        // the labelled worker span round-trips its precision + worker args
        assert!(spans.iter().any(|s| {
            s.get("name").and_then(|n| n.as_str()) == Some(names::KERNEL_TASK)
                && s.get("args").and_then(|a| a.get("precision")).and_then(|p| p.as_str())
                    == Some("fast")
                && s.get("args").and_then(|a| a.get("worker")).and_then(|w| w.as_u64())
                    == Some(3)
        }));
        // the span_arg numeric annotation round-trips
        assert!(spans.iter().any(|s| {
            s.get("cat").and_then(|c| c.as_str()) == Some(TEST_CAT)
                && s.get("args").and_then(|a| a.get("layer")).and_then(|l| l.as_u64())
                    == Some(2)
        }));
        assert!(text.contains("\"dropped_events\":"));
        reset();
    }

    #[test]
    fn aggregate_computes_count_total_mean_p95_and_wall() {
        // 20 spans of 1..=20 µs laid end to end: wall = 210 µs
        let spans: Vec<(String, u64, u64)> = (1..=20u64)
            .scan(0u64, |at, d| {
                let s = (names::SERVE_DECODE.to_string(), *at, d);
                *at += d;
                Some(s)
            })
            .collect();
        let stats = aggregate(spans);
        assert_eq!(stats.len(), 1);
        let s = &stats[0];
        assert_eq!(s.count, 20);
        assert_eq!(s.total_us, 210);
        assert!((s.mean_us - 10.5).abs() < 1e-9);
        assert_eq!(s.p95_us, 19); // ceil(20·0.95) = 19th of 1..=20
        assert!((s.pct_wall - 100.0).abs() < 1e-9);
    }

    #[test]
    fn aggregate_sorts_by_total_descending() {
        let stats = aggregate(vec![
            ("small".to_string(), 0, 5),
            ("big".to_string(), 0, 100),
            ("small".to_string(), 50, 5),
        ]);
        assert_eq!(stats[0].name, "big");
        assert_eq!(stats[1].name, "small");
        assert_eq!(stats[1].count, 2);
        let table = render_table(&stats);
        assert!(table.contains("phase") && table.contains("% wall"));
        assert!(table.find("big").unwrap() < table.find("small").unwrap());
    }

    #[test]
    fn span_names_are_unique_and_well_formed() {
        let mut seen = std::collections::BTreeSet::new();
        for name in names::ALL {
            assert!(seen.insert(*name), "duplicate span name {name}");
            assert!(
                name.chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '.' || c == '_'),
                "span name {name} is not lower.dot_case"
            );
            assert!(name.contains('.'), "span name {name} has no subsystem prefix");
        }
    }
}
