//! Live per-step training telemetry: length-prefixed frames over TCP.
//!
//! The push half of the observability plane. A training run binds a
//! [`Publisher`] (`--watch-addr`), which broadcasts one [`StreamFrame`]
//! per step to every connected subscriber; `repro watch --join ADDR`
//! ([`watch`]) tails the stream and prints per-step loss lines while the
//! run is live. Frames use the same wire idiom as `dist::wire`: a 1-byte
//! tag, an 8-byte little-endian payload length, then the payload, with
//! the same corrupt-frame hardening (truncation, oversized lengths,
//! unknown tags and trailing bytes all error instead of panicking).
//!
//! The stream is strictly one-way and lossy-by-design on the publisher
//! side: a subscriber that stalls past the write timeout is dropped so
//! the training loop can never block on an observer. Late subscribers
//! receive the stored [`StreamFrame::RunStart`] on connect, then every
//! subsequent step. The frame layouts are documented in
//! `docs/OBSERVABILITY.md` (streaming wire table).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

/// Bumped whenever a stream frame layout changes; carried by `RunStart`.
/// v2 added the `QuantHealth` frame (tag 4).
pub const STREAM_PROTOCOL_VERSION: u32 = 2;

/// Hard cap on one frame's payload — step telemetry is tiny, so anything
/// large is a corrupt length prefix.
pub const MAX_STREAM_FRAME_BYTES: u64 = 1 << 20;

/// Default for the subscriber write-stall eviction timeout, in ms.
const SUBSCRIBER_WRITE_TIMEOUT_MS: u64 = 2000;

/// Resolve a `DQT_WATCH_TIMEOUT_MS`-style value: positive integer
/// milliseconds, whitespace tolerated; anything else (unset, empty,
/// non-numeric, zero) falls back to the 2 s default. Pure so it is
/// testable without mutating the process environment.
fn parse_watch_timeout(raw: Option<String>) -> Duration {
    let ms = raw
        .as_deref()
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .unwrap_or(SUBSCRIBER_WRITE_TIMEOUT_MS);
    Duration::from_millis(ms)
}

/// How long a broadcast write may stall before the subscriber is
/// dropped — `DQT_WATCH_TIMEOUT_MS` (default 2000). Raise it for
/// watchers on slow links, lower it to shed stalls faster; either way
/// the training loop never blocks longer than this per subscriber.
fn subscriber_write_timeout() -> Duration {
    parse_watch_timeout(std::env::var("DQT_WATCH_TIMEOUT_MS").ok())
}

const TAG_RUN_START: u8 = 1;
const TAG_STEP: u8 = 2;
const TAG_RUN_END: u8 = 3;
const TAG_QUANT_HEALTH: u8 = 4;

/// One layer's row of a [`StreamFrame::QuantHealth`] frame — the live
/// subset of `obs::quant::LayerHealth` a watcher renders.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantLayerFrame {
    /// manifest param name (the `layer` metric label value)
    pub name: String,
    /// latest step's flip count
    pub flips: u64,
    /// run-average flips per weight per step
    pub flip_rate: f32,
    /// latest mean |update| per weight, grid-step units
    pub abs_upd: f32,
    /// latest stored inverse scale
    pub scale: f32,
    /// latest fraction of weights at the extreme grid levels
    pub saturation: f32,
    /// latest fraction of weights at the zero level
    pub zero_frac: f32,
    /// EMA of sign-alternating flip steps
    pub oscillation: f32,
    /// latest post-clip gradient norm over the layer
    pub grad_norm: f32,
}

/// One message of the step-streaming protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum StreamFrame {
    /// Sent once at run start (and replayed to late subscribers): what is
    /// training and how big the run is.
    RunStart {
        variant: String,
        dataset: String,
        world: u32,
        total_steps: u64,
    },
    /// One completed optimizer step — the fields of
    /// `train::metrics::StepRecord`, on the wire.
    Step {
        step: u64,
        loss: f32,
        lr: f32,
        upd_frac: f32,
        gnorm: f32,
        step_ms: f32,
    },
    /// The run finished; subscribers should disconnect. `final_dev_loss`
    /// is NaN when the run computed none.
    RunEnd {
        final_dev_loss: f32,
        wall_secs: f64,
    },
    /// Periodic per-layer quantization-health snapshot (cadence:
    /// `config::effective_quant_frame_every`). Only emitted by runs with
    /// grid-quantized layers.
    QuantHealth {
        step: u64,
        layers: Vec<QuantLayerFrame>,
    },
}

/// Outcome of a forward-compatible frame read ([`StreamFrame::read_lenient`]).
#[derive(Clone, Debug, PartialEq)]
pub enum LenientFrame {
    /// a known, fully decoded frame
    Frame(StreamFrame),
    /// an unknown tag whose payload was consumed and discarded
    SkippedUnknown(u8),
    /// the stream ended cleanly at a frame boundary
    Eof,
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Bounds-checked payload reader (the `dist::wire` idiom).
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow!("corrupt stream frame: {what} length overflows"))?;
        if end > self.buf.len() {
            return Err(anyhow!(
                "corrupt stream frame: {what} wants {n} bytes, {} remain",
                self.buf.len() - self.pos
            ));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let n = self.u32(what)? as usize;
        let bytes = self.take(n, what)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|_| anyhow!("corrupt stream frame: {what} is not UTF-8"))?
            .to_string())
    }

    fn finish(self, tag: &str) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(anyhow!(
                "corrupt stream frame: {tag} has {} trailing bytes",
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

impl StreamFrame {
    fn tag(&self) -> u8 {
        match self {
            StreamFrame::RunStart { .. } => TAG_RUN_START,
            StreamFrame::Step { .. } => TAG_STEP,
            StreamFrame::RunEnd { .. } => TAG_RUN_END,
            StreamFrame::QuantHealth { .. } => TAG_QUANT_HEALTH,
        }
    }

    fn payload(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        match self {
            StreamFrame::RunStart {
                variant,
                dataset,
                world,
                total_steps,
            } => {
                put_u32(&mut buf, STREAM_PROTOCOL_VERSION);
                put_str(&mut buf, variant);
                put_str(&mut buf, dataset);
                put_u32(&mut buf, *world);
                put_u64(&mut buf, *total_steps);
            }
            StreamFrame::Step {
                step,
                loss,
                lr,
                upd_frac,
                gnorm,
                step_ms,
            } => {
                put_u64(&mut buf, *step);
                put_f32(&mut buf, *loss);
                put_f32(&mut buf, *lr);
                put_f32(&mut buf, *upd_frac);
                put_f32(&mut buf, *gnorm);
                put_f32(&mut buf, *step_ms);
            }
            StreamFrame::RunEnd {
                final_dev_loss,
                wall_secs,
            } => {
                put_f32(&mut buf, *final_dev_loss);
                buf.extend_from_slice(&wall_secs.to_le_bytes());
            }
            StreamFrame::QuantHealth { step, layers } => {
                put_u64(&mut buf, *step);
                put_u32(&mut buf, layers.len() as u32);
                for l in layers {
                    put_str(&mut buf, &l.name);
                    put_u64(&mut buf, l.flips);
                    put_f32(&mut buf, l.flip_rate);
                    put_f32(&mut buf, l.abs_upd);
                    put_f32(&mut buf, l.scale);
                    put_f32(&mut buf, l.saturation);
                    put_f32(&mut buf, l.zero_frac);
                    put_f32(&mut buf, l.oscillation);
                    put_f32(&mut buf, l.grad_norm);
                }
            }
        }
        buf
    }

    /// Serialize to the full wire form: tag, length prefix, payload.
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut buf = Vec::with_capacity(9 + payload.len());
        buf.push(self.tag());
        buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        buf.extend_from_slice(&payload);
        buf
    }

    /// Read one raw `(tag, payload)` off the wire. `Ok(None)` means the
    /// stream ended cleanly at a frame boundary.
    fn read_raw(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>> {
        let mut header = [0u8; 9];
        let mut got = 0usize;
        while got < header.len() {
            let n = r
                .read(&mut header[got..])
                .map_err(|e| anyhow!("reading stream frame header: {e}"))?;
            if n == 0 {
                if got == 0 {
                    return Ok(None); // clean EOF between frames
                }
                return Err(anyhow!(
                    "truncated stream frame header (connection closed mid-frame)"
                ));
            }
            got += n;
        }
        let tag = header[0];
        let len = u64::from_le_bytes(header[1..9].try_into().unwrap());
        if len > MAX_STREAM_FRAME_BYTES {
            return Err(anyhow!(
                "corrupt stream frame: oversized payload length {len} (cap {MAX_STREAM_FRAME_BYTES})"
            ));
        }
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                anyhow!("stream frame payload truncated: wanted {len} bytes")
            } else {
                anyhow!("reading stream frame payload: {e}")
            }
        })?;
        Ok(Some((tag, payload)))
    }

    /// Read one frame. `Ok(None)` means the stream ended cleanly at a
    /// frame boundary (the publisher closed the connection). Strict: an
    /// unknown tag is an error (the `dist::wire` hardening posture).
    pub fn read_from(r: &mut impl Read) -> Result<Option<StreamFrame>> {
        match Self::read_raw(r)? {
            None => Ok(None),
            Some((tag, payload)) => Ok(Some(Self::decode(tag, &payload)?)),
        }
    }

    /// Forward-compatible read for tailing clients ([`watch`]): an
    /// unknown tag has its (length-bounded) payload consumed and is
    /// reported as [`LenientFrame::SkippedUnknown`] so an old watcher
    /// survives a newer producer's stream. Known-tag payloads still get
    /// the full strict decode.
    pub fn read_lenient(r: &mut impl Read) -> Result<LenientFrame> {
        match Self::read_raw(r)? {
            None => Ok(LenientFrame::Eof),
            Some((tag, payload)) => match tag {
                TAG_RUN_START | TAG_STEP | TAG_RUN_END | TAG_QUANT_HEALTH => {
                    Ok(LenientFrame::Frame(Self::decode(tag, &payload)?))
                }
                unknown => Ok(LenientFrame::SkippedUnknown(unknown)),
            },
        }
    }

    fn decode(tag: u8, payload: &[u8]) -> Result<StreamFrame> {
        let mut c = Cursor::new(payload);
        let frame = match tag {
            TAG_RUN_START => {
                let version = c.u32("run_start version")?;
                if version != STREAM_PROTOCOL_VERSION {
                    return Err(anyhow!(
                        "stream protocol mismatch: publisher speaks v{version}, \
                         this build speaks v{STREAM_PROTOCOL_VERSION}"
                    ));
                }
                StreamFrame::RunStart {
                    variant: c.str("run_start variant")?,
                    dataset: c.str("run_start dataset")?,
                    world: c.u32("run_start world")?,
                    total_steps: c.u64("run_start total_steps")?,
                }
            }
            TAG_STEP => StreamFrame::Step {
                step: c.u64("step index")?,
                loss: c.f32("step loss")?,
                lr: c.f32("step lr")?,
                upd_frac: c.f32("step upd_frac")?,
                gnorm: c.f32("step gnorm")?,
                step_ms: c.f32("step step_ms")?,
            },
            TAG_RUN_END => StreamFrame::RunEnd {
                final_dev_loss: c.f32("run_end final_dev_loss")?,
                wall_secs: c.f64("run_end wall_secs")?,
            },
            TAG_QUANT_HEALTH => {
                let step = c.u64("quant_health step")?;
                let count = c.u32("quant_health layer count")? as usize;
                let mut layers = Vec::with_capacity(count.min(1024));
                for _ in 0..count {
                    layers.push(QuantLayerFrame {
                        name: c.str("quant_health layer name")?,
                        flips: c.u64("quant_health flips")?,
                        flip_rate: c.f32("quant_health flip_rate")?,
                        abs_upd: c.f32("quant_health abs_upd")?,
                        scale: c.f32("quant_health scale")?,
                        saturation: c.f32("quant_health saturation")?,
                        zero_frac: c.f32("quant_health zero_frac")?,
                        oscillation: c.f32("quant_health oscillation")?,
                        grad_norm: c.f32("quant_health grad_norm")?,
                    });
                }
                StreamFrame::QuantHealth { step, layers }
            }
            other => return Err(anyhow!("unknown stream frame tag {other}")),
        };
        c.finish(match tag {
            TAG_RUN_START => "run_start",
            TAG_STEP => "step",
            TAG_QUANT_HEALTH => "quant_health",
            _ => "run_end",
        })?;
        Ok(frame)
    }
}

/// The step-stream broadcast endpoint a training run binds. An accept
/// thread admits subscribers (replaying the stored `RunStart` to late
/// joiners); [`Publisher::publish`] fans each frame out to every live
/// subscriber, dropping any that stall past the write timeout — the
/// training loop never blocks on an observer.
pub struct Publisher {
    addr: SocketAddr,
    clients: Arc<Mutex<Vec<TcpStream>>>,
    start_frame: Arc<Mutex<Option<Vec<u8>>>>,
    stop: Arc<AtomicBool>,
}

impl Publisher {
    /// Bind `addr` (port 0 picks a free port) and start accepting
    /// subscribers on a background thread.
    pub fn bind(addr: &str) -> Result<Publisher> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding watch address {addr}"))?;
        let bound = listener.local_addr()?;
        listener
            .set_nonblocking(true)
            .context("watch listener nonblocking")?;
        let clients: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let start_frame: Arc<Mutex<Option<Vec<u8>>>> = Arc::new(Mutex::new(None));
        let stop = Arc::new(AtomicBool::new(false));
        let (c2, s2, stop2) = (clients.clone(), start_frame.clone(), stop.clone());
        std::thread::spawn(move || loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let ok = stream.set_nonblocking(false).is_ok()
                        && stream
                            .set_write_timeout(Some(subscriber_write_timeout()))
                            .is_ok();
                    if !ok {
                        continue;
                    }
                    let mut stream = stream;
                    // replay the run header so late joiners have context
                    let replay_ok = match s2.lock().unwrap().as_ref() {
                        Some(buf) => stream.write_all(buf).and_then(|()| stream.flush()).is_ok(),
                        None => true,
                    };
                    if replay_ok {
                        c2.lock().unwrap().push(stream);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if stop2.load(Ordering::Relaxed) {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => return,
            }
        });
        Ok(Publisher {
            addr: bound,
            clients,
            start_frame,
            stop,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Broadcast one frame to every subscriber; a failed or stalled write
    /// evicts that subscriber. `RunStart` frames are additionally stored
    /// for replay to late joiners.
    pub fn publish(&self, frame: &StreamFrame) {
        let buf = frame.encode();
        if matches!(frame, StreamFrame::RunStart { .. }) {
            *self.start_frame.lock().unwrap() = Some(buf.clone());
        }
        self.clients
            .lock()
            .unwrap()
            .retain_mut(|c| c.write_all(&buf).and_then(|()| c.flush()).is_ok());
    }

    /// Subscribers currently connected (for tests and status lines).
    pub fn subscribers(&self) -> usize {
        self.clients.lock().unwrap().len()
    }
}

impl Drop for Publisher {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // closing the sockets tells subscribers the stream is over
        self.clients.lock().unwrap().clear();
    }
}

/// Tail a live run: connect to a [`Publisher`] at `addr` (retrying until
/// `connect_timeout` passes, so a watcher can be started slightly before
/// the run), then invoke `on_frame` for every received frame until
/// `RunEnd` or the publisher closes the stream.
///
/// The same `connect_timeout` budget also bounds the wait for the *first
/// frame*: a publisher replays its stored `RunStart` on connect, so a
/// connection that stays silent past the deadline means no run is live —
/// `watch` errors instead of hanging, and `repro watch --join` exits
/// nonzero.
pub fn watch(
    addr: &str,
    connect_timeout: Duration,
    mut on_frame: impl FnMut(&StreamFrame),
) -> Result<()> {
    let deadline = Instant::now() + connect_timeout;
    let mut stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("connecting to {addr} timed out: {e}"));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    let remaining = deadline.saturating_duration_since(Instant::now());
    stream.set_read_timeout(Some(remaining.max(Duration::from_millis(10))))?;
    // forward compatibility: a newer producer may interleave frame tags
    // this build does not know — skip each with one note per distinct tag
    // instead of erroring (reads stay strict for known tags)
    let mut noted: Vec<u8> = Vec::new();
    let mut note_skip = |tag: u8| {
        if !noted.contains(&tag) {
            noted.push(tag);
            eprintln!(
                "watch: skipping unknown frame tag {tag} (producer is newer than \
                 this build's protocol v{STREAM_PROTOCOL_VERSION})"
            );
        }
    };
    let first = loop {
        match StreamFrame::read_lenient(&mut stream) {
            Ok(LenientFrame::Frame(f)) => break Some(f),
            Ok(LenientFrame::SkippedUnknown(tag)) => note_skip(tag),
            Ok(LenientFrame::Eof) => break None,
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!(
                    "no RunStart from {addr} within {connect_timeout:?} — is a run publishing there? ({e})"
                ));
            }
            Err(e) => return Err(e),
        }
    };
    let Some(first) = first else {
        return Ok(()); // publisher closed before any frame: run is over
    };
    let done = matches!(first, StreamFrame::RunEnd { .. });
    on_frame(&first);
    if done {
        return Ok(());
    }
    stream.set_read_timeout(Some(Duration::from_secs(600)))?;
    loop {
        match StreamFrame::read_lenient(&mut stream)? {
            LenientFrame::Eof => return Ok(()), // publisher closed: run is over
            LenientFrame::SkippedUnknown(tag) => note_skip(tag),
            LenientFrame::Frame(frame) => {
                let done = matches!(frame, StreamFrame::RunEnd { .. });
                on_frame(&frame);
                if done {
                    return Ok(());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor as IoCursor;

    fn frames() -> Vec<StreamFrame> {
        vec![
            StreamFrame::RunStart {
                variant: "test-dqt-b1p58".into(),
                dataset: "tiny".into(),
                world: 2,
                total_steps: 40,
            },
            StreamFrame::Step {
                step: 7,
                loss: 3.25,
                lr: 1e-3,
                upd_frac: 0.015,
                gnorm: 0.75,
                step_ms: 12.5,
            },
            StreamFrame::RunEnd {
                final_dev_loss: 2.875,
                wall_secs: 1.5,
            },
        ]
    }

    fn quant_frame() -> StreamFrame {
        StreamFrame::QuantHealth {
            step: 30,
            layers: vec![
                QuantLayerFrame {
                    name: "layers.0.wq".into(),
                    flips: 12,
                    flip_rate: 0.01,
                    abs_upd: 0.02,
                    scale: 4.0,
                    saturation: 0.55,
                    zero_frac: 0.4,
                    oscillation: 0.1,
                    grad_norm: 1.5,
                },
                QuantLayerFrame {
                    name: "layers.1.w_down".into(),
                    flips: 0,
                    flip_rate: 0.0,
                    abs_upd: 0.0,
                    scale: 2.5,
                    saturation: 0.6,
                    zero_frac: 0.35,
                    oscillation: 0.0,
                    grad_norm: 0.25,
                },
            ],
        }
    }

    #[test]
    fn all_frames_roundtrip() {
        for f in frames().into_iter().chain([quant_frame()]) {
            let buf = f.encode();
            let back = StreamFrame::read_from(&mut IoCursor::new(&buf))
                .unwrap()
                .unwrap();
            assert_eq!(back, f);
        }
    }

    /// An unknown tag is an error on the strict path but a
    /// `SkippedUnknown` on the lenient path, with the payload fully
    /// consumed so the following frame still decodes — the
    /// forward-compatibility contract `watch` relies on.
    #[test]
    fn lenient_read_skips_unknown_tags_and_resumes() {
        // synthetic future frame: tag 9, 5-byte opaque payload
        let mut wire = vec![9u8];
        wire.extend_from_slice(&5u64.to_le_bytes());
        wire.extend_from_slice(&[0xDE, 0xAD, 0xBE, 0xEF, 0x01]);
        wire.extend_from_slice(&frames()[1].encode());
        wire.extend_from_slice(&quant_frame().encode());

        let mut cur = IoCursor::new(&wire);
        assert_eq!(
            StreamFrame::read_lenient(&mut cur).unwrap(),
            LenientFrame::SkippedUnknown(9)
        );
        assert_eq!(
            StreamFrame::read_lenient(&mut cur).unwrap(),
            LenientFrame::Frame(frames()[1].clone())
        );
        assert_eq!(
            StreamFrame::read_lenient(&mut cur).unwrap(),
            LenientFrame::Frame(quant_frame())
        );
        assert_eq!(StreamFrame::read_lenient(&mut cur).unwrap(), LenientFrame::Eof);

        // strict read still rejects the same bytes
        let err = StreamFrame::read_from(&mut IoCursor::new(&wire)).unwrap_err();
        assert!(err.to_string().contains("unknown stream frame tag"), "{err}");
        // lenient is not a corruption amnesty: an oversized length still errors
        let mut bad = vec![9u8];
        bad.extend_from_slice(&(MAX_STREAM_FRAME_BYTES + 1).to_le_bytes());
        let err = StreamFrame::read_lenient(&mut IoCursor::new(&bad)).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
    }

    /// End to end: a watcher tailing a stream that interleaves a
    /// future-protocol frame still delivers every known frame in order.
    #[test]
    fn watch_survives_unknown_frames_from_a_newer_producer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let mut wire = frames()[0].encode();
            wire.push(77); // unknown tag with an 8-byte opaque payload
            wire.extend_from_slice(&8u64.to_le_bytes());
            wire.extend_from_slice(&[0u8; 8]);
            wire.extend_from_slice(&frames()[1].encode());
            wire.extend_from_slice(&frames()[2].encode());
            conn.write_all(&wire).unwrap();
            conn.flush().unwrap();
        });
        let mut seen = Vec::new();
        watch(&addr, Duration::from_secs(10), |f| seen.push(f.clone())).unwrap();
        server.join().unwrap();
        assert_eq!(seen, frames(), "known frames delivered, unknown skipped");
    }

    #[test]
    fn clean_eof_is_none_and_truncation_errors() {
        assert_eq!(StreamFrame::read_from(&mut IoCursor::new(&[])).unwrap(), None);
        let buf = frames()[1].encode();
        for cut in 1..9 {
            let err = StreamFrame::read_from(&mut IoCursor::new(&buf[..cut])).unwrap_err();
            assert!(err.to_string().contains("truncated"), "cut {cut}: {err}");
        }
        let err =
            StreamFrame::read_from(&mut IoCursor::new(&buf[..buf.len() - 1])).unwrap_err();
        assert!(err.to_string().contains("payload truncated"), "{err}");
    }

    #[test]
    fn unknown_tag_oversized_length_and_trailing_bytes_rejected() {
        let mut buf = frames()[1].encode();
        buf[0] = 99;
        let err = StreamFrame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("unknown stream frame tag"), "{err}");

        let mut buf = frames()[1].encode();
        buf[1..9].copy_from_slice(&(MAX_STREAM_FRAME_BYTES + 1).to_le_bytes());
        let err = StreamFrame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");

        let mut buf = frames()[2].encode();
        buf.push(0xAB);
        let len = (buf.len() - 9) as u64;
        buf[1..9].copy_from_slice(&len.to_le_bytes());
        let err = StreamFrame::read_from(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("trailing bytes"), "{err}");
    }

    /// End to end over localhost TCP: a publisher broadcasts a run, a
    /// late-joining watcher still sees the RunStart header, every step,
    /// and the RunEnd that terminates the tail.
    #[test]
    fn publisher_and_watch_deliver_a_run() {
        let publisher = Publisher::bind("127.0.0.1:0").unwrap();
        let addr = publisher.local_addr().to_string();
        publisher.publish(&frames()[0]); // before any subscriber: stored

        let tail = std::thread::spawn(move || {
            let mut seen = Vec::new();
            watch(&addr, Duration::from_secs(10), |f| seen.push(f.clone())).unwrap();
            seen
        });
        // wait for the accept thread to admit the watcher
        let t0 = Instant::now();
        while publisher.subscribers() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "watcher never joined");
            std::thread::sleep(Duration::from_millis(10));
        }
        for step in 0..3u64 {
            publisher.publish(&StreamFrame::Step {
                step,
                loss: 4.0 - step as f32 * 0.5,
                lr: 1e-3,
                upd_frac: 0.01,
                gnorm: 1.0,
                step_ms: 5.0,
            });
        }
        publisher.publish(&frames()[2]);
        let seen = tail.join().unwrap();
        assert_eq!(seen.len(), 5, "run start + 3 steps + run end: {seen:?}");
        assert_eq!(seen[0], frames()[0], "late joiner must get the stored RunStart");
        assert!(matches!(seen[1], StreamFrame::Step { step: 0, .. }));
        assert!(matches!(seen[4], StreamFrame::RunEnd { .. }));
    }

    /// A publisher that never sends a `RunStart` must not hang the
    /// watcher: the connect-timeout budget also bounds the first-frame
    /// wait, and the error propagates (`repro watch --join` exits
    /// nonzero through `main`'s `?`).
    #[test]
    fn watch_times_out_when_no_run_start_arrives() {
        let publisher = Publisher::bind("127.0.0.1:0").unwrap();
        let addr = publisher.local_addr().to_string();
        let t0 = Instant::now();
        let err = watch(&addr, Duration::from_millis(300), |_| {
            panic!("no frame was ever published")
        })
        .unwrap_err();
        assert!(err.to_string().contains("RunStart"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "watch must give up promptly, took {:?}",
            t0.elapsed()
        );
    }

    /// The subscriber write-stall eviction timeout parses leniently and
    /// always lands on something usable (pure helper, no env mutation).
    #[test]
    fn watch_timeout_parsing_defaults_and_rejects_garbage() {
        let default = Duration::from_millis(SUBSCRIBER_WRITE_TIMEOUT_MS);
        assert_eq!(parse_watch_timeout(None), default);
        assert_eq!(parse_watch_timeout(Some("500".into())), Duration::from_millis(500));
        assert_eq!(parse_watch_timeout(Some(" 750 ".into())), Duration::from_millis(750));
        assert_eq!(parse_watch_timeout(Some(String::new())), default);
        assert_eq!(parse_watch_timeout(Some("  ".into())), default);
        assert_eq!(parse_watch_timeout(Some("abc".into())), default);
        assert_eq!(parse_watch_timeout(Some("-5".into())), default);
        assert_eq!(parse_watch_timeout(Some("0".into())), default);
    }

    /// A watcher that disconnects must be evicted on the next publish,
    /// never stalling the training loop.
    #[test]
    fn dead_subscribers_are_evicted() {
        let publisher = Publisher::bind("127.0.0.1:0").unwrap();
        let addr = publisher.local_addr();
        let conn = TcpStream::connect(addr).unwrap();
        let t0 = Instant::now();
        while publisher.subscribers() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "subscriber never joined");
            std::thread::sleep(Duration::from_millis(10));
        }
        drop(conn);
        // the drop may take a publish or two to surface as a write error
        let t0 = Instant::now();
        while publisher.subscribers() > 0 {
            publisher.publish(&frames()[1]);
            assert!(t0.elapsed() < Duration::from_secs(10), "dead subscriber kept");
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}
