//! Minimal pull endpoint: `GET /metrics` for train and dist runs.
//!
//! The serve subsystem mounts `/metrics` on its own HTTP server
//! (`serve::http`); training and the dist coordinator/workers have no
//! HTTP surface of their own, so [`MetricsServer::spawn`] gives them one
//! — a background accept loop that renders an [`obs::Registry`] in
//! Prometheus text exposition format v0.0.4 and serves nothing else.
//! Zero dependencies, one short-lived thread per scrape.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::registry::Registry;

/// Content type Prometheus scrapers expect from a text endpoint.
pub const METRICS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

const SCRAPE_TIMEOUT: Duration = Duration::from_secs(5);
const MAX_REQUEST_BYTES: usize = 16 * 1024;
/// Scrape handlers allowed in flight at once. A scrape endpoint has one
/// or two well-behaved clients; anything past this is a stuck scraper or
/// a misdirected load test, and the accept loop sheds it by closing the
/// connection immediately instead of spawning an unbounded thread pile
/// (each spawned handler can hold its thread for [`SCRAPE_TIMEOUT`]).
pub const MAX_CONCURRENT_SCRAPES: usize = 8;

/// Handle for a running metrics endpoint. Dropping it does not stop the
/// accept thread (it lives for the process, like the serve listener);
/// keep it to learn the bound address.
pub struct MetricsServer {
    addr: SocketAddr,
}

impl MetricsServer {
    /// Bind `addr` (port 0 picks a free port) and serve `GET /metrics`
    /// from `registry` on a background thread until the process exits.
    pub fn spawn(addr: &str, registry: Arc<Registry>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics address {addr}"))?;
        let bound = listener.local_addr()?;
        let active = Arc::new(AtomicUsize::new(0));
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                // overload shed: past the cap, drop the connection on the
                // floor (close = EOF for the client) rather than queue it
                if active.load(Ordering::Acquire) >= MAX_CONCURRENT_SCRAPES {
                    drop(stream);
                    continue;
                }
                active.fetch_add(1, Ordering::AcqRel);
                let reg = registry.clone();
                let active = active.clone();
                std::thread::spawn(move || {
                    let _ = serve_scrape(stream, &reg);
                    active.fetch_sub(1, Ordering::AcqRel);
                });
            }
        });
        Ok(MetricsServer { addr: bound })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

fn serve_scrape(mut stream: TcpStream, registry: &Registry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(SCRAPE_TIMEOUT))?;
    stream.set_write_timeout(Some(SCRAPE_TIMEOUT))?;

    // Read until the end of the request head; the endpoint takes no body.
    let mut head = Vec::new();
    let mut chunk = [0u8; 1024];
    while !head.windows(4).any(|w| w == b"\r\n\r\n") {
        let n = stream.read(&mut chunk)?;
        if n == 0 || head.len() + n > MAX_REQUEST_BYTES {
            return Ok(());
        }
        head.extend_from_slice(&chunk[..n]);
    }
    let request_line = std::str::from_utf8(&head)
        .unwrap_or("")
        .lines()
        .next()
        .unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));

    let (status, body) = match (method, path) {
        ("GET", "/metrics") => ("200 OK", registry.render()),
        // liveness probe, mirroring the serve server's endpoint: a rank
        // behind --metrics-addr answers as long as its accept loop is up
        ("GET", "/healthz") => ("200 OK", "ok\n".to_string()),
        _ => (
            "404 Not Found",
            format!("{method} {path} is not served here; try GET /metrics or GET /healthz\n"),
        ),
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {METRICS_CONTENT_TYPE}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(stream, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        stream.flush().unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn scrape_returns_prometheus_text_and_404_elsewhere() {
        let registry = Arc::new(Registry::new());
        let c = registry.counter("dqt_test_scrapes_total", "Scrapes served.");
        c.inc_by(3);
        let server = MetricsServer::spawn("127.0.0.1:0", registry).unwrap();

        let response = get(server.local_addr(), "/metrics");
        assert!(response.starts_with("HTTP/1.1 200 OK"), "{response}");
        assert!(response.contains(METRICS_CONTENT_TYPE), "{response}");
        assert!(
            response.contains("# TYPE dqt_test_scrapes_total counter"),
            "{response}"
        );
        assert!(response.contains("dqt_test_scrapes_total 3\n"), "{response}");

        let response = get(server.local_addr(), "/other");
        assert!(response.starts_with("HTTP/1.1 404"), "{response}");
    }

    /// `GET /healthz` answers 200 (train ranks are liveness-probeable),
    /// and unknown paths get a 404 body naming what *is* served.
    #[test]
    fn healthz_answers_and_404_body_names_the_endpoints() {
        let registry = Arc::new(Registry::new());
        let server = MetricsServer::spawn("127.0.0.1:0", registry).unwrap();

        let response = get(server.local_addr(), "/healthz");
        assert!(response.starts_with("HTTP/1.1 200 OK"), "{response}");
        assert!(response.ends_with("ok\n"), "{response}");

        let response = get(server.local_addr(), "/nope");
        assert!(response.starts_with("HTTP/1.1 404 Not Found"), "{response}");
        assert!(response.contains("GET /nope is not served here"), "{response}");
        assert!(response.contains("/metrics"), "{response}");
        assert!(response.contains("/healthz"), "{response}");
    }

    /// Past [`MAX_CONCURRENT_SCRAPES`] in-flight handlers the accept loop
    /// closes new connections instead of spawning more threads — and
    /// recovers once the pile drains.
    #[test]
    fn accept_loop_sheds_connections_past_the_cap() {
        let registry = Arc::new(Registry::new());
        registry
            .counter("dqt_test_shed_total", "Shed-test counter.")
            .inc();
        let server = MetricsServer::spawn("127.0.0.1:0", registry).unwrap();
        let addr = server.local_addr();

        // saturate: hold handler threads mid-request (partial head, no
        // terminating blank line) so they stay in flight
        let mut holds: Vec<TcpStream> = (0..MAX_CONCURRENT_SCRAPES)
            .map(|_| {
                let mut s = TcpStream::connect(addr).unwrap();
                write!(s, "GET /metrics HTT").unwrap();
                s.flush().unwrap();
                s
            })
            .collect();

        // a shed connection reads as EOF (or a reset when the request
        // raced the close) — either way, no status line comes back
        let try_get = |path: &str| -> String {
            let mut stream = TcpStream::connect(addr).unwrap();
            let _ = write!(stream, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n");
            let mut out = String::new();
            let _ = stream.read_to_string(&mut out);
            out
        };

        // the holds are accepted asynchronously, so poll until a probe is
        // shed
        let mut shed = false;
        for _ in 0..200 {
            if !try_get("/metrics").starts_with("HTTP/1.1") {
                shed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(shed, "probe was never shed at {MAX_CONCURRENT_SCRAPES} held scrapes");

        // drain: dropping the holds EOFs their handlers; scrapes recover
        holds.clear();
        let mut recovered = false;
        for _ in 0..200 {
            if try_get("/metrics").starts_with("HTTP/1.1 200 OK") {
                recovered = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(recovered, "scrapes must succeed again after the pile drains");
    }
}
