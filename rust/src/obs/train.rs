//! `TrainObs`: the training/distributed instrumentation handle.
//!
//! One `Arc<TrainObs>` rides through a run the way `kernels::Pool` does:
//! created default-on by `Trainer::new`, cloned into the dist exchange,
//! and recorded into from hot paths without allocating — every metric is
//! a pre-registered atomic in the owned [`Registry`]. The registry is
//! what `GET /metrics` renders (via [`obs::MetricsServer`]); an optional
//! [`Publisher`] attached with [`TrainObs::set_publisher`] additionally
//! streams one [`StreamFrame::Step`] per optimizer step for
//! `repro watch --join`. With no metrics address and no watch address
//! configured, the handle is pure atomics — no sockets, no threads.
//!
//! Metric names and units are the documented contract in
//! `docs/OBSERVABILITY.md`; `tests/obs_contract.rs` pins every name
//! registered here to an entry in that doc.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::quant::{QuantHealth, QuantStepRecord};
use super::registry::{Counter, Gauge, Histogram, Registry};
use super::stream::{Publisher, QuantLayerFrame, StreamFrame};
use crate::train::metrics::StepRecord;

/// Bucket bounds (seconds) shared by the step-time histogram and the
/// serve-side latency histograms — fixed so rendered output is stable.
pub const TIME_BUCKETS: [f64; 11] = [
    0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 10.0, 60.0,
];

/// The `format` label values of the per-format all-reduce series — the
/// gradient wire tiers of `--grad-format`. Order is the series index.
pub const GRAD_FORMATS: [&str; 3] = ["f32", "int8", "ternary"];

/// Index of a gradient-format tag into [`GRAD_FORMATS`]-shaped series;
/// unknown tags account under `f32` rather than dropping the sample.
fn grad_format_idx(format: &str) -> usize {
    GRAD_FORMATS.iter().position(|f| *f == format).unwrap_or(0)
}

/// Training + distributed metrics bundle. Field docs double as the
/// metric help strings.
pub struct TrainObs {
    registry: Arc<Registry>,
    publisher: Mutex<Option<Publisher>>,

    steps_total: Arc<Counter>,
    loss: Arc<Gauge>,
    lr: Arc<Gauge>,
    sr_update_fraction: Arc<Gauge>,
    grad_norm: Arc<Gauge>,
    dev_loss: Arc<Gauge>,
    step_seconds: Arc<Histogram>,
    forward_seconds_total: Arc<Counter>,
    optimizer_seconds_total: Arc<Counter>,

    dist_world: Arc<Gauge>,
    allreduce_total: Arc<Counter>,
    /// one series per `--grad-format` tier, indexed by [`GRAD_FORMATS`]
    allreduce_bytes_total: [Arc<Counter>; 3],
    allreduce_seconds_total: [Arc<Counter>; 3],
    grid_syncs_total: Arc<Counter>,
    grid_sync_bytes_total: Arc<Counter>,

    /// per-layer quant-health state; `None` until [`TrainObs::init_quant`]
    /// (i.e. until the run turns out to have grid-quantized layers)
    quant: Mutex<Option<QuantObsState>>,
}

/// Per-layer quant-health aggregation plus the pre-registered per-layer
/// metric handles, built once per run by [`TrainObs::init_quant`]. Handle
/// vectors are indexed like `QuantHealth::layers` (manifest grid order).
struct QuantObsState {
    health: QuantHealth,
    /// `QuantHealth` stream-frame cadence in steps (0 = frames off);
    /// see `config::effective_quant_frame_every`
    frame_every: u64,
    flips_total: Vec<Arc<Counter>>,
    flip_rate: Vec<Arc<Gauge>>,
    update_net: Vec<Arc<Gauge>>,
    update_abs: Vec<Arc<Gauge>>,
    scale: Vec<Arc<Gauge>>,
    scale_drift: Vec<Arc<Gauge>>,
    saturation: Vec<Arc<Gauge>>,
    zero_fraction: Vec<Arc<Gauge>>,
    oscillation: Vec<Arc<Gauge>>,
    grad_norm: Vec<Arc<Gauge>>,
}

impl Default for TrainObs {
    fn default() -> Self {
        Self::new()
    }
}

impl TrainObs {
    pub fn new() -> TrainObs {
        let r = Arc::new(Registry::new());
        TrainObs {
            steps_total: r.counter("dqt_train_steps_total", "Optimizer steps completed."),
            loss: r.gauge("dqt_train_loss", "Training loss at the latest step (nats)."),
            lr: r.gauge("dqt_train_lr", "Learning rate at the latest step."),
            sr_update_fraction: r.gauge(
                "dqt_train_sr_update_fraction",
                "Fraction of quantized weights the stochastic-rounding update moved at the latest step.",
            ),
            grad_norm: r.gauge("dqt_train_grad_norm", "Global gradient norm at the latest step."),
            dev_loss: r.gauge(
                "dqt_train_dev_loss",
                "Dev-set loss from the most recent periodic evaluation (nats).",
            ),
            step_seconds: r.histogram(
                "dqt_train_step_seconds",
                "Wall time per optimizer step (seconds).",
                &TIME_BUCKETS,
            ),
            forward_seconds_total: r.counter(
                "dqt_train_forward_seconds_total",
                "Cumulative seconds in forward+backward (loss and gradients).",
            ),
            optimizer_seconds_total: r.counter(
                "dqt_train_optimizer_seconds_total",
                "Cumulative seconds applying optimizer + stochastic-rounding updates.",
            ),
            dist_world: r.gauge(
                "dqt_dist_world",
                "World size of the current run (1 when not distributed).",
            ),
            allreduce_total: r.counter(
                "dqt_dist_allreduce_total",
                "Gradient all-reduce rounds completed.",
            ),
            allreduce_bytes_total: GRAD_FORMATS.map(|f| {
                r.counter_with(
                    "dqt_dist_allreduce_bytes_total",
                    "Bytes sent + received by gradient all-reduce on this rank, by wire format.",
                    &[("format", f)],
                )
            }),
            allreduce_seconds_total: GRAD_FORMATS.map(|f| {
                r.counter_with(
                    "dqt_dist_allreduce_seconds_total",
                    "Cumulative seconds blocked in gradient all-reduce on this rank, by wire format.",
                    &[("format", f)],
                )
            }),
            grid_syncs_total: r.counter(
                "dqt_dist_grid_syncs_total",
                "Periodic packed-grid weight resyncs completed.",
            ),
            grid_sync_bytes_total: r.counter(
                "dqt_dist_grid_sync_bytes_total",
                "Bytes sent + received by packed-grid weight resync on this rank.",
            ),
            registry: r,
            publisher: Mutex::new(None),
            quant: Mutex::new(None),
        }
    }

    /// The registry `GET /metrics` renders.
    pub fn registry(&self) -> Arc<Registry> {
        self.registry.clone()
    }

    /// Attach a step-stream publisher (`--watch-addr`). At most one.
    pub fn set_publisher(&self, publisher: Publisher) {
        *self.publisher.lock().unwrap() = Some(publisher);
    }

    fn publish(&self, frame: &StreamFrame) {
        if let Some(p) = self.publisher.lock().unwrap().as_ref() {
            p.publish(frame);
        }
    }

    /// Record run identity; announces the run to any watch subscribers.
    pub fn on_run_start(&self, variant: &str, dataset: &str, world: u32, total_steps: u64) {
        self.dist_world.set(world as f64);
        self.publish(&StreamFrame::RunStart {
            variant: variant.to_string(),
            dataset: dataset.to_string(),
            world,
            total_steps,
        });
    }

    /// Record one completed optimizer step. `fwd_ms`/`opt_ms` are the
    /// backend's phase timings (0 when the backend does not report them).
    pub fn on_step(&self, r: &StepRecord, fwd_ms: f32, opt_ms: f32) {
        self.steps_total.inc();
        self.loss.set(r.loss as f64);
        self.lr.set(r.lr as f64);
        self.sr_update_fraction.set(r.upd_frac as f64);
        self.grad_norm.set(r.gnorm as f64);
        self.step_seconds.observe(r.step_ms as f64 / 1e3);
        self.forward_seconds_total.add(fwd_ms as f64 / 1e3);
        self.optimizer_seconds_total.add(opt_ms as f64 / 1e3);
        self.publish(&StreamFrame::Step {
            step: r.step,
            loss: r.loss,
            lr: r.lr,
            upd_frac: r.upd_frac,
            gnorm: r.gnorm,
            step_ms: r.step_ms,
        });
    }

    /// Record a periodic dev evaluation.
    pub fn on_dev_loss(&self, loss: f32) {
        self.dev_loss.set(loss as f64);
    }

    /// Register the per-layer quant-health series and size the run
    /// aggregate. `layers` = (manifest param name, element count) per
    /// grid tensor, in grid order — each param name becomes the `layer`
    /// label value of every series. Called once per run by `Trainer`
    /// when the variant has grid-quantized layers; registration is
    /// idempotent per `(name, labels)`, so re-initializing with the same
    /// layer set reuses the existing handles.
    pub fn init_quant(&self, layers: &[(String, u64)]) {
        let r = &self.registry;
        let gauges = |name: &str, help: &str| -> Vec<Arc<Gauge>> {
            layers
                .iter()
                .map(|(l, _)| r.gauge_with(name, help, &[("layer", l.as_str())]))
                .collect()
        };
        let state = QuantObsState {
            health: QuantHealth::new(layers),
            frame_every: crate::config::effective_quant_frame_every(None),
            flips_total: layers
                .iter()
                .map(|(l, _)| {
                    r.counter_with(
                        "dqt_train_quant_flips_total",
                        "Grid-level flips (weights whose stored quantized value changed) accumulated over the run, per layer.",
                        &[("layer", l.as_str())],
                    )
                })
                .collect(),
            flip_rate: gauges(
                "dqt_train_quant_flip_rate",
                "Run-average grid flips per weight per step, per layer.",
            ),
            update_net: gauges(
                "dqt_train_quant_update_net_grid_steps",
                "Latest step's mean signed weight update per weight, in grid-step units, per layer.",
            ),
            update_abs: gauges(
                "dqt_train_quant_update_abs_grid_steps",
                "Latest step's mean absolute weight update per weight, in grid-step units, per layer.",
            ),
            scale: gauges(
                "dqt_train_quant_scale",
                "Stored inverse scale (the `.s` companion) after the latest step, per layer.",
            ),
            scale_drift: gauges(
                "dqt_train_quant_scale_drift",
                "Relative change of the stored scale at the latest step, per layer.",
            ),
            saturation: gauges(
                "dqt_train_quant_saturation",
                "Fraction of weights at the extreme grid levels after the latest step, per layer.",
            ),
            zero_fraction: gauges(
                "dqt_train_quant_zero_fraction",
                "Fraction of weights at the zero grid level after the latest step, per layer.",
            ),
            oscillation: gauges(
                "dqt_train_quant_oscillation",
                "EMA of sign-alternating flip steps (A<->B<->A reversals), per layer.",
            ),
            grad_norm: gauges(
                "dqt_train_quant_grad_norm",
                "Post-clip gradient norm over the layer's weights at the latest step, per layer.",
            ),
        };
        *self.quant.lock().unwrap() = Some(state);
    }

    /// Fold one step's raw per-layer stats into the run aggregate, update
    /// the per-layer series, and (on the configured cadence) publish a
    /// [`StreamFrame::QuantHealth`]. No-op until [`TrainObs::init_quant`].
    pub fn on_quant(&self, step: u64, rec: &QuantStepRecord) {
        let mut guard = self.quant.lock().unwrap();
        let Some(q) = guard.as_mut() else { return };
        q.health.record_step(rec);
        for (i, l) in q.health.layers.iter().enumerate() {
            q.flips_total[i].inc_by(l.last_flips);
            q.flip_rate[i].set(l.flip_rate());
            q.update_net[i].set(l.net_upd_grid_steps as f64);
            q.update_abs[i].set(l.abs_upd_grid_steps as f64);
            q.scale[i].set(l.scale as f64);
            q.scale_drift[i].set(l.scale_drift as f64);
            q.saturation[i].set(l.saturation as f64);
            q.zero_fraction[i].set(l.zero_frac as f64);
            q.oscillation[i].set(l.oscillation as f64);
            q.grad_norm[i].set(l.grad_norm as f64);
        }
        if q.frame_every == 0 || step % q.frame_every != 0 {
            return;
        }
        let frame = StreamFrame::QuantHealth {
            step,
            layers: q
                .health
                .layers
                .iter()
                .map(|l| QuantLayerFrame {
                    name: l.name.clone(),
                    flips: l.last_flips,
                    flip_rate: l.flip_rate() as f32,
                    abs_upd: l.abs_upd_grid_steps,
                    scale: l.scale,
                    saturation: l.saturation,
                    zero_frac: l.zero_frac,
                    oscillation: l.oscillation,
                    grad_norm: l.grad_norm,
                })
                .collect(),
        };
        drop(guard);
        self.publish(&frame);
    }

    /// Snapshot of the run aggregate (`None` until [`TrainObs::init_quant`]).
    pub fn quant_health(&self) -> Option<QuantHealth> {
        self.quant.lock().unwrap().as_ref().map(|q| q.health.clone())
    }

    /// Persist `quant_health.json` under the run's out dir. No-op `Ok`
    /// when the run had no grid-quantized layers.
    pub fn save_quant_health(&self, dir: &Path) -> Result<()> {
        if let Some(q) = self.quant.lock().unwrap().as_ref() {
            q.health.save(dir)?;
        }
        Ok(())
    }

    /// Record one gradient all-reduce round: wire bytes moved on this
    /// rank and wall time blocked, accounted under the run's gradient
    /// wire format (`f32|int8|ternary` — the `format` label).
    pub fn on_allreduce(&self, format: &str, bytes: u64, elapsed: Duration) {
        let i = grad_format_idx(format);
        self.allreduce_total.inc();
        self.allreduce_bytes_total[i].inc_by(bytes);
        self.allreduce_seconds_total[i].add(elapsed.as_secs_f64());
    }

    /// Record one packed-grid weight resync.
    pub fn on_grid_sync(&self, bytes: u64) {
        self.grid_syncs_total.inc();
        self.grid_sync_bytes_total.inc_by(bytes);
    }

    /// Record run completion; tells watch subscribers to disconnect.
    pub fn on_run_end(&self, final_dev_loss: Option<f32>, wall_secs: f64) {
        if let Some(l) = final_dev_loss {
            self.dev_loss.set(l as f64);
        }
        self.publish(&StreamFrame::RunEnd {
            final_dev_loss: final_dev_loss.unwrap_or(f32::NAN),
            wall_secs,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64) -> StepRecord {
        StepRecord {
            step,
            loss: 4.5,
            lr: 1e-3,
            upd_frac: 0.02,
            gnorm: 1.25,
            step_ms: 20.0,
        }
    }

    #[test]
    fn steps_and_dist_events_land_in_the_registry() {
        let obs = TrainObs::new();
        obs.on_run_start("t130-dqt", "tiny", 2, 100);
        obs.on_step(&rec(0), 15.0, 5.0);
        obs.on_step(&rec(1), 15.0, 5.0);
        obs.on_dev_loss(4.25);
        obs.on_allreduce("f32", 1024, Duration::from_millis(3));
        obs.on_allreduce("int8", 256, Duration::from_millis(1));
        obs.on_grid_sync(256);
        obs.on_run_end(Some(4.0), 1.5);

        let text = obs.registry().render();
        assert!(text.contains("dqt_train_steps_total 2\n"), "{text}");
        assert!(text.contains("dqt_train_loss 4.5\n"), "{text}");
        assert!(text.contains("dqt_train_dev_loss 4\n"), "{text}");
        assert!(text.contains("dqt_dist_world 2\n"), "{text}");
        // all-reduce traffic splits by wire format
        assert!(
            text.contains("dqt_dist_allreduce_bytes_total{format=\"f32\"} 1024\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_dist_allreduce_bytes_total{format=\"int8\"} 256\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_dist_allreduce_bytes_total{format=\"ternary\"} 0\n"),
            "{text}"
        );
        assert!(text.contains("dqt_dist_allreduce_total 2\n"), "{text}");
        assert!(text.contains("dqt_dist_grid_sync_bytes_total 256\n"), "{text}");
        assert!(text.contains("dqt_train_step_seconds_count 2\n"), "{text}");
        // 20 ms lands in the 0.02 s bucket
        assert!(
            text.contains("dqt_train_step_seconds_bucket{le=\"0.02\"} 2\n"),
            "{text}"
        );
    }

    #[test]
    fn quant_health_series_land_in_the_registry_per_layer() {
        let obs = TrainObs::new();
        obs.init_quant(&[("layers.0.wq".to_string(), 4)]);
        let mut qrec = QuantStepRecord::new(1);
        qrec.slots[0] = crate::obs::quant::LayerStep {
            n: 4,
            flips: 2,
            flips_up: 2,
            flips_down: 0,
            net_upd: 2.0,
            abs_upd: 2.0,
            occupancy: [1, 0, 2, 0, 1],
            scale: 2.0,
            gsq: 4.0,
        };
        obs.on_quant(0, &qrec);
        obs.on_quant(1, &qrec);
        let text = obs.registry().render();
        assert!(
            text.contains("dqt_train_quant_flips_total{layer=\"layers.0.wq\"} 4\n"),
            "{text}"
        );
        // 4 flips / (4 weights × 2 steps)
        assert!(
            text.contains("dqt_train_quant_flip_rate{layer=\"layers.0.wq\"} 0.5\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_train_quant_scale{layer=\"layers.0.wq\"} 2\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_train_quant_saturation{layer=\"layers.0.wq\"} 0.5\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_train_quant_grad_norm{layer=\"layers.0.wq\"} 2\n"),
            "{text}"
        );
        // before init_quant, on_quant is a no-op and registers nothing
        let idle = TrainObs::new();
        idle.on_quant(0, &qrec);
        assert!(!idle.registry().render().contains("dqt_train_quant"));
        assert!(idle.quant_health().is_none());
        assert_eq!(obs.quant_health().unwrap().steps, 2);
    }

    #[test]
    fn steps_stream_to_an_attached_publisher() {
        let obs = TrainObs::new();
        let publisher = Publisher::bind("127.0.0.1:0").unwrap();
        let addr = publisher.local_addr().to_string();
        obs.set_publisher(publisher);
        obs.on_run_start("t130-dqt", "tiny", 1, 10);

        let tail = std::thread::spawn(move || {
            let mut seen = Vec::new();
            super::super::stream::watch(&addr, Duration::from_secs(10), |f| {
                seen.push(f.clone());
            })
            .unwrap();
            seen
        });
        // wait for the watcher to connect before streaming steps
        let t0 = std::time::Instant::now();
        loop {
            let joined = obs
                .publisher
                .lock()
                .unwrap()
                .as_ref()
                .map(|p| p.subscribers())
                .unwrap_or(0);
            if joined > 0 {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "watcher never joined");
            std::thread::sleep(Duration::from_millis(10));
        }
        obs.on_step(&rec(0), 0.0, 0.0);
        obs.on_run_end(None, 0.5);

        let seen = tail.join().unwrap();
        assert!(matches!(seen[0], StreamFrame::RunStart { world: 1, .. }));
        assert!(matches!(seen[1], StreamFrame::Step { step: 0, .. }));
        match seen[2] {
            StreamFrame::RunEnd { final_dev_loss, .. } => assert!(final_dev_loss.is_nan()),
            ref other => panic!("expected RunEnd, got {other:?}"),
        }
    }
}
