//! `TrainObs`: the training/distributed instrumentation handle.
//!
//! One `Arc<TrainObs>` rides through a run the way `kernels::Pool` does:
//! created default-on by `Trainer::new`, cloned into the dist exchange,
//! and recorded into from hot paths without allocating — every metric is
//! a pre-registered atomic in the owned [`Registry`]. The registry is
//! what `GET /metrics` renders (via [`obs::MetricsServer`]); an optional
//! [`Publisher`] attached with [`TrainObs::set_publisher`] additionally
//! streams one [`StreamFrame::Step`] per optimizer step for
//! `repro watch --join`. With no metrics address and no watch address
//! configured, the handle is pure atomics — no sockets, no threads.
//!
//! Metric names and units are the documented contract in
//! `docs/OBSERVABILITY.md`; `tests/obs_contract.rs` pins every name
//! registered here to an entry in that doc.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::registry::{Counter, Gauge, Histogram, Registry};
use super::stream::{Publisher, StreamFrame};
use crate::train::metrics::StepRecord;

/// Bucket bounds (seconds) shared by the step-time histogram and the
/// serve-side latency histograms — fixed so rendered output is stable.
pub const TIME_BUCKETS: [f64; 11] = [
    0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 10.0, 60.0,
];

/// The `format` label values of the per-format all-reduce series — the
/// gradient wire tiers of `--grad-format`. Order is the series index.
pub const GRAD_FORMATS: [&str; 3] = ["f32", "int8", "ternary"];

/// Index of a gradient-format tag into [`GRAD_FORMATS`]-shaped series;
/// unknown tags account under `f32` rather than dropping the sample.
fn grad_format_idx(format: &str) -> usize {
    GRAD_FORMATS.iter().position(|f| *f == format).unwrap_or(0)
}

/// Training + distributed metrics bundle. Field docs double as the
/// metric help strings.
pub struct TrainObs {
    registry: Arc<Registry>,
    publisher: Mutex<Option<Publisher>>,

    steps_total: Arc<Counter>,
    loss: Arc<Gauge>,
    lr: Arc<Gauge>,
    sr_update_fraction: Arc<Gauge>,
    grad_norm: Arc<Gauge>,
    dev_loss: Arc<Gauge>,
    step_seconds: Arc<Histogram>,
    forward_seconds_total: Arc<Counter>,
    optimizer_seconds_total: Arc<Counter>,

    dist_world: Arc<Gauge>,
    allreduce_total: Arc<Counter>,
    /// one series per `--grad-format` tier, indexed by [`GRAD_FORMATS`]
    allreduce_bytes_total: [Arc<Counter>; 3],
    allreduce_seconds_total: [Arc<Counter>; 3],
    grid_syncs_total: Arc<Counter>,
    grid_sync_bytes_total: Arc<Counter>,
}

impl Default for TrainObs {
    fn default() -> Self {
        Self::new()
    }
}

impl TrainObs {
    pub fn new() -> TrainObs {
        let r = Arc::new(Registry::new());
        TrainObs {
            steps_total: r.counter("dqt_train_steps_total", "Optimizer steps completed."),
            loss: r.gauge("dqt_train_loss", "Training loss at the latest step (nats)."),
            lr: r.gauge("dqt_train_lr", "Learning rate at the latest step."),
            sr_update_fraction: r.gauge(
                "dqt_train_sr_update_fraction",
                "Fraction of quantized weights the stochastic-rounding update moved at the latest step.",
            ),
            grad_norm: r.gauge("dqt_train_grad_norm", "Global gradient norm at the latest step."),
            dev_loss: r.gauge(
                "dqt_train_dev_loss",
                "Dev-set loss from the most recent periodic evaluation (nats).",
            ),
            step_seconds: r.histogram(
                "dqt_train_step_seconds",
                "Wall time per optimizer step (seconds).",
                &TIME_BUCKETS,
            ),
            forward_seconds_total: r.counter(
                "dqt_train_forward_seconds_total",
                "Cumulative seconds in forward+backward (loss and gradients).",
            ),
            optimizer_seconds_total: r.counter(
                "dqt_train_optimizer_seconds_total",
                "Cumulative seconds applying optimizer + stochastic-rounding updates.",
            ),
            dist_world: r.gauge(
                "dqt_dist_world",
                "World size of the current run (1 when not distributed).",
            ),
            allreduce_total: r.counter(
                "dqt_dist_allreduce_total",
                "Gradient all-reduce rounds completed.",
            ),
            allreduce_bytes_total: GRAD_FORMATS.map(|f| {
                r.counter_with(
                    "dqt_dist_allreduce_bytes_total",
                    "Bytes sent + received by gradient all-reduce on this rank, by wire format.",
                    &[("format", f)],
                )
            }),
            allreduce_seconds_total: GRAD_FORMATS.map(|f| {
                r.counter_with(
                    "dqt_dist_allreduce_seconds_total",
                    "Cumulative seconds blocked in gradient all-reduce on this rank, by wire format.",
                    &[("format", f)],
                )
            }),
            grid_syncs_total: r.counter(
                "dqt_dist_grid_syncs_total",
                "Periodic packed-grid weight resyncs completed.",
            ),
            grid_sync_bytes_total: r.counter(
                "dqt_dist_grid_sync_bytes_total",
                "Bytes sent + received by packed-grid weight resync on this rank.",
            ),
            registry: r,
            publisher: Mutex::new(None),
        }
    }

    /// The registry `GET /metrics` renders.
    pub fn registry(&self) -> Arc<Registry> {
        self.registry.clone()
    }

    /// Attach a step-stream publisher (`--watch-addr`). At most one.
    pub fn set_publisher(&self, publisher: Publisher) {
        *self.publisher.lock().unwrap() = Some(publisher);
    }

    fn publish(&self, frame: &StreamFrame) {
        if let Some(p) = self.publisher.lock().unwrap().as_ref() {
            p.publish(frame);
        }
    }

    /// Record run identity; announces the run to any watch subscribers.
    pub fn on_run_start(&self, variant: &str, dataset: &str, world: u32, total_steps: u64) {
        self.dist_world.set(world as f64);
        self.publish(&StreamFrame::RunStart {
            variant: variant.to_string(),
            dataset: dataset.to_string(),
            world,
            total_steps,
        });
    }

    /// Record one completed optimizer step. `fwd_ms`/`opt_ms` are the
    /// backend's phase timings (0 when the backend does not report them).
    pub fn on_step(&self, r: &StepRecord, fwd_ms: f32, opt_ms: f32) {
        self.steps_total.inc();
        self.loss.set(r.loss as f64);
        self.lr.set(r.lr as f64);
        self.sr_update_fraction.set(r.upd_frac as f64);
        self.grad_norm.set(r.gnorm as f64);
        self.step_seconds.observe(r.step_ms as f64 / 1e3);
        self.forward_seconds_total.add(fwd_ms as f64 / 1e3);
        self.optimizer_seconds_total.add(opt_ms as f64 / 1e3);
        self.publish(&StreamFrame::Step {
            step: r.step,
            loss: r.loss,
            lr: r.lr,
            upd_frac: r.upd_frac,
            gnorm: r.gnorm,
            step_ms: r.step_ms,
        });
    }

    /// Record a periodic dev evaluation.
    pub fn on_dev_loss(&self, loss: f32) {
        self.dev_loss.set(loss as f64);
    }

    /// Record one gradient all-reduce round: wire bytes moved on this
    /// rank and wall time blocked, accounted under the run's gradient
    /// wire format (`f32|int8|ternary` — the `format` label).
    pub fn on_allreduce(&self, format: &str, bytes: u64, elapsed: Duration) {
        let i = grad_format_idx(format);
        self.allreduce_total.inc();
        self.allreduce_bytes_total[i].inc_by(bytes);
        self.allreduce_seconds_total[i].add(elapsed.as_secs_f64());
    }

    /// Record one packed-grid weight resync.
    pub fn on_grid_sync(&self, bytes: u64) {
        self.grid_syncs_total.inc();
        self.grid_sync_bytes_total.inc_by(bytes);
    }

    /// Record run completion; tells watch subscribers to disconnect.
    pub fn on_run_end(&self, final_dev_loss: Option<f32>, wall_secs: f64) {
        if let Some(l) = final_dev_loss {
            self.dev_loss.set(l as f64);
        }
        self.publish(&StreamFrame::RunEnd {
            final_dev_loss: final_dev_loss.unwrap_or(f32::NAN),
            wall_secs,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64) -> StepRecord {
        StepRecord {
            step,
            loss: 4.5,
            lr: 1e-3,
            upd_frac: 0.02,
            gnorm: 1.25,
            step_ms: 20.0,
        }
    }

    #[test]
    fn steps_and_dist_events_land_in_the_registry() {
        let obs = TrainObs::new();
        obs.on_run_start("t130-dqt", "tiny", 2, 100);
        obs.on_step(&rec(0), 15.0, 5.0);
        obs.on_step(&rec(1), 15.0, 5.0);
        obs.on_dev_loss(4.25);
        obs.on_allreduce("f32", 1024, Duration::from_millis(3));
        obs.on_allreduce("int8", 256, Duration::from_millis(1));
        obs.on_grid_sync(256);
        obs.on_run_end(Some(4.0), 1.5);

        let text = obs.registry().render();
        assert!(text.contains("dqt_train_steps_total 2\n"), "{text}");
        assert!(text.contains("dqt_train_loss 4.5\n"), "{text}");
        assert!(text.contains("dqt_train_dev_loss 4\n"), "{text}");
        assert!(text.contains("dqt_dist_world 2\n"), "{text}");
        // all-reduce traffic splits by wire format
        assert!(
            text.contains("dqt_dist_allreduce_bytes_total{format=\"f32\"} 1024\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_dist_allreduce_bytes_total{format=\"int8\"} 256\n"),
            "{text}"
        );
        assert!(
            text.contains("dqt_dist_allreduce_bytes_total{format=\"ternary\"} 0\n"),
            "{text}"
        );
        assert!(text.contains("dqt_dist_allreduce_total 2\n"), "{text}");
        assert!(text.contains("dqt_dist_grid_sync_bytes_total 256\n"), "{text}");
        assert!(text.contains("dqt_train_step_seconds_count 2\n"), "{text}");
        // 20 ms lands in the 0.02 s bucket
        assert!(
            text.contains("dqt_train_step_seconds_bucket{le=\"0.02\"} 2\n"),
            "{text}"
        );
    }

    #[test]
    fn steps_stream_to_an_attached_publisher() {
        let obs = TrainObs::new();
        let publisher = Publisher::bind("127.0.0.1:0").unwrap();
        let addr = publisher.local_addr().to_string();
        obs.set_publisher(publisher);
        obs.on_run_start("t130-dqt", "tiny", 1, 10);

        let tail = std::thread::spawn(move || {
            let mut seen = Vec::new();
            super::super::stream::watch(&addr, Duration::from_secs(10), |f| {
                seen.push(f.clone());
            })
            .unwrap();
            seen
        });
        // wait for the watcher to connect before streaming steps
        let t0 = std::time::Instant::now();
        loop {
            let joined = obs
                .publisher
                .lock()
                .unwrap()
                .as_ref()
                .map(|p| p.subscribers())
                .unwrap_or(0);
            if joined > 0 {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "watcher never joined");
            std::thread::sleep(Duration::from_millis(10));
        }
        obs.on_step(&rec(0), 0.0, 0.0);
        obs.on_run_end(None, 0.5);

        let seen = tail.join().unwrap();
        assert!(matches!(seen[0], StreamFrame::RunStart { world: 1, .. }));
        assert!(matches!(seen[1], StreamFrame::Step { step: 0, .. }));
        match seen[2] {
            StreamFrame::RunEnd { final_dev_loss, .. } => assert!(final_dev_loss.is_nan()),
            ref other => panic!("expected RunEnd, got {other:?}"),
        }
    }
}
