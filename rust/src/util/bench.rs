//! Mini benchmark harness (in-tree criterion substitute).
//!
//! Warmup + timed iterations with mean / p50 / p95 / throughput reporting,
//! plus a JSON record per benchmark appended under `results/bench/` so the
//! perf pass can diff before/after (EXPERIMENTS.md §Perf).

use std::time::{Duration, Instant};

pub struct Bench {
    group: String,
    /// minimum measuring time per benchmark
    pub measure_time: Duration,
    pub warmup_time: Duration,
    /// thread count stamped on subsequent records; `None` = the process
    /// environment's (`config::effective_threads`). Benchmarks that run
    /// on an explicit `kernels::Pool` must set this so the recorded
    /// configuration matches the pool actually used.
    threads_override: Option<usize>,
    records: Vec<Record>,
}

#[derive(Clone, Debug)]
pub struct Record {
    pub group: String,
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub bytes: Option<u64>,
    pub elements: Option<u64>,
    /// kernel worker threads active in this process (`--threads` /
    /// `DQT_THREADS` / cores) — recorded so every perf number is
    /// attributable to a configuration
    pub threads: usize,
}

impl Record {
    fn report(&self) {
        let mut line = format!(
            "{}/{:<36} {:>12} mean  {:>12} p50  {:>12} p95",
            self.group,
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns)
        );
        if let Some(b) = self.bytes {
            line.push_str(&format!(
                "  {:>10.2} GB/s",
                b as f64 / self.mean_ns * 1e9 / 1e9
            ));
        }
        if let Some(e) = self.elements {
            line.push_str(&format!(
                "  {:>12.0} elem/s",
                e as f64 / self.mean_ns * 1e9
            ));
        }
        println!("{line}  ({} iters)", self.iters);
    }

    fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::obj()
            .set("group", self.group.as_str())
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_ns", self.mean_ns)
            .set("p50_ns", self.p50_ns)
            .set("p95_ns", self.p95_ns)
            .set("bytes", self.bytes.map(Value::from).unwrap_or(Value::Null))
            .set(
                "elements",
                self.elements.map(Value::from).unwrap_or(Value::Null),
            )
            .set("threads", self.threads)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // honor DQT_BENCH_FAST=1 for CI-speed runs
        let fast = std::env::var("DQT_BENCH_FAST").is_ok();
        Bench {
            group: group.to_string(),
            measure_time: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            warmup_time: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(500)
            },
            threads_override: None,
            records: Vec::new(),
        }
    }

    /// Stamp subsequent records with an explicit thread count (the pool
    /// the benchmark actually runs on) instead of the process default.
    pub fn set_threads(&mut self, threads: usize) -> &mut Self {
        self.threads_override = Some(threads);
        self
    }

    /// Benchmark `f`, which performs ONE iteration per call.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &mut Self {
        self.bench_inner(name, None, None, &mut || {
            std::hint::black_box(f());
        })
    }

    /// Benchmark with a bytes-throughput annotation.
    pub fn bench_bytes<T>(
        &mut self,
        name: &str,
        bytes: u64,
        mut f: impl FnMut() -> T,
    ) -> &mut Self {
        self.bench_inner(name, Some(bytes), None, &mut || {
            std::hint::black_box(f());
        })
    }

    /// Benchmark with an elements-throughput annotation.
    pub fn bench_elements<T>(
        &mut self,
        name: &str,
        elements: u64,
        mut f: impl FnMut() -> T,
    ) -> &mut Self {
        self.bench_inner(name, None, Some(elements), &mut || {
            std::hint::black_box(f());
        })
    }

    fn bench_inner(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        elements: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &mut Self {
        // warmup
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup_time || warm_iters < 1 {
            f();
            warm_iters += 1;
        }
        // measure
        let mut samples: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure_time || samples.len() < 5 {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let rec = Record {
            group: self.group.clone(),
            name: name.to_string(),
            iters: samples.len() as u64,
            mean_ns: mean,
            p50_ns: p(0.5),
            p95_ns: p(0.95),
            bytes,
            elements,
            threads: self
                .threads_override
                .unwrap_or_else(|| crate::config::effective_threads(None)),
        };
        rec.report();
        self.records.push(rec);
        self
    }

    /// Mean nanoseconds of an already-recorded benchmark — lets a bench
    /// binary assert acceptance floors on itself (e.g. the fast-tier
    /// kernels must beat the exact tier at equal thread count).
    pub fn mean_ns(&self, name: &str) -> Option<f64> {
        self.records.iter().find(|r| r.name == name).map(|r| r.mean_ns)
    }

    /// Write all records as JSON under `results/bench/<group>.json`.
    pub fn save(&self) {
        use crate::util::json::Value;
        let dir = crate::default_results_root().join("bench");
        if std::fs::create_dir_all(&dir).is_err() {
            return;
        }
        let arr = Value::Arr(self.records.iter().map(|r| r.to_json()).collect());
        let _ = std::fs::write(dir.join(format!("{}.json", self.group)), arr.to_string_pretty());
    }
}

impl Drop for Bench {
    fn drop(&mut self) {
        self.save();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new("selftest");
        b.measure_time = Duration::from_millis(20);
        b.warmup_time = Duration::from_millis(5);
        b.bench("noop", || 1 + 1);
        assert_eq!(b.records.len(), 1);
        assert!(b.records[0].iters >= 5);
        assert!(b.records[0].mean_ns > 0.0);
        assert!(b.records[0].threads >= 1);
        b.records.clear(); // avoid writing results in unit tests
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12e3).ends_with("µs"));
        assert!(fmt_ns(12e6).ends_with("ms"));
        assert!(fmt_ns(12e9).ends_with('s'));
    }
}
