//! Minimal JSON codec (parser + writer) — in-tree substrate.
//!
//! The offline build environment vendors no serde, so the L2↔L3 contract
//! (manifest.json, index.json) and all result files go through this ~300-
//! line implementation. It supports the full JSON grammar we emit/consume:
//! objects (insertion-ordered), arrays, strings with escapes (incl. \uXXXX),
//! f64 numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------
    pub fn obj() -> Value {
        Value::Obj(Vec::new())
    }
    pub fn set(mut self, key: &str, v: impl Into<Value>) -> Value {
        if let Value::Obj(ref mut kv) = self {
            kv.push((key.to_string(), v.into()));
        }
        self
    }

    // -- serialization -----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 9.0e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<f32> for Value {
    fn from(n: f32) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i32> for Value {
    fn from(n: i32) -> Self {
        Value::Num(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<Vec<Value>> for Value {
    fn from(a: Vec<Value>) -> Self {
        Value::Arr(a)
    }
}
impl<T: Into<Value> + Clone> From<&[T]> for Value {
    fn from(a: &[T]) -> Self {
        Value::Arr(a.iter().cloned().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Value>> for Value {
    fn from(m: BTreeMap<String, Value>) -> Self {
        Value::Obj(m.into_iter().collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        self.i += 1;
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() != Some(c) {
            bail!("expected {:?} at byte {}", c as char, self.i - 1);
        }
        Ok(())
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(kv)),
                _ => bail!("expected ',' or '}}' at byte {}", self.i - 1),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => bail!("expected ',' or ']' at byte {}", self.i - 1),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => bail!("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hex = self
                            .b
                            .get(self.i..self.i + 4)
                            .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        self.i += 4;
                        let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                        // surrogate pairs
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                bail!("unpaired surrogate");
                            }
                            let hex2 = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            self.i += 4;
                            let lo = u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        s.push(char::from_u32(c).ok_or_else(|| anyhow!("bad codepoint"))?);
                    }
                    other => bail!("bad escape {:?}", other.map(|c| c as char)),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the full sequence
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Value::obj()
            .set("name", "dqt")
            .set("bits", 1.58)
            .set("n", 42u64)
            .set("flag", true)
            .set("arr", Value::Arr(vec![1i32.into(), 2i32.into()]));
        for text in [v.to_string(), v.to_string_pretty()] {
            let back = parse(&text).unwrap();
            assert_eq!(back, v, "{text}");
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote\" backslash\\ newline\n tab\t unicode\u{1F600}é";
        let v = Value::Str(s.into());
        assert_eq!(parse(&v.to_string()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
        assert_eq!(parse(r#""😀""#).unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Value::Num(42.0).to_string(), "42");
        assert_eq!(Value::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn parses_python_manifest_style() {
        let text = r#"{
 "variant": {"model": {"name": "test", "param_count": 22688}, "bits": 1.58},
 "params": [{"name": "emb", "shape": [64, 32], "dtype": "float32", "role": "dense"}],
 "entries": ["init", "train_step"]
}"#;
        let v = parse(text).unwrap();
        assert_eq!(
            v.req("variant").unwrap().req("model").unwrap().req("name").unwrap().as_str(),
            Some("test")
        );
        assert_eq!(
            v.req("params").unwrap().as_arr().unwrap()[0]
                .req("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect::<Vec<_>>(),
            vec![64, 32]
        );
    }
}
