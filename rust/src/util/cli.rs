//! Tiny CLI argument parser (in-tree clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! typed getters with defaults and a rendered usage/help string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse raw args (without argv[0]). `--x=1`, `--x 1` and bare `--x`
    /// all work; a bare flag stores an empty string.
    pub fn parse(raw: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.flags
                        .entry(name.to_string())
                        .or_default()
                        .push(raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.entry(name.to_string()).or_default().push(String::new());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&raw)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn req(&self, key: &str) -> Result<String> {
        self.get(key)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None | Some("") => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{key} {s:?}: {e}")),
        }
    }

    /// Reject unknown flags (typo guard).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_styles() {
        let a = Args::parse(&s(&["train", "--steps", "300", "--bits=1.58", "--verbose"])).unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("steps"), Some("300"));
        assert_eq!(a.get("bits"), Some("1.58"));
        assert!(a.has("verbose"));
        assert_eq!(a.parse_or("steps", 0u64).unwrap(), 300);
        assert_eq!(a.parse_or("missing", 7u64).unwrap(), 7);
    }

    #[test]
    fn typed_errors() {
        let a = Args::parse(&s(&["--steps", "abc"])).unwrap();
        assert!(a.parse_or("steps", 0u64).is_err());
        assert!(a.req("nope").is_err());
    }

    #[test]
    fn unknown_flag_guard() {
        let a = Args::parse(&s(&["--goood", "1"])).unwrap();
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["goood"]).is_ok());
    }

    #[test]
    fn repeated_flags_keep_last() {
        let a = Args::parse(&s(&["--x", "1", "--x", "2"])).unwrap();
        assert_eq!(a.get("x"), Some("2"));
        assert_eq!(a.flags["x"].len(), 2);
    }

    #[test]
    fn negative_number_values() {
        // `--lr -0.5` — the value starts with '-' but not '--'
        let a = Args::parse(&s(&["--lr", "-0.5"])).unwrap();
        assert_eq!(a.parse_or("lr", 0.0f64).unwrap(), -0.5);
    }
}
