//! In-tree substrates for an offline build: JSON codec, CLI parser,
//! benchmark harness, property-testing harness (serde/clap/criterion/
//! proptest are not vendored in this environment — see DESIGN.md).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
