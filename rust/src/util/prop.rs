//! Mini property-testing harness (in-tree proptest substitute).
//!
//! `check(cases, gen, prop)` runs `prop` on `cases` generated inputs from a
//! seeded RNG; on failure it retries with the recorded case seed in the
//! panic message so failures are reproducible. Generators are plain
//! closures over [`crate::data::corpus::Rng`].

use crate::data::corpus::Rng;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the failing
/// case's seed and a debug dump of the input.
pub fn check<T: std::fmt::Debug>(
    cases: u32,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = std::env::var("DQT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD97u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed on case {case} (DQT_PROP_SEED={base}): input = {input:#?}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::data::corpus::Rng;

    pub fn f32_in(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * rng.next_f64() as f32
    }

    pub fn vec_f32(rng: &mut Rng, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = 1 + rng.below(max_len.max(1));
        (0..n).map(|_| f32_in(rng, lo, hi)).collect()
    }

    pub fn vec_i32(rng: &mut Rng, max_len: usize, lo: i32, hi: i32) -> Vec<i32> {
        let n = 1 + rng.below(max_len.max(1));
        (0..n)
            .map(|_| lo + rng.below((hi - lo + 1) as usize) as i32)
            .collect()
    }

    pub fn ascii_string(rng: &mut Rng, max_len: usize) -> String {
        let n = rng.below(max_len.max(1));
        (0..n)
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_valid_property() {
        check(
            50,
            |rng| gen::vec_i32(rng, 20, -5, 5),
            |v| v.iter().all(|&x| (-5..=5).contains(&x)),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(50, |rng| rng.below(10), |&x| x < 5);
    }

    #[test]
    fn deterministic_generation() {
        let mut a = Vec::new();
        check(
            5,
            |rng| gen::vec_f32(rng, 4, 0.0, 1.0),
            |v| {
                a.push(v.clone());
                true
            },
        );
        let mut b = Vec::new();
        check(
            5,
            |rng| gen::vec_f32(rng, 4, 0.0, 1.0),
            |v| {
                b.push(v.clone());
                true
            },
        );
        assert_eq!(a, b);
    }
}
