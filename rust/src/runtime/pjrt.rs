//! The PJRT backend: compiled AOT artifacts executed through XLA (the
//! only place that touches PJRT executables). Owns the compiled entry
//! points of one variant; state crosses the boundary as f32 literals in
//! the manifest's flat order. Python never runs here — everything comes
//! from `artifacts/`.

use std::path::Path;

use anyhow::{anyhow, Result};

use super::artifact::{ArtifactDir, Manifest};
use super::client::{
    lit_f32, lit_f32_scalar, lit_i32, lit_u32_scalar, scalar_f32, to_vec_f32, Executable, Runtime,
};
use super::{Backend, State, StepMetrics};

/// Compiled entry points of a variant + the manifest that drives buffer
/// layout.
pub struct PjrtBackend {
    pub artifact: ArtifactDir,
    init: Executable,
    train_step: Executable,
    eval_step: Executable,
    logits_step: Executable,
    eval_step_ternary: Option<Executable>,
    logits_step_ternary: Option<Executable>,
}

impl PjrtBackend {
    /// Load + compile every entry point of `variant_name`.
    pub fn load(
        rt: &Runtime,
        artifacts_root: impl AsRef<Path>,
        variant_name: &str,
    ) -> Result<Self> {
        let artifact = ArtifactDir::locate(artifacts_root, variant_name)?;
        let load = |entry: &str| rt.load(artifact.hlo_path(entry));
        let maybe = |entry: &str| -> Result<Option<Executable>> {
            if artifact.has_entry(entry) {
                Ok(Some(rt.load(artifact.hlo_path(entry))?))
            } else {
                Ok(None)
            }
        };
        Ok(PjrtBackend {
            init: load("init")?,
            train_step: load("train_step")?,
            eval_step: load("eval_step")?,
            logits_step: load("logits_step")?,
            eval_step_ternary: maybe("eval_step_ternary")?,
            logits_step_ternary: maybe("logits_step_ternary")?,
            artifact,
        })
    }

    fn split_state(&self, outs: Vec<xla::Literal>) -> Result<(State, Vec<xla::Literal>)> {
        let m = self.manifest();
        let n_p = m.params.len();
        let n_o = m.opt_state.len();
        if outs.len() < n_p + n_o {
            return Err(anyhow!("expected ≥{} outputs, got {}", n_p + n_o, outs.len()));
        }
        let mut it = outs.into_iter();
        let params: Vec<Vec<f32>> = (&mut it)
            .take(n_p)
            .map(|l| to_vec_f32(&l))
            .collect::<Result<_>>()?;
        let opt: Vec<Vec<f32>> = (&mut it)
            .take(n_o)
            .map(|l| to_vec_f32(&l))
            .collect::<Result<_>>()?;
        Ok((State::from_dense(params, opt), it.collect()))
    }

    fn state_literals(&self, state: &State) -> Result<Vec<xla::Literal>> {
        let m = self.manifest();
        let mut lits = Vec::with_capacity(m.n_state());
        for (meta, p) in m.params.iter().zip(&state.params) {
            lits.push(lit_f32(&p.values()?, &meta.shape)?);
        }
        for (meta, vals) in m.opt_state.iter().zip(&state.opt) {
            lits.push(lit_f32(vals, &meta.shape)?);
        }
        Ok(lits)
    }

    fn param_literals(&self, state: &State) -> Result<Vec<xla::Literal>> {
        let m = self.manifest();
        m.params
            .iter()
            .zip(&state.params)
            .map(|(meta, p)| lit_f32(&p.values()?, &meta.shape))
            .collect()
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.artifact.manifest
    }

    fn init_state(&self, seed: u32) -> Result<State> {
        let outs = self.init.run(&[lit_u32_scalar(seed)?])?;
        let (state, rest) = self.split_state(outs)?;
        if !rest.is_empty() {
            return Err(anyhow!("init returned {} extra outputs", rest.len()));
        }
        Ok(state)
    }

    fn train_step(
        &self,
        state: State,
        tokens: &[i32],
        sr_seed: u32,
        lr: f32,
    ) -> Result<(State, StepMetrics)> {
        let m = self.manifest();
        let mut args = self.state_literals(&state)?;
        args.push(lit_i32(tokens, &m.tokens_shape)?);
        args.push(lit_u32_scalar(sr_seed)?);
        args.push(lit_f32_scalar(lr)?);
        let outs = self.train_step.run(&args)?;
        let (new_state, metrics) = self.split_state(outs)?;
        if metrics.len() != m.train_step_outputs.metrics.len() {
            return Err(anyhow!(
                "expected {} metrics, got {}",
                m.train_step_outputs.metrics.len(),
                metrics.len()
            ));
        }
        Ok((
            new_state,
            StepMetrics {
                loss: scalar_f32(&metrics[0])?,
                upd_frac: scalar_f32(&metrics[1])?,
                gnorm: scalar_f32(&metrics[2])?,
                // the compiled graph reports no phase timings
                fwd_ms: 0.0,
                opt_ms: 0.0,
            },
        ))
    }

    fn eval_step(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<(f32, f32)> {
        let m = self.manifest();
        let exe = if ternary {
            self.eval_step_ternary
                .as_ref()
                .ok_or_else(|| anyhow!("variant has no ternary-inference entry"))?
        } else {
            &self.eval_step
        };
        let mut args = self.param_literals(state)?;
        args.push(lit_i32(tokens, &m.tokens_shape)?);
        let outs = exe.run(&args)?;
        if outs.len() != 2 {
            return Err(anyhow!("eval_step: expected 2 outputs, got {}", outs.len()));
        }
        Ok((scalar_f32(&outs[0])?, scalar_f32(&outs[1])?))
    }

    fn logits(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<Vec<f32>> {
        let m = self.manifest();
        let exe = if ternary {
            self.logits_step_ternary
                .as_ref()
                .ok_or_else(|| anyhow!("variant has no ternary-inference entry"))?
        } else {
            &self.logits_step
        };
        let mut args = self.param_literals(state)?;
        args.push(lit_i32(tokens, &m.logits_tokens_shape)?);
        let outs = exe.run(&args)?;
        if outs.len() != 1 {
            return Err(anyhow!("logits_step: expected 1 output, got {}", outs.len()));
        }
        to_vec_f32(&outs[0])
    }

    fn has_ternary_inference(&self) -> bool {
        self.eval_step_ternary.is_some()
    }
}
