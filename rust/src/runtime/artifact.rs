//! Artifact manifests: the serialized L2↔L3 contract.
//!
//! `python/compile/aot.py` writes one directory per variant containing HLO
//! text for each entry point plus `manifest.json` describing every buffer
//! (name/shape/dtype/role) in flat positional order. The Rust side never
//! hardcodes a parameter layout — it is driven entirely by the manifest.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Value};

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    /// "grid" (on the INTn grid, + has a `.s` companion), "scale", "dense"
    pub role: Option<String>,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
    pub fn is_grid(&self) -> bool {
        self.role.as_deref() == Some("grid")
    }
    pub fn is_scale(&self) -> bool {
        self.role.as_deref() == Some("scale")
    }
}

#[derive(Clone, Debug)]
pub struct OptMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

impl OptMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct TrainStepOutputs {
    pub n_params: usize,
    pub n_opt: usize,
    pub metrics: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct VariantModelMeta {
    pub name: String,
    pub vocab_size: usize,
    pub hidden_size: usize,
    pub num_hidden_layers: usize,
    pub max_seq_len: usize,
    pub batch_size: usize,
    pub param_count: u64,
}

#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub model: VariantModelMeta,
    pub mode: String,
    pub bits: f64,
    pub env: String,
    pub optimizer: String,
    pub intervention: String,
    pub variant_name: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub variant: VariantMeta,
    pub params: Vec<ParamMeta>,
    pub opt_state: Vec<OptMeta>,
    pub tokens_shape: Vec<usize>,
    pub logits_tokens_shape: Vec<usize>,
    pub pad_id: i32,
    pub train_step_outputs: TrainStepOutputs,
    pub entries: Vec<String>,
}

fn shape_of(v: &Value) -> Result<Vec<usize>> {
    Ok(v.as_arr()
        .ok_or_else(|| anyhow!("shape is not an array"))?
        .iter()
        .map(|x| x.as_usize().unwrap_or(0))
        .collect())
}

fn str_of(v: &Value, key: &str) -> Result<String> {
    Ok(v.req(key)?
        .as_str()
        .ok_or_else(|| anyhow!("{key} is not a string"))?
        .to_string())
}

impl Manifest {
    pub fn from_json(v: &Value) -> Result<Manifest> {
        let variant = v.req("variant")?;
        let model = variant.req("model")?;
        let usz = |obj: &Value, key: &str| -> Result<usize> {
            obj.req(key)?
                .as_usize()
                .ok_or_else(|| anyhow!("{key} is not a number"))
        };
        let model_meta = VariantModelMeta {
            name: str_of(model, "name")?,
            vocab_size: usz(model, "vocab_size")?,
            hidden_size: usz(model, "hidden_size")?,
            num_hidden_layers: usz(model, "num_hidden_layers")?,
            max_seq_len: usz(model, "max_seq_len")?,
            batch_size: usz(model, "batch_size")?,
            param_count: model.req("param_count")?.as_u64().unwrap_or(0),
        };
        let variant_meta = VariantMeta {
            model: model_meta,
            mode: str_of(variant, "mode")?,
            bits: variant.req("bits")?.as_f64().unwrap_or(1.58),
            env: str_of(variant, "env")?,
            optimizer: str_of(variant, "optimizer")?,
            intervention: str_of(variant, "intervention")?,
            variant_name: str_of(variant, "variant_name")?,
        };
        let params = v
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow!("params not array"))?
            .iter()
            .map(|p| {
                Ok(ParamMeta {
                    name: str_of(p, "name")?,
                    shape: shape_of(p.req("shape")?)?,
                    dtype: str_of(p, "dtype")?,
                    role: p.get("role").and_then(|r| r.as_str()).map(String::from),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let opt_state = v
            .req("opt_state")?
            .as_arr()
            .ok_or_else(|| anyhow!("opt_state not array"))?
            .iter()
            .map(|o| {
                Ok(OptMeta {
                    name: str_of(o, "name")?,
                    shape: shape_of(o.req("shape")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let tso = v.req("train_step_outputs")?;
        let train_step_outputs = TrainStepOutputs {
            n_params: tso.req("n_params")?.as_usize().unwrap_or(0),
            n_opt: tso.req("n_opt")?.as_usize().unwrap_or(0),
            metrics: tso
                .req("metrics")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|m| m.as_str().map(String::from))
                .collect(),
        };
        Ok(Manifest {
            variant: variant_meta,
            params,
            opt_state,
            tokens_shape: shape_of(v.req("tokens_shape")?)?,
            logits_tokens_shape: shape_of(v.req("logits_tokens_shape")?)?,
            pad_id: v.req("pad_id")?.as_i64().unwrap_or(0) as i32,
            train_step_outputs,
            entries: v
                .req("entries")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|e| e.as_str().map(String::from))
                .collect(),
        })
    }

    pub fn total_param_values(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
    pub fn total_opt_values(&self) -> usize {
        self.opt_state.iter().map(|o| o.numel()).sum()
    }
    pub fn n_state(&self) -> usize {
        self.params.len() + self.opt_state.len()
    }
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }
}

/// One variant's artifact directory on disk.
#[derive(Clone, Debug)]
pub struct ArtifactDir {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl ArtifactDir {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        let v = parse(&text).with_context(|| format!("parsing {}", mpath.display()))?;
        let manifest =
            Manifest::from_json(&v).with_context(|| format!("decoding {}", mpath.display()))?;
        Ok(ArtifactDir { dir, manifest })
    }

    /// Locate `artifacts/<variant>` under the artifacts root.
    pub fn locate(artifacts_root: impl AsRef<Path>, variant_name: &str) -> Result<Self> {
        let dir = artifacts_root.as_ref().join(variant_name);
        if !dir.join("manifest.json").is_file() {
            return Err(anyhow!(
                "artifact {variant_name:?} not built — run `make artifacts` \
                 (or `python -m compile.aot` with the matching flags)"
            ));
        }
        Self::open(dir)
    }

    pub fn hlo_path(&self, entry: &str) -> PathBuf {
        self.dir.join(format!("{entry}.hlo.txt"))
    }

    pub fn has_entry(&self, entry: &str) -> bool {
        self.manifest.entries.iter().any(|e| e == entry)
    }
}

/// Parse `artifacts/index.json` (variant name → summary).
pub fn read_index(artifacts_root: impl AsRef<Path>) -> Result<Vec<String>> {
    let p = artifacts_root.as_ref().join("index.json");
    let idx = parse(&std::fs::read_to_string(&p)?)?;
    Ok(idx
        .as_obj()
        .map(|o| o.iter().map(|(k, _)| k.clone()).collect())
        .unwrap_or_default())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn open_core_manifest() {
        let root = artifacts_root();
        if !root.join("index.json").is_file() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let a = ArtifactDir::locate(&root, "test-dqt-b1p58").unwrap();
        let m = &a.manifest;
        assert_eq!(m.variant.mode, "dqt");
        assert_eq!(m.variant.model.name, "test");
        assert!(m.params.iter().any(|p| p.is_grid()));
        assert!(m.params.iter().any(|p| p.is_scale()));
        assert_eq!(m.train_step_outputs.metrics, ["loss", "upd_frac", "gnorm"]);
        assert!(a.hlo_path("train_step").is_file());
        // grid params are immediately followed by their scale
        for (i, p) in m.params.iter().enumerate() {
            if p.is_grid() {
                assert!(m.params[i + 1].is_scale(), "{}", p.name);
                assert_eq!(m.params[i + 1].name, format!("{}.s", p.name));
            }
        }
    }

    #[test]
    fn locate_missing_is_helpful() {
        let err = ArtifactDir::locate(artifacts_root(), "nope-variant").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
