//! PJRT runtime: load HLO text, compile once, execute from the hot loop.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All entry points were lowered with
//! `return_tuple=True`, so each execution returns one tuple literal that we
//! decompose positionally per the manifest.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

/// Whether a real PJRT runtime is linked into this binary. The in-tree
/// `vendor/xla-stub` reports platform `"stub"` (and cannot compile), so
/// backend auto-selection falls back to the native CPU backend there.
/// Probed once per process — client construction is heavyweight on real
/// PJRT.
pub fn pjrt_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| {
        xla::PjRtClient::cpu()
            .map(|c| !c.platform_name().starts_with("stub"))
            .unwrap_or(false)
    })
}

/// Shared PJRT client (compile + execute). One per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text entry point and compile it.
    pub fn load(&self, hlo_path: impl AsRef<Path>) -> Result<Executable> {
        let path = hlo_path.as_ref();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            compile_secs: t0.elapsed().as_secs_f64(),
        })
    }
}

/// A compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_secs: f64,
}

impl Executable {
    /// Execute with host literals; return the flattened tuple outputs.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        let device0 = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no device outputs", self.name))?;
        let mut literals = Vec::new();
        for buf in device0 {
            let lit = buf
                .to_literal_sync()
                .map_err(|e| anyhow!("{}: fetching output: {e}", self.name))?;
            // return_tuple=True ⇒ outputs arrive as (possibly) one tuple
            match lit.shape() {
                Ok(xla::Shape::Tuple(_)) => {
                    let mut l = lit;
                    literals.extend(
                        l.decompose_tuple()
                            .map_err(|e| anyhow!("{}: decompose: {e}", self.name))?,
                    );
                }
                _ => literals.push(lit),
            }
        }
        Ok(literals)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape from a host slice.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    if data.len() != numel {
        return Err(anyhow!("shape {shape:?} wants {numel} values, got {}", data.len()));
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(|e| anyhow!("creating f32 literal: {e}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product::<usize>().max(1);
    if data.len() != numel {
        return Err(anyhow!("shape {shape:?} wants {numel} values, got {}", data.len()));
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(|e| anyhow!("creating i32 literal: {e}"))
}

/// Scalar u32 literal.
pub fn lit_u32_scalar(v: u32) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U32,
        &[],
        &v.to_le_bytes(),
    )
    .map_err(|e| anyhow!("creating u32 scalar: {e}"))
}

/// Scalar f32 literal.
pub fn lit_f32_scalar(v: f32) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[],
        &v.to_le_bytes(),
    )
    .map_err(|e| anyhow!("creating f32 scalar: {e}"))
}

/// Read an f32 literal back to a host vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal→vec<f32>: {e}"))
}

/// Read a scalar f32 literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("literal→f32 scalar: {e}"))
}
