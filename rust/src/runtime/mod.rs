//! Runtime layer: backend-dispatched execution of one variant's entry
//! points. [`VariantRuntime`] owns a [`Backend`] — either the PJRT path
//! (compiled AOT artifacts, [`pjrt`]) or the pure-Rust CPU reference
//! backend ([`native`]) — plus the typed state (params + optimizer)
//! flowing between steps. `Trainer`, `checkpoint`, `eval`, the
//! coordinator and the examples all drive the same four entry points
//! (`init_state`, `train_step`, `eval_step`, `logits`) and never see
//! which backend executes them.
//!
//! Host state supports two storage modes per parameter ([`Param`]):
//! `Dense` (a plain `Vec<f32>`, what the train loop shuttles) and `Packed`
//! (a [`PackedTensor`] in the grid's true bit width). Packed grid params
//! are decoded to f32 only at the backend boundary, so a resident
//! ternary model really costs ~2 bits/weight on the host — the paper's §1
//! memory claim, realized in RSS instead of only on disk.

pub mod artifact;
pub mod client;
pub mod native;
pub mod pjrt;

use std::borrow::Cow;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::config::{BackendKind, VariantSpec};
use crate::obs::quant::QuantStepRecord;
use crate::quant::codec::{Format, PackedTensor};

pub use artifact::{ArtifactDir, Manifest};
pub use client::{
    lit_f32, lit_f32_scalar, lit_i32, lit_u32_scalar, pjrt_available, scalar_f32, to_vec_f32,
    Executable, Runtime,
};
pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

/// One host-resident parameter: dense f32 values or a packed grid tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Param {
    Dense(Vec<f32>),
    Packed(PackedTensor),
}

impl Param {
    pub fn numel(&self) -> usize {
        match self {
            Param::Dense(v) => v.len(),
            Param::Packed(p) => p.numel(),
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self, Param::Packed(_))
    }

    /// The f32 values: borrowed for dense params, decoded on the fly for
    /// packed ones (the backend-boundary decode). A corrupt packed tensor
    /// (e.g. from a damaged checkpoint) reports as an error instead of
    /// aborting mid-train.
    pub fn values(&self) -> Result<Cow<'_, [f32]>> {
        match self {
            Param::Dense(v) => Ok(Cow::Borrowed(v.as_slice())),
            Param::Packed(p) => Ok(Cow::Owned(
                p.unpack().map_err(|e| anyhow!("decoding packed param: {e}"))?,
            )),
        }
    }

    /// Owned copy of the f32 values.
    pub fn to_vec(&self) -> Result<Vec<f32>> {
        Ok(self.values()?.into_owned())
    }

    /// First element (scalar params: `.s` scales, counters).
    pub fn scalar(&self) -> Result<f32> {
        Ok(self.values()?.first().copied().unwrap_or(0.0))
    }

    /// Heap bytes this param keeps resident on the host: 4·n dense,
    /// `packed_bytes` when packed. The accounting unit of the packed-grid
    /// mode.
    pub fn host_bytes(&self) -> usize {
        match self {
            Param::Dense(v) => v.len() * 4,
            Param::Packed(p) => p.packed_bytes(),
        }
    }
}

impl From<Vec<f32>> for Param {
    fn from(v: Vec<f32>) -> Param {
        Param::Dense(v)
    }
}

/// Host-side copy of the model state in the manifest's flat order.
#[derive(Clone, Debug)]
pub struct State {
    /// one entry per params entry (matrices, norms, `.s` scales)
    pub params: Vec<Param>,
    /// one vec per opt_state entry (step, m/v or vr/vc); always dense
    pub opt: Vec<Vec<f32>>,
}

impl State {
    /// Wrap dense vectors (the backend output shape) into a state.
    pub fn from_dense(params: Vec<Vec<f32>>, opt: Vec<Vec<f32>>) -> State {
        State {
            params: params.into_iter().map(Param::Dense).collect(),
            opt,
        }
    }

    pub fn param_by_name(&self, manifest: &Manifest, name: &str) -> Result<Option<Cow<'_, [f32]>>> {
        match manifest.param_index(name) {
            Some(i) => Ok(Some(self.params[i].values()?)),
            None => Ok(None),
        }
    }

    pub fn step(&self) -> f32 {
        // opt_state[0] is always the scalar step counter
        self.opt.first().and_then(|v| v.first()).copied().unwrap_or(0.0)
    }

    /// Switch to packed-grid mode: every grid param is re-encoded as a
    /// [`PackedTensor`] in the variant's true bit width (its scale read
    /// from the `{name}.s` companion param), freeing the dense copy. Dense
    /// (non-grid) params are untouched. Idempotent.
    pub fn pack_grids(&mut self, manifest: &Manifest) -> Result<()> {
        let fmt = Format::from_bits(manifest.variant.bits);
        for (i, meta) in manifest.params.iter().enumerate() {
            if !meta.is_grid() || self.params[i].is_packed() {
                continue;
            }
            let scale_name = format!("{}.s", meta.name);
            let j = manifest.param_index(&scale_name).ok_or_else(|| {
                anyhow!("grid param {:?} has no companion scale {scale_name:?}", meta.name)
            })?;
            let s = self.params[j].scalar()?;
            let vals = self.params[i].to_vec()?;
            let pt = PackedTensor::pack(&vals, meta.shape.clone(), fmt, Some(s))
                .map_err(|e| anyhow!("packing {:?}: {e}", meta.name))?;
            self.params[i] = Param::Packed(pt);
        }
        Ok(())
    }

    /// Decode every packed param back to dense f32 (inverse of
    /// [`State::pack_grids`]).
    pub fn unpack_grids(&mut self) -> Result<()> {
        for p in &mut self.params {
            if p.is_packed() {
                let dense = p.to_vec()?;
                *p = Param::Dense(dense);
            }
        }
        Ok(())
    }

    /// Host-resident bytes of all params (the packed-grid accounting API).
    pub fn host_param_bytes(&self) -> usize {
        self.params.iter().map(Param::host_bytes).sum()
    }

    /// Host-resident bytes of the grid params only — compare against
    /// `numel × 4` to see the packed-mode reduction.
    pub fn grid_param_bytes(&self, manifest: &Manifest) -> usize {
        manifest
            .params
            .iter()
            .zip(&self.params)
            .filter(|(meta, _)| meta.is_grid())
            .map(|(_, p)| p.host_bytes())
            .sum()
    }
}

/// Metrics returned by one train step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub upd_frac: f32,
    pub gnorm: f32,
    /// forward + backward wall milliseconds (0 when the backend does not
    /// report phase timings)
    pub fwd_ms: f32,
    /// optimizer + SR-update wall milliseconds (cross-rank reduce time is
    /// excluded — the dist exchange accounts it separately)
    pub opt_ms: f32,
}

/// Per-param gradient buffers in the manifest's flat order (`None` for
/// `.s` scales and anything else without a gradient) — the value that
/// crosses the backward → optimizer boundary in the sharded train step.
pub type GradBuffers = Vec<Option<Vec<f32>>>;

/// Hook between backward and the optimizer in
/// [`Backend::train_step_sharded`]: turns one rank's band-partial gradient
/// sums (plus the band's masked-NLL sum and non-pad token count) into the
/// global sums across every band, in place.
///
/// Determinism contract: the reduction must combine rank partials with the
/// *fixed halving tree over ranks* (see `dist::collective::tree_reduce`),
/// which — because contiguous equal bands of a power-of-two world are
/// subtrees of the same halving tree over global batch rows the backend
/// uses within a band — reproduces the 1-worker summation chain bit for
/// bit. [`NoReduce`] is the world-1 identity.
pub trait GradReducer {
    /// Total ranks participating (1 = single process).
    fn world(&self) -> usize {
        1
    }
    /// Reduce the local band partial into the global sum, in place, on
    /// every rank. `step` identifies the train step for cross-rank frame
    /// validation.
    fn reduce(
        &mut self,
        step: u64,
        grads: &mut [Option<Vec<f32>>],
        nll: &mut f32,
        count: &mut u64,
    ) -> Result<()>;
}

/// One internal node of the fixed reduction tree: `left[i] += right[i]`
/// elementwise over aligned gradient buffers. Every cross-row and
/// cross-rank combine in the sharded path goes through this exact loop, so
/// the whole tree's arithmetic is one addition order regardless of where
/// its nodes execute. Errors on layout mismatches (a corrupt or
/// mis-matched peer frame) instead of panicking mid-train.
pub fn add_grad_buffers(
    left: &mut [Option<Vec<f32>>],
    right: &[Option<Vec<f32>>],
) -> Result<()> {
    if left.len() != right.len() {
        return Err(anyhow!(
            "gradient sets disagree: {} vs {} entries",
            left.len(),
            right.len()
        ));
    }
    for (i, (l, r)) in left.iter_mut().zip(right.iter()).enumerate() {
        match (l, r) {
            (Some(l), Some(r)) => {
                if l.len() != r.len() {
                    return Err(anyhow!(
                        "gradient entry {i} disagrees: {} vs {} values",
                        l.len(),
                        r.len()
                    ));
                }
                for (a, &b) in l.iter_mut().zip(r.iter()) {
                    *a += b;
                }
            }
            (None, None) => {}
            _ => return Err(anyhow!("gradient entry {i}: presence mismatch")),
        }
    }
    Ok(())
}

/// The identity reducer: a 1-worker sharded run reduces nothing, so the
/// N-worker contract can be checked against it bit for bit.
pub struct NoReduce;

impl GradReducer for NoReduce {
    fn reduce(
        &mut self,
        _step: u64,
        _grads: &mut [Option<Vec<f32>>],
        _nll: &mut f32,
        _count: &mut u64,
    ) -> Result<()> {
        Ok(())
    }
}

/// Per-sequence KV-cache handle for incremental decoding — created by
/// [`Decoder::new_cache`], advanced by [`Decoder::step_batch`]. Opaque to
/// callers; the concrete layout belongs to the backend that made it.
pub trait DecoderCache: Send {
    /// Number of tokens appended so far (the next token's absolute
    /// position).
    fn position(&self) -> usize;
    /// Forget the sequence (buffers stay allocated for reuse).
    fn reset(&mut self);
    /// Backend-side downcast hook.
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;
}

/// A prepared incremental decoder for one model state: weights resident in
/// their serving form (2-bit packed ternary for the quantized projections,
/// dense f32 for embedding/norms), stepped one token per sequence per call
/// with per-sequence KV caches. Built once per state via
/// [`Backend::decoder`]; shared read-only across serving threads.
pub trait Decoder: Send + Sync {
    /// Positions a cache holds before the ring wraps (the model's trained
    /// sequence length — generation beyond it slides the window).
    fn max_positions(&self) -> usize;
    /// Worker threads the decoder's kernel layer fans matmuls across
    /// (1 = serial). Purely informational: results are bitwise identical
    /// at every thread count.
    fn threads(&self) -> usize {
        1
    }
    /// Kernel numeric tier the decoder's matmuls dispatch on
    /// (`--precision exact|fast`). Informational, like
    /// [`Decoder::threads`] — reported by `/healthz` and `/v1/stats` so
    /// every serving number is attributable to a configuration.
    fn precision(&self) -> crate::config::Precision {
        crate::config::Precision::Exact
    }
    fn vocab_size(&self) -> usize;
    /// KV bytes one sequence adds per cached position
    /// (`2 · n_layer · d_model · 4`).
    fn kv_bytes_per_position(&self) -> usize;
    /// Resident weight bytes in serving form (packed codes + dense f32).
    fn weight_bytes(&self) -> usize;
    /// How many of the projection matmuls run fused off packed codes
    /// (decode-free) vs densely.
    fn packed_projections(&self) -> usize;
    /// Total projection matmuls (`7 · n_layer`).
    fn n_projections(&self) -> usize;
    /// Fresh, empty per-sequence KV cache.
    fn new_cache(&self) -> Box<dyn DecoderCache>;
    /// One batched decode step: append `tokens[i]` to `caches[i]` and
    /// return next-token logits `[len, V]` row-major. Rows are
    /// independent — batching never changes a sequence's numerics.
    fn step_batch(
        &self,
        caches: &mut [&mut dyn DecoderCache],
        tokens: &[i32],
    ) -> Result<Vec<f32>>;
    /// Single-sequence convenience wrapper over [`Decoder::step_batch`].
    fn step(&self, cache: &mut dyn DecoderCache, token: i32) -> Result<Vec<f32>> {
        let mut caches = [cache];
        self.step_batch(&mut caches, &[token])
    }
}

/// One executable variant: the four entry points plus the manifest that
/// drives buffer layout. Implemented by [`PjrtBackend`] (compiled AOT
/// artifacts) and [`NativeBackend`] (pure-Rust CPU reference).
pub trait Backend {
    /// Short backend identifier (`"native"` / `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Worker threads the backend's kernel layer fans matmuls across
    /// (1 = serial; the native backend reports its pool size). A pure
    /// throughput knob — never a numerics knob.
    fn threads(&self) -> usize {
        1
    }

    /// Kernel numeric tier (`--precision exact|fast`): `Exact` keeps the
    /// bitwise-deterministic chains, `Fast` opts into tolerance-gated
    /// SIMD-friendly kernels. The native backend reports its pool's tier.
    fn precision(&self) -> crate::config::Precision {
        crate::config::Precision::Exact
    }

    fn manifest(&self) -> &Manifest;

    /// Run the initializer (LLaMA init + grid projection).
    fn init_state(&self, seed: u32) -> Result<State>;

    /// One training step: consumes `state`, returns the updated state and
    /// the step metrics. `sr_seed` feeds the SR stream; `lr` the
    /// scheduler's current learning rate.
    fn train_step(
        &self,
        state: State,
        tokens: &[i32],
        sr_seed: u32,
        lr: f32,
    ) -> Result<(State, StepMetrics)>;

    /// One *sharded* training step for distributed data parallelism: the
    /// caller holds rows `band.0..band.1` of the `global_rows`-row global
    /// batch (`tokens` is `[band_rows, seq+1]`), the backend computes the
    /// band's gradient partial as a fixed halving tree over per-row
    /// unnormalized gradients, hands it to `reducer` for the cross-rank
    /// sum, normalizes by the reduced global token count, and applies the
    /// optimizer + SR projection exactly once to the reduced update — so
    /// every rank steps to a bit-identical state. `step` tags the
    /// reducer's wire frames. Backends without a distributed entry keep
    /// the default error.
    #[allow(clippy::too_many_arguments)]
    fn train_step_sharded(
        &self,
        state: State,
        tokens: &[i32],
        band: (usize, usize),
        global_rows: usize,
        step: u64,
        sr_seed: u32,
        lr: f32,
        reducer: &mut dyn GradReducer,
    ) -> Result<(State, StepMetrics)> {
        let _ = (state, tokens, band, global_rows, step, sr_seed, lr, reducer);
        Err(anyhow!(
            "backend {:?} has no sharded train entry",
            self.name()
        ))
    }

    /// Grid tensors this backend's optimizer can introspect, as
    /// `(manifest param name, element count)` in grid order — the slot
    /// layout of [`QuantStepRecord`]. Empty when the variant has no grid
    /// params or the backend exposes no quant telemetry (PJRT), which
    /// turns quant-health recording off for the run.
    fn quant_layers(&self) -> Vec<(String, u64)> {
        Vec::new()
    }

    /// [`Backend::train_step`] with an optional quantization-health
    /// recorder: slot *k* of `quant` receives grid tensor *k*'s per-step
    /// stats (see `obs::quant`). Recording is read-only on training
    /// state, so the step's numerics are identical with or without it.
    /// The default ignores the recorder.
    fn train_step_quant(
        &self,
        state: State,
        tokens: &[i32],
        sr_seed: u32,
        lr: f32,
        quant: Option<&mut QuantStepRecord>,
    ) -> Result<(State, StepMetrics)> {
        let _ = quant;
        self.train_step(state, tokens, sr_seed, lr)
    }

    /// [`Backend::train_step_sharded`] with the optional quant-health
    /// recorder ([`Backend::train_step_quant`] semantics). The default
    /// ignores the recorder.
    #[allow(clippy::too_many_arguments)]
    fn train_step_sharded_quant(
        &self,
        state: State,
        tokens: &[i32],
        band: (usize, usize),
        global_rows: usize,
        step: u64,
        sr_seed: u32,
        lr: f32,
        reducer: &mut dyn GradReducer,
        quant: Option<&mut QuantStepRecord>,
    ) -> Result<(State, StepMetrics)> {
        let _ = quant;
        self.train_step_sharded(state, tokens, band, global_rows, step, sr_seed, lr, reducer)
    }

    /// Sum-NLL + token count over one batch (dev loss / perplexity).
    fn eval_step(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<(f32, f32)>;

    /// Full logits for a `[batch, seq]` token matrix (zero-shot scoring).
    fn logits(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<Vec<f32>>;

    /// Whether deploy-time ternary projection (§A.2) is available.
    fn has_ternary_inference(&self) -> bool;

    /// Build a prepared incremental decoder for `state` (KV-cached
    /// generation). `ternary` forces §A.2 deploy-time ternary projection.
    /// Backends without a serving path keep the default error.
    fn decoder(&self, state: &State, ternary: bool) -> Result<Box<dyn Decoder>> {
        let _ = (state, ternary);
        Err(anyhow!(
            "backend {:?} has no incremental decode entry",
            self.name()
        ))
    }
}

/// A variant bound to an execution backend. The train loop, checkpointing,
/// eval harness and coordinator all drive this type and work unchanged on
/// either backend.
pub struct VariantRuntime {
    backend: Box<dyn Backend>,
}

impl VariantRuntime {
    /// Load + compile every PJRT entry point of `variant_name` (the
    /// artifact path; requires `make artifacts` and linked PJRT).
    pub fn load(
        rt: &Runtime,
        artifacts_root: impl AsRef<Path>,
        variant_name: &str,
    ) -> Result<Self> {
        Ok(VariantRuntime {
            backend: Box::new(PjrtBackend::load(rt, artifacts_root, variant_name)?),
        })
    }

    /// Build the pure-Rust CPU reference backend for `spec` — no
    /// artifacts, no PJRT, no Python anywhere. Kernel pool sized from the
    /// environment (`DQT_THREADS` / available cores).
    pub fn native(spec: &VariantSpec) -> Result<Self> {
        Ok(VariantRuntime {
            backend: Box::new(NativeBackend::new(spec)?),
        })
    }

    /// Build the native backend on an explicit kernel pool — the
    /// `--threads` CLI path and the thread-count parity tests.
    pub fn native_with_pool(
        spec: &VariantSpec,
        pool: std::sync::Arc<crate::kernels::Pool>,
    ) -> Result<Self> {
        Ok(VariantRuntime {
            backend: Box::new(NativeBackend::with_pool(spec, pool)?),
        })
    }

    /// Open `spec` on the selected backend. [`BackendKind::Auto`] resolves
    /// to PJRT when a real runtime is linked and to the native backend
    /// otherwise (the zero-dependency default). `rt` is reused for the
    /// PJRT path when provided; a fresh client is created if not.
    pub fn open(
        kind: BackendKind,
        rt: Option<&Runtime>,
        artifacts_root: impl AsRef<Path>,
        spec: &VariantSpec,
    ) -> Result<Self> {
        Self::open_with_pool(kind, rt, artifacts_root, spec, None)
    }

    /// [`VariantRuntime::open`] with an explicit kernel pool for the
    /// native backend (`None` = size from `DQT_THREADS` / cores; the PJRT
    /// path ignores it — its parallelism lives in XLA).
    pub fn open_with_pool(
        kind: BackendKind,
        rt: Option<&Runtime>,
        artifacts_root: impl AsRef<Path>,
        spec: &VariantSpec,
        pool: Option<std::sync::Arc<crate::kernels::Pool>>,
    ) -> Result<Self> {
        match kind.resolve(pjrt_available()) {
            BackendKind::Native => match pool {
                Some(pool) => Self::native_with_pool(spec, pool),
                None => Self::native(spec),
            },
            _ => {
                let name = spec.variant_name();
                match rt {
                    Some(rt) => Self::load(rt, artifacts_root, &name),
                    None => Self::load(&Runtime::cpu()?, artifacts_root, &name),
                }
            }
        }
    }

    /// Which backend executes this variant (`"native"` / `"pjrt"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Kernel-layer worker threads (see [`Backend::threads`]).
    pub fn threads(&self) -> usize {
        self.backend.threads()
    }

    /// Kernel numeric tier (see [`Backend::precision`]).
    pub fn precision(&self) -> crate::config::Precision {
        self.backend.precision()
    }

    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    pub fn init_state(&self, seed: u32) -> Result<State> {
        self.backend.init_state(seed)
    }

    pub fn train_step(
        &self,
        state: State,
        tokens: &[i32],
        sr_seed: u32,
        lr: f32,
    ) -> Result<(State, StepMetrics)> {
        self.backend.train_step(state, tokens, sr_seed, lr)
    }

    /// Sharded train step for distributed data parallelism (see
    /// [`Backend::train_step_sharded`]).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step_sharded(
        &self,
        state: State,
        tokens: &[i32],
        band: (usize, usize),
        global_rows: usize,
        step: u64,
        sr_seed: u32,
        lr: f32,
        reducer: &mut dyn GradReducer,
    ) -> Result<(State, StepMetrics)> {
        self.backend
            .train_step_sharded(state, tokens, band, global_rows, step, sr_seed, lr, reducer)
    }

    /// Grid-tensor telemetry layout (see [`Backend::quant_layers`]).
    pub fn quant_layers(&self) -> Vec<(String, u64)> {
        self.backend.quant_layers()
    }

    /// Train step with an optional quant-health recorder (see
    /// [`Backend::train_step_quant`]).
    pub fn train_step_quant(
        &self,
        state: State,
        tokens: &[i32],
        sr_seed: u32,
        lr: f32,
        quant: Option<&mut QuantStepRecord>,
    ) -> Result<(State, StepMetrics)> {
        self.backend.train_step_quant(state, tokens, sr_seed, lr, quant)
    }

    /// Sharded train step with an optional quant-health recorder (see
    /// [`Backend::train_step_sharded_quant`]).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step_sharded_quant(
        &self,
        state: State,
        tokens: &[i32],
        band: (usize, usize),
        global_rows: usize,
        step: u64,
        sr_seed: u32,
        lr: f32,
        reducer: &mut dyn GradReducer,
        quant: Option<&mut QuantStepRecord>,
    ) -> Result<(State, StepMetrics)> {
        self.backend.train_step_sharded_quant(
            state,
            tokens,
            band,
            global_rows,
            step,
            sr_seed,
            lr,
            reducer,
            quant,
        )
    }

    pub fn eval_step(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<(f32, f32)> {
        self.backend.eval_step(state, tokens, ternary)
    }

    pub fn logits(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<Vec<f32>> {
        self.backend.logits(state, tokens, ternary)
    }

    pub fn has_ternary_inference(&self) -> bool {
        self.backend.has_ternary_inference()
    }

    /// Prepared incremental decoder for serving (see [`Decoder`]).
    pub fn decoder(&self, state: &State, ternary: bool) -> Result<Box<dyn Decoder>> {
        self.backend.decoder(state, ternary)
    }
}
