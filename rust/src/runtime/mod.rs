//! Runtime layer: PJRT execution of AOT artifacts (the only place that
//! touches XLA). `VariantRuntime` owns the compiled entry points of one
//! variant and the typed state (params + optimizer) flowing between steps.
//!
//! Host state supports two storage modes per parameter ([`Param`]):
//! `Dense` (a plain `Vec<f32>`, what the train loop shuttles) and `Packed`
//! (a [`PackedTensor`] in the grid's true bit width). Packed grid params
//! are decoded to f32 literals only at the PJRT boundary, so a resident
//! ternary model really costs ~2 bits/weight on the host — the paper's §1
//! memory claim, realized in RSS instead of only on disk.

pub mod artifact;
pub mod client;

use std::borrow::Cow;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::quant::codec::{Format, PackedTensor};

pub use artifact::{ArtifactDir, Manifest};
pub use client::{
    lit_f32, lit_f32_scalar, lit_i32, lit_u32_scalar, scalar_f32, to_vec_f32, Executable, Runtime,
};

/// One host-resident parameter: dense f32 values or a packed grid tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Param {
    Dense(Vec<f32>),
    Packed(PackedTensor),
}

impl Param {
    pub fn numel(&self) -> usize {
        match self {
            Param::Dense(v) => v.len(),
            Param::Packed(p) => p.numel(),
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self, Param::Packed(_))
    }

    /// The f32 values: borrowed for dense params, decoded on the fly for
    /// packed ones (the PJRT-boundary decode).
    pub fn values(&self) -> Cow<'_, [f32]> {
        match self {
            Param::Dense(v) => Cow::Borrowed(v.as_slice()),
            Param::Packed(p) => {
                Cow::Owned(p.unpack().expect("PackedTensor invariant: bytes match format"))
            }
        }
    }

    /// Owned copy of the f32 values.
    pub fn to_vec(&self) -> Vec<f32> {
        self.values().into_owned()
    }

    /// First element (scalar params: `.s` scales, counters).
    pub fn scalar(&self) -> f32 {
        self.values().first().copied().unwrap_or(0.0)
    }

    /// Heap bytes this param keeps resident on the host: 4·n dense,
    /// `packed_bytes` when packed. The accounting unit of the packed-grid
    /// mode.
    pub fn host_bytes(&self) -> usize {
        match self {
            Param::Dense(v) => v.len() * 4,
            Param::Packed(p) => p.packed_bytes(),
        }
    }
}

impl From<Vec<f32>> for Param {
    fn from(v: Vec<f32>) -> Param {
        Param::Dense(v)
    }
}

/// Host-side copy of the model state in the manifest's flat order.
#[derive(Clone, Debug)]
pub struct State {
    /// one entry per params entry (matrices, norms, `.s` scales)
    pub params: Vec<Param>,
    /// one vec per opt_state entry (step, m/v or vr/vc); always dense
    pub opt: Vec<Vec<f32>>,
}

impl State {
    /// Wrap dense vectors (the PJRT output shape) into a state.
    pub fn from_dense(params: Vec<Vec<f32>>, opt: Vec<Vec<f32>>) -> State {
        State {
            params: params.into_iter().map(Param::Dense).collect(),
            opt,
        }
    }

    pub fn param_by_name(&self, manifest: &Manifest, name: &str) -> Option<Cow<'_, [f32]>> {
        manifest.param_index(name).map(|i| self.params[i].values())
    }

    pub fn step(&self) -> f32 {
        // opt_state[0] is always the scalar step counter
        self.opt.first().and_then(|v| v.first()).copied().unwrap_or(0.0)
    }

    /// Switch to packed-grid mode: every grid param is re-encoded as a
    /// [`PackedTensor`] in the variant's true bit width (its scale read
    /// from the `{name}.s` companion param), freeing the dense copy. Dense
    /// (non-grid) params are untouched. Idempotent.
    pub fn pack_grids(&mut self, manifest: &Manifest) -> Result<()> {
        let fmt = Format::from_bits(manifest.variant.bits);
        for (i, meta) in manifest.params.iter().enumerate() {
            if !meta.is_grid() || self.params[i].is_packed() {
                continue;
            }
            let scale_name = format!("{}.s", meta.name);
            let j = manifest.param_index(&scale_name).ok_or_else(|| {
                anyhow!("grid param {:?} has no companion scale {scale_name:?}", meta.name)
            })?;
            let s = self.params[j].scalar();
            let vals = self.params[i].to_vec();
            let pt = PackedTensor::pack(&vals, meta.shape.clone(), fmt, Some(s))
                .map_err(|e| anyhow!("packing {:?}: {e}", meta.name))?;
            self.params[i] = Param::Packed(pt);
        }
        Ok(())
    }

    /// Decode every packed param back to dense f32 (inverse of
    /// [`State::pack_grids`]).
    pub fn unpack_grids(&mut self) {
        for p in &mut self.params {
            if p.is_packed() {
                let dense = p.to_vec();
                *p = Param::Dense(dense);
            }
        }
    }

    /// Host-resident bytes of all params (the packed-grid accounting API).
    pub fn host_param_bytes(&self) -> usize {
        self.params.iter().map(Param::host_bytes).sum()
    }

    /// Host-resident bytes of the grid params only — compare against
    /// `numel × 4` to see the packed-mode reduction.
    pub fn grid_param_bytes(&self, manifest: &Manifest) -> usize {
        manifest
            .params
            .iter()
            .zip(&self.params)
            .filter(|(meta, _)| meta.is_grid())
            .map(|(_, p)| p.host_bytes())
            .sum()
    }
}

/// Metrics returned by one train step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub upd_frac: f32,
    pub gnorm: f32,
}

/// Compiled entry points of a variant + the manifest that drives buffer
/// layout. Python never runs here: everything comes from `artifacts/`.
pub struct VariantRuntime {
    pub artifact: ArtifactDir,
    init: Executable,
    train_step: Executable,
    eval_step: Executable,
    logits_step: Executable,
    eval_step_ternary: Option<Executable>,
    logits_step_ternary: Option<Executable>,
}

impl VariantRuntime {
    /// Load + compile every entry point of `variant_name`.
    pub fn load(
        rt: &Runtime,
        artifacts_root: impl AsRef<Path>,
        variant_name: &str,
    ) -> Result<Self> {
        let artifact = ArtifactDir::locate(artifacts_root, variant_name)?;
        let load = |entry: &str| rt.load(artifact.hlo_path(entry));
        let maybe = |entry: &str| -> Result<Option<Executable>> {
            if artifact.has_entry(entry) {
                Ok(Some(rt.load(artifact.hlo_path(entry))?))
            } else {
                Ok(None)
            }
        };
        Ok(VariantRuntime {
            init: load("init")?,
            train_step: load("train_step")?,
            eval_step: load("eval_step")?,
            logits_step: load("logits_step")?,
            eval_step_ternary: maybe("eval_step_ternary")?,
            logits_step_ternary: maybe("logits_step_ternary")?,
            artifact,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.artifact.manifest
    }

    fn split_state(&self, outs: Vec<xla::Literal>) -> Result<(State, Vec<xla::Literal>)> {
        let m = self.manifest();
        let n_p = m.params.len();
        let n_o = m.opt_state.len();
        if outs.len() < n_p + n_o {
            return Err(anyhow!("expected ≥{} outputs, got {}", n_p + n_o, outs.len()));
        }
        let mut it = outs.into_iter();
        let params: Vec<Vec<f32>> = (&mut it)
            .take(n_p)
            .map(|l| to_vec_f32(&l))
            .collect::<Result<_>>()?;
        let opt: Vec<Vec<f32>> = (&mut it)
            .take(n_o)
            .map(|l| to_vec_f32(&l))
            .collect::<Result<_>>()?;
        Ok((State::from_dense(params, opt), it.collect()))
    }

    /// Run the in-graph initializer (LLaMA init + grid projection).
    pub fn init_state(&self, seed: u32) -> Result<State> {
        let outs = self.init.run(&[lit_u32_scalar(seed)?])?;
        let (state, rest) = self.split_state(outs)?;
        if !rest.is_empty() {
            return Err(anyhow!("init returned {} extra outputs", rest.len()));
        }
        Ok(state)
    }

    fn state_literals(&self, state: &State) -> Result<Vec<xla::Literal>> {
        let m = self.manifest();
        let mut lits = Vec::with_capacity(m.n_state());
        for (meta, p) in m.params.iter().zip(&state.params) {
            lits.push(lit_f32(&p.values(), &meta.shape)?);
        }
        for (meta, vals) in m.opt_state.iter().zip(&state.opt) {
            lits.push(lit_f32(vals, &meta.shape)?);
        }
        Ok(lits)
    }

    fn param_literals(&self, state: &State) -> Result<Vec<xla::Literal>> {
        let m = self.manifest();
        m.params
            .iter()
            .zip(&state.params)
            .map(|(meta, p)| lit_f32(&p.values(), &meta.shape))
            .collect()
    }

    /// One training step: consumes `state`, returns the updated state and
    /// the step metrics. `sr_seed` feeds the in-graph SR stream; `lr` the
    /// scheduler's current learning rate.
    pub fn train_step(
        &self,
        state: State,
        tokens: &[i32],
        sr_seed: u32,
        lr: f32,
    ) -> Result<(State, StepMetrics)> {
        let m = self.manifest();
        let mut args = self.state_literals(&state)?;
        args.push(lit_i32(tokens, &m.tokens_shape)?);
        args.push(lit_u32_scalar(sr_seed)?);
        args.push(lit_f32_scalar(lr)?);
        let outs = self.train_step.run(&args)?;
        let (new_state, metrics) = self.split_state(outs)?;
        if metrics.len() != m.train_step_outputs.metrics.len() {
            return Err(anyhow!(
                "expected {} metrics, got {}",
                m.train_step_outputs.metrics.len(),
                metrics.len()
            ));
        }
        Ok((
            new_state,
            StepMetrics {
                loss: scalar_f32(&metrics[0])?,
                upd_frac: scalar_f32(&metrics[1])?,
                gnorm: scalar_f32(&metrics[2])?,
            },
        ))
    }

    /// Sum-NLL + token count over one batch (dev loss / perplexity).
    pub fn eval_step(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<(f32, f32)> {
        let m = self.manifest();
        let exe = if ternary {
            self.eval_step_ternary
                .as_ref()
                .ok_or_else(|| anyhow!("variant has no ternary-inference entry"))?
        } else {
            &self.eval_step
        };
        let mut args = self.param_literals(state)?;
        args.push(lit_i32(tokens, &m.tokens_shape)?);
        let outs = exe.run(&args)?;
        if outs.len() != 2 {
            return Err(anyhow!("eval_step: expected 2 outputs, got {}", outs.len()));
        }
        Ok((scalar_f32(&outs[0])?, scalar_f32(&outs[1])?))
    }

    /// Full logits for a `[batch, seq]` token matrix (zero-shot scoring).
    pub fn logits(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<Vec<f32>> {
        let m = self.manifest();
        let exe = if ternary {
            self.logits_step_ternary
                .as_ref()
                .ok_or_else(|| anyhow!("variant has no ternary-inference entry"))?
        } else {
            &self.logits_step
        };
        let mut args = self.param_literals(state)?;
        args.push(lit_i32(tokens, &m.logits_tokens_shape)?);
        let outs = exe.run(&args)?;
        if outs.len() != 1 {
            return Err(anyhow!("logits_step: expected 1 output, got {}", outs.len()));
        }
        to_vec_f32(&outs[0])
    }

    pub fn has_ternary_inference(&self) -> bool {
        self.eval_step_ternary.is_some()
    }
}
