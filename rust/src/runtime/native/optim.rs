//! Native optimizer step: AdamW / Adafactor dense updates, the paper's
//! §3 stochastic-rounding projection of grid weights (no FP32 master
//! anywhere), the Fig. 5 absmax ablation, Fig. 7 interventions, abl1
//! scale recomputation and the §4.3 low-precision storage environments.
//! Twin of `python/compile/optim.py::apply_updates` — including the
//! per-tensor SR seed stream `hash_u32(trainable_index, sr_seed)`.

use crate::config::{Env, Mode};
use crate::kernels::Pool;
use crate::obs::quant::QuantStepRecord;
use crate::obs::trace;
use crate::quant::sr::{hash_u32, sr_scalar};
use crate::quant::{absmean_scale, bf16, fp8, qrange};

use super::model::Grads;
use super::spec::{Hyper, Intervention, Layout, OptSlots};

const ADAFACTOR_B2: f32 = 0.99;
const ADAFACTOR_EPS: f32 = 1e-30;

/// Storage cast of environment `env` (weights, Adam first moment).
fn env_cast(x: &mut [f32], env: Env) {
    match env {
        Env::Fp32 => {}
        Env::Bf16 => bf16::cast_slice(x),
        Env::Fp8 => fp8::cast_slice(x, fp8::Format::E4M3),
    }
}

/// Cast for optimizer *second-moment* state: E4M3's 448 max overflows
/// Adam's v, so the fp8 env stores it as E5M2 (range over precision) —
/// the MS-AMP O2 split the python twin applies.
fn env_state_cast(x: &mut [f32], env: Env) {
    match env {
        Env::Fp32 => {}
        Env::Bf16 => bf16::cast_slice(x),
        Env::Fp8 => fp8::cast_slice(x, fp8::Format::E5M2),
    }
}

/// `jnp.sign` semantics (`signum(0) == 0`, unlike `f32::signum`).
fn sgn(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Linear-interpolation percentile (twin of `jnp.percentile`); sorts in
/// place.
fn percentile(vals: &mut [f32], q: f64) -> f32 {
    if vals.is_empty() {
        return 0.0;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = (q / 100.0) * (vals.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = (rank - lo as f64) as f32;
    vals[lo] + (vals[hi] - vals[lo]) * frac
}

fn two_mut(v: &mut [Vec<f32>], i: usize, j: usize) -> (&mut Vec<f32>, &mut Vec<f32>) {
    assert!(i < j, "slots must be distinct and ordered");
    let (a, b) = v.split_at_mut(j);
    (&mut a[i], &mut b[0])
}

/// One optimizer step over every trainable parameter, in place.
/// Returns `(upd_frac, gnorm)` — the fraction of quantized weights whose
/// value changed (Fig. 6) and the pre-clip global gradient norm.
///
/// `quant` taps the pass the loop already makes over each grid tensor:
/// when present, slot *k* (grid order) records the projection's
/// per-layer health stats from `(w_old, w_new, s_new, g)` before the
/// new weights are stored. Recording is read-only on training state —
/// see `obs::quant`.
///
/// The §3 stochastic-rounding projection (the per-weight hot loop of the
/// DQT update) fans across `pool`; `sr_scalar` is a pure function of the
/// weight index, so the partition cannot change a bit of the result. The
/// moment updates and reductions stay serial — their accumulation order
/// is part of the determinism contract and they are a small fraction of
/// the step next to the backward matmuls.
#[allow(clippy::too_many_arguments)]
pub(super) fn apply_updates(
    hyper: &Hyper,
    layout: &Layout,
    pool: &Pool,
    params: &mut [Vec<f32>],
    mut grads: Grads,
    opt: &mut [Vec<f32>],
    lr: f32,
    sr_seed: u32,
    mut quant: Option<&mut QuantStepRecord>,
) -> (f32, f32) {
    let step = opt[0][0] + 1.0;
    opt[0][0] = step;

    // global-norm clip (gnorm reported pre-clip)
    let mut sq = 0f64;
    for g in grads.iter().flatten() {
        for &v in g {
            sq += (v as f64) * (v as f64);
        }
    }
    let gnorm = (sq + 1e-12).sqrt() as f32;
    let clip = (hyper.grad_clip / gnorm).min(1.0);
    if clip < 1.0 {
        for g in grads.iter_mut().flatten() {
            for v in g.iter_mut() {
                *v *= clip;
            }
        }
    }

    let (b1, b2, eps, wd) = (
        hyper.adam_b1,
        hyper.adam_b2,
        hyper.adam_eps,
        hyper.weight_decay,
    );
    let c1 = 1.0 - b1.powf(step);
    let c2 = 1.0 - b2.powf(step);
    let mut changed = 0u64;
    let mut total = 0u64;
    let mut grid_ord = 0usize;

    for (idx, t) in layout.trainables.iter().enumerate() {
        let g = grads[t.param].take().expect("trainable param has a gradient");
        let n = g.len();
        let tseed = hash_u32(idx as u32, sr_seed);

        // --- dense update W' (transient, never stored) ---
        let mut w_dense = vec![0f32; n];
        match t.opt {
            OptSlots::AdamW { m: mi, v: vi } => {
                let w = &params[t.param];
                let (m_arr, v_arr) = two_mut(opt, mi, vi);
                for i in 0..n {
                    let gm = b1 * m_arr[i] + (1.0 - b1) * g[i];
                    let gv = b2 * v_arr[i] + (1.0 - b2) * g[i] * g[i];
                    m_arr[i] = gm;
                    v_arr[i] = gv;
                    let mhat = gm / c1;
                    let vhat = gv / c2;
                    w_dense[i] = w[i] - lr * (mhat / (vhat.sqrt() + eps) + wd * w[i]);
                }
                env_cast(m_arr, hyper.env);
                env_state_cast(v_arr, hyper.env);
            }
            OptSlots::Factored { vr: ri, vc: ci } => {
                let shape = &layout.manifest.params[t.param].shape;
                let (rows, cols) = (shape[0], shape[1]);
                let mut u = vec![0f32; n];
                {
                    let (vr, vc) = two_mut(opt, ri, ci);
                    for r in 0..rows {
                        let mut acc = 0f64;
                        for c in 0..cols {
                            let gv = g[r * cols + c];
                            acc += (gv * gv + ADAFACTOR_EPS) as f64;
                        }
                        vr[r] = ADAFACTOR_B2 * vr[r]
                            + (1.0 - ADAFACTOR_B2) * (acc / cols as f64) as f32;
                    }
                    for c in 0..cols {
                        let mut acc = 0f64;
                        for r in 0..rows {
                            let gv = g[r * cols + c];
                            acc += (gv * gv + ADAFACTOR_EPS) as f64;
                        }
                        vc[c] = ADAFACTOR_B2 * vc[c]
                            + (1.0 - ADAFACTOR_B2) * (acc / rows as f64) as f32;
                    }
                    let mean_vr = (vr.iter().map(|&v| v as f64).sum::<f64>()
                        / rows as f64)
                        .max(ADAFACTOR_EPS as f64) as f32;
                    for r in 0..rows {
                        for c in 0..cols {
                            let denom = (vr[r] * vc[c] / mean_vr).sqrt().max(1e-12);
                            u[r * cols + c] = g[r * cols + c] / denom;
                        }
                    }
                    env_state_cast(vr, hyper.env);
                    env_state_cast(vc, hyper.env);
                }
                finish_adafactor(&params[t.param], &mut u, &mut w_dense, lr, wd);
            }
            OptSlots::Vector { v: vi } => {
                let mut u = vec![0f32; n];
                {
                    let v_arr = &mut opt[vi];
                    for i in 0..n {
                        let g2 = g[i] * g[i] + ADAFACTOR_EPS;
                        v_arr[i] = ADAFACTOR_B2 * v_arr[i] + (1.0 - ADAFACTOR_B2) * g2;
                        u[i] = g[i] / v_arr[i].sqrt().max(1e-12);
                    }
                    env_state_cast(v_arr, hyper.env);
                }
                finish_adafactor(&params[t.param], &mut u, &mut w_dense, lr, wd);
            }
        }

        // --- projection back onto the grid / storage format ---
        if let Some(sidx) = t.scale {
            let _sp =
                trace::span_arg("train", trace::names::TRAIN_SR_PROJECT, "tensor", idx as u64);
            let (qn, qp) = qrange(hyper.grid_bits);
            let (qn, qp) = (qn as f32, qp as f32);
            let mut s = params[sidx][0];
            if hyper.recompute_scale {
                // abl1: re-derive the grid from the transient dense update
                s = absmean_scale(&w_dense, hyper.grid_bits);
            }
            let (w_new, s_new) = {
                let w_old = &params[t.param];
                match (hyper.mode, hyper.intervention) {
                    (Mode::DqtAbsmax, _) => {
                        // Fig. 5 ablation: per-step absmax scale +
                        // round-to-nearest — small updates are absorbed
                        let amax = w_dense.iter().fold(0f32, |a, &v| a.max(v.abs()));
                        let s_max = qp / (amax + 1e-8);
                        let w_new: Vec<f32> = w_dense
                            .iter()
                            .map(|&v| (v * s_max).round().clamp(qn, qp) / s_max)
                            .collect();
                        (w_new, s_max)
                    }
                    (_, Intervention::None) => {
                        // the paper's SR projection, fanned across the
                        // pool — elementwise in the weight index, so the
                        // chunking is invisible to the result (work per
                        // weight ≈ one counter hash + a few flops)
                        let mut w_new = vec![0f32; n];
                        let chunk = pool.chunk_rows(n, 8);
                        pool.for_each_chunk_mut(&mut w_new, chunk, |ci, seg| {
                            let off = ci * chunk;
                            for (j, o) in seg.iter_mut().enumerate() {
                                let i = off + j;
                                *o = sr_scalar(w_dense[i], i as u32, tseed, qn, qp, s);
                            }
                        });
                        (w_new, s)
                    }
                    (_, iv) => {
                        // Fig. 7: rank |update| in grid units, intervene on
                        // the bottom fraction
                        let delta: Vec<f32> = w_dense
                            .iter()
                            .zip(w_old.iter())
                            .map(|(&wn, &wo)| (wn - wo) * s)
                            .collect();
                        let mut mags: Vec<f32> = delta.iter().map(|d| d.abs()).collect();
                        let thresh =
                            percentile(&mut mags, hyper.intervention_frac * 100.0);
                        let w_new: Vec<f32> = w_dense
                            .iter()
                            .enumerate()
                            .map(|(i, &v)| {
                                let small = delta[i].abs() <= thresh;
                                if !small {
                                    return sr_scalar(v, i as u32, tseed, qn, qp, s);
                                }
                                match iv {
                                    Intervention::ForceRemain => w_old[i],
                                    _ => {
                                        // force_update: move to the adjacent
                                        // grid point in the update's direction
                                        let dir = if delta[i] >= 0.0 { 1.0 } else { -1.0 };
                                        ((w_old[i] * s).round() + dir).clamp(qn, qp) / s
                                    }
                                }
                            })
                            .collect();
                        (w_new, s)
                    }
                }
            };
            if let Some(q) = quant.as_deref_mut() {
                if let Some(slot) = q.slots.get_mut(grid_ord) {
                    slot.record(&params[t.param], &w_new, s_new, qn, qp, &g);
                }
            }
            grid_ord += 1;
            changed += w_new
                .iter()
                .zip(params[t.param].iter())
                .filter(|(a, b)| a != b)
                .count() as u64;
            total += n as u64;
            params[t.param] = w_new;
            params[sidx][0] = s_new;
        } else if hyper.mode == Mode::Bitnet158 && t.is_qlinear {
            // BitNet master update, stored in the env's precision — the
            // Fig. 3 degradation mechanism (RTN-absorbed small updates).
            env_cast(&mut w_dense, hyper.env);
            {
                // Fig. 6: BitNet update freq = change in the *quantized*
                // weights under their per-step AbsMean scales
                let w_old = &params[t.param];
                let s_old = absmean_scale(w_old, 1.58);
                let s_new = absmean_scale(&w_dense, 1.58);
                changed += w_old
                    .iter()
                    .zip(w_dense.iter())
                    .filter(|(&wo, &wn)| {
                        sgn((wo * s_old).round().clamp(-1.0, 1.0))
                            != sgn((wn * s_new).round().clamp(-1.0, 1.0))
                    })
                    .count() as u64;
                total += n as u64;
            }
            params[t.param] = w_dense;
        } else {
            // dense (non-grid) parameter; fp32 baseline counts all params
            if hyper.mode != Mode::Fp32 {
                env_cast(&mut w_dense, hyper.env);
            } else {
                changed += w_dense
                    .iter()
                    .zip(params[t.param].iter())
                    .filter(|(a, b)| a != b)
                    .count() as u64;
                total += n as u64;
            }
            params[t.param] = w_dense;
        }
    }

    let upd_frac = if total > 0 {
        changed as f32 / total as f32
    } else {
        0.0
    };
    (upd_frac, gnorm)
}

/// Adafactor tail: update clipping (`d = 1.0`) then the weight-decayed
/// dense step.
fn finish_adafactor(w: &[f32], u: &mut [f32], w_dense: &mut [f32], lr: f32, wd: f32) {
    let n = u.len().max(1);
    let rms =
        ((u.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n as f64) + 1e-12).sqrt();
    let scale = 1.0 / (rms.max(1.0) as f32);
    for ((o, &uv), &wv) in w_dense.iter_mut().zip(u.iter()).zip(w.iter()) {
        *o = wv - lr * (uv * scale + wd * wv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_matches_linear_interpolation() {
        let mut v = vec![4.0f32, 1.0, 3.0, 2.0];
        // sorted [1,2,3,4]; p20 → rank 0.6 → 1.6
        assert!((percentile(&mut v, 20.0) - 1.6).abs() < 1e-6);
        let mut v = vec![5.0f32];
        assert_eq!(percentile(&mut v, 20.0), 5.0);
        assert_eq!(percentile(&mut [], 50.0), 0.0);
    }

    #[test]
    fn sgn_zero_is_zero() {
        assert_eq!(sgn(0.0), 0.0);
        assert_eq!(sgn(2.5), 1.0);
        assert_eq!(sgn(-0.1), -1.0);
    }

    #[test]
    fn env_state_cast_survives_large_second_moments() {
        // E4M3 saturates at 448 — v must use E5M2 in the fp8 env
        let mut v = vec![1000.0f32];
        env_state_cast(&mut v, Env::Fp8);
        assert!(v[0] > 448.0, "{}", v[0]);
        let mut m = vec![1000.0f32];
        env_cast(&mut m, Env::Fp8);
        assert_eq!(m[0], 448.0);
    }
}
