//! Dense math primitives for the native backend: matmuls against
//! row-major `[out, in]` weights, RMSNorm forward/backward, per-row
//! absmax activation fake-quantization and numerically stable softmax
//! helpers.
//!
//! The matmuls are a thin facade over the shared parallel kernel layer
//! ([`crate::kernels::gemm`]) — cache-blocked and fanned across the
//! backend's [`Pool`], bitwise-deterministic at every thread count on
//! the default exact tier. A pool carrying `Precision::Fast` dispatches
//! the same three entry points to the wide multi-accumulator fast
//! kernels (tolerance vs exact, still deterministic per thread count) —
//! the facade itself is tier-agnostic. The original scalar triple loops
//! survive only as `#[cfg(test)]` reference oracles below, pinned
//! against the exact blocked kernels by exact-equality property tests
//! over odd (non-block-multiple) shapes. The row-wise
//! norm/softmax/quant helpers remain plain loops: they are O(tokens ·
//! width) against the matmuls' O(tokens · width²).

use crate::kernels::{gemm, Pool};

/// `y[M,N] = x[M,K] @ w[N,K]ᵀ` — the forward linear (`w` row-major
/// `[out, in]`, matching the python `x @ w.T`), fanned across `pool`.
pub fn matmul_nt(pool: &Pool, x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    gemm::matmul_nt(pool, x, w, m, k, n)
}

/// `dx[M,K] += dy[M,N] @ w[N,K]` — input gradient of the linear.
pub fn add_matmul_nn(
    pool: &Pool,
    dy: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dx: &mut [f32],
) {
    gemm::add_matmul_nn(pool, dy, w, m, n, k, dx)
}

/// `dw[N,K] += dy[M,N]ᵀ @ x[M,K]` — weight gradient of the linear.
pub fn add_matmul_tn(
    pool: &Pool,
    dy: &[f32],
    x: &[f32],
    m: usize,
    n: usize,
    k: usize,
    dw: &mut [f32],
) {
    gemm::add_matmul_tn(pool, dy, x, m, n, k, dw)
}

/// RMSNorm over rows of width `h`: `y = x · rsqrt(mean(x²)+eps) · g`.
/// Returns `(y, inv_rms)` with one inverse-rms per row (the backward
/// cache).
pub fn rmsnorm(x: &[f32], g: &[f32], eps: f32, h: usize) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len() % h, 0);
    let rows = x.len() / h;
    let mut y = vec![0f32; x.len()];
    let mut inv = vec![0f32; rows];
    for r in 0..rows {
        let xr = &x[r * h..(r + 1) * h];
        let mut ms = 0f64;
        for &v in xr {
            ms += (v as f64) * (v as f64);
        }
        let ir = 1.0 / ((ms / h as f64) as f32 + eps).sqrt();
        inv[r] = ir;
        let yr = &mut y[r * h..(r + 1) * h];
        for ((o, &v), &gv) in yr.iter_mut().zip(xr.iter()).zip(g.iter()) {
            *o = v * ir * gv;
        }
    }
    (y, inv)
}

/// RMSNorm backward: accumulates `dx += ∂L/∂x` and `dg += ∂L/∂g` from the
/// output gradient `dy`, the forward input `x` and the cached `inv_rms`.
pub fn rmsnorm_bwd(
    dy: &[f32],
    x: &[f32],
    g: &[f32],
    inv: &[f32],
    h: usize,
    dx: &mut [f32],
    dg: &mut [f32],
) {
    let rows = x.len() / h;
    for r in 0..rows {
        let xr = &x[r * h..(r + 1) * h];
        let dyr = &dy[r * h..(r + 1) * h];
        let ir = inv[r];
        // Σ_i dy_i · g_i · x_i (the shared mean-square term)
        let mut dot = 0f64;
        for i in 0..h {
            dot += (dyr[i] * g[i] * xr[i]) as f64;
        }
        let coeff = ir * ir * ir / h as f32 * dot as f32;
        let dxr = &mut dx[r * h..(r + 1) * h];
        for i in 0..h {
            dxr[i] += dyr[i] * g[i] * ir - xr[i] * coeff;
            dg[i] += dyr[i] * xr[i] * ir;
        }
    }
}

/// Per-row absmax fake-quantization of activations to INTn (BitNet's
/// 8-bit setting; python `act_quantize_ref`). The backward pass is a
/// straight-through estimator, so no cache is needed.
pub fn act_quant(x: &[f32], h: usize, bits: u32) -> Vec<f32> {
    let qp = ((1i64 << (bits - 1)) - 1) as f32;
    let rows = x.len() / h;
    let mut y = vec![0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * h..(r + 1) * h];
        let mut amax = 0f32;
        for &v in xr {
            amax = amax.max(v.abs());
        }
        let scale = qp / amax.max(1e-8);
        let yr = &mut y[r * h..(r + 1) * h];
        for (o, &v) in yr.iter_mut().zip(xr.iter()) {
            *o = (v * scale).round().clamp(-qp - 1.0, qp) / scale;
        }
    }
    y
}

/// In-place softmax over `row[..len]` (the causal prefix), numerically
/// stable via max subtraction. Entries past `len` are zeroed.
pub fn softmax_prefix(row: &mut [f32], len: usize) {
    let mut mx = f32::NEG_INFINITY;
    for &v in &row[..len] {
        mx = mx.max(v);
    }
    let mut sum = 0f32;
    for v in &mut row[..len] {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in &mut row[..len] {
        *v /= sum;
    }
    for v in &mut row[len..] {
        *v = 0.0;
    }
}

/// `log Σ exp(row)`, numerically stable.
pub fn logsumexp(row: &[f32]) -> f32 {
    let mut mx = f32::NEG_INFINITY;
    for &v in row {
        mx = mx.max(v);
    }
    let mut sum = 0f32;
    for &v in row {
        sum += (v - mx).exp();
    }
    mx + sum.ln()
}

/// SiLU `x·σ(x)` and its derivative `σ(x)(1 + x(1-σ(x)))`.
pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

pub fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::Rng;

    // -----------------------------------------------------------------
    // Scalar reference oracles — the seed's original triple loops, kept
    // verbatim so the blocked/parallel kernels have a fixed point to be
    // bitwise-compared against.
    // -----------------------------------------------------------------

    fn matmul_nt_ref(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0f32; m * n];
        for r in 0..m {
            let xr = &x[r * k..(r + 1) * k];
            let yr = &mut y[r * n..(r + 1) * n];
            for (c, yc) in yr.iter_mut().enumerate() {
                let wr = &w[c * k..(c + 1) * k];
                let mut acc = 0f32;
                for (a, b) in xr.iter().zip(wr.iter()) {
                    acc += a * b;
                }
                *yc = acc;
            }
        }
        y
    }

    fn add_matmul_nn_ref(dy: &[f32], w: &[f32], m: usize, n: usize, k: usize, dx: &mut [f32]) {
        for r in 0..m {
            let dyr = &dy[r * n..(r + 1) * n];
            let dxr = &mut dx[r * k..(r + 1) * k];
            for (c, &d) in dyr.iter().enumerate() {
                if d == 0.0 {
                    continue;
                }
                let wr = &w[c * k..(c + 1) * k];
                for (o, &wv) in dxr.iter_mut().zip(wr.iter()) {
                    *o += d * wv;
                }
            }
        }
    }

    fn add_matmul_tn_ref(dy: &[f32], x: &[f32], m: usize, n: usize, k: usize, dw: &mut [f32]) {
        for r in 0..m {
            let dyr = &dy[r * n..(r + 1) * n];
            let xr = &x[r * k..(r + 1) * k];
            for (c, &d) in dyr.iter().enumerate() {
                if d == 0.0 {
                    continue;
                }
                let dwr = &mut dw[c * k..(c + 1) * k];
                for (o, &xv) in dwr.iter_mut().zip(xr.iter()) {
                    *o += d * xv;
                }
            }
        }
    }

    /// The determinism contract, end to end: blocked kernels equal the
    /// scalar oracles *bitwise* — not within a tolerance — on random odd
    /// shapes (M, N, K deliberately not multiples of any block size),
    /// at one thread and at several.
    #[test]
    fn prop_blocked_kernels_match_scalar_oracles_bitwise() {
        let mut rng = Rng::new(0xB10C);
        for case in 0..50 {
            let m = 1 + rng.below(11);
            let k = 1 + rng.below(600); // crosses the KC=256 panel boundary
            let n = 1 + rng.below(90); // crosses the NC=64 panel boundary
            let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let w: Vec<f32> = (0..n * k).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let dy: Vec<f32> = (0..m * n).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let want = matmul_nt_ref(&x, &w, m, k, n);
            let mut want_dx = vec![0f32; m * k];
            add_matmul_nn_ref(&dy, &w, m, n, k, &mut want_dx);
            let mut want_dw = vec![0f32; n * k];
            add_matmul_tn_ref(&dy, &x, m, n, k, &mut want_dw);
            for threads in [1usize, 4] {
                let pool = Pool::new(threads);
                let got = matmul_nt(&pool, &x, &w, m, k, n);
                assert_eq!(got, want, "case {case} t{threads} (m={m} k={k} n={n})");
                let mut dx = vec![0f32; m * k];
                add_matmul_nn(&pool, &dy, &w, m, n, k, &mut dx);
                assert_eq!(dx, want_dx, "case {case} t{threads} dx");
                let mut dw = vec![0f32; n * k];
                add_matmul_tn(&pool, &dy, &x, m, n, k, &mut dw);
                assert_eq!(dw, want_dw, "case {case} t{threads} dw");
            }
        }
    }

    #[test]
    fn matmul_nt_small() {
        // x = [[1,2],[3,4]], w = [[1,0],[0,1],[1,1]] (3 outputs)
        let pool = Pool::serial();
        let y = matmul_nt(&pool, &[1.0, 2.0, 3.0, 4.0], &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0], 2, 2, 3);
        assert_eq!(y, vec![1.0, 2.0, 3.0, 3.0, 4.0, 7.0]);
    }

    #[test]
    fn linear_backward_matches_numeric_gradient() {
        let pool = Pool::serial();
        let (m, k, n) = (2usize, 3usize, 2usize);
        let x: Vec<f32> = (0..m * k).map(|i| (i as f32 - 2.5) * 0.3).collect();
        let w: Vec<f32> = (0..n * k).map(|i| (i as f32 - 2.0) * 0.17).collect();
        // L = Σ y²/2 ⇒ dy = y
        let y = matmul_nt(&pool, &x, &w, m, k, n);
        let mut dx = vec![0f32; m * k];
        let mut dw = vec![0f32; n * k];
        add_matmul_nn(&pool, &y, &w, m, n, k, &mut dx);
        add_matmul_tn(&pool, &y, &x, m, n, k, &mut dw);
        let loss = |x: &[f32], w: &[f32]| -> f32 {
            matmul_nt(&Pool::serial(), x, w, m, k, n)
                .iter()
                .map(|v| v * v / 2.0)
                .sum()
        };
        let eps = 1e-3;
        for i in 0..m * k {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps);
            assert!((num - dx[i]).abs() < 1e-2, "dx[{i}]: {num} vs {}", dx[i]);
        }
        for i in 0..n * k {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let num = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps);
            assert!((num - dw[i]).abs() < 1e-2, "dw[{i}]: {num} vs {}", dw[i]);
        }
    }

    #[test]
    fn rmsnorm_forward_and_backward() {
        let h = 4;
        let x: Vec<f32> = vec![0.5, -1.0, 2.0, 0.25, 1.0, 1.0, 1.0, 1.0];
        let g: Vec<f32> = vec![1.0, 0.5, 2.0, 1.0];
        let eps = 1e-5;
        let (y, inv) = rmsnorm(&x, &g, eps, h);
        // second row: mean(x²)=1 ⇒ y = g
        for (a, b) in y[h..].iter().zip(g.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        // numeric gradient of L = Σ y²/2
        let mut dx = vec![0f32; x.len()];
        let mut dg = vec![0f32; h];
        rmsnorm_bwd(&y, &x, &g, &inv, h, &mut dx, &mut dg);
        let loss = |x: &[f32], g: &[f32]| -> f32 {
            rmsnorm(x, g, eps, h).0.iter().map(|v| v * v / 2.0).sum()
        };
        let e = 1e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += e;
            let mut xm = x.clone();
            xm[i] -= e;
            let num = (loss(&xp, &g) - loss(&xm, &g)) / (2.0 * e);
            assert!((num - dx[i]).abs() < 2e-2, "dx[{i}]: {num} vs {}", dx[i]);
        }
        for i in 0..h {
            let mut gp = g.clone();
            gp[i] += e;
            let mut gm = g.clone();
            gm[i] -= e;
            let num = (loss(&x, &gp) - loss(&x, &gm)) / (2.0 * e);
            assert!((num - dg[i]).abs() < 2e-2, "dg[{i}]: {num} vs {}", dg[i]);
        }
    }

    #[test]
    fn act_quant_rows_on_grid() {
        let x = vec![0.1f32, -0.5, 1.0, 0.3, 0.0, 0.0, 0.0, 0.0];
        let q = act_quant(&x, 4, 8);
        // max row 0 is 1.0 → scale 127; every value lands on k/127
        for (a, b) in q[..4].iter().zip(x[..4].iter()) {
            let k = a * 127.0;
            assert!((k - k.round()).abs() < 1e-4);
            assert!((a - b).abs() <= 0.5 / 127.0 + 1e-6);
        }
        // all-zero row stays zero (clamped scale, no NaN)
        assert_eq!(&q[4..], &[0.0; 4]);
    }

    #[test]
    fn softmax_and_logsumexp_stable() {
        let mut row = vec![1000.0f32, 1001.0, 999.0, 55.0];
        softmax_prefix(&mut row, 3);
        let sum: f32 = row[..3].iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert_eq!(row[3], 0.0);
        assert!(row[1] > row[0] && row[0] > row[2]);
        let z = logsumexp(&[1000.0, 1001.0, 999.0]);
        assert!(z.is_finite() && (z - 1001.4076).abs() < 1e-3);
    }

    #[test]
    fn silu_matches_definition() {
        for x in [-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let s = 1.0 / (1.0 + (-x).exp());
            assert!((silu(x) - x * s).abs() < 1e-6);
            let e = 1e-3;
            let num = (silu(x + e) - silu(x - e)) / (2.0 * e);
            assert!((silu_grad(x) - num).abs() < 1e-3);
        }
    }
}
