//! The native LLaMA-structured model: embedding → N × [RMSNorm → causal
//! MHA with RoPE → residual → RMSNorm → SwiGLU MLP → residual] → final
//! RMSNorm → tied LM head, with a hand-written backward pass.
//!
//! Mirrors `python/compile/model.py` + `quant.py` semantics exactly:
//! the seven per-layer projections run under the variant's weight-handling
//! mode (fp32 / BitNet-STE / DQT grid / §A.2 ternary-inference), their
//! inputs are absmax-fake-quantized activations (STE backward), and the
//! embedding/norms/tied head stay high-precision. The backward pass
//! treats every straight-through estimator as identity, so gradients for
//! grid weights land on the grid values themselves — what the SR update
//! rule (paper §3) consumes.

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{Mode, ModelConfig};
use crate::data::tokenizer::PAD_ID;
use crate::kernels::{self, Pool};
use crate::obs::trace;
use crate::quant::{absmean_quantize, absmean_scale};

use super::math::{
    act_quant, add_matmul_nn, add_matmul_tn, logsumexp, matmul_nt, rmsnorm, rmsnorm_bwd, silu,
    silu_grad, softmax_prefix,
};
use super::spec::{Hyper, Layout, Lin};

pub(super) type Params<'a> = [Cow<'a, [f32]>];

/// Per-param gradient buffers in manifest order (`None` for `.s` scales).
pub(super) type Grads = Vec<Option<Vec<f32>>>;

/// The model context: hyperparameters + parameter index map + the kernel
/// pool every matmul (and the batch×head attention fan-out) runs on.
pub(super) struct Net<'a> {
    pub hyper: &'a Hyper,
    pub cfg: &'a ModelConfig,
    pub layout: &'a Layout,
    pub pool: &'a Pool,
}

/// Per-layer forward caches consumed by the backward pass. (The STE
/// treatment means post-quantization activations `xq*` are the matmul
/// inputs the weight gradients see; the pre-quantization values only
/// matter where a nonlinearity needs them.)
struct LayerCache {
    x_in: Vec<f32>,
    inv1: Vec<f32>,
    xq: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// attention probabilities `[B, A, S, S]` (zeros above the diagonal)
    att: Vec<f32>,
    ctx_q: Vec<f32>,
    h_mid: Vec<f32>,
    inv2: Vec<f32>,
    xq2: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    down_in_q: Vec<f32>,
}

/// Forward result: logits plus every cache the backward pass needs.
pub(super) struct Forward {
    /// `[B·S, V]` row-major
    pub logits: Vec<f32>,
    tokens: Vec<usize>,
    layers: Vec<LayerCache>,
    x_final_in: Vec<f32>,
    xf: Vec<f32>,
    invf: Vec<f32>,
}

impl<'a> Net<'a> {
    /// Effective weight of one projection under the variant's mode:
    /// fp32/DQT use the stored values directly; BitNet re-quantizes its
    /// master to ternary every forward; `dqt_ternary_inf` (and the
    /// deploy-time `ternary` override) project the grid to ternary via
    /// AbsMean. All projections are STE — gradients flow to the stored
    /// weight.
    fn effective_weight<'w>(&self, w: &'w [f32], ternary: bool) -> Cow<'w, [f32]> {
        let project = match self.hyper.mode {
            Mode::Fp32 => false,
            Mode::Bitnet158 | Mode::DqtTernaryInf => true,
            Mode::Dqt | Mode::DqtAbsmax => ternary,
        };
        if project {
            let s3 = absmean_scale(w, 1.58);
            Cow::Owned(absmean_quantize(w, 1.58, s3))
        } else {
            Cow::Borrowed(w)
        }
    }

    /// Activation fake-quantization in quantized modes, identity in fp32.
    fn maybe_quant(&self, x: &[f32], width: usize) -> Vec<f32> {
        if self.hyper.mode == Mode::Fp32 {
            x.to_vec()
        } else {
            act_quant(x, width, self.hyper.act_bits)
        }
    }

    /// One projection forward over an already-quantized input (shared by
    /// wq/wk/wv and w_gate/w_up so the act-quant runs once per group).
    #[allow(clippy::too_many_arguments)]
    fn lin_fwd(
        &self,
        params: &Params,
        lin: Lin,
        input_q: &[f32],
        m: usize,
        k_in: usize,
        n_out: usize,
        ternary: bool,
    ) -> Vec<f32> {
        let wf = self.effective_weight(&params[lin.w], ternary);
        matmul_nt(self.pool, input_q, &wf, m, k_in, n_out)
    }

    /// One projection backward: accumulates the weight gradient (STE: on
    /// the stored param) and `dx += dy @ W_eff`.
    #[allow(clippy::too_many_arguments)]
    fn lin_bwd(
        &self,
        params: &Params,
        lin: Lin,
        input_q: &[f32],
        dy: &[f32],
        m: usize,
        k_in: usize,
        n_out: usize,
        grads: &mut [Option<Vec<f32>>],
        dx: &mut [f32],
    ) {
        let wf = self.effective_weight(&params[lin.w], false);
        if let Some(dw) = grads[lin.w].as_mut() {
            add_matmul_tn(self.pool, dy, input_q, m, n_out, k_in, dw);
        }
        add_matmul_nn(self.pool, dy, &wf, m, n_out, k_in, dx);
    }

    fn rope_tables(&self, s: usize) -> (Vec<f32>, Vec<f32>) {
        let d = self.cfg.hidden_size / self.cfg.num_attention_heads;
        let half = d / 2;
        let mut cos = vec![0f32; s * half];
        let mut sin = vec![0f32; s * half];
        for t in 0..s {
            for j in 0..half {
                let inv = 1.0 / self.hyper.rope_theta.powf(2.0 * j as f32 / d as f32);
                let ang = t as f32 * inv;
                cos[t * half + j] = ang.cos();
                sin[t * half + j] = ang.sin();
            }
        }
        (cos, sin)
    }

    /// Full forward over a `[b, s]` token matrix; caches everything the
    /// backward pass needs. `ternary` forces §A.2 deploy-time ternary
    /// projection of the grid weights.
    pub fn forward(
        &self,
        params: &Params,
        tokens: &[i32],
        b: usize,
        s: usize,
        ternary: bool,
    ) -> Result<Forward> {
        let (h, i_, v) = (
            self.cfg.hidden_size,
            self.cfg.intermediate_size,
            self.cfg.vocab_size,
        );
        let nh = self.cfg.num_attention_heads;
        let d = h / nh;
        let m = b * s;
        if tokens.len() != m {
            return Err(anyhow!("expected {m} tokens, got {}", tokens.len()));
        }
        let ids: Vec<usize> = tokens
            .iter()
            .map(|&t| {
                if (0..v as i32).contains(&t) {
                    Ok(t as usize)
                } else {
                    Err(anyhow!("token id {t} outside vocab 0..{v}"))
                }
            })
            .collect::<Result<_>>()?;

        // embedding lookup
        let emb = &params[self.layout.emb];
        let mut x = vec![0f32; m * h];
        for (r, &id) in ids.iter().enumerate() {
            x[r * h..(r + 1) * h].copy_from_slice(&emb[id * h..(id + 1) * h]);
        }

        let (cos, sin) = self.rope_tables(s);
        let half = d / 2;
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let mut layers = Vec::with_capacity(self.cfg.num_hidden_layers);

        for (l, li) in self.layout.layers.iter().enumerate() {
            let x_in = x;
            // --- attention block ---
            let (xn, inv1) = {
                let _sp = trace::span_arg("fwd", trace::names::FWD_RMSNORM, "layer", l as u64);
                rmsnorm(&x_in, &params[li.attn_norm], self.hyper.rms_eps, h)
            };
            let attn_sp = trace::span_arg("fwd", trace::names::FWD_ATTENTION, "layer", l as u64);
            let xq = self.maybe_quant(&xn, h);
            let mut q = self.lin_fwd(params, li.wq, &xq, m, h, h, ternary);
            let mut k = self.lin_fwd(params, li.wk, &xq, m, h, h, ternary);
            let v_proj = self.lin_fwd(params, li.wv, &xq, m, h, h, ternary);
            for buf in [&mut q, &mut k] {
                apply_rope(buf, &cos, &sin, b, s, nh, half);
            }
            // attention fans out over (batch × head): each task owns one
            // head's `[s, s]` probability block and `[s, d]` context block,
            // so the arithmetic inside a task — and therefore the result —
            // is identical at every thread count. The blocks are scattered
            // back into the `[B, A, S, S]` / `[M, H]` layouts serially.
            let blocks = self.pool.map_collect(b * nh, |t| {
                let bi = t / nh;
                let base = (t % nh) * d;
                let mut att_blk = vec![0f32; s * s];
                let mut ctx_blk = vec![0f32; s * d];
                for i in 0..s {
                    let qi = &q[(bi * s + i) * h + base..][..d];
                    let row = &mut att_blk[i * s..(i + 1) * s];
                    for (j, rj) in row.iter_mut().enumerate().take(i + 1) {
                        let kj = &k[(bi * s + j) * h + base..][..d];
                        let mut acc = 0f32;
                        for (qa, kb) in qi.iter().zip(kj.iter()) {
                            acc += qa * kb;
                        }
                        *rj = acc * inv_sqrt_d;
                    }
                    softmax_prefix(row, i + 1);
                    let ci = i * d;
                    for (j, &p) in row.iter().enumerate().take(i + 1) {
                        let vj = &v_proj[(bi * s + j) * h + base..][..d];
                        for (o, &vv) in ctx_blk[ci..ci + d].iter_mut().zip(vj.iter()) {
                            *o += p * vv;
                        }
                    }
                }
                (att_blk, ctx_blk)
            });
            let mut att = vec![0f32; b * nh * s * s];
            let mut ctx = vec![0f32; m * h];
            for (t, (att_blk, ctx_blk)) in blocks.into_iter().enumerate() {
                let (bi, base) = (t / nh, (t % nh) * d);
                att[t * s * s..(t + 1) * s * s].copy_from_slice(&att_blk);
                for i in 0..s {
                    ctx[(bi * s + i) * h + base..][..d]
                        .copy_from_slice(&ctx_blk[i * d..(i + 1) * d]);
                }
            }
            let ctx_q = self.maybe_quant(&ctx, h);
            let attn_out = self.lin_fwd(params, li.wo, &ctx_q, m, h, h, ternary);
            let mut h_mid = x_in.clone();
            for (o, &a) in h_mid.iter_mut().zip(attn_out.iter()) {
                *o += a;
            }
            drop(attn_sp);

            // --- MLP block (SwiGLU) ---
            let (xn2, inv2) = {
                let _sp = trace::span_arg("fwd", trace::names::FWD_RMSNORM, "layer", l as u64);
                rmsnorm(&h_mid, &params[li.mlp_norm], self.hyper.rms_eps, h)
            };
            let mlp_sp = trace::span_arg("fwd", trace::names::FWD_SWIGLU, "layer", l as u64);
            let xq2 = self.maybe_quant(&xn2, h);
            let gate = self.lin_fwd(params, li.w_gate, &xq2, m, h, i_, ternary);
            let up = self.lin_fwd(params, li.w_up, &xq2, m, h, i_, ternary);
            let mut down_in = vec![0f32; m * i_];
            for ((o, &g), &u) in down_in.iter_mut().zip(gate.iter()).zip(up.iter()) {
                *o = silu(g) * u;
            }
            let down_in_q = self.maybe_quant(&down_in, i_);
            let down_out = self.lin_fwd(params, li.w_down, &down_in_q, m, i_, h, ternary);
            let mut x_out = h_mid.clone();
            for (o, &dv) in x_out.iter_mut().zip(down_out.iter()) {
                *o += dv;
            }
            drop(mlp_sp);

            layers.push(LayerCache {
                x_in,
                inv1,
                xq,
                q,
                k,
                v: v_proj,
                att,
                ctx_q,
                h_mid,
                inv2,
                xq2,
                gate,
                up,
                down_in_q,
            });
            x = x_out;
        }

        let x_final_in = x;
        let _head_sp = trace::span("fwd", trace::names::FWD_HEAD);
        let (xf, invf) =
            rmsnorm(&x_final_in, &params[self.layout.final_norm], self.hyper.rms_eps, h);
        // tied LM head — high precision, never quantized
        let logits = matmul_nt(self.pool, &xf, emb, m, h, v);
        Ok(Forward {
            logits,
            tokens: ids,
            layers,
            x_final_in,
            xf,
            invf,
        })
    }

    /// `(sum NLL, token count)` over non-pad label positions.
    pub fn nll_sums(
        &self,
        params: &Params,
        inputs: &[i32],
        labels: &[i32],
        b: usize,
        s: usize,
        ternary: bool,
    ) -> Result<(f32, f32)> {
        let v = self.cfg.vocab_size;
        let fwd = self.forward(params, inputs, b, s, ternary)?;
        let mut nll = 0f64;
        let mut count = 0f64;
        for (r, &label) in labels.iter().enumerate() {
            if label == PAD_ID {
                continue;
            }
            if !(0..v as i32).contains(&label) {
                return Err(anyhow!("label id {label} outside vocab 0..{v}"));
            }
            let row = &fwd.logits[r * v..(r + 1) * v];
            nll += (logsumexp(row) - row[label as usize]) as f64;
            count += 1.0;
        }
        Ok((nll as f32, count as f32))
    }

    /// Mean masked cross-entropy + gradients for every trainable param
    /// (aligned to the manifest's param order; `None` for `.s` scales).
    pub fn loss_and_grads(
        &self,
        params: &Params,
        inputs: &[i32],
        labels: &[i32],
        b: usize,
        s: usize,
    ) -> Result<(f32, Grads)> {
        self.loss_and_grads_scaled(params, inputs, labels, b, s, None)
    }

    /// [`Net::loss_and_grads`] with an explicit CE normalization constant.
    /// `None` divides by this batch's own non-pad token count (the
    /// single-process mean loss). `Some(d)` lets a caller normalize over a
    /// larger global batch: the distributed sharded step passes `Some(1.0)`
    /// to obtain raw NLL-*sum* gradients per row (division by 1.0 is exact,
    /// so every per-row chain stays bitwise canonical) and applies the
    /// global `1/denom` once, after the cross-rank reduction.
    pub fn loss_and_grads_scaled(
        &self,
        params: &Params,
        inputs: &[i32],
        labels: &[i32],
        b: usize,
        s: usize,
        denom_override: Option<f32>,
    ) -> Result<(f32, Grads)> {
        let (h, i_, v) = (
            self.cfg.hidden_size,
            self.cfg.intermediate_size,
            self.cfg.vocab_size,
        );
        let nh = self.cfg.num_attention_heads;
        let d = h / nh;
        let half = d / 2;
        let m = b * s;
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let fwd = {
            let _sp = trace::span("train", trace::names::TRAIN_FORWARD);
            self.forward(params, inputs, b, s, false)?
        };
        // everything from here back to the embeddings is the backward pass
        let _bwd_sp = trace::span("train", trace::names::TRAIN_BACKWARD);

        let mut grads: Grads = self
            .layout
            .manifest
            .params
            .iter()
            .map(|p| {
                if p.is_scale() {
                    None
                } else {
                    Some(vec![0f32; p.numel()])
                }
            })
            .collect();

        // --- cross-entropy: loss + dlogits ---
        let n_mask = labels.iter().filter(|&&l| l != PAD_ID).count();
        let denom = denom_override.unwrap_or((n_mask as f32).max(1.0));
        let mut loss = 0f64;
        let mut dlogits = vec![0f32; m * v];
        for (r, &label) in labels.iter().enumerate() {
            if label == PAD_ID {
                continue;
            }
            if !(0..v as i32).contains(&label) {
                return Err(anyhow!("label id {label} outside vocab 0..{v}"));
            }
            let row = &fwd.logits[r * v..(r + 1) * v];
            let logz = logsumexp(row);
            loss += ((logz - row[label as usize]) / denom) as f64;
            let drow = &mut dlogits[r * v..(r + 1) * v];
            for (o, &lv) in drow.iter_mut().zip(row.iter()) {
                *o = (lv - logz).exp() / denom;
            }
            drow[label as usize] -= 1.0 / denom;
        }

        // --- tied head backward ---
        let emb = &params[self.layout.emb];
        let mut dxf = vec![0f32; m * h];
        add_matmul_nn(self.pool, &dlogits, emb, m, v, h, &mut dxf);
        if let Some(demb) = grads[self.layout.emb].as_mut() {
            add_matmul_tn(self.pool, &dlogits, &fwd.xf, m, v, h, demb);
        }
        drop(dlogits);

        // --- final RMSNorm backward ---
        let mut dx = vec![0f32; m * h];
        {
            let mut dg = grads[self.layout.final_norm].take().unwrap();
            rmsnorm_bwd(
                &dxf,
                &fwd.x_final_in,
                &params[self.layout.final_norm],
                &fwd.invf,
                h,
                &mut dx,
                &mut dg,
            );
            grads[self.layout.final_norm] = Some(dg);
        }

        // --- layers, reversed ---
        let (cos, sin) = self.rope_tables(s);
        for (li, cache) in self.layout.layers.iter().zip(fwd.layers.iter()).rev() {
            // x_out = h_mid + w_down(down_in_q)
            let mut dh = dx.clone(); // residual branch
            let mut d_down_in = vec![0f32; m * i_];
            self.lin_bwd(
                params, li.w_down, &cache.down_in_q, &dx, m, i_, h, &mut grads, &mut d_down_in,
            );
            // down_in = silu(gate) · up
            let mut dgate = vec![0f32; m * i_];
            let mut dup = vec![0f32; m * i_];
            for r in 0..m * i_ {
                let g = cache.gate[r];
                dgate[r] = d_down_in[r] * cache.up[r] * silu_grad(g);
                dup[r] = d_down_in[r] * silu(g);
            }
            drop(d_down_in);
            let mut dxn2 = vec![0f32; m * h];
            self.lin_bwd(params, li.w_up, &cache.xq2, &dup, m, h, i_, &mut grads, &mut dxn2);
            self.lin_bwd(params, li.w_gate, &cache.xq2, &dgate, m, h, i_, &mut grads, &mut dxn2);
            {
                let mut dg = grads[li.mlp_norm].take().unwrap();
                rmsnorm_bwd(
                    &dxn2,
                    &cache.h_mid,
                    &params[li.mlp_norm],
                    &cache.inv2,
                    h,
                    &mut dh,
                    &mut dg,
                );
                grads[li.mlp_norm] = Some(dg);
            }

            // h_mid = x_in + wo(ctx_q)
            let mut dx_in = dh.clone(); // residual branch
            let mut dctx = vec![0f32; m * h];
            self.lin_bwd(params, li.wo, &cache.ctx_q, &dh, m, h, h, &mut grads, &mut dctx);

            // attention backward fans out over (batch × head), like the
            // forward: each task accumulates its head's `[s, d]` dq/dk/dv
            // blocks locally (every write in the serial loop stayed inside
            // one head's column block, so the blocks partition the output
            // exactly) and the scatter back to `[M, H]` is serial.
            let dctx_ref = &dctx;
            let bwd_blocks = self.pool.map_collect(b * nh, |t| {
                let bi = t / nh;
                let base = (t % nh) * d;
                let mut dq_blk = vec![0f32; s * d];
                let mut dk_blk = vec![0f32; s * d];
                let mut dv_blk = vec![0f32; s * d];
                for i in 0..s {
                    let arow = &cache.att[(t * s + i) * s..][..s];
                    let dci = &dctx_ref[(bi * s + i) * h + base..][..d];
                    // datt + dv
                    let mut datt = vec![0f32; i + 1];
                    for (j, dj) in datt.iter_mut().enumerate() {
                        let vj = &cache.v[(bi * s + j) * h + base..][..d];
                        let mut acc = 0f32;
                        for (ca, vb) in dci.iter().zip(vj.iter()) {
                            acc += ca * vb;
                        }
                        *dj = acc;
                        let p = arow[j];
                        if p != 0.0 {
                            let dvj = &mut dv_blk[j * d..(j + 1) * d];
                            for (o, &ca) in dvj.iter_mut().zip(dci.iter()) {
                                *o += p * ca;
                            }
                        }
                    }
                    // softmax backward
                    let mut tsum = 0f32;
                    for (j, &dj) in datt.iter().enumerate() {
                        tsum += dj * arow[j];
                    }
                    let qi = &cache.q[(bi * s + i) * h + base..][..d];
                    let dqi = &mut dq_blk[i * d..(i + 1) * d];
                    for (j, &dj) in datt.iter().enumerate() {
                        let dz = arow[j] * (dj - tsum) * inv_sqrt_d;
                        if dz == 0.0 {
                            continue;
                        }
                        let kj = &cache.k[(bi * s + j) * h + base..][..d];
                        for (o, &kv) in dqi.iter_mut().zip(kj.iter()) {
                            *o += dz * kv;
                        }
                        let dkj = &mut dk_blk[j * d..(j + 1) * d];
                        for (o, &qv) in dkj.iter_mut().zip(qi.iter()) {
                            *o += dz * qv;
                        }
                    }
                }
                (dq_blk, dk_blk, dv_blk)
            });
            drop(dctx);
            let mut dq = vec![0f32; m * h];
            let mut dk = vec![0f32; m * h];
            let mut dv = vec![0f32; m * h];
            for (t, (dq_blk, dk_blk, dv_blk)) in bwd_blocks.into_iter().enumerate() {
                let (bi, base) = (t / nh, (t % nh) * d);
                for i in 0..s {
                    let row = (bi * s + i) * h + base;
                    dq[row..row + d].copy_from_slice(&dq_blk[i * d..(i + 1) * d]);
                    dk[row..row + d].copy_from_slice(&dk_blk[i * d..(i + 1) * d]);
                    dv[row..row + d].copy_from_slice(&dv_blk[i * d..(i + 1) * d]);
                }
            }
            // RoPE is an orthogonal rotation — backward is the inverse spin
            for buf in [&mut dq, &mut dk] {
                unapply_rope(buf, &cos, &sin, b, s, nh, half);
            }
            let mut dxn = vec![0f32; m * h];
            self.lin_bwd(params, li.wq, &cache.xq, &dq, m, h, h, &mut grads, &mut dxn);
            self.lin_bwd(params, li.wk, &cache.xq, &dk, m, h, h, &mut grads, &mut dxn);
            self.lin_bwd(params, li.wv, &cache.xq, &dv, m, h, h, &mut grads, &mut dxn);
            {
                let mut dg = grads[li.attn_norm].take().unwrap();
                rmsnorm_bwd(
                    &dxn,
                    &cache.x_in,
                    &params[li.attn_norm],
                    &cache.inv1,
                    h,
                    &mut dx_in,
                    &mut dg,
                );
                grads[li.attn_norm] = Some(dg);
            }
            dx = dx_in;
        }

        // --- embedding lookup backward ---
        if let Some(demb) = grads[self.layout.emb].as_mut() {
            for (r, &id) in fwd.tokens.iter().enumerate() {
                let row = &dx[r * h..(r + 1) * h];
                let er = &mut demb[id * h..(id + 1) * h];
                for (o, &g) in er.iter_mut().zip(row.iter()) {
                    *o += g;
                }
            }
        }

        Ok((loss as f32, grads))
    }
}

// ---------------------------------------------------------------------------
// Incremental decoding (KV-cached generation, the serving path)
// ---------------------------------------------------------------------------

/// Decode-time representation of one projection: dense f32 (fp32 mode and
/// non-ternary integer grids) or 2-bit packed ternary codes with their
/// AbsMean scale — the decode-free path, where every matmul runs fused off
/// the codes via [`kernels::ternary::gemm_nt`] and no f32 weight is
/// materialized. Both forms fan across the serving pool: output channels
/// of the packed stream, output rows/columns of the dense GEMM. The
/// pool's precision tier rides along — a fast-tier pool routes eligible
/// dense matmuls to the multi-accumulator microkernels and eligible
/// packed matmuls to the activation-block LUT GEMM, with no change here.
pub(crate) enum DecodeLin {
    Dense(Vec<f32>),
    Ternary { words: Vec<u32>, scale: f32 },
}

impl DecodeLin {
    /// `y[M,N] = x[M,K] @ Wᵀ` for the decode micro-batch.
    fn matmul(&self, pool: &Pool, x: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        match self {
            DecodeLin::Dense(w) => matmul_nt(pool, x, w, m, k, n),
            DecodeLin::Ternary { words, scale } => {
                kernels::ternary::gemm_nt(pool, words, x, m, k, n, *scale)
            }
        }
    }

    pub(crate) fn is_packed(&self) -> bool {
        matches!(self, DecodeLin::Ternary { .. })
    }

    /// Resident bytes of this projection in serving form.
    pub(crate) fn resident_bytes(&self) -> usize {
        match self {
            DecodeLin::Dense(w) => w.len() * 4,
            DecodeLin::Ternary { words, .. } => words.len() * 4 + 4,
        }
    }
}

/// One layer's decode-time weights (norms stay dense f32, like training).
pub(crate) struct DecodeLayer {
    pub(crate) attn_norm: Vec<f32>,
    pub(crate) mlp_norm: Vec<f32>,
    pub(crate) wq: DecodeLin,
    pub(crate) wk: DecodeLin,
    pub(crate) wv: DecodeLin,
    pub(crate) wo: DecodeLin,
    pub(crate) w_gate: DecodeLin,
    pub(crate) w_up: DecodeLin,
    pub(crate) w_down: DecodeLin,
}

impl DecodeLayer {
    pub(crate) fn lins(&self) -> [&DecodeLin; 7] {
        [
            &self.wq, &self.wk, &self.wv, &self.wo, &self.w_gate, &self.w_up, &self.w_down,
        ]
    }
}

/// Per-sequence KV cache: one `[seq_len, H]` ring buffer pair (keys and
/// values, already RoPE-rotated) per layer, bounded by the model's trained
/// sequence length. Positions past `seq_len` overwrite the oldest slot —
/// attention then runs over the sliding window of the last `seq_len`
/// tokens.
pub struct KvCache {
    /// `[n_layer, seq_len, H]`, keys
    k: Vec<f32>,
    /// `[n_layer, seq_len, H]`, values
    v: Vec<f32>,
    /// absolute next position (== tokens appended so far)
    pos: usize,
    seq_len: usize,
    h: usize,
}

impl KvCache {
    pub(crate) fn new(n_layer: usize, seq_len: usize, h: usize) -> KvCache {
        KvCache {
            k: vec![0f32; n_layer * seq_len * h],
            v: vec![0f32; n_layer * seq_len * h],
            pos: 0,
            seq_len,
            h,
        }
    }
}

impl crate::runtime::DecoderCache for KvCache {
    fn position(&self) -> usize {
        self.pos
    }
    fn reset(&mut self) {
        self.pos = 0;
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// Prepared decode-time weights of one model state: the serving twin of
/// [`Net::forward`]. Everything the incremental step needs is resident in
/// its serving form — packed ternary codes for the quantized projections,
/// dense f32 for the embedding/norms/tied head.
pub(crate) struct DecodeWeights {
    /// act-quantize inputs to the projections (all quantized modes)
    pub(crate) quantized_acts: bool,
    pub(crate) act_bits: u32,
    pub(crate) rope_theta: f32,
    pub(crate) rms_eps: f32,
    pub(crate) hidden: usize,
    pub(crate) inter: usize,
    pub(crate) vocab: usize,
    pub(crate) n_heads: usize,
    pub(crate) seq_len: usize,
    pub(crate) emb: Vec<f32>,
    pub(crate) final_norm: Vec<f32>,
    pub(crate) layers: Vec<DecodeLayer>,
    /// kernel pool the projection matmuls and the per-sequence attention
    /// fan across (threaded in from the backend at decoder build)
    pub(crate) pool: Arc<Pool>,
}

impl DecodeWeights {
    pub(crate) fn new_cache(&self) -> KvCache {
        KvCache::new(self.layers.len(), self.seq_len, self.hidden)
    }

    fn maybe_quant(&self, x: &[f32], width: usize) -> Vec<f32> {
        if self.quantized_acts {
            act_quant(x, width, self.act_bits)
        } else {
            x.to_vec()
        }
    }

    /// Single-sequence incremental decode: append `token` at the cache's
    /// position, return next-token logits `[V]`.
    pub(crate) fn forward_step(&self, cache: &mut KvCache, token: i32) -> Result<Vec<f32>> {
        self.forward_step_batch(&mut [cache], &[token])
    }

    /// One incremental decode step over a micro-batch of independent
    /// sequences: append `tokens[i]` at `caches[i]`'s position and return
    /// next-token logits `[m, V]`. Reuses the training forward's exact
    /// RMSNorm / RoPE / softmax / SwiGLU arithmetic, so logits match
    /// [`Net::forward`] position by position; the projections run through
    /// [`DecodeLin`] (fused packed-ternary GEMV on the serving path).
    /// Rows are independent — batch composition never changes a
    /// sequence's numerics.
    pub(crate) fn forward_step_batch(
        &self,
        caches: &mut [&mut KvCache],
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let m = tokens.len();
        if caches.len() != m {
            return Err(anyhow!("{} caches for {m} tokens", caches.len()));
        }
        if m == 0 {
            return Ok(Vec::new());
        }
        let (h, i_, v) = (self.hidden, self.inter, self.vocab);
        let nh = self.n_heads;
        let d = h / nh;
        let half = d / 2;
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let kv_len = self.layers.len() * self.seq_len * h;
        for cache in caches.iter() {
            if cache.h != h || cache.seq_len != self.seq_len || cache.k.len() != kv_len {
                return Err(anyhow!("KV cache geometry does not match this decoder"));
            }
        }
        let mut x = vec![0f32; m * h];
        for (r, &t) in tokens.iter().enumerate() {
            if !(0..v as i32).contains(&t) {
                return Err(anyhow!("token id {t} outside vocab 0..{v}"));
            }
            let id = t as usize;
            x[r * h..(r + 1) * h].copy_from_slice(&self.emb[id * h..(id + 1) * h]);
        }

        let pool = &*self.pool;
        for (li, layer) in self.layers.iter().enumerate() {
            // --- attention block ---
            let (xn, _) = rmsnorm(&x, &layer.attn_norm, self.rms_eps, h);
            let xq = self.maybe_quant(&xn, h);
            let mut q = layer.wq.matmul(pool, &xq, m, h, h);
            let mut k_new = layer.wk.matmul(pool, &xq, m, h, h);
            let v_new = layer.wv.matmul(pool, &xq, m, h, h);
            // phase 1 (serial): RoPE + append this step's K/V to each cache
            for (bi, cache) in caches.iter_mut().enumerate() {
                let pos = cache.pos;
                rope_row(&mut q[bi * h..(bi + 1) * h], pos, nh, half, self.rope_theta);
                rope_row(&mut k_new[bi * h..(bi + 1) * h], pos, nh, half, self.rope_theta);
                let slot = pos % self.seq_len;
                let base_l = (li * self.seq_len + slot) * h;
                cache.k[base_l..base_l + h].copy_from_slice(&k_new[bi * h..(bi + 1) * h]);
                cache.v[base_l..base_l + h].copy_from_slice(&v_new[bi * h..(bi + 1) * h]);
            }
            // phase 2 (parallel over sequences): attention reads the caches
            // immutably; each task computes its own `[H]` context row, so
            // batch rows stay numerically independent and thread-invariant
            let cache_refs: Vec<&KvCache> = caches.iter().map(|c| &**c).collect();
            let q_ref = &q;
            let ctx_rows = pool.map_collect(m, |bi| {
                let cache = cache_refs[bi];
                let pos = cache.pos;
                // window of cached positions, oldest first (chronological —
                // the same accumulation order as the full forward)
                let n_ctx = (pos + 1).min(self.seq_len);
                let first = pos + 1 - n_ctx;
                let mut row = vec![0f32; h];
                let mut att = vec![0f32; n_ctx];
                for a in 0..nh {
                    let hb = a * d;
                    let qi = &q_ref[bi * h + hb..][..d];
                    for (jj, abs) in (first..=pos).enumerate() {
                        let sj = abs % self.seq_len;
                        let kj = &cache.k[(li * self.seq_len + sj) * h + hb..][..d];
                        let mut acc = 0f32;
                        for (qa, kb) in qi.iter().zip(kj.iter()) {
                            acc += qa * kb;
                        }
                        att[jj] = acc * inv_sqrt_d;
                    }
                    softmax_prefix(&mut att, n_ctx);
                    for (jj, abs) in (first..=pos).enumerate() {
                        let p = att[jj];
                        if p == 0.0 {
                            continue;
                        }
                        let sj = abs % self.seq_len;
                        let vj = &cache.v[(li * self.seq_len + sj) * h + hb..][..d];
                        for (o, &vv) in row[hb..hb + d].iter_mut().zip(vj.iter()) {
                            *o += p * vv;
                        }
                    }
                }
                row
            });
            let mut ctx = vec![0f32; m * h];
            for (bi, row) in ctx_rows.into_iter().enumerate() {
                ctx[bi * h..(bi + 1) * h].copy_from_slice(&row);
            }
            let ctx_q = self.maybe_quant(&ctx, h);
            let attn_out = layer.wo.matmul(pool, &ctx_q, m, h, h);
            let mut h_mid = x;
            for (o, &a) in h_mid.iter_mut().zip(attn_out.iter()) {
                *o += a;
            }

            // --- MLP block (SwiGLU) ---
            let (xn2, _) = rmsnorm(&h_mid, &layer.mlp_norm, self.rms_eps, h);
            let xq2 = self.maybe_quant(&xn2, h);
            let gate = layer.w_gate.matmul(pool, &xq2, m, h, i_);
            let up = layer.w_up.matmul(pool, &xq2, m, h, i_);
            let mut down_in = vec![0f32; m * i_];
            for ((o, &g), &u) in down_in.iter_mut().zip(gate.iter()).zip(up.iter()) {
                *o = silu(g) * u;
            }
            let down_in_q = self.maybe_quant(&down_in, i_);
            let down = layer.w_down.matmul(pool, &down_in_q, m, i_, h);
            let mut x_out = h_mid;
            for (o, &dv) in x_out.iter_mut().zip(down.iter()) {
                *o += dv;
            }
            x = x_out;
        }
        for cache in caches.iter_mut() {
            cache.pos += 1;
        }

        let (xf, _) = rmsnorm(&x, &self.final_norm, self.rms_eps, h);
        // tied LM head — dense f32, never quantized (same as training)
        Ok(matmul_nt(pool, &xf, &self.emb, m, h, v))
    }
}

/// RoPE rotation of one `[H]` row at absolute position `pos` — the
/// incremental twin of [`apply_rope`], with identical angle arithmetic so
/// cached keys match the full forward bit for bit.
fn rope_row(x: &mut [f32], pos: usize, nh: usize, half: usize, theta: f32) {
    let d = 2 * half;
    for a in 0..nh {
        let base = a * d;
        for j in 0..half {
            let inv = 1.0 / theta.powf(2.0 * j as f32 / d as f32);
            let ang = pos as f32 * inv;
            let (c, sn) = (ang.cos(), ang.sin());
            let x0 = x[base + 2 * j];
            let x1 = x[base + 2 * j + 1];
            x[base + 2 * j] = x0 * c - x1 * sn;
            x[base + 2 * j + 1] = x0 * sn + x1 * c;
        }
    }
}

/// Rotate adjacent pairs of each head's dims by the position angle
/// (`[M,H]` layout, heads = contiguous `2·half` column blocks).
fn apply_rope(x: &mut [f32], cos: &[f32], sin: &[f32], b: usize, s: usize, nh: usize, half: usize) {
    let d = 2 * half;
    let h = nh * d;
    for bi in 0..b {
        for i in 0..s {
            let row = (bi * s + i) * h;
            for a in 0..nh {
                let base = row + a * d;
                for j in 0..half {
                    let (c, sn) = (cos[i * half + j], sin[i * half + j]);
                    let x0 = x[base + 2 * j];
                    let x1 = x[base + 2 * j + 1];
                    x[base + 2 * j] = x0 * c - x1 * sn;
                    x[base + 2 * j + 1] = x0 * sn + x1 * c;
                }
            }
        }
    }
}

/// Inverse rotation (RoPE backward).
fn unapply_rope(
    x: &mut [f32],
    cos: &[f32],
    sin: &[f32],
    b: usize,
    s: usize,
    nh: usize,
    half: usize,
) {
    let d = 2 * half;
    let h = nh * d;
    for bi in 0..b {
        for i in 0..s {
            let row = (bi * s + i) * h;
            for a in 0..nh {
                let base = row + a * d;
                for j in 0..half {
                    let (c, sn) = (cos[i * half + j], sin[i * half + j]);
                    let y0 = x[base + 2 * j];
                    let y1 = x[base + 2 * j + 1];
                    x[base + 2 * j] = y0 * c + y1 * sn;
                    x[base + 2 * j + 1] = -y0 * sn + y1 * c;
                }
            }
        }
    }
}
