//! Pure-Rust CPU reference backend: the paper's whole training loop —
//! LLaMA forward/backward, cross-entropy, AdamW/Adafactor, and the §3
//! stochastic-rounding update applied straight to the quantized grids
//! (no FP32 master weights) — executable on any machine with nothing but
//! this crate. No artifacts, no PJRT, no Python.
//!
//! The layout ([`spec`]) synthesizes the same manifest the AOT pipeline
//! writes, so checkpoints, eval, the coordinator and the memory model
//! work identically on both backends; [`model`] is the forward/backward
//! twin of `python/compile/model.py`; [`optim`] mirrors
//! `optim.py::apply_updates` including the SR seed stream, so the native
//! backend is a *semantic* reference for the compiled graphs, not just an
//! approximation.

mod math;
mod model;
mod optim;
mod spec;

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{Mode, ModelConfig, VariantSpec};
use crate::kernels::Pool;
use crate::obs::quant::QuantStepRecord;
use crate::obs::trace;
use crate::quant::codec::Format;
use crate::quant::sr::{hash_u32, uniform01};
use crate::quant::{absmean_quantize, absmean_scale, ternary};

use super::{
    add_grad_buffers, Backend, Decoder, DecoderCache, GradReducer, Manifest, Param, State,
    StepMetrics,
};

/// The native CPU backend for one variant.
pub struct NativeBackend {
    hyper: spec::Hyper,
    cfg: ModelConfig,
    layout: spec::Layout,
    /// kernel pool every matmul (training, eval, serving prep) fans
    /// across; shared with decoders built from this backend
    pool: Arc<Pool>,
}

impl NativeBackend {
    /// Build the backend for `spec` (errors on unknown models or
    /// unsupported bit widths — no filesystem access involved). The
    /// kernel pool is sized from the environment (`DQT_THREADS` /
    /// available cores) on the `DQT_PRECISION` tier; use
    /// [`NativeBackend::with_pool`] for an explicit handle (the
    /// `--threads`/`--precision` CLI path and the parity tests).
    pub fn new(vspec: &VariantSpec) -> Result<Self> {
        Self::with_pool(vspec, Arc::new(Pool::from_env()))
    }

    /// Build the backend for `spec` on an explicit kernel pool.
    pub fn with_pool(vspec: &VariantSpec, pool: Arc<Pool>) -> Result<Self> {
        let (hyper, cfg, layout) = spec::build(vspec)?;
        Ok(NativeBackend {
            hyper,
            cfg,
            layout,
            pool,
        })
    }

    fn net(&self) -> model::Net<'_> {
        model::Net {
            hyper: &self.hyper,
            cfg: &self.cfg,
            layout: &self.layout,
            pool: &self.pool,
        }
    }

    /// Reject states whose param layout does not match the manifest
    /// before any indexing happens.
    fn check_state(&self, state: &State) -> Result<()> {
        if state.params.len() != self.layout.manifest.params.len() {
            return Err(anyhow!(
                "state has {} params, manifest wants {}",
                state.params.len(),
                self.layout.manifest.params.len()
            ));
        }
        Ok(())
    }

    fn dense_view<'s>(&self, state: &'s State) -> Result<Vec<Cow<'s, [f32]>>> {
        self.check_state(state)?;
        state.params.iter().map(|p| p.values()).collect()
    }

    fn check_ternary(&self, ternary: bool) -> Result<()> {
        if ternary && !self.has_ternary_inference() {
            return Err(anyhow!("variant has no ternary-inference entry"));
        }
        Ok(())
    }

    /// Build the decode-time weights for `state` (see [`Decoder`]).
    ///
    /// `packed` selects the fused 2-bit representation for every
    /// ternary-effective projection — the serving default, where the
    /// decode matmuls run straight off the codes via
    /// [`ternary::gemm_nt`] and no f32 weight is ever materialized.
    /// `packed = false` keeps the same effective weights dense f32: the
    /// bit-exact twin of the training forward, used by the KV-cache
    /// parity tests.
    pub fn decoder_with(
        &self,
        state: &State,
        ternary_inf: bool,
        packed: bool,
    ) -> Result<Box<dyn Decoder>> {
        self.check_state(state)?;
        self.check_ternary(ternary_inf)?;
        // decode-time weight treatment, mirroring `Net::effective_weight`:
        // project = §A.2 AbsMean re-projection to ternary at build time
        let project = match self.hyper.mode {
            Mode::Fp32 => false,
            Mode::Bitnet158 | Mode::DqtTernaryInf => true,
            Mode::Dqt | Mode::DqtAbsmax => ternary_inf,
        };
        // grids already stored on the ternary grid pack without projection
        let stored_ternary = self.hyper.has_grid_weights()
            && Format::from_bits(self.hyper.grid_bits) == Format::Ternary2bit;
        let build_lin = |lin: &spec::Lin| -> Result<model::DecodeLin> {
            let p = &state.params[lin.w];
            if project {
                let w = p.values()?;
                let s3 = absmean_scale(&w, 1.58);
                let w3 = absmean_quantize(&w, 1.58, s3);
                if packed {
                    let codes: Vec<f32> = w3.iter().map(|&x| (x * s3).round()).collect();
                    let words =
                        ternary::pack(&codes).map_err(|e| anyhow!("packing projection: {e}"))?;
                    Ok(model::DecodeLin::Ternary { words, scale: s3 })
                } else {
                    Ok(model::DecodeLin::Dense(w3))
                }
            } else if stored_ternary && packed {
                match p {
                    // resident 2-bit grids: adopt the packed bytes as-is
                    // (no f32 round trip anywhere)
                    Param::Packed(pt) if pt.format == Format::Ternary2bit => {
                        let words = pt
                            .bytes
                            .chunks_exact(4)
                            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                            .collect();
                        let scale = pt
                            .scale
                            .ok_or_else(|| anyhow!("packed ternary grid without scale"))?;
                        Ok(model::DecodeLin::Ternary { words, scale })
                    }
                    _ => {
                        let si = lin
                            .s
                            .ok_or_else(|| anyhow!("ternary grid without companion scale"))?;
                        let s = state.params[si].scalar()?;
                        let w = p.values()?;
                        let codes: Vec<f32> = w.iter().map(|&x| (x * s).round()).collect();
                        let words =
                            ternary::pack(&codes).map_err(|e| anyhow!("packing grid: {e}"))?;
                        Ok(model::DecodeLin::Ternary { words, scale: s })
                    }
                }
            } else {
                Ok(model::DecodeLin::Dense(p.to_vec()?))
            }
        };
        let mut layers = Vec::with_capacity(self.layout.layers.len());
        for li in &self.layout.layers {
            layers.push(model::DecodeLayer {
                attn_norm: state.params[li.attn_norm].to_vec()?,
                mlp_norm: state.params[li.mlp_norm].to_vec()?,
                wq: build_lin(&li.wq)?,
                wk: build_lin(&li.wk)?,
                wv: build_lin(&li.wv)?,
                wo: build_lin(&li.wo)?,
                w_gate: build_lin(&li.w_gate)?,
                w_up: build_lin(&li.w_up)?,
                w_down: build_lin(&li.w_down)?,
            });
        }
        let w = model::DecodeWeights {
            quantized_acts: self.hyper.mode != Mode::Fp32,
            act_bits: self.hyper.act_bits,
            rope_theta: self.hyper.rope_theta,
            rms_eps: self.hyper.rms_eps,
            hidden: self.cfg.hidden_size,
            inter: self.cfg.intermediate_size,
            vocab: self.cfg.vocab_size,
            n_heads: self.cfg.num_attention_heads,
            seq_len: self.cfg.max_seq_len,
            emb: state.params[self.layout.emb].to_vec()?,
            final_norm: state.params[self.layout.final_norm].to_vec()?,
            layers,
            pool: self.pool.clone(),
        };
        Ok(Box::new(NativeDecoder { w }))
    }

    /// The band's gradient partial: the fixed halving tree over global
    /// row indices `lo..hi`, whose leaves are per-row *unnormalized*
    /// (sum-CE) gradients and whose internal nodes are
    /// [`add_grad_buffers`] (left + right, in that order). Because the
    /// split index is a pure function of the global range — never of the
    /// world size — an N-rank partition into contiguous equal bands (N a
    /// power of two) slices this tree at subtree boundaries, which is
    /// what makes the cross-rank reduction able to finish the 1-worker
    /// chain bit for bit. Memory: one gradient set per tree level
    /// (O(log rows)), not one per row.
    #[allow(clippy::too_many_arguments)]
    fn band_grads(
        &self,
        view: &[Cow<'_, [f32]>],
        inputs: &[i32],
        labels: &[i32],
        s: usize,
        band_lo: usize,
        lo: usize,
        hi: usize,
    ) -> Result<(f32, u64, Vec<Option<Vec<f32>>>)> {
        if hi - lo == 1 {
            let off = (lo - band_lo) * s;
            let row_in = &inputs[off..off + s];
            let row_lab = &labels[off..off + s];
            let count = row_lab
                .iter()
                .filter(|&&l| l != crate::data::tokenizer::PAD_ID)
                .count() as u64;
            let (nll, grads) =
                self.net()
                    .loss_and_grads_scaled(view, row_in, row_lab, 1, s, Some(1.0))?;
            return Ok((nll, count, grads));
        }
        let mid = lo + (hi - lo) / 2;
        let (nll_l, c_l, mut g_l) = self.band_grads(view, inputs, labels, s, band_lo, lo, mid)?;
        let (nll_r, c_r, g_r) = self.band_grads(view, inputs, labels, s, band_lo, mid, hi)?;
        add_grad_buffers(&mut g_l, &g_r)?;
        Ok((nll_l + nll_r, c_l + c_r, g_l))
    }

    /// Split a `[b, s+1]` token matrix into (inputs, labels) rows.
    fn split_rows(&self, tokens: &[i32]) -> Result<(Vec<i32>, Vec<i32>, usize, usize)> {
        let shape = &self.layout.manifest.tokens_shape;
        let (b, w) = (shape[0], shape[1]);
        if tokens.len() != b * w {
            return Err(anyhow!("expected {}x{} tokens, got {}", b, w, tokens.len()));
        }
        let s = w - 1;
        let mut inputs = Vec::with_capacity(b * s);
        let mut labels = Vec::with_capacity(b * s);
        for bi in 0..b {
            let row = &tokens[bi * w..(bi + 1) * w];
            inputs.extend_from_slice(&row[..s]);
            labels.extend_from_slice(&row[1..]);
        }
        Ok((inputs, labels, b, s))
    }
}

/// Deterministic standard normal via Box–Muller on the counter-hash PRNG
/// (the same stream family the SR kernels draw from).
fn normal(counter: u32, seed: u32) -> f32 {
    let u1 = uniform01(counter, seed);
    let u2 = uniform01(counter.wrapping_add(1), seed);
    (-2.0 * (1.0 - u1).ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn precision(&self) -> crate::config::Precision {
        self.pool.precision()
    }

    fn manifest(&self) -> &Manifest {
        &self.layout.manifest
    }

    /// LLaMA-style init (normal·0.02, norms at one); DQT modes project
    /// every linear onto its grid and store the fixed AbsMean scale as the
    /// `.s` companion (paper §3.2 skips Eq. 2-4 after initialization).
    fn init_state(&self, seed: u32) -> Result<State> {
        let metas = &self.layout.manifest.params;
        let mut params: Vec<Vec<f32>> = Vec::with_capacity(metas.len());
        let mut pi = 0;
        while pi < metas.len() {
            let meta = &metas[pi];
            let n = meta.numel();
            if meta.name.ends_with("norm") {
                params.push(vec![1.0; n]);
                pi += 1;
                continue;
            }
            let pseed = hash_u32(pi as u32, seed);
            let mut w = vec![0f32; n];
            for (i, o) in w.iter_mut().enumerate() {
                *o = normal(2 * i as u32, pseed) * self.hyper.init_std;
            }
            if meta.is_grid() {
                let s = absmean_scale(&w, self.hyper.grid_bits);
                params.push(absmean_quantize(&w, self.hyper.grid_bits, s));
                params.push(vec![s]);
                pi += 2;
            } else {
                params.push(w);
                pi += 1;
            }
        }
        let opt = self
            .layout
            .manifest
            .opt_state
            .iter()
            .map(|o| vec![0.0; o.numel()])
            .collect();
        Ok(State::from_dense(params, opt))
    }

    fn train_step(
        &self,
        state: State,
        tokens: &[i32],
        sr_seed: u32,
        lr: f32,
    ) -> Result<(State, StepMetrics)> {
        self.train_step_quant(state, tokens, sr_seed, lr, None)
    }

    /// Grid tensors in `trainables` order — identical to the manifest's
    /// grid-param order (`spec::build_layout` enumerates the manifest),
    /// which fixes the quant-health slot layout.
    fn quant_layers(&self) -> Vec<(String, u64)> {
        self.layout
            .trainables
            .iter()
            .filter(|t| t.scale.is_some())
            .map(|t| {
                let meta = &self.layout.manifest.params[t.param];
                (meta.name.clone(), meta.numel() as u64)
            })
            .collect()
    }

    fn train_step_quant(
        &self,
        state: State,
        tokens: &[i32],
        sr_seed: u32,
        lr: f32,
        quant: Option<&mut QuantStepRecord>,
    ) -> Result<(State, StepMetrics)> {
        let (inputs, labels, b, s) = self.split_rows(tokens)?;
        self.check_state(&state)?;
        let mut params: Vec<Vec<f32>> = state
            .params
            .iter()
            .map(|p| p.to_vec())
            .collect::<Result<_>>()?;
        let mut opt = state.opt;
        if opt.len() != self.layout.manifest.opt_state.len() || opt.is_empty() {
            return Err(anyhow!("optimizer state does not match the manifest"));
        }
        let t_fwd = std::time::Instant::now();
        let (loss, grads) = {
            let view: Vec<Cow<'_, [f32]>> =
                params.iter().map(|v| Cow::Borrowed(v.as_slice())).collect();
            self.net().loss_and_grads(&view, &inputs, &labels, b, s)?
        };
        let fwd_ms = t_fwd.elapsed().as_secs_f32() * 1e3;
        let t_opt = std::time::Instant::now();
        let (upd_frac, gnorm) = {
            let _sp = trace::span("train", trace::names::TRAIN_OPTIMIZER);
            optim::apply_updates(
                &self.hyper,
                &self.layout,
                &self.pool,
                &mut params,
                grads,
                &mut opt,
                lr,
                sr_seed,
                quant,
            )
        };
        let opt_ms = t_opt.elapsed().as_secs_f32() * 1e3;
        Ok((
            State::from_dense(params, opt),
            StepMetrics {
                loss,
                upd_frac,
                gnorm,
                fwd_ms,
                opt_ms,
            },
        ))
    }

    /// The distributed twin of [`NativeBackend::train_step`] (paper-
    /// faithful data parallelism): per-row unnormalized gradients are
    /// combined by the fixed halving tree over global batch rows
    /// ([`NativeBackend::band_grads`]), the reducer completes the tree
    /// across ranks, every rank then scales by the *global* non-pad token
    /// count and runs the identical optimizer + §3 SR projection — one SR
    /// application to the reduced update, so weights stay on-grid and all
    /// ranks step to a bit-identical state.
    #[allow(clippy::too_many_arguments)]
    fn train_step_sharded(
        &self,
        state: State,
        tokens: &[i32],
        band: (usize, usize),
        global_rows: usize,
        step: u64,
        sr_seed: u32,
        lr: f32,
        reducer: &mut dyn GradReducer,
    ) -> Result<(State, StepMetrics)> {
        self.train_step_sharded_quant(
            state,
            tokens,
            band,
            global_rows,
            step,
            sr_seed,
            lr,
            reducer,
            None,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn train_step_sharded_quant(
        &self,
        state: State,
        tokens: &[i32],
        band: (usize, usize),
        global_rows: usize,
        step: u64,
        sr_seed: u32,
        lr: f32,
        reducer: &mut dyn GradReducer,
        quant: Option<&mut QuantStepRecord>,
    ) -> Result<(State, StepMetrics)> {
        let shape = &self.layout.manifest.tokens_shape;
        let (bsz, w) = (shape[0], shape[1]);
        if global_rows != bsz {
            return Err(anyhow!(
                "global batch is {global_rows} rows, manifest wants {bsz}"
            ));
        }
        let (lo, hi) = band;
        if lo >= hi || hi > global_rows {
            return Err(anyhow!("bad band {lo}..{hi} of {global_rows} rows"));
        }
        let rows = hi - lo;
        if tokens.len() != rows * w {
            return Err(anyhow!(
                "expected {rows}x{w} shard tokens, got {}",
                tokens.len()
            ));
        }
        self.check_state(&state)?;
        let s = w - 1;
        let mut inputs = Vec::with_capacity(rows * s);
        let mut labels = Vec::with_capacity(rows * s);
        for bi in 0..rows {
            let row = &tokens[bi * w..(bi + 1) * w];
            inputs.extend_from_slice(&row[..s]);
            labels.extend_from_slice(&row[1..]);
        }
        let mut params: Vec<Vec<f32>> = state
            .params
            .iter()
            .map(|p| p.to_vec())
            .collect::<Result<_>>()?;
        let mut opt = state.opt;
        if opt.len() != self.layout.manifest.opt_state.len() || opt.is_empty() {
            return Err(anyhow!("optimizer state does not match the manifest"));
        }
        let t_fwd = std::time::Instant::now();
        let (mut nll, mut count, mut grads) = {
            let view: Vec<Cow<'_, [f32]>> =
                params.iter().map(|v| Cow::Borrowed(v.as_slice())).collect();
            self.band_grads(&view, &inputs, &labels, s, lo, lo, hi)?
        };
        let fwd_ms = t_fwd.elapsed().as_secs_f32() * 1e3;
        reducer.reduce(step, &mut grads, &mut nll, &mut count)?;
        // global normalization, applied identically on every rank *after*
        // the reduction (the per-row leaves were built with denom = 1.0)
        let denom = (count as f32).max(1.0);
        let inv = 1.0 / denom;
        for g in grads.iter_mut().flatten() {
            for v in g.iter_mut() {
                *v *= inv;
            }
        }
        let loss = nll / denom;
        let t_opt = std::time::Instant::now();
        let (upd_frac, gnorm) = {
            let _sp = trace::span("train", trace::names::TRAIN_OPTIMIZER);
            optim::apply_updates(
                &self.hyper,
                &self.layout,
                &self.pool,
                &mut params,
                grads,
                &mut opt,
                lr,
                sr_seed,
                quant,
            )
        };
        let opt_ms = t_opt.elapsed().as_secs_f32() * 1e3;
        Ok((
            State::from_dense(params, opt),
            StepMetrics {
                loss,
                upd_frac,
                gnorm,
                fwd_ms,
                opt_ms,
            },
        ))
    }

    fn eval_step(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<(f32, f32)> {
        self.check_ternary(ternary)?;
        let (inputs, labels, b, s) = self.split_rows(tokens)?;
        let view = self.dense_view(state)?;
        self.net().nll_sums(&view, &inputs, &labels, b, s, ternary)
    }

    fn logits(&self, state: &State, tokens: &[i32], ternary: bool) -> Result<Vec<f32>> {
        self.check_ternary(ternary)?;
        let shape = &self.layout.manifest.logits_tokens_shape;
        let (b, s) = (shape[0], shape[1]);
        if tokens.len() != b * s {
            return Err(anyhow!("expected {}x{} tokens, got {}", b, s, tokens.len()));
        }
        let view = self.dense_view(state)?;
        Ok(self.net().forward(&view, tokens, b, s, ternary)?.logits)
    }

    fn has_ternary_inference(&self) -> bool {
        self.hyper.mode.quantized()
    }

    fn decoder(&self, state: &State, ternary: bool) -> Result<Box<dyn Decoder>> {
        self.decoder_with(state, ternary, true)
    }
}

/// The native backend's serving decoder: prepared [`model::DecodeWeights`]
/// (packed ternary projections + dense embedding/norms) behind the
/// backend-agnostic [`Decoder`] trait.
pub struct NativeDecoder {
    w: model::DecodeWeights,
}

impl Decoder for NativeDecoder {
    fn max_positions(&self) -> usize {
        self.w.seq_len
    }

    fn threads(&self) -> usize {
        self.w.pool.threads()
    }

    fn precision(&self) -> crate::config::Precision {
        self.w.pool.precision()
    }

    fn vocab_size(&self) -> usize {
        self.w.vocab
    }

    fn kv_bytes_per_position(&self) -> usize {
        2 * self.w.layers.len() * self.w.hidden * 4
    }

    fn weight_bytes(&self) -> usize {
        let mut b = (self.w.emb.len() + self.w.final_norm.len()) * 4;
        for l in &self.w.layers {
            b += (l.attn_norm.len() + l.mlp_norm.len()) * 4;
            for lin in l.lins() {
                b += lin.resident_bytes();
            }
        }
        b
    }

    fn packed_projections(&self) -> usize {
        self.w
            .layers
            .iter()
            .flat_map(|l| l.lins())
            .filter(|l| l.is_packed())
            .count()
    }

    fn n_projections(&self) -> usize {
        self.w.layers.len() * 7
    }

    fn new_cache(&self) -> Box<dyn DecoderCache> {
        Box::new(self.w.new_cache())
    }

    fn step_batch(
        &self,
        caches: &mut [&mut dyn DecoderCache],
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let mut kvs: Vec<&mut model::KvCache> = Vec::with_capacity(caches.len());
        for c in caches.iter_mut() {
            kvs.push(
                c.as_any_mut()
                    .downcast_mut::<model::KvCache>()
                    .ok_or_else(|| anyhow!("cache was not created by the native decoder"))?,
            );
        }
        self.w.forward_step_batch(&mut kvs, tokens)
    }

    fn step(&self, cache: &mut dyn DecoderCache, token: i32) -> Result<Vec<f32>> {
        let kv = cache
            .as_any_mut()
            .downcast_mut::<model::KvCache>()
            .ok_or_else(|| anyhow!("cache was not created by the native decoder"))?;
        self.w.forward_step(kv, token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::quant::qrange;

    fn backend(mode: Mode, bits: f64) -> NativeBackend {
        NativeBackend::new(&VariantSpec::new("test", mode, bits)).unwrap()
    }

    fn tiny_tokens(backend: &NativeBackend, seed: u32) -> Vec<i32> {
        let shape = &backend.layout.manifest.tokens_shape;
        let v = backend.cfg.vocab_size as u32;
        (0..shape[0] * shape[1])
            .map(|i| (hash_u32(i as u32, seed) % v) as i32)
            .collect()
    }

    #[test]
    fn init_shapes_match_manifest_and_grids_are_on_grid() {
        for (mode, bits) in [(Mode::Fp32, 1.58), (Mode::Dqt, 1.58), (Mode::Dqt, 4.0)] {
            let be = backend(mode, bits);
            let st = be.init_state(42).unwrap();
            assert_eq!(st.params.len(), be.layout.manifest.params.len());
            assert_eq!(st.opt.len(), be.layout.manifest.opt_state.len());
            for (meta, p) in be.layout.manifest.params.iter().zip(&st.params) {
                assert_eq!(p.numel(), meta.numel(), "{}", meta.name);
            }
            assert_eq!(st.step(), 0.0);
            let (qn, qp) = qrange(be.hyper.grid_bits);
            for (i, meta) in be.layout.manifest.params.iter().enumerate() {
                if meta.is_grid() {
                    let s = st.params[i + 1].scalar().unwrap();
                    assert!(s > 0.0);
                    for &v in st.params[i].values().unwrap().iter() {
                        let k = (v * s) as f64;
                        assert!((k - k.round()).abs() < 1e-3, "{} off grid", meta.name);
                        assert!(k >= qn - 1e-3 && k <= qp + 1e-3);
                    }
                }
            }
        }
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let be = backend(Mode::Dqt, 1.58);
        let a = be.init_state(7).unwrap();
        let b = be.init_state(7).unwrap();
        let c = be.init_state(8).unwrap();
        for (x, y) in a.params.iter().zip(b.params.iter()) {
            assert_eq!(x, y);
        }
        assert!(a.params.iter().zip(c.params.iter()).any(|(x, y)| x != y));
    }

    #[test]
    fn train_step_runs_and_counts_updates() {
        let be = backend(Mode::Dqt, 1.58);
        let st = be.init_state(1).unwrap();
        let tokens = tiny_tokens(&be, 3);
        let (st2, m1) = be.train_step(st, &tokens, 11, 1e-2).unwrap();
        assert!(m1.loss.is_finite() && m1.loss > 0.0);
        assert!(m1.gnorm > 0.0);
        assert_eq!(st2.step(), 1.0);
        // weights stay on the ternary grid after the SR update
        for (i, meta) in be.layout.manifest.params.iter().enumerate() {
            if meta.is_grid() {
                let s = st2.params[i + 1].scalar().unwrap();
                for &v in st2.params[i].values().unwrap().iter() {
                    let k = v * s;
                    assert!((k - k.round()).abs() < 1e-3);
                }
            }
        }
        let (_, m2) = be.train_step(st2, &tokens, 12, 1e-2).unwrap();
        assert!(m2.loss.is_finite());
    }

    #[test]
    fn train_step_is_deterministic() {
        let be = backend(Mode::Dqt, 1.58);
        let tokens = tiny_tokens(&be, 9);
        let run = || {
            let st = be.init_state(5).unwrap();
            let (st2, m) = be.train_step(st, &tokens, 77, 1e-3).unwrap();
            (st2, m)
        };
        let (a, ma) = run();
        let (b, mb) = run();
        assert_eq!(ma.loss, mb.loss);
        assert_eq!(ma.upd_frac, mb.upd_frac);
        for (x, y) in a.params.iter().zip(b.params.iter()) {
            assert_eq!(x, y);
        }
        // a different SR seed flips different trits
        let st = be.init_state(5).unwrap();
        let (c, _) = be.train_step(st, &tokens, 78, 1e-3).unwrap();
        assert!(a.params.iter().zip(c.params.iter()).any(|(x, y)| x != y));
    }

    #[test]
    fn eval_and_logits_shapes() {
        let be = backend(Mode::Dqt, 8.0);
        let st = be.init_state(2).unwrap();
        let tokens = tiny_tokens(&be, 4);
        let (nll, count) = be.eval_step(&st, &tokens, false).unwrap();
        assert!(nll.is_finite() && nll > 0.0);
        assert!(count > 0.0);
        // ternary inference changes the model (§A.2 projection)
        let (nll3, count3) = be.eval_step(&st, &tokens, true).unwrap();
        assert_eq!(count, count3);
        assert_ne!(nll, nll3);
        let shape = &be.layout.manifest.logits_tokens_shape;
        let lt: Vec<i32> = (0..shape[0] * shape[1])
            .map(|i| (i % be.cfg.vocab_size) as i32)
            .collect();
        let logits = be.logits(&st, &lt, false).unwrap();
        assert_eq!(logits.len(), shape[0] * shape[1] * be.cfg.vocab_size);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fp32_has_no_ternary_entry() {
        let be = backend(Mode::Fp32, 1.58);
        let st = be.init_state(1).unwrap();
        let tokens = tiny_tokens(&be, 1);
        assert!(!be.has_ternary_inference());
        assert!(be.eval_step(&st, &tokens, true).is_err());
    }

    #[test]
    fn bad_tokens_error_cleanly() {
        let be = backend(Mode::Dqt, 1.58);
        let st = be.init_state(1).unwrap();
        let mut tokens = tiny_tokens(&be, 1);
        assert!(be
            .train_step(st.clone(), &tokens[..tokens.len() - 1], 0, 1e-3)
            .is_err());
        tokens[3] = be.cfg.vocab_size as i32 + 5;
        assert!(be.train_step(st, &tokens, 0, 1e-3).is_err());
    }

    /// KV-cached incremental decoding reproduces the full-sequence forward
    /// position by position, for ternary and int8 variants (the parity
    /// requirement of the serving subsystem). Dense decode weights are the
    /// bit-exact twin of the training forward, so 1e-5 holds with margin;
    /// the fused 2-bit GEMV path applies the AbsMean scale once per row
    /// instead of once per weight, so it gets a (still tight) float-
    /// association tolerance.
    #[test]
    fn kv_cache_decode_matches_full_forward() {
        for (mode, bits, packed, tol) in [
            (Mode::Dqt, 8.0, true, 1e-5f32), // int8 grid serves dense f32
            (Mode::Dqt, 1.58, false, 1e-5),  // ternary grid, dense twin
            (Mode::Fp32, 1.58, true, 1e-5),
            (Mode::Dqt, 1.58, true, 2e-3), // fused packed-ternary GEMV
        ] {
            let be = backend(mode, bits);
            let st = be.init_state(11).unwrap();
            let shape = &be.layout.manifest.logits_tokens_shape;
            let (b, s) = (shape[0], shape[1]);
            let v = be.cfg.vocab_size;
            let tokens: Vec<i32> = (0..b * s)
                .map(|i| (hash_u32(i as u32, 5) % v as u32) as i32)
                .collect();
            let full = be.logits(&st, &tokens, false).unwrap();
            let dec = be.decoder_with(&st, false, packed).unwrap();
            for bi in 0..b {
                let mut cache = dec.new_cache();
                for i in 0..s {
                    let step = dec.step(cache.as_mut(), tokens[bi * s + i]).unwrap();
                    let want = &full[(bi * s + i) * v..(bi * s + i + 1) * v];
                    for (c, (a, w)) in step.iter().zip(want.iter()).enumerate() {
                        assert!(
                            (a - w).abs() < tol,
                            "{mode:?} b{bits} packed={packed} row {bi} pos {i} logit {c}: {a} vs {w}"
                        );
                    }
                }
                assert_eq!(cache.position(), s);
            }
        }
    }

    /// §A.2 deploy-time ternary projection: the decoder's build-time
    /// AbsMean projection matches the full forward's per-call projection.
    #[test]
    fn kv_cache_decode_matches_ternary_projection() {
        let be = backend(Mode::Dqt, 8.0);
        let st = be.init_state(4).unwrap();
        let shape = &be.layout.manifest.logits_tokens_shape;
        let (_, s) = (shape[0], shape[1]);
        let v = be.cfg.vocab_size;
        let tokens: Vec<i32> = (0..shape[0] * s).map(|i| (i % v) as i32).collect();
        let full = be.logits(&st, &tokens, true).unwrap();
        let dec = be.decoder_with(&st, true, false).unwrap();
        let mut cache = dec.new_cache();
        for i in 0..s {
            let step = dec.step(cache.as_mut(), tokens[i]).unwrap();
            let want = &full[i * v..(i + 1) * v];
            for (a, w) in step.iter().zip(want.iter()) {
                assert!((a - w).abs() < 1e-5, "pos {i}: {a} vs {w}");
            }
        }
        // fp32 has no ternary-inference entry on the decode path either
        let fe = backend(Mode::Fp32, 1.58);
        let fst = fe.init_state(1).unwrap();
        assert!(fe.decoder_with(&fst, true, true).is_err());
    }

    /// The serving decoder is decode-free for ternary grids: every
    /// projection runs fused off 2-bit codes, packed-resident states are
    /// adopted without an f32 round trip, and the ring cache slides past
    /// `seq_len` without erroring.
    #[test]
    fn decoder_serves_packed_grids_decode_free() {
        let be = backend(Mode::Dqt, 1.58);
        let mut st = be.init_state(2).unwrap();
        st.pack_grids(&be.layout.manifest).unwrap();
        let dec = be.decoder_with(&st, false, true).unwrap();
        assert_eq!(dec.packed_projections(), dec.n_projections());
        let dense_all: usize = be
            .layout
            .manifest
            .params
            .iter()
            .filter(|p| !p.is_scale())
            .map(|p| p.numel() * 4)
            .sum();
        assert!(dec.weight_bytes() < dense_all, "{} !< {dense_all}", dec.weight_bytes());
        assert_eq!(
            dec.kv_bytes_per_position(),
            2 * be.cfg.num_hidden_layers * be.cfg.hidden_size * 4
        );
        // packed-resident and dense states produce bitwise-equal steps
        let st_dense = be.init_state(2).unwrap();
        let dec_dense = be.decoder_with(&st_dense, false, true).unwrap();
        let mut c1 = dec.new_cache();
        let mut c2 = dec_dense.new_cache();
        for t in [1i32, 3, 5, 7] {
            let a = dec.step(c1.as_mut(), t).unwrap();
            let b = dec_dense.step(c2.as_mut(), t).unwrap();
            assert_eq!(a, b);
        }
        // sliding window: decode far past the trained sequence length
        let mut cache = dec.new_cache();
        for i in 0..3 * be.cfg.max_seq_len {
            let l = dec
                .step(cache.as_mut(), (i % be.cfg.vocab_size) as i32)
                .unwrap();
            assert!(l.iter().all(|x| x.is_finite()), "pos {i}");
        }
        assert_eq!(cache.position(), 3 * be.cfg.max_seq_len);
        // out-of-vocab tokens error cleanly
        assert!(dec
            .step(dec.new_cache().as_mut(), be.cfg.vocab_size as i32)
            .is_err());
    }

    /// A gradient-set reducer that steals the band partial and aborts —
    /// used to harvest one rank's contribution without stepping.
    struct CaptureReducer {
        out: Option<(Vec<Option<Vec<f32>>>, f32, u64)>,
    }

    impl GradReducer for CaptureReducer {
        fn world(&self) -> usize {
            2
        }
        fn reduce(
            &mut self,
            _step: u64,
            grads: &mut [Option<Vec<f32>>],
            nll: &mut f32,
            count: &mut u64,
        ) -> anyhow::Result<()> {
            self.out = Some((grads.to_vec(), *nll, *count));
            Err(anyhow!("captured"))
        }
    }

    /// A reducer standing in for the peer rank: combines the local left-
    /// band partial with a pre-captured right-band partial in tree order
    /// (left + right), exactly what the TCP collective does for world 2.
    struct InjectRight {
        right: Vec<Option<Vec<f32>>>,
        right_nll: f32,
        right_count: u64,
    }

    impl GradReducer for InjectRight {
        fn world(&self) -> usize {
            2
        }
        fn reduce(
            &mut self,
            _step: u64,
            grads: &mut [Option<Vec<f32>>],
            nll: &mut f32,
            count: &mut u64,
        ) -> anyhow::Result<()> {
            add_grad_buffers(grads, &self.right)?;
            *nll += self.right_nll;
            *count += self.right_count;
            Ok(())
        }
    }

    /// The sharded-step determinism contract at the backend level: a full-
    /// band 1-worker step and a two-band step whose partials are combined
    /// in tree order produce bitwise-identical states and metrics. This is
    /// the socket-free pin of what `dist::Collective` does over TCP.
    #[test]
    fn sharded_step_band_split_is_bitwise_invariant() {
        let be = backend(Mode::Dqt, 1.58);
        let st = be.init_state(3).unwrap();
        let tokens = tiny_tokens(&be, 8);
        let shape = &be.layout.manifest.tokens_shape;
        let (bsz, w) = (shape[0], shape[1]);
        assert!(bsz >= 2 && bsz % 2 == 0);
        let (lr, seed) = (2e-3f32, 77u32);

        // 1-worker reference: the whole band through the identity reducer
        let nr = &mut crate::runtime::NoReduce;
        let (full_state, full_m) = be
            .train_step_sharded(st.clone(), &tokens, (0, bsz), bsz, 0, seed, lr, nr)
            .unwrap();

        // capture the right band's partial, then inject it into the left
        let mid = bsz / 2;
        let mut cap = CaptureReducer { out: None };
        let err = be
            .train_step_sharded(
                st.clone(),
                &tokens[mid * w..],
                (mid, bsz),
                bsz,
                0,
                seed,
                lr,
                &mut cap,
            )
            .unwrap_err();
        assert!(err.to_string().contains("captured"));
        let (right, right_nll, right_count) = cap.out.unwrap();
        let mut inj = InjectRight {
            right,
            right_nll,
            right_count,
        };
        let (split_state, split_m) = be
            .train_step_sharded(st, &tokens[..mid * w], (0, mid), bsz, 0, seed, lr, &mut inj)
            .unwrap();

        assert_eq!(full_m.loss.to_bits(), split_m.loss.to_bits());
        assert_eq!(full_m.upd_frac.to_bits(), split_m.upd_frac.to_bits());
        assert_eq!(full_m.gnorm.to_bits(), split_m.gnorm.to_bits());
        for (i, (a, b)) in full_state
            .params
            .iter()
            .zip(split_state.params.iter())
            .enumerate()
        {
            assert_eq!(a, b, "param {i} diverged across the band split");
        }
        for (a, b) in full_state.opt.iter().zip(split_state.opt.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn sharded_step_trains_and_validates() {
        let be = backend(Mode::Dqt, 1.58);
        let st = be.init_state(1).unwrap();
        let tokens = tiny_tokens(&be, 3);
        let shape = &be.layout.manifest.tokens_shape;
        let (bsz, w) = (shape[0], shape[1]);
        let nr = &mut crate::runtime::NoReduce;
        let (st2, m) = be
            .train_step_sharded(st.clone(), &tokens, (0, bsz), bsz, 0, 11, 1e-2, nr)
            .unwrap();
        assert!(m.loss.is_finite() && m.loss > 0.0);
        assert!(m.gnorm > 0.0);
        assert_eq!(st2.step(), 1.0);
        // weights stay on the ternary grid after the single SR projection
        for (i, meta) in be.layout.manifest.params.iter().enumerate() {
            if meta.is_grid() {
                let s = st2.params[i + 1].scalar().unwrap();
                for &v in st2.params[i].values().unwrap().iter() {
                    let k = v * s;
                    assert!((k - k.round()).abs() < 1e-3);
                }
            }
        }
        // error paths: empty/overflowing bands, wrong shard length, wrong
        // global batch
        let nr = &mut crate::runtime::NoReduce;
        assert!(be
            .train_step_sharded(st.clone(), &tokens, (1, 1), bsz, 0, 0, 1e-3, nr)
            .is_err());
        assert!(be
            .train_step_sharded(st.clone(), &tokens, (0, bsz + 1), bsz, 0, 0, 1e-3, nr)
            .is_err());
        assert!(be
            .train_step_sharded(st.clone(), &tokens[..w], (0, bsz), bsz, 0, 0, 1e-3, nr)
            .is_err());
        assert!(be
            .train_step_sharded(st, &tokens, (0, bsz), bsz + 2, 0, 0, 1e-3, nr)
            .is_err());
    }

    /// End-to-end gradient check of the full backward pass (embedding →
    /// RoPE attention → SwiGLU → tied head → masked CE) against numeric
    /// differences. Only valid in fp32 mode, where the loss is smooth —
    /// every quantized path is a step function under STE by design.
    #[test]
    fn fp32_gradients_match_numeric_differences() {
        let be = backend(Mode::Fp32, 1.58);
        let st = be.init_state(3).unwrap();
        let tokens = tiny_tokens(&be, 6);
        let (inputs, labels, b, s) = be.split_rows(&tokens).unwrap();
        let mut params: Vec<Vec<f32>> = st.params.iter().map(|p| p.to_vec().unwrap()).collect();
        let loss_of = |params: &[Vec<f32>]| -> f32 {
            let view: Vec<std::borrow::Cow<'_, [f32]>> =
                params.iter().map(|v| Cow::Borrowed(v.as_slice())).collect();
            be.net()
                .loss_and_grads(&view, &inputs, &labels, b, s)
                .unwrap()
                .0
        };
        let (_, grads) = {
            let view: Vec<Cow<'_, [f32]>> =
                params.iter().map(|v| Cow::Borrowed(v.as_slice())).collect();
            be.net().loss_and_grads(&view, &inputs, &labels, b, s).unwrap()
        };
        // probe a few entries of every trainable tensor
        let eps = 3e-3;
        for (pi, meta) in be.layout.manifest.params.iter().enumerate() {
            let Some(g) = grads[pi].as_ref() else { continue };
            let n = meta.numel();
            for probe in 0..3 {
                let i = (hash_u32(probe, pi as u32) as usize) % n;
                let orig = params[pi][i];
                params[pi][i] = orig + eps;
                let up = loss_of(&params);
                params[pi][i] = orig - eps;
                let down = loss_of(&params);
                params[pi][i] = orig;
                let num = (up - down) / (2.0 * eps);
                let tol = 2e-2f32.max(0.2 * num.abs());
                assert!(
                    (num - g[i]).abs() < tol,
                    "{}[{i}]: numeric {num} vs analytic {}",
                    meta.name,
                    g[i]
                );
            }
        }
    }
}
