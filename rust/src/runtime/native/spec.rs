//! Variant layout for the native backend: synthesizes the [`Manifest`]
//! (param/opt-state order, shapes, roles) directly from a [`VariantSpec`]
//! — the Rust twin of `python/compile/model.py::flat_param_names` +
//! `optim.py::opt_state_names`, so a native-trained state checkpoints and
//! reloads exactly like an artifact-trained one.

use anyhow::{anyhow, Result};

use crate::config::{Env, Mode, ModelConfig, Optimizer, VariantSpec};
use crate::runtime::artifact::{
    Manifest, OptMeta, ParamMeta, TrainStepOutputs, VariantMeta, VariantModelMeta,
};

/// Fig. 7 intervention on the bottom-`intervention_frac` smallest updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Intervention {
    None,
    ForceRemain,
    ForceUpdate,
}

/// Training hyperparameters fixed per variant (twin of the python
/// `VariantConfig` defaults — the AOT graphs bake in the same values).
#[derive(Clone, Debug)]
pub struct Hyper {
    pub mode: Mode,
    /// bit width of the stored weight grid (`dqt_ternary_inf` stores 8-bit,
    /// BitNet's quantized *forward* is ternary)
    pub grid_bits: f64,
    pub env: Env,
    pub optimizer: Optimizer,
    pub intervention: Intervention,
    pub intervention_frac: f64,
    pub recompute_scale: bool,
    pub act_bits: u32,
    pub weight_decay: f32,
    pub adam_b1: f32,
    pub adam_b2: f32,
    pub adam_eps: f32,
    pub grad_clip: f32,
    pub rope_theta: f32,
    pub rms_eps: f32,
    pub init_std: f32,
}

impl Hyper {
    /// DQT-family variants store grid weights (+ fixed scales); BitNet
    /// stores FP32 masters (twin of `model.has_grid_weights`).
    pub fn has_grid_weights(&self) -> bool {
        self.mode.quantized() && self.mode != Mode::Bitnet158
    }
}

/// Index of one projection matrix (+ its `.s` scale when on the grid).
#[derive(Clone, Copy, Debug)]
pub struct Lin {
    pub w: usize,
    pub s: Option<usize>,
}

/// Parameter indices of one transformer layer.
#[derive(Clone, Debug)]
pub struct LayerIdx {
    pub attn_norm: usize,
    pub wq: Lin,
    pub wk: Lin,
    pub wv: Lin,
    pub wo: Lin,
    pub mlp_norm: usize,
    pub w_gate: Lin,
    pub w_up: Lin,
    pub w_down: Lin,
}

/// Optimizer-state slots of one trainable parameter.
#[derive(Clone, Copy, Debug)]
pub enum OptSlots {
    /// AdamW first/second moment (full shape each).
    AdamW { m: usize, v: usize },
    /// Adafactor factored second moment of a matrix (row + col vectors).
    Factored { vr: usize, vc: usize },
    /// Adafactor unfactored second moment (vectors/scalars).
    Vector { v: usize },
}

/// One trainable parameter (everything except the frozen `.s` scales), in
/// the python `trainable_names` order — the SR seed stream is keyed by
/// this enumeration index.
#[derive(Clone, Debug)]
pub struct Trainable {
    pub param: usize,
    /// `.s` companion index — present exactly for grid params
    pub scale: Option<usize>,
    /// one of the seven per-layer projections (the BitNet-quantized set)
    pub is_qlinear: bool,
    pub opt: OptSlots,
}

/// Full index map of a variant: the synthesized manifest plus direct
/// indices into its flat param/opt order.
#[derive(Clone, Debug)]
pub struct Layout {
    pub manifest: Manifest,
    pub emb: usize,
    pub final_norm: usize,
    pub layers: Vec<LayerIdx>,
    pub trainables: Vec<Trainable>,
}

fn parse_intervention(iv: &Option<String>) -> Result<Intervention> {
    Ok(match iv.as_deref() {
        None | Some("none") => Intervention::None,
        Some("force_remain") => Intervention::ForceRemain,
        Some("force_update") => Intervention::ForceUpdate,
        Some(other) => return Err(anyhow!("unknown intervention {other:?}")),
    })
}

/// Build hyperparameters + layout for `spec`. Errors on unknown models,
/// unsupported bit widths and malformed head counts — the same conditions
/// the python `VariantConfig.__post_init__` asserts.
pub fn build(spec: &VariantSpec) -> Result<(Hyper, ModelConfig, Layout)> {
    let cfg = spec
        .model_config()
        .ok_or_else(|| anyhow!("unknown model {:?}", spec.model))?;
    if cfg.hidden_size % cfg.num_attention_heads != 0 {
        return Err(anyhow!(
            "hidden {} not divisible by heads {}",
            cfg.hidden_size,
            cfg.num_attention_heads
        ));
    }
    if cfg.hidden_size / cfg.num_attention_heads % 2 != 0 {
        return Err(anyhow!("head_dim must be even for RoPE"));
    }
    let grid_bits = match spec.mode {
        Mode::DqtTernaryInf => 8.0, // §A.2: train an 8-bit grid, deploy ternary
        Mode::Bitnet158 => 1.58,
        _ => spec.bits,
    };
    if matches!(spec.mode, Mode::Dqt | Mode::DqtAbsmax)
        && !((grid_bits - 1.58).abs() < 1e-9
            || (grid_bits.fract() == 0.0 && (2.0..=8.0).contains(&grid_bits)))
    {
        return Err(anyhow!("unsupported grid bits {grid_bits}"));
    }
    let hyper = Hyper {
        mode: spec.mode,
        grid_bits,
        env: spec.env,
        optimizer: spec.optimizer,
        intervention: parse_intervention(&spec.intervention)?,
        intervention_frac: 0.2,
        recompute_scale: spec.recompute_scale,
        act_bits: 8,
        weight_decay: 0.01,
        adam_b1: 0.9,
        adam_b2: 0.95,
        adam_eps: 1e-8,
        grad_clip: 1.0,
        rope_theta: 10000.0,
        rms_eps: 1e-5,
        init_std: 0.02,
    };
    let layout = build_layout(spec, &cfg, &hyper)?;
    Ok((hyper, cfg, layout))
}

fn push(params: &mut Vec<ParamMeta>, name: String, shape: Vec<usize>, role: &str) -> usize {
    params.push(ParamMeta {
        name,
        shape,
        dtype: "float32".into(),
        role: Some(role.to_string()),
    });
    params.len() - 1
}

/// A projection matrix: grid (+ `.s` scale) in DQT modes, dense otherwise.
fn lin(params: &mut Vec<ParamMeta>, grid: bool, name: String, shape: Vec<usize>) -> Lin {
    if grid {
        let w = push(params, name.clone(), shape, "grid");
        let s = push(params, format!("{name}.s"), vec![], "scale");
        Lin { w, s: Some(s) }
    } else {
        Lin {
            w: push(params, name, shape, "dense"),
            s: None,
        }
    }
}

fn build_layout(spec: &VariantSpec, cfg: &ModelConfig, hyper: &Hyper) -> Result<Layout> {
    let (h, i_, v) = (cfg.hidden_size, cfg.intermediate_size, cfg.vocab_size);
    let grid = hyper.has_grid_weights();

    let mut params: Vec<ParamMeta> = Vec::new();
    let emb = push(&mut params, "emb".into(), vec![v, h], "dense");
    let mut layers = Vec::with_capacity(cfg.num_hidden_layers);
    for l in 0..cfg.num_hidden_layers {
        let p = format!("layers.{l}.");
        layers.push(LayerIdx {
            attn_norm: push(&mut params, format!("{p}attn_norm"), vec![h], "dense"),
            wq: lin(&mut params, grid, format!("{p}wq"), vec![h, h]),
            wk: lin(&mut params, grid, format!("{p}wk"), vec![h, h]),
            wv: lin(&mut params, grid, format!("{p}wv"), vec![h, h]),
            wo: lin(&mut params, grid, format!("{p}wo"), vec![h, h]),
            mlp_norm: push(&mut params, format!("{p}mlp_norm"), vec![h], "dense"),
            w_gate: lin(&mut params, grid, format!("{p}w_gate"), vec![i_, h]),
            w_up: lin(&mut params, grid, format!("{p}w_up"), vec![i_, h]),
            w_down: lin(&mut params, grid, format!("{p}w_down"), vec![h, i_]),
        });
    }
    let final_norm = push(&mut params, "final_norm".into(), vec![h], "dense");

    // optimizer state: step counter, then per-trainable slots in param
    // order (`.s` scales are frozen grid metadata, not trainable)
    let mut opt_state = vec![OptMeta {
        name: "step".into(),
        shape: vec![],
    }];
    let mut trainables = Vec::new();
    for (pi, meta) in params.iter().enumerate() {
        if meta.is_scale() {
            continue;
        }
        let scale = if meta.is_grid() { Some(pi + 1) } else { None };
        let is_qlinear = meta.name.contains(".w");
        let opt = match hyper.optimizer {
            Optimizer::Adamw => {
                opt_state.push(OptMeta {
                    name: format!("{}.m", meta.name),
                    shape: meta.shape.clone(),
                });
                opt_state.push(OptMeta {
                    name: format!("{}.v", meta.name),
                    shape: meta.shape.clone(),
                });
                OptSlots::AdamW {
                    m: opt_state.len() - 2,
                    v: opt_state.len() - 1,
                }
            }
            Optimizer::Adafactor if meta.shape.len() == 2 => {
                opt_state.push(OptMeta {
                    name: format!("{}.vr", meta.name),
                    shape: vec![meta.shape[0]],
                });
                opt_state.push(OptMeta {
                    name: format!("{}.vc", meta.name),
                    shape: vec![meta.shape[1]],
                });
                OptSlots::Factored {
                    vr: opt_state.len() - 2,
                    vc: opt_state.len() - 1,
                }
            }
            Optimizer::Adafactor => {
                opt_state.push(OptMeta {
                    name: format!("{}.v", meta.name),
                    shape: meta.shape.clone(),
                });
                OptSlots::Vector {
                    v: opt_state.len() - 1,
                }
            }
        };
        trainables.push(Trainable {
            param: pi,
            scale,
            is_qlinear,
            opt,
        });
    }

    let mut entries = vec![
        "init".to_string(),
        "train_step".to_string(),
        "eval_step".to_string(),
        "logits_step".to_string(),
    ];
    if spec.mode.quantized() {
        entries.push("eval_step_ternary".to_string());
        entries.push("logits_step_ternary".to_string());
    }

    let manifest = Manifest {
        variant: VariantMeta {
            model: VariantModelMeta {
                name: cfg.name.clone(),
                vocab_size: cfg.vocab_size,
                hidden_size: cfg.hidden_size,
                num_hidden_layers: cfg.num_hidden_layers,
                max_seq_len: cfg.max_seq_len,
                batch_size: cfg.batch_size,
                param_count: cfg.param_count(),
            },
            mode: spec.mode.as_str().to_string(),
            bits: spec.bits,
            env: spec.env.as_str().to_string(),
            optimizer: spec.optimizer.as_str().to_string(),
            intervention: spec
                .intervention
                .clone()
                .unwrap_or_else(|| "none".to_string()),
            variant_name: spec.variant_name(),
        },
        tokens_shape: vec![cfg.batch_size, cfg.max_seq_len + 1],
        logits_tokens_shape: vec![cfg.batch_size, cfg.max_seq_len],
        pad_id: crate::data::tokenizer::PAD_ID,
        train_step_outputs: TrainStepOutputs {
            n_params: params.len(),
            n_opt: opt_state.len(),
            metrics: vec!["loss".into(), "upd_frac".into(), "gnorm".into()],
        },
        entries,
        params,
        opt_state,
    };

    Ok(Layout {
        manifest,
        emb,
        final_norm,
        layers,
        trainables,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(mode: Mode, bits: f64) -> VariantSpec {
        VariantSpec::new("test", mode, bits)
    }

    #[test]
    fn manifest_param_count_matches_model_config() {
        for (mode, bits) in [
            (Mode::Fp32, 1.58),
            (Mode::Bitnet158, 1.58),
            (Mode::Dqt, 1.58),
            (Mode::Dqt, 8.0),
        ] {
            let (_, cfg, layout) = build(&spec(mode, bits)).unwrap();
            let n: u64 = layout
                .manifest
                .params
                .iter()
                .filter(|p| !p.is_scale())
                .map(|p| p.numel() as u64)
                .sum();
            assert_eq!(n, cfg.param_count(), "{mode:?}");
        }
    }

    #[test]
    fn grid_params_only_in_dqt_modes() {
        let (_, _, fp32) = build(&spec(Mode::Fp32, 1.58)).unwrap();
        assert!(fp32.manifest.params.iter().all(|p| !p.is_grid()));
        let (_, _, bitnet) = build(&spec(Mode::Bitnet158, 1.58)).unwrap();
        assert!(bitnet.manifest.params.iter().all(|p| !p.is_grid()));
        let (_, cfg, dqt) = build(&spec(Mode::Dqt, 1.58)).unwrap();
        let grids = dqt.manifest.params.iter().filter(|p| p.is_grid()).count();
        assert_eq!(grids, cfg.num_hidden_layers * 7);
        // grid params are immediately followed by their `.s` companion —
        // the artifact-manifest invariant the integration tests pin
        for (i, p) in dqt.manifest.params.iter().enumerate() {
            if p.is_grid() {
                assert!(dqt.manifest.params[i + 1].is_scale(), "{}", p.name);
                assert_eq!(dqt.manifest.params[i + 1].name, format!("{}.s", p.name));
            }
        }
    }

    #[test]
    fn quantized_param_share_matches_config() {
        let (_, cfg, layout) = build(&spec(Mode::Dqt, 1.58)).unwrap();
        let grid_values: u64 = layout
            .manifest
            .params
            .iter()
            .filter(|p| p.is_grid())
            .map(|p| p.numel() as u64)
            .sum();
        assert_eq!(grid_values, cfg.quantized_param_count());
    }

    #[test]
    fn opt_state_layout_adamw_and_adafactor() {
        let (_, _, adamw) = build(&spec(Mode::Dqt, 1.58)).unwrap();
        // step + (m, v) per trainable param
        assert_eq!(adamw.manifest.opt_state.len(), 1 + 2 * adamw.trainables.len());
        assert_eq!(adamw.manifest.opt_state[0].name, "step");

        let sp = spec(Mode::Dqt, 1.58).with_optimizer(crate::config::Optimizer::Adafactor);
        let (_, _, af) = build(&sp).unwrap();
        // matrices get vr+vc (rows + cols), vectors a same-shape v
        for t in &af.trainables {
            let meta = &af.manifest.params[t.param];
            match t.opt {
                OptSlots::Factored { vr, vc } => {
                    assert_eq!(meta.shape.len(), 2);
                    assert_eq!(af.manifest.opt_state[vr].shape, vec![meta.shape[0]]);
                    assert_eq!(af.manifest.opt_state[vc].shape, vec![meta.shape[1]]);
                }
                OptSlots::Vector { v } => {
                    assert!(meta.shape.len() < 2);
                    assert_eq!(af.manifest.opt_state[v].shape, meta.shape);
                }
                OptSlots::AdamW { .. } => panic!("adafactor layout has no adamw slots"),
            }
        }
    }

    #[test]
    fn ternary_inf_trains_an_8bit_grid() {
        let (hyper, _, _) = build(&spec(Mode::DqtTernaryInf, 8.0)).unwrap();
        assert_eq!(hyper.grid_bits, 8.0);
        let (hyper, _, _) = build(&spec(Mode::Bitnet158, 1.58)).unwrap();
        assert_eq!(hyper.grid_bits, 1.58);
        assert!(!hyper.has_grid_weights());
    }

    #[test]
    fn qlinear_flags_cover_the_seven_projections() {
        let (_, cfg, layout) = build(&spec(Mode::Bitnet158, 1.58)).unwrap();
        let n = layout.trainables.iter().filter(|t| t.is_qlinear).count();
        assert_eq!(n, cfg.num_hidden_layers * 7);
    }

    #[test]
    fn bad_specs_error() {
        assert!(build(&VariantSpec::new("nope", Mode::Dqt, 1.58)).is_err());
        assert!(build(&spec(Mode::Dqt, 9.0)).is_err());
        assert!(build(&spec(Mode::Dqt, 1.0)).is_err());
        let iv = spec(Mode::Dqt, 1.58).with_intervention("bogus");
        assert!(build(&iv).is_err());
    }
}
