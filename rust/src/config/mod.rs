//! Configuration system: model presets (paper Table 2 + scaled testbed),
//! variant descriptors (mode × bits × env × optimizer), and run configs.
//!
//! `VariantSpec::variant_name()` must produce exactly the directory names
//! `python/compile/configs.py` writes under `artifacts/` — that string is
//! the L2↔L3 contract and is covered by an integration test against the
//! manifest index.

/// Resolve the kernel-layer worker-thread count. Precedence: an explicit
/// CLI value (`--threads`, when `Some` and non-zero) > the `DQT_THREADS`
/// environment variable (non-zero) > the machine's available parallelism.
/// Thread count is a pure throughput knob: the kernel layer is
/// bitwise-deterministic across thread counts (see `docs/PERFORMANCE.md`).
pub fn effective_threads(cli: Option<usize>) -> usize {
    if let Some(t) = cli {
        if t > 0 {
            return t;
        }
    }
    if let Ok(s) = std::env::var("DQT_THREADS") {
        if let Ok(t) = s.trim().parse::<usize>() {
            if t > 0 {
                return t;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Default cadence, in optimizer steps, of the `QuantHealth` stream frame.
pub const QUANT_FRAME_EVERY_DEFAULT: u64 = 10;

/// Resolve the `QuantHealth` stream-frame cadence (steps between frames).
/// Precedence: an explicit CLI value > the `DQT_QUANT_FRAME_EVERY`
/// environment variable > 10. Unlike `effective_threads`, zero is a
/// meaningful value: it disables QuantHealth frames entirely (per-layer
/// metrics and `quant_health.json` are unaffected).
pub fn effective_quant_frame_every(cli: Option<u64>) -> u64 {
    if let Some(n) = cli {
        return n;
    }
    if let Ok(s) = std::env::var("DQT_QUANT_FRAME_EVERY") {
        if let Ok(n) = s.trim().parse::<u64>() {
            return n;
        }
    }
    QUANT_FRAME_EVERY_DEFAULT
}

/// Kernel numeric tier (`--precision exact|fast` / `DQT_PRECISION`).
///
/// `Exact` keeps every kernel on the scalar, ascending-`k` accumulation
/// chains that make results bitwise-reproducible across thread counts —
/// the default everywhere and the oracle the fast tier is tested against.
/// `Fast` opts into reassociated float summation (wide multi-accumulator
/// dense microkernels, activation-block LUT ternary GEMM) that LLVM can
/// auto-vectorize: the same math in a different addition order, so
/// results agree with exact to f32 tolerance rather than bitwise. Fast
/// mode is still deterministic for a *fixed* thread count (same seed ⇒
/// same bits). See `docs/PERFORMANCE.md` §"Two-tier precision policy".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    #[default]
    Exact,
    Fast,
}

impl Precision {
    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::Exact => "exact",
            Precision::Fast => "fast",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "exact" => Precision::Exact,
            "fast" => Precision::Fast,
            _ => return None,
        })
    }
}

/// Resolve the kernel numeric tier. Precedence: an explicit CLI value
/// (`--precision`, when `Some`) > the `DQT_PRECISION` environment
/// variable > [`Precision::Exact`]. Unlike `--threads`, the tier *can*
/// change low-order result bits (fast reassociates sums), which is why
/// exact is the unconditional default and fast is strictly opt-in.
pub fn effective_precision(cli: Option<Precision>) -> Precision {
    if let Some(p) = cli {
        return p;
    }
    if let Ok(s) = std::env::var("DQT_PRECISION") {
        if let Some(p) = Precision::parse(s.trim()) {
            return p;
        }
    }
    Precision::Exact
}

/// LLaMA-structured model configuration (paper Table 2 schema).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub hidden_size: usize,
    pub intermediate_size: usize,
    pub num_hidden_layers: usize,
    pub num_attention_heads: usize,
    pub max_seq_len: usize,
    pub batch_size: usize,
    pub tie_embeddings: bool,
}

impl ModelConfig {
    /// Exact parameter count (mirrors `configs.ModelConfig.param_count`).
    pub fn param_count(&self) -> u64 {
        let (v, h, i, l) = (
            self.vocab_size as u64,
            self.hidden_size as u64,
            self.intermediate_size as u64,
            self.num_hidden_layers as u64,
        );
        let emb = v * h;
        let per_layer = 4 * h * h + 3 * h * i + 2 * h;
        let head = if self.tie_embeddings { 0 } else { v * h };
        emb + l * per_layer + h + head
    }

    /// Parameters living on the INTn grid in quantized modes (the 7
    /// projection matrices per layer).
    pub fn quantized_param_count(&self) -> u64 {
        let (h, i, l) = (
            self.hidden_size as u64,
            self.intermediate_size as u64,
            self.num_hidden_layers as u64,
        );
        l * (4 * h * h + 3 * h * i)
    }

    fn preset(
        name: &str,
        vocab_size: usize,
        hidden_size: usize,
        intermediate_size: usize,
        num_hidden_layers: usize,
        num_attention_heads: usize,
        max_seq_len: usize,
        batch_size: usize,
    ) -> Self {
        ModelConfig {
            name: name.into(),
            vocab_size,
            hidden_size,
            intermediate_size,
            num_hidden_layers,
            num_attention_heads,
            max_seq_len,
            batch_size,
            tie_embeddings: true,
        }
    }

    /// Look up a named preset (paper-exact `p*` + scaled testbed `t*`).
    pub fn by_name(name: &str) -> Option<Self> {
        Some(match name {
            // paper Table 2 (exact)
            "p130m" => Self::preset("p130m", 32000, 768, 2048, 12, 12, 512, 64),
            "p320m" => Self::preset("p320m", 32000, 1024, 2048, 24, 16, 512, 32),
            "p1b" => Self::preset("p1b", 32000, 2048, 3072, 24, 32, 512, 16),
            // scaled testbed (same ratios, CPU-sized) — python configs.py twin
            "t130" => Self::preset("t130", 512, 96, 256, 6, 6, 128, 16),
            "t320" => Self::preset("t320", 512, 128, 256, 12, 8, 128, 8),
            "t1b" => Self::preset("t1b", 512, 256, 384, 12, 8, 128, 4),
            "test" => Self::preset("test", 64, 32, 64, 2, 2, 16, 2),
            _ => return None,
        })
    }

    pub fn testbed_names() -> [&'static str; 3] {
        ["t130", "t320", "t1b"]
    }
    pub fn paper_names() -> [&'static str; 3] {
        ["p130m", "p320m", "p1b"]
    }
}

/// Weight-handling mode (paper §3/§4/§5 + §A.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    Fp32,
    Bitnet158,
    Dqt,
    DqtAbsmax,
    DqtTernaryInf,
}

impl Mode {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Fp32 => "fp32",
            Mode::Bitnet158 => "bitnet158",
            Mode::Dqt => "dqt",
            Mode::DqtAbsmax => "dqt_absmax",
            Mode::DqtTernaryInf => "dqt_ternary_inf",
        }
    }
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "fp32" => Mode::Fp32,
            "bitnet158" => Mode::Bitnet158,
            "dqt" => Mode::Dqt,
            "dqt_absmax" => Mode::DqtAbsmax,
            "dqt_ternary_inf" => Mode::DqtTernaryInf,
            _ => return None,
        })
    }
    pub fn quantized(&self) -> bool {
        !matches!(self, Mode::Fp32)
    }
}

/// Precision environment (paper §4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Env {
    Fp32,
    Bf16,
    Fp8,
}

impl Env {
    pub fn as_str(&self) -> &'static str {
        match self {
            Env::Fp32 => "fp32",
            Env::Bf16 => "bf16",
            Env::Fp8 => "fp8",
        }
    }
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "fp32" => Env::Fp32,
            "bf16" => Env::Bf16,
            "fp8" => Env::Fp8,
            _ => return None,
        })
    }
    /// Bytes per value when *storing* state in this environment.
    pub fn bytes_per_value(&self) -> f64 {
        match self {
            Env::Fp32 => 4.0,
            Env::Bf16 => 2.0,
            Env::Fp8 => 1.0,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Optimizer {
    Adamw,
    Adafactor,
}

impl Optimizer {
    pub fn as_str(&self) -> &'static str {
        match self {
            Optimizer::Adamw => "adamw",
            Optimizer::Adafactor => "adafactor",
        }
    }
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "adamw" => Optimizer::Adamw,
            "adafactor" => Optimizer::Adafactor,
            _ => return None,
        })
    }
}

/// Execution backend selector (`--backend native|pjrt|auto`).
///
/// `Native` is the pure-Rust CPU reference backend (`runtime::native`) —
/// no artifacts, no PJRT, runs anywhere. `Pjrt` executes compiled AOT
/// artifacts through XLA. `Auto` picks PJRT when a real runtime is linked
/// and falls back to native otherwise (the zero-dependency default).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BackendKind {
    #[default]
    Auto,
    Native,
    Pjrt,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "auto" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt,
            _ => return None,
        })
    }

    /// Resolve `Auto` given whether a real PJRT runtime is linked
    /// (`runtime::pjrt_available()`); explicit choices pass through.
    pub fn resolve(self, pjrt_linked: bool) -> BackendKind {
        match self {
            BackendKind::Auto if pjrt_linked => BackendKind::Pjrt,
            BackendKind::Auto => BackendKind::Native,
            explicit => explicit,
        }
    }
}

/// Full variant descriptor == one artifact directory (twin of python's
/// `VariantConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct VariantSpec {
    pub model: String,
    pub mode: Mode,
    pub bits: f64,
    pub env: Env,
    pub optimizer: Optimizer,
    pub intervention: Option<String>,
    pub recompute_scale: bool,
}

impl VariantSpec {
    pub fn new(model: &str, mode: Mode, bits: f64) -> Self {
        VariantSpec {
            model: model.into(),
            mode,
            bits,
            env: Env::Fp32,
            optimizer: Optimizer::Adamw,
            intervention: None,
            recompute_scale: false,
        }
    }
    pub fn with_env(mut self, env: Env) -> Self {
        self.env = env;
        self
    }
    pub fn with_optimizer(mut self, opt: Optimizer) -> Self {
        self.optimizer = opt;
        self
    }
    pub fn with_intervention(mut self, iv: &str) -> Self {
        self.intervention = Some(iv.into());
        self
    }
    pub fn with_recompute_scale(mut self) -> Self {
        self.recompute_scale = true;
        self
    }

    pub fn model_config(&self) -> Option<ModelConfig> {
        ModelConfig::by_name(&self.model)
    }

    /// The artifact directory name — must match `configs.py` exactly.
    pub fn variant_name(&self) -> String {
        let mut parts = vec![self.model.clone(), self.mode.as_str().to_string()];
        if self.mode.as_str().starts_with("dqt") {
            let b = if (self.bits - self.bits.round()).abs() < 1e-9 {
                format!("b{}", self.bits as i64)
            } else {
                format!("b{}", self.bits).replace('.', "p")
            };
            parts.push(b);
        }
        if self.env != Env::Fp32 {
            parts.push(self.env.as_str().into());
        }
        if self.optimizer != Optimizer::Adamw {
            parts.push(self.optimizer.as_str().into());
        }
        if let Some(iv) = &self.intervention {
            if iv != "none" {
                parts.push(iv.clone());
            }
        }
        if self.recompute_scale {
            parts.push("rescale".into());
        }
        parts.join("-")
    }
}

/// Run-level configuration for a training job.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: u64,
    pub warmup_steps: u64,
    pub peak_lr: f64,
    pub min_lr: f64,
    pub seed: u64,
    pub dataset: String,
    /// evaluate dev loss every N steps (0 = only at the end)
    pub eval_every: u64,
    pub log_every: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            warmup_steps: 30,
            peak_lr: 1e-3,
            min_lr: 1e-5,
            seed: 42,
            dataset: "wiki".into(),
            eval_every: 0,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::obj()
            .set("steps", self.steps)
            .set("warmup_steps", self.warmup_steps)
            .set("peak_lr", self.peak_lr)
            .set("min_lr", self.min_lr)
            .set("seed", self.seed)
            .set("dataset", self.dataset.as_str())
            .set("eval_every", self.eval_every)
            .set("log_every", self.log_every)
    }

    pub fn from_json(v: &crate::util::json::Value) -> Self {
        let d = TrainConfig::default();
        let u = |k: &str, dv: u64| v.get(k).and_then(|x| x.as_u64()).unwrap_or(dv);
        let f = |k: &str, dv: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(dv);
        TrainConfig {
            steps: u("steps", d.steps),
            warmup_steps: u("warmup_steps", d.warmup_steps),
            peak_lr: f("peak_lr", d.peak_lr),
            min_lr: f("min_lr", d.min_lr),
            seed: u("seed", d.seed),
            dataset: v
                .get("dataset")
                .and_then(|x| x.as_str())
                .map(|s| s.to_string())
                .unwrap_or(d.dataset),
            eval_every: u("eval_every", d.eval_every),
            log_every: u("log_every", d.log_every),
        }
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Ok(Self::from_json(&crate::util::json::parse(
            &std::fs::read_to_string(path)?,
        )?))
    }
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// Distributed data-parallel run configuration (`dqt train --workers N` /
/// `dqt worker --rank R --join ADDR`; see `docs/DISTRIBUTED.md`).
///
/// The determinism contract binds the legal world sizes: per-rank shard
/// bands must slice the fixed gradient-reduction tree at subtree
/// boundaries, which holds exactly when `world` is a power of two that
/// divides the model's global batch size ([`DistConfig::validate`]).
#[derive(Clone, Debug, PartialEq)]
pub struct DistConfig {
    /// how per-step gradient partials travel
    /// (`--grad-format f32|int8|ternary`): `F32` keeps the bitwise
    /// N-worker == 1-worker contract; the quantized formats trade it for
    /// a pinned convergence tolerance at 4× / 16× less wire traffic
    /// (stochastic rounding + per-rank error-feedback residuals — see
    /// `docs/DISTRIBUTED.md` §Quantized gradient exchange)
    pub grad_format: GradFormat,
    /// total ranks (1 = the single-process reference run)
    pub world: usize,
    /// this process's rank; rank 0 hosts the rendezvous and owns outputs
    pub rank: usize,
    /// rendezvous address — rank 0 binds it, workers join it
    /// (port 0 lets the OS pick; the spawned-local path passes the bound
    /// port to its workers)
    pub addr: String,
    /// broadcast the grid weights every N steps (0 = never). Under the
    /// determinism contract this is a bit-exact no-op — it exists to pin
    /// ranks back together in deployments that break the contract
    /// (mixed hardware, lossy transports) and to bound silent drift.
    pub sync_every: u64,
    /// ship the resync as packed grid codes + scales (the variant's true
    /// bit width, ~16× less traffic for ternary) instead of f32
    pub packed_sync: bool,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            grad_format: GradFormat::F32,
            world: 1,
            rank: 0,
            addr: "127.0.0.1:0".into(),
            sync_every: 25,
            packed_sync: true,
        }
    }
}

/// Wire format of the every-step gradient exchange
/// (`--grad-format f32|int8|ternary`). Mirrors the [`Precision`] two-tier
/// pattern: `F32` is the unconditional default and keeps the bitwise
/// determinism contract; the quantized tiers are strictly opt-in and
/// carry their own convergence contract instead (`rust/tests/dist.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum GradFormat {
    /// dense f32 partials — bitwise N-worker == 1-worker
    #[default]
    F32,
    /// stochastically rounded int8 + per-tensor absmax scale (~4× smaller)
    Int8,
    /// stochastically rounded ternary, 2-bit packed (~16× smaller)
    Ternary,
}

impl GradFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            GradFormat::F32 => "f32",
            GradFormat::Int8 => "int8",
            GradFormat::Ternary => "ternary",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "f32" => GradFormat::F32,
            "int8" => GradFormat::Int8,
            "ternary" => GradFormat::Ternary,
            _ => return None,
        })
    }

    /// True for the formats that quantize the wire (and so swap the
    /// bitwise contract for the convergence contract).
    pub fn is_quantized(&self) -> bool {
        !matches!(self, GradFormat::F32)
    }
}

/// The determinism contract's sharding rule, in one place: check that
/// `world` is a power of two dividing the `rows`-row global batch (so
/// contiguous equal bands are subtrees of the fixed gradient-reduction
/// tree) and return rank's band `[lo, hi)`. Every sharding site —
/// [`DistConfig::validate`], [`DistConfig::band`], the trainer's
/// `run_sharded` — derives from here, so the contract cannot drift
/// between them.
pub fn shard_band(world: usize, rank: usize, rows: usize) -> anyhow::Result<(usize, usize)> {
    if world == 0 || !world.is_power_of_two() {
        anyhow::bail!(
            "world {world} is not a power of two (the fixed reduction \
             tree splits bands in halves)"
        );
    }
    if rows % world != 0 {
        anyhow::bail!("world {world} does not divide the global batch of {rows} rows");
    }
    if rank >= world {
        anyhow::bail!("rank {rank} out of range for world {world}");
    }
    let per = rows / world;
    Ok((rank * per, (rank + 1) * per))
}

/// Observability endpoints and sinks (`--metrics-addr` / `--watch-addr`
/// / `--trace-out`; see `docs/OBSERVABILITY.md`). All default to off:
/// metric *recording* is always on (pure atomics behind
/// `TrainObs`/`ServeMetrics`), these only control whether anything is
/// exposed on the network or written to disk — and span *tracing* is
/// fully off (one atomic load per span site) unless `trace_out` is set.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObsConfig {
    /// bind a `GET /metrics` Prometheus endpoint here (e.g. `127.0.0.1:9100`)
    pub metrics_addr: Option<String>,
    /// bind a step-stream publisher here for `dqt watch --join ADDR`
    pub watch_addr: Option<String>,
    /// enable span tracing and write a Chrome trace-event JSON here at
    /// run end (e.g. `trace.json`)
    pub trace_out: Option<String>,
}

impl ObsConfig {
    /// Resolve from CLI values with environment fallback: an explicit CLI
    /// value wins, else `DQT_METRICS_ADDR` / `DQT_WATCH_ADDR` /
    /// `DQT_TRACE_OUT`; empty strings (from either source) mean "off".
    /// Mirrors the precedence of [`effective_threads`] /
    /// [`effective_precision`].
    pub fn resolve(
        cli_metrics: Option<String>,
        cli_watch: Option<String>,
        cli_trace: Option<String>,
    ) -> ObsConfig {
        let pick = |cli: Option<String>, env_key: &str| -> Option<String> {
            cli.or_else(|| std::env::var(env_key).ok())
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
        };
        ObsConfig {
            metrics_addr: pick(cli_metrics, "DQT_METRICS_ADDR"),
            watch_addr: pick(cli_watch, "DQT_WATCH_ADDR"),
            trace_out: pick(cli_trace, "DQT_TRACE_OUT"),
        }
    }

    /// True when at least one network endpoint is configured (the trace
    /// sink is file-only and gates nothing here).
    pub fn enabled(&self) -> bool {
        self.metrics_addr.is_some() || self.watch_addr.is_some()
    }
}

impl DistConfig {
    pub fn is_distributed(&self) -> bool {
        self.world > 1
    }

    /// Check the world/rank against the determinism contract for a model
    /// with `batch_size` global rows.
    pub fn validate(&self, batch_size: usize) -> anyhow::Result<()> {
        shard_band(self.world, self.rank, batch_size).map(|_| ())
    }

    /// This rank's contiguous row band `[lo, hi)` of a `rows`-row global
    /// batch (equal bands; `validate` guarantees divisibility).
    pub fn band(&self, rows: usize) -> (usize, usize) {
        let per = rows / self.world;
        (self.rank * per, (self.rank + 1) * per)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_python() {
        // values from `python -m compile.configs`
        assert_eq!(ModelConfig::by_name("t130").unwrap().param_count(), 713_952);
        assert_eq!(ModelConfig::by_name("t320").unwrap().param_count(), 2_034_816);
        assert_eq!(ModelConfig::by_name("t1b").unwrap().param_count(), 6_822_144);
        assert_eq!(ModelConfig::by_name("test").unwrap().param_count(), 22_688);
        assert_eq!(
            ModelConfig::by_name("p130m").unwrap().param_count(),
            109_529_856
        );
        assert_eq!(
            ModelConfig::by_name("p1b").unwrap().param_count(),
            921_274_368
        );
    }

    #[test]
    fn variant_names_match_python_convention() {
        let v = VariantSpec::new("t130", Mode::Dqt, 1.58);
        assert_eq!(v.variant_name(), "t130-dqt-b1p58");
        let v = VariantSpec::new("t130", Mode::Dqt, 8.0);
        assert_eq!(v.variant_name(), "t130-dqt-b8");
        let v = VariantSpec::new("t130", Mode::Fp32, 1.58);
        assert_eq!(v.variant_name(), "t130-fp32");
        let v = VariantSpec::new("t130", Mode::Bitnet158, 1.58);
        assert_eq!(v.variant_name(), "t130-bitnet158");
        let v = VariantSpec::new("t1b", Mode::Dqt, 8.0)
            .with_env(Env::Fp8)
            .with_optimizer(Optimizer::Adafactor);
        assert_eq!(v.variant_name(), "t1b-dqt-b8-fp8-adafactor");
        let v = VariantSpec::new("t130", Mode::Dqt, 1.58).with_intervention("force_remain");
        assert_eq!(v.variant_name(), "t130-dqt-b1p58-force_remain");
        let v = VariantSpec::new("t130", Mode::Dqt, 1.58).with_recompute_scale();
        assert_eq!(v.variant_name(), "t130-dqt-b1p58-rescale");
        let v = VariantSpec::new("t130", Mode::DqtAbsmax, 1.58);
        assert_eq!(v.variant_name(), "t130-dqt_absmax-b1p58");
        let v = VariantSpec::new("t130", Mode::DqtTernaryInf, 8.0);
        assert_eq!(v.variant_name(), "t130-dqt_ternary_inf-b8");
    }

    #[test]
    fn backend_kind_parse_and_resolve() {
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("auto"), Some(BackendKind::Auto));
        assert_eq!(BackendKind::parse("gpu"), None);
        assert_eq!(BackendKind::default(), BackendKind::Auto);
        assert_eq!(BackendKind::Auto.resolve(true), BackendKind::Pjrt);
        assert_eq!(BackendKind::Auto.resolve(false), BackendKind::Native);
        assert_eq!(BackendKind::Native.resolve(true), BackendKind::Native);
        assert_eq!(BackendKind::Pjrt.resolve(false), BackendKind::Pjrt);
        for k in [BackendKind::Auto, BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.as_str()), Some(k));
        }
    }

    #[test]
    fn effective_threads_prefers_explicit_value() {
        assert_eq!(effective_threads(Some(3)), 3);
        assert_eq!(effective_threads(Some(1)), 1);
        // Some(0) and None fall through to env/cores — at least one thread
        assert!(effective_threads(Some(0)) >= 1);
        assert!(effective_threads(None) >= 1);
    }

    #[test]
    fn effective_quant_frame_every_prefers_explicit_value() {
        // explicit CLI wins, including the meaningful 0 = frames off
        assert_eq!(effective_quant_frame_every(Some(25)), 25);
        assert_eq!(effective_quant_frame_every(Some(0)), 0);
        // no CLI: env or the documented default (no env mutation in tests,
        // so just pin that the fallback path yields a sane cadence)
        let n = effective_quant_frame_every(None);
        assert!(n == QUANT_FRAME_EVERY_DEFAULT || std::env::var("DQT_QUANT_FRAME_EVERY").is_ok());
    }

    #[test]
    fn precision_parse_roundtrip_and_default() {
        assert_eq!(Precision::parse("exact"), Some(Precision::Exact));
        assert_eq!(Precision::parse("fast"), Some(Precision::Fast));
        assert_eq!(Precision::parse("loose"), None);
        assert_eq!(Precision::default(), Precision::Exact);
        for p in [Precision::Exact, Precision::Fast] {
            assert_eq!(Precision::parse(p.as_str()), Some(p));
        }
        // an explicit CLI tier always wins; no CLI and no env ⇒ exact
        assert_eq!(effective_precision(Some(Precision::Fast)), Precision::Fast);
        assert_eq!(effective_precision(Some(Precision::Exact)), Precision::Exact);
    }

    #[test]
    fn grad_format_parse_roundtrip_and_default() {
        assert_eq!(GradFormat::parse("f32"), Some(GradFormat::F32));
        assert_eq!(GradFormat::parse("int8"), Some(GradFormat::Int8));
        assert_eq!(GradFormat::parse("ternary"), Some(GradFormat::Ternary));
        assert_eq!(GradFormat::parse("int4"), None);
        // f32 is the unconditional default: quantization is opt-in
        assert_eq!(GradFormat::default(), GradFormat::F32);
        assert_eq!(DistConfig::default().grad_format, GradFormat::F32);
        for f in [GradFormat::F32, GradFormat::Int8, GradFormat::Ternary] {
            assert_eq!(GradFormat::parse(f.as_str()), Some(f));
            assert_eq!(f.is_quantized(), f != GradFormat::F32);
        }
    }

    #[test]
    fn dist_config_validation() {
        let mut d = DistConfig::default();
        assert!(!d.is_distributed());
        assert!(d.validate(16).is_ok());
        d.world = 2;
        assert!(d.is_distributed());
        assert!(d.validate(16).is_ok());
        d.world = 4;
        assert!(d.validate(16).is_ok());
        assert!(d.validate(6).is_err()); // 4 does not divide 6
        d.world = 3;
        assert!(d.validate(6).is_err()); // 3 is not a power of two
        d.world = 0;
        assert!(d.validate(16).is_err());
        d.world = 4;
        d.rank = 4;
        assert!(d.validate(16).is_err()); // rank out of range
    }

    #[test]
    fn dist_config_bands_tile_the_batch() {
        let rows = 16;
        for world in [1usize, 2, 4, 8] {
            let mut covered = vec![0u32; rows];
            for rank in 0..world {
                let d = DistConfig {
                    world,
                    rank,
                    ..DistConfig::default()
                };
                d.validate(rows).unwrap();
                let (lo, hi) = d.band(rows);
                assert_eq!(hi - lo, rows / world);
                for c in &mut covered[lo..hi] {
                    *c += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "world {world}");
        }
    }

    #[test]
    fn obs_config_resolution() {
        // CLI wins; blank strings disable; default is fully off
        let o = ObsConfig::resolve(Some("127.0.0.1:9100".into()), None, None);
        assert_eq!(o.metrics_addr.as_deref(), Some("127.0.0.1:9100"));
        assert!(o.enabled());
        let o = ObsConfig::resolve(Some("  ".into()), Some(String::new()), Some("\t".into()));
        assert_eq!(o, ObsConfig::default());
        assert!(!o.enabled());
        let o = ObsConfig::resolve(None, Some("0.0.0.0:7007".into()), None);
        assert_eq!(o.watch_addr.as_deref(), Some("0.0.0.0:7007"));
        assert!(o.metrics_addr.is_none() || std::env::var("DQT_METRICS_ADDR").is_ok());
        // trace sink rides the same precedence but does not flip enabled()
        let o = ObsConfig::resolve(None, None, Some(" trace.json ".into()));
        assert_eq!(o.trace_out.as_deref(), Some("trace.json"));
        assert!(!o.enabled());
    }

    #[test]
    fn roundtrip_train_config() {
        let c = TrainConfig::default();
        let dir = std::env::temp_dir().join("dqt_cfg_test.json");
        c.save(&dir).unwrap();
        let c2 = TrainConfig::load(&dir).unwrap();
        assert_eq!(c.steps, c2.steps);
        std::fs::remove_file(dir).ok();
    }
}
