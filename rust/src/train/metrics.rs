//! Run metrics: per-step records, summaries, CSV/JSON persistence.
//!
//! Every experiment (figures 2-10) is rendered from these records — the
//! coordinator writes one `metrics.json` + `curve.csv` per job under
//! `results/<exp>/<variant>/`.

use std::io::Write;
use std::path::Path;

use crate::util::json::{parse, Value};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub lr: f32,
    /// fraction of quantized weights whose value changed this step (Fig. 6)
    pub upd_frac: f32,
    pub gnorm: f32,
    pub step_ms: f32,
}

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub variant: String,
    pub dataset: String,
    pub records: Vec<StepRecord>,
    /// (step, dev loss) pairs from periodic evaluation
    pub dev_losses: Vec<(u64, f32)>,
    pub final_dev_loss: Option<f32>,
    pub wall_secs: f64,
}

impl RunMetrics {
    pub fn new(variant: &str, dataset: &str) -> Self {
        RunMetrics {
            variant: variant.into(),
            dataset: dataset.into(),
            ..Default::default()
        }
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    /// Mean training loss over the last `n` records (smoothed tail loss).
    pub fn tail_loss(&self, n: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    /// Peak weight-update fraction across the run (Fig. 6 reporting).
    pub fn peak_upd_frac(&self) -> Option<f32> {
        self.records
            .iter()
            .map(|r| r.upd_frac)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f32| a.max(v))))
    }

    pub fn mean_step_ms(&self) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        Some(self.records.iter().map(|r| r.step_ms).sum::<f32>() / self.records.len() as f32)
    }

    pub fn to_json(&self) -> Value {
        let records = Value::Arr(
            self.records
                .iter()
                .map(|r| {
                    Value::obj()
                        .set("step", r.step)
                        .set("loss", r.loss)
                        .set("lr", r.lr)
                        .set("upd_frac", r.upd_frac)
                        .set("gnorm", r.gnorm)
                        .set("step_ms", r.step_ms)
                })
                .collect(),
        );
        let dev = Value::Arr(
            self.dev_losses
                .iter()
                .map(|&(s, l)| Value::Arr(vec![s.into(), l.into()]))
                .collect(),
        );
        Value::obj()
            .set("variant", self.variant.as_str())
            .set("dataset", self.dataset.as_str())
            .set("records", records)
            .set("dev_losses", dev)
            .set(
                "final_dev_loss",
                self.final_dev_loss.map(Value::from).unwrap_or(Value::Null),
            )
            .set("wall_secs", self.wall_secs)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let records = v
            .req("records")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|r| StepRecord {
                step: r.get("step").and_then(|x| x.as_u64()).unwrap_or(0),
                loss: r.get("loss").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                lr: r.get("lr").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                upd_frac: r.get("upd_frac").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                gnorm: r.get("gnorm").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                step_ms: r.get("step_ms").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
            })
            .collect();
        let dev_losses = v
            .req("dev_losses")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|p| {
                let a = p.as_arr()?;
                Some((a[0].as_u64()?, a[1].as_f64()? as f32))
            })
            .collect();
        Ok(RunMetrics {
            variant: v.req("variant")?.as_str().unwrap_or("").to_string(),
            dataset: v.req("dataset")?.as_str().unwrap_or("").to_string(),
            records,
            dev_losses,
            final_dev_loss: v
                .get("final_dev_loss")
                .and_then(|x| x.as_f64())
                .map(|f| f as f32),
            wall_secs: v.get("wall_secs").and_then(|x| x.as_f64()).unwrap_or(0.0),
        })
    }

    pub fn save(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("metrics.json"), self.to_json().to_string_pretty())?;
        let mut csv = std::fs::File::create(dir.join("curve.csv"))?;
        writeln!(csv, "step,loss,lr,upd_frac,gnorm,step_ms")?;
        for r in &self.records {
            writeln!(
                csv,
                "{},{},{},{},{},{}",
                r.step, r.loss, r.lr, r.upd_frac, r.gnorm, r.step_ms
            )?;
        }
        Ok(())
    }

    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        Self::from_json(&parse(&std::fs::read_to_string(dir.join("metrics.json"))?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, loss: f32, upd: f32) -> StepRecord {
        StepRecord {
            step,
            loss,
            lr: 1e-3,
            upd_frac: upd,
            gnorm: 1.0,
            step_ms: 5.0,
        }
    }

    #[test]
    fn tail_loss_and_peak() {
        let mut m = RunMetrics::new("v", "d");
        for i in 0..10 {
            m.push(rec(i, 10.0 - i as f32, i as f32 * 0.01));
        }
        assert!((m.tail_loss(2).unwrap() - 1.5).abs() < 1e-6);
        assert!((m.peak_upd_frac().unwrap() - 0.09).abs() < 1e-6);
        assert_eq!(m.mean_step_ms(), Some(5.0));
    }

    #[test]
    fn empty_metrics() {
        let m = RunMetrics::new("v", "d");
        assert_eq!(m.tail_loss(5), None);
        assert_eq!(m.peak_upd_frac(), None);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut m = RunMetrics::new("var1", "wiki");
        m.push(rec(0, 5.0, 0.01));
        m.dev_losses.push((0, 5.1));
        m.final_dev_loss = Some(4.9);
        let dir = std::env::temp_dir().join("dqt_metrics_test");
        m.save(&dir).unwrap();
        let m2 = RunMetrics::load(&dir).unwrap();
        assert_eq!(m.records, m2.records);
        assert_eq!(m2.final_dev_loss, Some(4.9));
        assert!(dir.join("curve.csv").is_file());
        std::fs::remove_dir_all(dir).ok();
    }
}
