//! Checkpointing with format-true weight packing.
//!
//! DQT's deployment story (paper §1, §4.5): grid weights serialize in their
//! *actual* bit width — 2-bit ternary, bit-packed INTn for 2<n<8, byte INT8
//! — plus per-matrix f32 scales; dense params in f32 (or BF16/FP8 when the
//! training env dictates). The resulting file sizes realize the memory
//! arithmetic the paper cites (1B ternary ≈ 0.25 GB vs 4 GB FP32).
//!
//! Layout (little-endian): a JSON header (manifest echo + per-entry codec +
//! byte offsets), `\n`, then the raw payload blob. The full wire format is
//! specified in `docs/CHECKPOINT_FORMAT.md` and pinned by the golden-file
//! test in `rust/tests/integration.rs`.
//!
//! All per-format behavior (tags, sizes, encode/decode) comes from the
//! codec registry in [`crate::quant::codec`]; this module only does layout.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Result};
use crate::util::json::{parse, Value};

use crate::quant::codec::{Format, PackedTensor};
use crate::runtime::{Manifest, Param, State};

/// Historical name for the storage format selector — the private enum this
/// alias replaced lived here before the registry existed.
pub use crate::quant::codec::Format as Codec;

#[derive(Clone, Debug)]
struct EntryHeader {
    name: String,
    shape: Vec<usize>,
    codec: Format,
    offset: usize,
    bytes: usize,
    /// grid scale (for grid codecs) — needed to decode back to f32 values
    scale: Option<f32>,
}

#[derive(Clone, Debug)]
struct Header {
    magic: String,
    variant: String,
    step: f32,
    params: Vec<EntryHeader>,
    opt: Vec<EntryHeader>,
    payload_bytes: usize,
}

impl EntryHeader {
    fn to_json(&self) -> Value {
        Value::obj()
            .set("name", self.name.as_str())
            .set("shape", self.shape.as_slice())
            .set("codec", self.codec.tag())
            .set("offset", self.offset)
            .set("bytes", self.bytes)
            .set(
                "scale",
                self.scale.map(Value::from).unwrap_or(Value::Null),
            )
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(EntryHeader {
            name: v.req("name")?.as_str().unwrap_or("").to_string(),
            shape: v
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            codec: Format::from_tag(v.req("codec")?.as_str().unwrap_or(""))
                .map_err(|e| anyhow!(e))?,
            offset: v.req("offset")?.as_usize().unwrap_or(0),
            bytes: v.req("bytes")?.as_usize().unwrap_or(0),
            scale: v.get("scale").and_then(|x| x.as_f64()).map(|f| f as f32),
        })
    }
}

impl Header {
    fn to_json(&self) -> Value {
        Value::obj()
            .set("magic", self.magic.as_str())
            .set("variant", self.variant.as_str())
            .set("step", self.step)
            .set(
                "params",
                Value::Arr(self.params.iter().map(|e| e.to_json()).collect()),
            )
            .set(
                "opt",
                Value::Arr(self.opt.iter().map(|e| e.to_json()).collect()),
            )
            .set("payload_bytes", self.payload_bytes)
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Header {
            magic: v.req("magic")?.as_str().unwrap_or("").to_string(),
            variant: v.req("variant")?.as_str().unwrap_or("").to_string(),
            step: v.req("step")?.as_f64().unwrap_or(0.0) as f32,
            params: v
                .req("params")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(EntryHeader::from_json)
                .collect::<Result<_>>()?,
            opt: v
                .req("opt")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(EntryHeader::from_json)
                .collect::<Result<_>>()?,
            payload_bytes: v.req("payload_bytes")?.as_usize().unwrap_or(0),
        })
    }
}

/// Resolve the grid scale of a grid param by its companion's *name*
/// (`{name}.s`), not by position.
fn companion_scale(manifest: &Manifest, state: &State, name: &str) -> Result<f32> {
    let scale_name = format!("{name}.s");
    let j = manifest
        .param_index(&scale_name)
        .ok_or_else(|| anyhow!("grid param {name:?} has no companion scale {scale_name:?}"))?;
    state.params[j].scalar()
}

/// Serialize a full training state (params + optimizer) with format-true
/// packing. `dense_codec` controls non-grid params/opt state (use Bf16/Fp8
/// to mirror a low-precision training env's storage). Params already held
/// packed in the right format are written without a decode/re-encode trip.
pub fn save(
    path: &Path,
    manifest: &Manifest,
    state: &State,
    dense_codec: Format,
    include_opt: bool,
) -> Result<u64> {
    let bits = manifest.variant.bits;
    let mut payload: Vec<u8> = Vec::new();
    let mut params = Vec::new();
    for (i, meta) in manifest.params.iter().enumerate() {
        let codec = if meta.is_scale() {
            Format::F32
        } else {
            Format::for_entry(meta.is_grid(), bits, dense_codec)
        };
        let scale = if meta.is_grid() {
            Some(companion_scale(manifest, state, &meta.name)?)
        } else {
            None
        };
        let enc = match &state.params[i] {
            // packed-grid mode fast path: the resident bytes ARE the wire
            // bytes when format and scale line up
            Param::Packed(pt) if pt.format == codec && pt.scale == scale => pt.bytes.clone(),
            p => {
                let vals = p
                    .values()
                    .map_err(|e| anyhow!("reading {:?}: {e}", meta.name))?;
                codec
                    .encode(&vals, scale)
                    .map_err(|e| anyhow!("encoding {:?}: {e}", meta.name))?
            }
        };
        params.push(EntryHeader {
            name: meta.name.clone(),
            shape: meta.shape.clone(),
            codec,
            offset: payload.len(),
            bytes: enc.len(),
            scale,
        });
        payload.extend(enc);
    }
    let mut opt = Vec::new();
    if include_opt {
        for (i, meta) in manifest.opt_state.iter().enumerate() {
            let enc = dense_codec
                .encode(&state.opt[i], None)
                .map_err(|e| anyhow!("encoding opt {:?}: {e}", meta.name))?;
            opt.push(EntryHeader {
                name: meta.name.clone(),
                shape: meta.shape.clone(),
                codec: dense_codec,
                offset: payload.len(),
                bytes: enc.len(),
                scale: None,
            });
            payload.extend(enc);
        }
    }
    let header = Header {
        magic: "DQT1".into(),
        variant: manifest.variant.variant_name.clone(),
        step: state.step(),
        params,
        opt,
        payload_bytes: payload.len(),
    };
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    let header_text = header.to_json().to_string();
    f.write_all(header_text.as_bytes())?;
    f.write_all(b"\n")?;
    f.write_all(&payload)?;
    Ok((payload.len() + header_text.len() + 1) as u64)
}

/// Parse and validate the header + payload split of a raw `.dqt` file.
/// Returns the whole file plus the payload's start offset (no payload
/// copy — large checkpoints are held in memory exactly once).
fn read_checked(path: &Path, manifest: &Manifest) -> Result<(Header, Vec<u8>, usize)> {
    let mut raw = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut raw)?;
    let nl = raw
        .iter()
        .position(|&b| b == b'\n')
        .ok_or_else(|| anyhow!("corrupt checkpoint: no header delimiter"))?;
    let header = Header::from_json(&parse(std::str::from_utf8(&raw[..nl])?)?)?;
    if header.magic != "DQT1" {
        return Err(anyhow!("bad checkpoint magic {:?}", header.magic));
    }
    if header.variant != manifest.variant.variant_name {
        return Err(anyhow!(
            "checkpoint is for variant {:?}, manifest is {:?}",
            header.variant,
            manifest.variant.variant_name
        ));
    }
    let payload_len = raw.len() - (nl + 1);
    if header.params.len() != manifest.params.len() {
        return Err(anyhow!(
            "corrupt checkpoint: header has {} params, manifest has {}",
            header.params.len(),
            manifest.params.len()
        ));
    }
    if header.payload_bytes != payload_len {
        return Err(anyhow!(
            "corrupt checkpoint: header claims {} payload bytes, file has {payload_len}",
            header.payload_bytes
        ));
    }
    Ok((header, raw, nl + 1))
}

/// Bounds-checked payload slice for one entry (truncated or lying headers
/// return `Err` instead of panicking on out-of-bounds).
fn entry_slice<'a>(payload: &'a [u8], eh: &EntryHeader, n: usize) -> Result<&'a [u8]> {
    let end = eh
        .offset
        .checked_add(eh.bytes)
        .ok_or_else(|| anyhow!("corrupt checkpoint: entry {:?} offset overflow", eh.name))?;
    if end > payload.len() {
        return Err(anyhow!(
            "corrupt checkpoint: entry {:?} spans {}..{end} beyond payload ({} bytes)",
            eh.name,
            eh.offset,
            payload.len()
        ));
    }
    let want = eh.codec.packed_bytes(n);
    if eh.bytes != want {
        return Err(anyhow!(
            "corrupt checkpoint: entry {:?} has {} bytes, {} expects {want}",
            eh.name,
            eh.bytes,
            eh.codec.tag()
        ));
    }
    Ok(&payload[eh.offset..end])
}

fn load_impl(path: &Path, manifest: &Manifest, packed: bool) -> Result<State> {
    let (header, raw, payload_start) = read_checked(path, manifest)?;
    let payload = &raw[payload_start..];
    let mut params = Vec::with_capacity(manifest.params.len());
    for (meta, eh) in manifest.params.iter().zip(&header.params) {
        let n = meta.numel();
        let bytes = entry_slice(payload, eh, n)?;
        if packed && eh.codec.is_grid_format() {
            // packed-grid load: adopt the wire bytes as the resident form
            let pt = PackedTensor::from_bytes(
                bytes.to_vec(),
                meta.shape.clone(),
                eh.codec,
                eh.scale,
            )
            .map_err(|e| anyhow!("entry {:?}: {e}", eh.name))?;
            params.push(Param::Packed(pt));
        } else {
            let vals = eh
                .codec
                .decode(bytes, n, eh.scale)
                .map_err(|e| anyhow!("entry {:?}: {e}", eh.name))?;
            params.push(Param::Dense(vals));
        }
    }
    let mut opt: Vec<Vec<f32>> = Vec::with_capacity(manifest.opt_state.len());
    if header.opt.len() == manifest.opt_state.len() {
        for (meta, eh) in manifest.opt_state.iter().zip(&header.opt) {
            let n = meta.numel();
            let bytes = entry_slice(payload, eh, n)?;
            opt.push(
                eh.codec
                    .decode(bytes, n, eh.scale)
                    .map_err(|e| anyhow!("opt entry {:?}: {e}", eh.name))?,
            );
        }
    } else {
        // optimizer section absent (deployment checkpoints): zeros so eval
        // works, with the step counter restored from the header
        for meta in &manifest.opt_state {
            opt.push(vec![0.0; meta.numel()]);
        }
        if let Some(step) = opt.first_mut() {
            step[0] = header.step;
        }
    }
    Ok(State { params, opt })
}

/// Load a checkpoint back into a dense `State`. The optimizer section may
/// be absent (deployment checkpoints): zeros are substituted so eval works.
pub fn load(path: &Path, manifest: &Manifest) -> Result<State> {
    load_impl(path, manifest, false)
}

/// Load a checkpoint keeping grid params in their packed wire form — the
/// host stays at ~`bits_per_weight/8` bytes per grid weight and decoding
/// happens lazily at the PJRT boundary.
pub fn load_packed(path: &Path, manifest: &Manifest) -> Result<State> {
    load_impl(path, manifest, true)
}

/// Checkpoint size report (for the memory/deployment tables).
pub fn packed_param_bytes(manifest: &Manifest) -> usize {
    let bits = manifest.variant.bits;
    manifest
        .params
        .iter()
        .map(|m| {
            let codec = if m.is_scale() {
                Format::F32
            } else {
                Format::for_entry(m.is_grid(), bits, Format::F32)
            };
            codec.packed_bytes(m.numel())
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_sizes() {
        assert_eq!(Codec::F32.packed_bytes(100), 400);
        assert_eq!(Codec::Bf16.packed_bytes(100), 200);
        assert_eq!(Codec::Fp8E4m3.packed_bytes(100), 100);
        assert_eq!(Codec::Ternary2bit.packed_bytes(100), 28);
        assert_eq!(Codec::IntN(3).packed_bytes(100), 38);
        assert_eq!(Codec::IntN(8).packed_bytes(100), 100);
    }

    #[test]
    fn entry_roundtrip_all_codecs() {
        let vals: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.01).collect();
        // f32 exact
        let enc = Codec::F32.encode(&vals, None).unwrap();
        assert_eq!(Codec::F32.decode(&enc, 64, None).unwrap(), vals);
        // bf16 lossy but idempotent
        let enc = Codec::Bf16.encode(&vals, None).unwrap();
        let dec = Codec::Bf16.decode(&enc, 64, None).unwrap();
        let enc2 = Codec::Bf16.encode(&dec, None).unwrap();
        assert_eq!(enc, enc2);
        // ternary grid exact
        let s = 25.0f32;
        let grid: Vec<f32> = (0..64).map(|i| ((i % 3) as f32 - 1.0) / s).collect();
        let enc = Codec::Ternary2bit.encode(&grid, Some(s)).unwrap();
        let dec = Codec::Ternary2bit.decode(&enc, 64, Some(s)).unwrap();
        for (a, b) in grid.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
        // int4 grid exact
        let grid4: Vec<f32> = (0..64).map(|i| ((i % 16) as f32 - 8.0) / s).collect();
        let enc = Codec::IntN(4).encode(&grid4, Some(s)).unwrap();
        let dec = Codec::IntN(4).decode(&enc, 64, Some(s)).unwrap();
        for (a, b) in grid4.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
