//! Checkpointing with format-true weight packing.
//!
//! DQT's deployment story (paper §1, §4.5): grid weights serialize in their
//! *actual* bit width — 2-bit ternary, bit-packed INTn for 2<n<8, byte INT8
//! — plus per-matrix f32 scales; dense params in f32 (or BF16/FP8 when the
//! training env dictates). The resulting file sizes realize the memory
//! arithmetic the paper cites (1B ternary ≈ 0.25 GB vs 4 GB FP32).
//!
//! Layout (little-endian): a JSON header (manifest echo + per-entry codec +
//! byte offsets), `\n`, then the raw payload blob.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Result};
use crate::util::json::{parse, Value};

use crate::quant::{self, intn, ternary};
use crate::runtime::{Manifest, State};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    F32,
    Bf16,
    Fp8E4m3,
    Ternary2bit,
    IntN(u32),
}

impl Codec {
    /// Codec for one manifest entry under a grid bit-width.
    fn for_entry(is_grid: bool, bits: f64, dense: Codec) -> Codec {
        if !is_grid {
            return dense;
        }
        if (bits - 1.58).abs() < 1e-9 {
            Codec::Ternary2bit
        } else {
            Codec::IntN(bits as u32)
        }
    }

    fn tag(&self) -> String {
        match self {
            Codec::F32 => "f32".into(),
            Codec::Bf16 => "bf16".into(),
            Codec::Fp8E4m3 => "fp8_e4m3".into(),
            Codec::Ternary2bit => "ternary_2bit".into(),
            Codec::IntN(b) => format!("int{b}"),
        }
    }

    fn from_tag(s: &str) -> Result<Codec> {
        Ok(match s {
            "f32" => Codec::F32,
            "bf16" => Codec::Bf16,
            "fp8_e4m3" => Codec::Fp8E4m3,
            "ternary_2bit" => Codec::Ternary2bit,
            _ => {
                let b: u32 = s
                    .strip_prefix("int")
                    .and_then(|x| x.parse().ok())
                    .ok_or_else(|| anyhow!("unknown codec {s:?}"))?;
                Codec::IntN(b)
            }
        })
    }

    pub fn bytes_for(&self, n: usize) -> usize {
        match self {
            Codec::F32 => n * 4,
            Codec::Bf16 => n * 2,
            Codec::Fp8E4m3 => n,
            Codec::Ternary2bit => ternary::packed_bytes(n),
            Codec::IntN(b) => intn::packed_bytes(n, *b),
        }
    }
}

#[derive(Clone, Debug)]
struct EntryHeader {
    name: String,
    shape: Vec<usize>,
    codec: Codec,
    offset: usize,
    bytes: usize,
    /// grid scale (for grid codecs) — needed to decode back to f32 values
    scale: Option<f32>,
}

#[derive(Clone, Debug)]
struct Header {
    magic: String,
    variant: String,
    step: f32,
    params: Vec<EntryHeader>,
    opt: Vec<EntryHeader>,
    payload_bytes: usize,
}

impl EntryHeader {
    fn to_json(&self) -> Value {
        Value::obj()
            .set("name", self.name.as_str())
            .set("shape", self.shape.as_slice())
            .set("codec", self.codec.tag())
            .set("offset", self.offset)
            .set("bytes", self.bytes)
            .set(
                "scale",
                self.scale.map(Value::from).unwrap_or(Value::Null),
            )
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(EntryHeader {
            name: v.req("name")?.as_str().unwrap_or("").to_string(),
            shape: v
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            codec: Codec::from_tag(v.req("codec")?.as_str().unwrap_or(""))?,
            offset: v.req("offset")?.as_usize().unwrap_or(0),
            bytes: v.req("bytes")?.as_usize().unwrap_or(0),
            scale: v.get("scale").and_then(|x| x.as_f64()).map(|f| f as f32),
        })
    }
}

impl Header {
    fn to_json(&self) -> Value {
        Value::obj()
            .set("magic", self.magic.as_str())
            .set("variant", self.variant.as_str())
            .set("step", self.step)
            .set(
                "params",
                Value::Arr(self.params.iter().map(|e| e.to_json()).collect()),
            )
            .set(
                "opt",
                Value::Arr(self.opt.iter().map(|e| e.to_json()).collect()),
            )
            .set("payload_bytes", self.payload_bytes)
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Header {
            magic: v.req("magic")?.as_str().unwrap_or("").to_string(),
            variant: v.req("variant")?.as_str().unwrap_or("").to_string(),
            step: v.req("step")?.as_f64().unwrap_or(0.0) as f32,
            params: v
                .req("params")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(EntryHeader::from_json)
                .collect::<Result<_>>()?,
            opt: v
                .req("opt")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(EntryHeader::from_json)
                .collect::<Result<_>>()?,
            payload_bytes: v.req("payload_bytes")?.as_usize().unwrap_or(0),
        })
    }
}

fn encode_entry(vals: &[f32], codec: Codec, scale: Option<f32>) -> Result<Vec<u8>> {
    Ok(match codec {
        Codec::F32 => vals.iter().flat_map(|v| v.to_le_bytes()).collect(),
        Codec::Bf16 => vals
            .iter()
            .flat_map(|&v| quant::bf16::encode(v).to_le_bytes())
            .collect(),
        Codec::Fp8E4m3 => vals
            .iter()
            .map(|&v| quant::fp8::encode(v, quant::fp8::Format::E4M3))
            .collect(),
        Codec::Ternary2bit => {
            let s = scale.ok_or_else(|| anyhow!("ternary codec needs scale"))?;
            let k: Vec<f32> = vals.iter().map(|&v| (v * s).round()).collect();
            ternary::pack(&k)
                .map_err(|e| anyhow!(e))?
                .iter()
                .flat_map(|w| w.to_le_bytes())
                .collect()
        }
        Codec::IntN(bits) => {
            let s = scale.ok_or_else(|| anyhow!("intn codec needs scale"))?;
            intn::pack_grid(vals, s, bits).map_err(|e| anyhow!(e))?
        }
    })
}

fn decode_entry(bytes: &[u8], n: usize, codec: Codec, scale: Option<f32>) -> Result<Vec<f32>> {
    Ok(match codec {
        Codec::F32 => bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        Codec::Bf16 => bytes
            .chunks_exact(2)
            .map(|c| quant::bf16::decode(u16::from_le_bytes(c.try_into().unwrap())))
            .collect(),
        Codec::Fp8E4m3 => bytes
            .iter()
            .map(|&b| quant::fp8::decode(b, quant::fp8::Format::E4M3))
            .collect(),
        Codec::Ternary2bit => {
            let s = scale.ok_or_else(|| anyhow!("ternary codec needs scale"))?;
            let words: Vec<u32> = bytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            ternary::unpack(&words, n).iter().map(|&k| k / s).collect()
        }
        Codec::IntN(bits) => {
            let s = scale.ok_or_else(|| anyhow!("intn codec needs scale"))?;
            intn::unpack_grid(bytes, n, s, bits)
        }
    })
}

/// Serialize a full training state (params + optimizer) with format-true
/// packing. `dense_codec` controls non-grid params/opt state (use Bf16/Fp8
/// to mirror a low-precision training env's storage).
pub fn save(
    path: &Path,
    manifest: &Manifest,
    state: &State,
    dense_codec: Codec,
    include_opt: bool,
) -> Result<u64> {
    let bits = manifest.variant.bits;
    let mut payload: Vec<u8> = Vec::new();
    let mut params = Vec::new();
    for (i, meta) in manifest.params.iter().enumerate() {
        let vals = &state.params[i];
        let codec = if meta.is_scale() {
            Codec::F32
        } else {
            Codec::for_entry(meta.is_grid(), bits, dense_codec)
        };
        // grid entries read their scale from the companion `.s` param
        let scale = if meta.is_grid() {
            Some(state.params[i + 1][0])
        } else {
            None
        };
        let enc = encode_entry(vals, codec, scale)?;
        params.push(EntryHeader {
            name: meta.name.clone(),
            shape: meta.shape.clone(),
            codec,
            offset: payload.len(),
            bytes: enc.len(),
            scale,
        });
        payload.extend(enc);
    }
    let mut opt = Vec::new();
    if include_opt {
        for (i, meta) in manifest.opt_state.iter().enumerate() {
            let enc = encode_entry(&state.opt[i], dense_codec, None)?;
            opt.push(EntryHeader {
                name: meta.name.clone(),
                shape: meta.shape.clone(),
                codec: dense_codec,
                offset: payload.len(),
                bytes: enc.len(),
                scale: None,
            });
            payload.extend(enc);
        }
    }
    let header = Header {
        magic: "DQT1".into(),
        variant: manifest.variant.variant_name.clone(),
        step: state.step(),
        params,
        opt,
        payload_bytes: payload.len(),
    };
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    let header_text = header.to_json().to_string();
    f.write_all(header_text.as_bytes())?;
    f.write_all(b"\n")?;
    f.write_all(&payload)?;
    Ok((payload.len() + header_text.len() + 1) as u64)
}

/// Load a checkpoint back into a `State`. The optimizer section may be
/// absent (deployment checkpoints): zeros are substituted so eval works.
pub fn load(path: &Path, manifest: &Manifest) -> Result<State> {
    let mut raw = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut raw)?;
    let nl = raw
        .iter()
        .position(|&b| b == b'\n')
        .ok_or_else(|| anyhow!("corrupt checkpoint: no header delimiter"))?;
    let header = Header::from_json(&parse(std::str::from_utf8(&raw[..nl])?)?)?;
    if header.magic != "DQT1" {
        return Err(anyhow!("bad checkpoint magic {:?}", header.magic));
    }
    if header.variant != manifest.variant.variant_name {
        return Err(anyhow!(
            "checkpoint is for variant {:?}, manifest is {:?}",
            header.variant,
            manifest.variant.variant_name
        ));
    }
    let payload = &raw[nl + 1..];
    let mut params = Vec::with_capacity(manifest.params.len());
    for (meta, eh) in manifest.params.iter().zip(&header.params) {
        let n = meta.numel();
        let bytes = &payload[eh.offset..eh.offset + eh.bytes];
        params.push(decode_entry(bytes, n, eh.codec, eh.scale)?);
    }
    let mut opt: Vec<Vec<f32>> = Vec::with_capacity(manifest.opt_state.len());
    if header.opt.len() == manifest.opt_state.len() {
        for (meta, eh) in manifest.opt_state.iter().zip(&header.opt) {
            let bytes = &payload[eh.offset..eh.offset + eh.bytes];
            opt.push(decode_entry(bytes, meta.numel(), eh.codec, eh.scale)?);
        }
    } else {
        for meta in &manifest.opt_state {
            opt.push(vec![0.0; meta.numel()]);
        }
        if let Some(step) = opt.first_mut() {
            step[0] = header.step;
        }
    }
    Ok(State { params, opt })
}

/// Checkpoint size report (for the memory/deployment tables).
pub fn packed_param_bytes(manifest: &Manifest) -> usize {
    let bits = manifest.variant.bits;
    manifest
        .params
        .iter()
        .map(|m| {
            let codec = if m.is_scale() {
                Codec::F32
            } else {
                Codec::for_entry(m.is_grid(), bits, Codec::F32)
            };
            codec.bytes_for(m.numel())
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_sizes() {
        assert_eq!(Codec::F32.bytes_for(100), 400);
        assert_eq!(Codec::Bf16.bytes_for(100), 200);
        assert_eq!(Codec::Fp8E4m3.bytes_for(100), 100);
        assert_eq!(Codec::Ternary2bit.bytes_for(100), 28);
        assert_eq!(Codec::IntN(3).bytes_for(100), 38);
        assert_eq!(Codec::IntN(8).bytes_for(100), 100);
    }

    #[test]
    fn entry_roundtrip_all_codecs() {
        let vals: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.01).collect();
        // f32 exact
        let enc = encode_entry(&vals, Codec::F32, None).unwrap();
        assert_eq!(decode_entry(&enc, 64, Codec::F32, None).unwrap(), vals);
        // bf16 lossy but idempotent
        let enc = encode_entry(&vals, Codec::Bf16, None).unwrap();
        let dec = decode_entry(&enc, 64, Codec::Bf16, None).unwrap();
        let enc2 = encode_entry(&dec, Codec::Bf16, None).unwrap();
        assert_eq!(enc, enc2);
        // ternary grid exact
        let s = 25.0f32;
        let grid: Vec<f32> = (0..64).map(|i| ((i % 3) as f32 - 1.0) / s).collect();
        let enc = encode_entry(&grid, Codec::Ternary2bit, Some(s)).unwrap();
        let dec = decode_entry(&enc, 64, Codec::Ternary2bit, Some(s)).unwrap();
        for (a, b) in grid.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
        // int4 grid exact
        let grid4: Vec<f32> = (0..64).map(|i| ((i % 16) as f32 - 8.0) / s).collect();
        let enc = encode_entry(&grid4, Codec::IntN(4), Some(s)).unwrap();
        let dec = decode_entry(&enc, 64, Codec::IntN(4), Some(s)).unwrap();
        for (a, b) in grid4.iter().zip(dec.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
